//! End-to-end driver (DESIGN.md section 5): the full workload the paper's
//! system exists for, at laptop scale.
//!
//! 1. pretrain the deep `paper12` network in float on SynthShapes,
//!    logging the loss curve;
//! 2. calibrate per-layer fixed-point formats (SQNR rule);
//! 3. fine-tune at 8-bit weights / 8-bit activations with Proposal 3
//!    (the Table 1 bottom-to-top schedule);
//! 4. evaluate: float baseline vs no-fine-tune vs Proposal 3;
//! 5. deploy-check: run the pure-integer engine and report parity.
//!
//! Results of a full run are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example train_e2e            # full (~10 min)
//! E2E_PRETRAIN=60 E2E_PHASE=5 cargo run --release --example train_e2e  # smoke
//! ```

use fxpnet::coordinator::backend::XlaBackend;
use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::evaluator::evaluate;
use fxpnet::coordinator::regimes::{self, CellCtx};
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::verify::parity_report;
use fxpnet::inference::FixedPointNet;
use fxpnet::model::checkpoint::save_params;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::WidthSpec;
use fxpnet::runtime::Engine;
use fxpnet::util::timer::Stopwatch;

fn envn(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> fxpnet::Result<()> {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let backend = XlaBackend::new(Engine::cpu(&artifacts)?);
    let engine = backend.engine();
    let arch = "paper12";
    let spec = engine.manifest.arch(arch)?.clone();

    let pretrain_steps = envn("E2E_PRETRAIN", 700);
    let phase_steps = envn("E2E_PHASE", 25);
    let train_n = envn("E2E_TRAIN_N", 6144);
    let eval_n = envn("E2E_EVAL_N", 1024);

    println!("== fxpnet end-to-end driver ==");
    println!(
        "arch {arch}: {} weighted layers, input {}x{}x{}",
        spec.num_layers, spec.input[0], spec.input[1], spec.input[2]
    );

    let sw = Stopwatch::start();
    let train = Dataset::generate(train_n, spec.input[0], spec.input[1], 101);
    let eval = Dataset::generate(eval_n, spec.input[0], spec.input[1], 102);
    println!("data: {train_n} train / {eval_n} eval in {:.1}s", sw.elapsed().as_secs_f64());

    // ---- 1. float pretraining with a two-stage lr decay ----------------
    // Escaping the initial saddle on this task takes several hundred
    // steps and is seed-sensitive; when a full pretrain checkpoint exists
    // (`fxpnet pretrain`, 1500 steps), reuse it and log a short training
    // continuation instead of repeating the whole run.
    let ckpt_path = "paper12_float.ckpt";
    let from_ckpt = std::path::Path::new(ckpt_path).exists();
    let params = if from_ckpt {
        println!("using pretrained checkpoint {ckpt_path} (delete it to pretrain from scratch)");
        let ck = fxpnet::model::checkpoint::Checkpoint::load(ckpt_path)?;
        ck.check_matches(arch, &spec.params)?;
        ck.params
    } else {
        println!("pretraining from scratch for {pretrain_steps} steps ...");
        ParamSet::init(&spec, 42)
    };
    let nq_float = fxpnet::quant::policy::NetQuant::all_float(spec.num_layers);
    let mut tr = Trainer::new(
        engine, arch, &params, &nq_float, &upd_all(spec.num_layers),
        if from_ckpt { 0.002 } else { 0.05 }, 0.9, train.clone(),
        LoaderCfg { batch: spec.train_batch, augment: true, max_shift: 2, seed: 42 },
        30.0,
    )?;
    let mut curve: Vec<(usize, f32)> = Vec::new();
    if from_ckpt {
        // short logged continuation at the final pretrain lr
        let out = tr.run(60, 10)?;
        assert!(!out.diverged);
        curve.extend(out.history);
    } else {
        let stages = [
            (pretrain_steps * 3 / 5, 0.05f32),
            (pretrain_steps / 4, 0.01),
            (pretrain_steps - pretrain_steps * 3 / 5 - pretrain_steps / 4, 0.002),
        ];
        for (i, (n, lr)) in stages.iter().enumerate() {
            if i > 0 {
                tr.set_config(&nq_float, &upd_all(spec.num_layers), *lr, 0.9)?;
            }
            let out = tr.run(*n, 20)?;
            assert!(!out.diverged, "float pretraining diverged?!");
            curve.extend(out.history);
        }
    }
    println!("loss curve (step, loss):");
    for (s, l) in &curve {
        println!("  {s:>5} {l:.4}");
    }
    let base = tr.params()?;
    if !from_ckpt {
        // never clobber a full CLI pretrain with a shorter example run
        save_params("paper12_float.ckpt", arch, tr.global_step() as u64, &base)?;
    }
    let ev_float = evaluate(engine, arch, &base, &nq_float, &eval)?;
    println!("float baseline: {ev_float}");

    // ---- 2. calibration -------------------------------------------------
    let calib = calibrate::activation_stats(engine, arch, &base, &train, 4)?;
    println!("calibrated activation formats (8-bit, SQNR):");
    let cfg = RunCfg { phase_steps, finetune_steps: 150, ..RunCfg::default() };
    let ctx = CellCtx {
        backend: &backend,
        arch,
        train_data: &train,
        eval_data: &eval,
        a_stats: &calib.a_stats,
        cfg: &cfg,
        cell_seed: cfg.seed,
    };
    let w8 = WidthSpec::Bits(8);
    let a8 = WidthSpec::Bits(8);
    let nq = ctx.resolve(&base, w8, a8)?;
    for (i, (wf, af)) in nq.weights.iter().zip(&nq.acts).enumerate() {
        println!("  layer {i:>2}: w {} a {}", wf.unwrap(), af.unwrap());
    }

    // ---- 3. regimes: no-FT vs Proposal 3 --------------------------------
    let noft = regimes::run_no_finetune(&ctx, &base, w8, a8)?
        .ok()
        .expect("no-fine-tune eval diverged");
    println!("8w/8a no fine-tune : {noft}");

    let p1net = regimes::train_float_act_net(&ctx, &base, w8)?
        .expect("float-act fine-tune diverged");
    let (p3, _telemetry) = regimes::run_prop3(&ctx, &p1net, w8, a8)?;
    let p3 = p3.ok().expect("proposal 3 diverged");
    println!("8w/8a Proposal 3   : {p3}");

    // ---- 4. integer-engine deployment check ----------------------------
    let tuned_nq = ctx.resolve(&p1net, w8, a8)?;
    let net = FixedPointNet::build(&spec, &p1net, &tuned_nq, QFormat::new(16, 14)?)?;
    let n = 256.min(eval.len());
    let rows: Vec<usize> = (0..n).collect();
    let imgs = eval.images.gather_rows(&rows)?;
    let sw2 = Stopwatch::start();
    let int_logits = net.forward_batch(&imgs)?;
    let ips = n as f64 / sw2.elapsed().as_secs_f64();
    let sub = Dataset { images: imgs, labels: eval.labels.gather_rows(&rows)?, h: spec.input[0], w: spec.input[1] };
    let xla_logits =
        fxpnet::cli::commands::evaluate_logits(engine, arch, &p1net, &tuned_nq, &sub)?;
    let parity = parity_report(&int_logits, &xla_logits)?;
    println!("integer engine     : {ips:.1} img/s, parity {parity}");

    println!("\nsummary:");
    println!("  float baseline        top-1 {:.2}%", ev_float.top1_err * 100.0);
    println!("  8w/8a no fine-tune    top-1 {:.2}%", noft.top1_err * 100.0);
    println!("  8w/8a Proposal 3      top-1 {:.2}%", p3.top1_err * 100.0);
    println!("  wall time             {:.1}s", sw.elapsed().as_secs_f64());
    Ok(())
}
