//! Deployment path: run the pure-integer fixed-point engine (Figure 1
//! semantics, i64 accumulators, no float in the layer loop) and check it
//! against the XLA simulated-quantization path.
//!
//! ```sh
//! cargo run --release --example fixedpoint_inference [ckpt]
//! ```

use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::verify::parity_report;
use fxpnet::inference::FixedPointNet;
use fxpnet::model::checkpoint::Checkpoint;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::runtime::Engine;
use fxpnet::util::timer::Stopwatch;

fn main() -> fxpnet::Result<()> {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let engine = Engine::cpu(&artifacts)?;
    let arch = "shallow";
    let spec = engine.manifest.arch(arch)?.clone();
    let train = Dataset::generate(2048, spec.input[0], spec.input[1], 71);
    let eval = Dataset::generate(512, spec.input[0], spec.input[1], 72);

    let ckpt = std::env::args().nth(1);
    let params = match ckpt {
        Some(p) if std::path::Path::new(&p).exists() => {
            println!("using checkpoint {p}");
            Checkpoint::load(&p)?.params
        }
        _ => {
            println!("pretraining shallow net (250 steps) ...");
            let p = ParamSet::init(&spec, 17);
            let nq = NetQuant::all_float(spec.num_layers);
            let mut tr = Trainer::new(
                &engine, arch, &p, &nq, &upd_all(spec.num_layers), 0.05, 0.9,
                train.clone(),
                LoaderCfg { batch: spec.train_batch, augment: true, max_shift: 2, seed: 8 },
                30.0,
            )?;
            tr.run(250, 50)?;
            tr.params()?
        }
    };

    let calib = calibrate::activation_stats(&engine, arch, &params, &train, 3)?;
    for &bits in &[16u8, 8, 4] {
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(bits),
            WidthSpec::Bits(bits),
            &params.weight_stats(),
            &calib.a_stats,
            CalibMethod::SqnrGaussian,
        )?;
        let net = FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14)?)?;
        let sw = Stopwatch::start();
        let int_logits = net.forward_batch(&eval.images)?;
        let dt = sw.elapsed().as_secs_f64();
        let top1 = int_logits.topk_rows(1)?;
        let wrong = (0..eval.len())
            .filter(|&i| top1[i][0] != eval.labels.data()[i] as usize)
            .count();
        let xla_logits = fxpnet::cli::commands::evaluate_logits(
            &engine, arch, &params, &nq, &eval,
        )?;
        let parity = parity_report(&int_logits, &xla_logits)?;
        println!(
            "{bits:>2}w/{bits}a: {:.0} img/s ({:.1} MMAC/img)  top-1 err {:.2}%  \
             parity[{parity}]",
            eval.len() as f64 / dt,
            net.macs_per_image() as f64 / 1e6,
            100.0 * wrong as f64 / eval.len() as f64,
        );
    }
    Ok(())
}
