//! Quickstart: the minimal end-to-end path through the library.
//!
//! Build artifacts once (`make artifacts`), then:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT-compiled `tiny` network, trains it for a few dozen steps
//! in float, calibrates fixed-point formats, and evaluates the same
//! parameters at 8-bit weights / 8-bit activations -- all from Rust, with
//! Python nowhere on the path.

use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::evaluator::evaluate;
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::runtime::Engine;

fn main() -> fxpnet::Result<()> {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let engine = Engine::cpu(&artifacts)?;
    let arch = "tiny";
    let spec = engine.manifest.arch(arch)?.clone();
    println!(
        "loaded arch '{arch}': {}x{}x{} input, {} weighted layers",
        spec.input[0], spec.input[1], spec.input[2], spec.num_layers
    );

    // 1. data + init
    let train = Dataset::generate(1024, spec.input[0], spec.input[1], 1);
    let eval = Dataset::generate(256, spec.input[0], spec.input[1], 2);
    let params = ParamSet::init(&spec, 42);
    println!("initialised {} parameters", params.num_scalars());

    // 2. a short float training run
    let nq_float = NetQuant::all_float(spec.num_layers);
    let mut tr = Trainer::new(
        &engine, arch, &params, &nq_float, &upd_all(spec.num_layers),
        0.05, 0.9, train.clone(),
        LoaderCfg { batch: spec.train_batch, augment: false, max_shift: 0, seed: 1 },
        30.0,
    )?;
    let out = tr.run(80, 10)?;
    for (s, l) in &out.history {
        println!("  step {s:>3}  loss {l:.4}");
    }
    let tuned = tr.params()?;

    // 3. evaluate float vs 8w/8a fixed point
    let ev_f = evaluate(&engine, arch, &tuned, &nq_float, &eval)?;
    let calib = calibrate::activation_stats(&engine, arch, &tuned, &train, 2)?;
    let nq_q = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &tuned.weight_stats(),
        &calib.a_stats,
        CalibMethod::SqnrGaussian,
    )?;
    let ev_q = evaluate(&engine, arch, &tuned, &nq_q, &eval)?;
    println!("float    : {ev_f}");
    println!("8w/8a    : {ev_q}");
    println!("formats  : {:?}", nq_q.acts.iter().map(|a| a.unwrap().to_string()).collect::<Vec<_>>());
    Ok(())
}
