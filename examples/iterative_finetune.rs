//! Proposal 3 walk-through: watch the Table 1 schedule run phase by phase.
//!
//! Trains a float `shallow` net briefly, then fine-tunes it at 4-bit
//! weights / 4-bit activations twice: once vanilla (all layers, fully
//! quantized from step 0) and once with the bottom-to-top iterative
//! schedule, printing the per-phase configuration and losses.  At 4 bits
//! the vanilla run is expected to be unstable or clearly worse -- exactly
//! the paper's motivation for the schedule.
//!
//! ```sh
//! cargo run --release --example iterative_finetune
//! ```

use fxpnet::coordinator::backend::XlaBackend;
use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::evaluator::evaluate;
use fxpnet::coordinator::phases;
use fxpnet::coordinator::regimes::{self, CellCtx, CellEval};
use fxpnet::coordinator::trainer::{upd_all, upd_single, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::runtime::Engine;

fn main() -> fxpnet::Result<()> {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let backend = XlaBackend::new(Engine::cpu(&artifacts)?);
    let engine = backend.engine();
    let arch = "shallow";
    let spec = engine.manifest.arch(arch)?.clone();
    let l = spec.num_layers;

    let train = Dataset::generate(3072, spec.input[0], spec.input[1], 31);
    let eval = Dataset::generate(768, spec.input[0], spec.input[1], 32);

    // print the paper's Table 1 for this depth
    println!("{}", phases::render_table1(l));

    // float base
    println!("pretraining float base (300 steps) ...");
    let p0 = ParamSet::init(&spec, 9);
    let nq_f = NetQuant::all_float(l);
    let lcfg = LoaderCfg { batch: spec.train_batch, augment: true, max_shift: 2, seed: 5 };
    let mut tr = Trainer::new(
        engine, arch, &p0, &nq_f, &upd_all(l), 0.05, 0.9, train.clone(),
        lcfg.clone(), 30.0,
    )?;
    tr.run(300, 50)?;
    let base = tr.params()?;
    let ev_f = evaluate(engine, arch, &base, &nq_f, &eval)?;
    println!("float base: {ev_f}\n");

    let cfg = RunCfg { finetune_steps: 120, phase_steps: 60, ..RunCfg::default() };
    let calib = calibrate::activation_stats(engine, arch, &base, &train, 3)?;
    let ctx = CellCtx {
        backend: &backend,
        arch,
        train_data: &train,
        eval_data: &eval,
        a_stats: &calib.a_stats,
        cfg: &cfg,
        cell_seed: cfg.seed,
    };
    let w = WidthSpec::Bits(4);
    let a = WidthSpec::Bits(4);

    // --- vanilla -----------------------------------------------------------
    println!("vanilla 4w/4a fine-tuning ({} steps) ...", cfg.finetune_steps);
    match regimes::run_vanilla(&ctx, &base, w, a)? {
        (CellEval::Ok(ev), _) => println!("vanilla result: {ev}\n"),
        _ => println!("vanilla result: n/a (diverged)\n"),
    }

    // --- Proposal 3, narrated ------------------------------------------------
    println!("Proposal 3: float-activation seed net first ...");
    let p1 = regimes::train_float_act_net(&ctx, &base, w)?.expect("seed diverged");
    let full = ctx.resolve(&p1, w, a)?;
    let mut tr = {
        let p = phases::schedule(l)[0];
        let nq = full.with_act_prefix(p.act_prefix);
        Trainer::new(
            engine, arch, &p1, &nq, &upd_single(l, p.update_layer),
            cfg.lr, cfg.momentum, train.clone(), lcfg, cfg.max_loss,
        )?
    };
    for (i, p) in phases::schedule(l).iter().enumerate() {
        if i > 0 {
            let nq = full.with_act_prefix(p.act_prefix);
            tr.set_config(&nq, &upd_single(l, p.update_layer), cfg.lr, cfg.momentum)?;
            tr.reset_momenta()?;
        }
        let out = tr.run(cfg.phase_steps, 15)?;
        let losses: Vec<String> =
            out.history.iter().map(|(s, v)| format!("{s}:{v:.3}")).collect();
        println!(
            "  phase {}: acts[0..{}) fixed point, layer {} updating -> {}",
            p.number,
            p.act_prefix,
            p.update_layer,
            losses.join("  ")
        );
        assert!(!out.diverged, "phase {} diverged", p.number);
    }
    let tuned = tr.params()?;
    let nq_eval = ctx.resolve(&tuned, w, a)?;
    let ev = evaluate(engine, arch, &tuned, &nq_eval, &eval)?;
    println!("\nProposal 3 result: {ev}");
    println!("float baseline   : {ev_f}");
    Ok(())
}
