//! Section 2.2 validation: measure the gradient mismatch directly.
//!
//! For each activation/weight bit-width, compares the weight gradients of
//! the quantized(-STE) graph against the float graph, layer by layer.
//! The paper's claim -- the mismatch *accumulates* as the error signal
//! propagates toward the bottom of the network, and worsens as bit-width
//! shrinks -- appears as cosine similarity falling (a) toward layer 0 and
//! (b) from 16-bit to 4-bit columns.
//!
//! ```sh
//! cargo run --release --example gradient_mismatch [ckpt]
//! ```
//! Uses `paper12_float.ckpt` if present (from `fxpnet pretrain` or the
//! train_e2e example), otherwise does a short pretrain first.

use fxpnet::bench::Table;
use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::mismatch::gradient_mismatch;
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::checkpoint::Checkpoint;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::NetQuant;
use fxpnet::runtime::Engine;

fn main() -> fxpnet::Result<()> {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let engine = Engine::cpu(&artifacts)?;
    let arch = "paper12";
    let spec = engine.manifest.arch(arch)?.clone();
    let train = Dataset::generate(2048, spec.input[0], spec.input[1], 55);

    // load or quickly produce a sensible network (mismatch at random init
    // is even more extreme; a trained net is the paper's setting)
    let ckpt_path = std::env::args().nth(1).unwrap_or("paper12_float.ckpt".into());
    let params = if std::path::Path::new(&ckpt_path).exists() {
        println!("using checkpoint {ckpt_path}");
        Checkpoint::load(&ckpt_path)?.params
    } else {
        println!("no checkpoint at {ckpt_path}; pretraining 120 steps ...");
        let p = ParamSet::init(&spec, 42);
        let nq = NetQuant::all_float(spec.num_layers);
        let mut tr = Trainer::new(
            &engine, arch, &p, &nq, &upd_all(spec.num_layers), 0.05, 0.9,
            train.clone(),
            LoaderCfg { batch: spec.train_batch, augment: false, max_shift: 0, seed: 3 },
            30.0,
        )?;
        tr.run(120, 50)?;
        tr.params()?
    };

    let calib = calibrate::activation_stats(&engine, arch, &params, &train, 3)?;
    let widths: [u8; 3] = [16, 8, 4];
    let mut cols: Vec<Vec<f64>> = Vec::new();
    for &bits in &widths {
        println!("measuring {bits}-bit gradient mismatch ...");
        cols.push(gradient_mismatch(
            &engine,
            arch,
            &params,
            &calib.a_stats,
            &train,
            bits,
            CalibMethod::SqnrGaussian,
        )?);
    }

    let mut t = Table::new(
        "cos(float gradient, quantized gradient) per layer",
        &["layer", "16-bit", "8-bit", "4-bit"],
    );
    for l in 0..spec.num_layers {
        t.row(vec![
            format!("{l}"),
            format!("{:+.4}", cols[0][l]),
            format!("{:+.4}", cols[1][l]),
            format!("{:+.4}", cols[2][l]),
        ]);
    }
    println!("{}", t.render());

    for (i, &bits) in widths.iter().enumerate() {
        let third = spec.num_layers / 3;
        let bottom: f64 = cols[i][..third].iter().sum::<f64>() / third as f64;
        let top: f64 =
            cols[i][spec.num_layers - third..].iter().sum::<f64>() / third as f64;
        println!(
            "{bits:>2}-bit: bottom-third mean {bottom:+.4}  top-third mean {top:+.4}  \
             (section 2.2 predicts top > bottom)"
        );
    }
    Ok(())
}
