//! Regenerates **Table 5** (Proposal 2): starting from the Proposal-1
//! nets, fine-tune only the top fully-connected layer under full
//! quantization.  The top layer's gradient has not accumulated mismatch,
//! so this trains stably and buys a small improvement over Table 4.
//!
//! Scale via FXP_BENCH_* (see rust/src/bench/fixtures.rs).

use fxpnet::bench::fixtures::bench_env;
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report;
use fxpnet::util::timer::Stopwatch;

fn main() {
    let env = bench_env().expect("bench env (run `make artifacts` first)");
    let mut runner = env.runner();
    let sw = Stopwatch::start();
    let grid = runner.run_grid(Regime::Prop2 { top_layers: 1 }).expect("grid");
    println!("{}", grid.render(env.cfg.topk));
    println!("table 5 regenerated in {:.1}s", sw.elapsed().as_secs_f64());
    report::save_grid(&grid, "results", env.cfg.topk).expect("save");
}
