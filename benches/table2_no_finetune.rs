//! Regenerates **Table 2**: error grid with *no fine-tuning* -- the
//! pretrained float network is quantized per (weight width, activation
//! width) cell and evaluated.
//!
//! Paper shape to expect: the Float/Float corner is best; 4-bit weights
//! without fine-tuning are catastrophic (paper: ~97-99% on every 4-bit-
//! weight cell); 4-bit activations degrade strongly; 8/8 loses a few
//! points vs float.
//!
//! Scale via FXP_BENCH_* (see rust/src/bench/fixtures.rs).

use fxpnet::bench::fixtures::bench_env;
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report;
use fxpnet::util::timer::Stopwatch;

fn main() {
    let env = bench_env().expect("bench env (run `make artifacts` first)");
    let mut runner = env.runner();
    let sw = Stopwatch::start();
    let grid = runner.run_grid(Regime::NoFinetune).expect("grid");
    println!("{}", grid.render(env.cfg.topk));
    println!("table 2 regenerated in {:.1}s", sw.elapsed().as_secs_f64());
    report::save_grid(&grid, "results", env.cfg.topk).expect("save");
}
