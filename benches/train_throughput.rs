//! Native training-engine throughput: SGD steps/second of the pure-Rust
//! backprop + stochastic-rounding fixed-point trainer, single-threaded
//! vs `--threads`-sharded, fully offline.  Writes `BENCH_train.json`
//! for CI artifact upload next to `BENCH_engine.json`.
//!
//! Three gates ride on this bench:
//!
//! * **bit-identity** (always on): the 1-thread, N-thread, and
//!   forced-scalar-kernel runs must all produce byte-identical loss
//!   sequences -- the tentpole determinism contract, checked here on
//!   every bench run for free;
//! * **perf trajectory** (`FXP_BENCH_ASSERT`): the threaded step must be
//!   at least `train_throughput.min_threaded_step_speedup` times the
//!   single-threaded step, floor committed in `BENCH_baseline.json`
//!   (a numeric `FXP_BENCH_ASSERT=2.0` overrides the floor directly);
//! * **SIMD dispatch** (`FXP_BENCH_ASSERT`, SIMD hosts only): the
//!   auto-dispatched single-thread step must beat the forced-scalar
//!   step by `train_throughput.min_simd_step_speedup`.
//!
//! Scale via:
//! * `FXP_BENCH_TRAIN_ARCH`    -- architecture (default "shallow")
//! * `FXP_BENCH_TRAIN_STEPS`   -- timed steps per case (default 30)
//! * `FXP_BENCH_TRAIN_N`      -- training set size (default 512)
//! * `FXP_BENCH_TRAIN_THREADS` -- threaded-case workers (default: all
//!   cores); 1 skips the speedup gate (nothing to compare)
//! * `FXP_BENCH_TRAIN_REPS`    -- repetitions per case (default 3); the
//!   *fastest* rep is scored, so a descheduling blip on a shared CI
//!   runner cannot fail the speedup floor on its own

use fxpnet::bench::fixtures::{baseline_floor, env_str, env_usize};
use fxpnet::bench::Table;
use fxpnet::coordinator::backend::{Backend, SessionCfg};
use fxpnet::coordinator::trainer::{upd_all, TrainSession};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::inference::{Isa, Kernels};
use fxpnet::model::manifest::ArchSpec;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::train::{NativeBackend, NativeTrainer};

/// Run `warmup + steps` SGD steps of one fresh session on the given
/// kernel facade; returns every loss and the wall time of the timed
/// span.
#[allow(clippy::too_many_arguments)]
fn run_case(
    spec: &ArchSpec,
    params: &ParamSet,
    nq: &NetQuant,
    data: &Dataset,
    kernels: &'static Kernels,
    threads: usize,
    warmup: usize,
    steps: usize,
) -> (Vec<f32>, f64) {
    let mut sess = NativeTrainer::new(
        spec,
        SessionCfg {
            arch: &spec.name,
            params,
            nq,
            upd: &upd_all(spec.num_layers),
            lr: 0.02,
            momentum: 0.9,
            data: data.clone(),
            loader: LoaderCfg {
                batch: spec.train_batch,
                augment: true,
                max_shift: 2,
                seed: 42,
            },
            max_loss: 30.0,
            seed: 42,
            threads,
        },
    )
    .expect("session");
    sess.set_kernels(kernels);
    let mut losses = Vec::with_capacity(warmup + steps);
    for _ in 0..warmup {
        losses.push(sess.step().expect("warmup step"));
    }
    let t = std::time::Instant::now();
    for _ in 0..steps {
        losses.push(sess.step().expect("train step"));
    }
    (losses, t.elapsed().as_secs_f64())
}

fn main() {
    fxpnet::util::logging::init();
    let arch = env_str("FXP_BENCH_TRAIN_ARCH", "shallow");
    let steps = env_usize("FXP_BENCH_TRAIN_STEPS", 30);
    let train_n = env_usize("FXP_BENCH_TRAIN_N", 512);
    let threads = env_usize(
        "FXP_BENCH_TRAIN_THREADS",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    let backend = NativeBackend::new();
    let spec = backend.arch(&arch).expect("zoo arch");
    let data = Dataset::generate(train_n, spec.input[0], spec.input[1], 301);
    let params = ParamSet::init(&spec, 42);
    let a_stats = backend
        .activation_stats(&arch, &params, &data, 2)
        .expect("calibration");
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        fxpnet::quant::calib::CalibMethod::SqnrGaussian,
    )
    .expect("cell");

    let auto = Kernels::auto();
    let scalar = Kernels::for_isa(Isa::Scalar);
    let simd = auto.isa() != Isa::Scalar;
    println!(
        "kernel dispatch: {}{}",
        auto.name(),
        if simd { " (forced-scalar comparison case alongside)" } else { "" }
    );

    let reps = env_usize("FXP_BENCH_TRAIN_REPS", 3).max(1);
    // best-of-reps: sessions are deterministic, so reps only differ in
    // wall time -- the min absorbs scheduler noise on shared runners
    let run_best = |kernels: &'static Kernels, t: usize| {
        let mut best: Option<(Vec<f32>, f64)> = None;
        for _ in 0..reps {
            let (losses, dt) =
                run_case(&spec, &params, &nq, &data, kernels, t, 3, steps);
            best = Some(match best {
                None => (losses, dt),
                Some((prev, prev_dt)) => {
                    assert_eq!(prev, losses, "losses differ between reps");
                    (prev, prev_dt.min(dt))
                }
            });
        }
        best.unwrap()
    };
    let (losses_s1, dt_s1) = run_best(scalar, 1);
    let (losses_1t, dt_1t) = run_best(auto, 1);
    let (losses_mt, dt_mt) = run_best(auto, threads);

    // tentpole bit-identity: neither the thread count nor the kernel
    // ISA may touch the math
    assert_eq!(
        losses_1t.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        losses_mt.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "loss history differs between 1 and {threads} train threads"
    );
    assert_eq!(
        losses_s1.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        losses_1t.iter().map(|l| l.to_bits()).collect::<Vec<_>>(),
        "loss history differs between scalar and {} kernels",
        auto.name()
    );

    let ms_s1 = 1e3 * dt_s1 / steps as f64;
    let ms_1t = 1e3 * dt_1t / steps as f64;
    let ms_mt = 1e3 * dt_mt / steps as f64;
    let steps_per_s_s1 = steps as f64 / dt_s1.max(1e-12);
    let steps_per_s_1t = steps as f64 / dt_1t.max(1e-12);
    let steps_per_s_mt = steps as f64 / dt_mt.max(1e-12);
    let speedup = ms_1t / ms_mt.max(1e-12);
    // the f32-GEMM dispatch win on the whole SGD step (1.0 on
    // scalar-only hosts where both cases run the same kernels)
    let simd_step_speedup = ms_s1 / ms_1t.max(1e-12);

    let mut table = Table::new(
        &format!(
            "native train throughput ({arch}, batch {}, 8w/8a)",
            spec.train_batch
        ),
        &["case", "ms/step", "steps/s", "img/s", "speedup"],
    );
    for (name, ms, sps, sp) in [
        ("1 thread, scalar kernels".to_string(), ms_s1, steps_per_s_s1, 1.0),
        (
            format!("1 thread, {} kernels", auto.name()),
            ms_1t,
            steps_per_s_1t,
            simd_step_speedup,
        ),
        (format!("{threads} threads"), ms_mt, steps_per_s_mt, speedup),
    ] {
        table.row(vec![
            name,
            format!("{ms:.2}"),
            format!("{sps:.1}"),
            format!("{:.0}", sps * spec.train_batch as f64),
            format!("{sp:.2}x"),
        ]);
    }
    table.row(vec![
        "loss".into(),
        format!(
            "{:.4} -> {:.4}",
            losses_mt[0],
            losses_mt[losses_mt.len() - 1]
        ),
        String::new(),
        String::new(),
        String::new(),
    ]);
    println!("{}", table.render());

    let json = format!(
        "{{\n  \"bench\": \"train_throughput\",\n  \"arch\": \"{arch}\",\n  \
         \"batch\": {},\n  \"steps\": {steps},\n  \"threads\": {threads},\n  \
         \"kernel_isa\": \"{}\",\n  \
         \"ms_per_step_scalar_1t\": {ms_s1:.3},\n  \
         \"ms_per_step_1t\": {ms_1t:.3},\n  \"ms_per_step_mt\": {ms_mt:.3},\n  \
         \"steps_per_s_1t\": {steps_per_s_1t:.2},\n  \
         \"steps_per_s_mt\": {steps_per_s_mt:.2},\n  \
         \"speedup_threaded\": {speedup:.3},\n  \
         \"simd_step_speedup\": {simd_step_speedup:.3},\n  \
         \"histories_bit_identical\": true,\n  \
         \"first_loss\": {:.6},\n  \"final_loss\": {:.6}\n}}\n",
        spec.train_batch,
        auto.name(),
        losses_mt[0],
        losses_mt[losses_mt.len() - 1],
    );
    // cargo runs bench executables with cwd = the package root (rust/);
    // anchor the report at the workspace root where CI picks it up
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_train.json");
    std::fs::write(&path, &json).expect("write BENCH_train.json");
    println!("wrote {}", path.display());

    if let Ok(v) = std::env::var("FXP_BENCH_ASSERT") {
        assert!(
            losses_mt.iter().all(|l| l.is_finite()),
            "non-finite training loss: {losses_mt:?}"
        );
        let floor = v.parse::<f64>().ok().filter(|&f| f > 1.0).unwrap_or_else(
            || baseline_floor("train_throughput", "min_threaded_step_speedup", 1.5),
        );
        if threads > 1 {
            assert!(
                speedup >= floor,
                "threaded training step only {speedup:.2}x the \
                 single-thread step (need >= {floor}x, {threads} threads)"
            );
            println!(
                "FXP_BENCH_ASSERT ok: {speedup:.2}x threaded step speedup \
                 (floor {floor}x), histories bit-identical"
            );
        } else {
            println!(
                "FXP_BENCH_ASSERT: single core -- speedup gate skipped, \
                 losses finite, histories bit-identical"
            );
        }
        if simd {
            let simd_floor =
                baseline_floor("train_throughput", "min_simd_step_speedup", 1.1);
            assert!(
                simd_step_speedup >= simd_floor,
                "{} kernels only {simd_step_speedup:.2}x the forced-scalar \
                 step (need >= {simd_floor}x)",
                auto.name()
            );
            println!(
                "FXP_BENCH_ASSERT ok: {simd_step_speedup:.2}x SIMD step \
                 speedup over scalar kernels (floor {simd_floor}x)"
            );
        }
    }
}
