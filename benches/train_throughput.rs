//! Native training-engine throughput: SGD steps/second (and images/s)
//! of the pure-Rust backprop + stochastic-rounding fixed-point trainer,
//! fully offline.  Writes `BENCH_train.json` for CI artifact upload
//! next to `BENCH_engine.json`.
//!
//! Scale via:
//! * `FXP_BENCH_TRAIN_ARCH`  -- architecture (default "tiny")
//! * `FXP_BENCH_TRAIN_STEPS` -- timed steps (default 30)
//! * `FXP_BENCH_TRAIN_N`     -- training set size (default 512)
//! * `FXP_BENCH_ASSERT`      -- if set, require finite losses and a
//!   positive step rate (the convergence *gate* lives in
//!   `fxpnet train --gate`; this bench only measures)

use fxpnet::bench::fixtures::{env_str, env_usize};
use fxpnet::bench::Table;
use fxpnet::coordinator::backend::{Backend, SessionCfg};
use fxpnet::coordinator::trainer::{upd_all, TrainSession};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::train::NativeBackend;

fn main() {
    fxpnet::util::logging::init();
    let arch = env_str("FXP_BENCH_TRAIN_ARCH", "tiny");
    let steps = env_usize("FXP_BENCH_TRAIN_STEPS", 30);
    let train_n = env_usize("FXP_BENCH_TRAIN_N", 512);

    let backend = NativeBackend::new();
    let spec = backend.arch(&arch).expect("zoo arch");
    let data = Dataset::generate(train_n, spec.input[0], spec.input[1], 301);
    let params = ParamSet::init(&spec, 42);
    let a_stats = backend
        .activation_stats(&arch, &params, &data, 2)
        .expect("calibration");
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        fxpnet::quant::calib::CalibMethod::SqnrGaussian,
    )
    .expect("cell");
    let mut sess = backend
        .new_session(SessionCfg {
            arch: &arch,
            params: &params,
            nq: &nq,
            upd: &upd_all(spec.num_layers),
            lr: 0.02,
            momentum: 0.9,
            data,
            loader: LoaderCfg {
                batch: spec.train_batch,
                augment: true,
                max_shift: 2,
                seed: 42,
            },
            max_loss: 30.0,
            seed: 42,
        })
        .expect("session");

    // warm up buffers, the loader prefetch, and the weight packer
    let mut losses = Vec::with_capacity(steps + 3);
    for _ in 0..3 {
        losses.push(sess.step().expect("warmup step"));
    }
    let t = std::time::Instant::now();
    for _ in 0..steps {
        losses.push(sess.step().expect("train step"));
    }
    let dt = t.elapsed().as_secs_f64();
    let steps_per_s = steps as f64 / dt.max(1e-12);
    let img_per_s = steps_per_s * spec.train_batch as f64;

    let mut table = Table::new(
        &format!(
            "native train throughput ({arch}, batch {}, 8w/8a)",
            spec.train_batch
        ),
        &["metric", "value"],
    );
    table.row(vec!["steps timed".into(), steps.to_string()]);
    table.row(vec!["ms/step".into(), format!("{:.2}", 1e3 * dt / steps as f64)]);
    table.row(vec!["steps/s".into(), format!("{steps_per_s:.1}")]);
    table.row(vec!["img/s".into(), format!("{img_per_s:.0}")]);
    table.row(vec![
        "loss".into(),
        format!("{:.4} -> {:.4}", losses[0], losses[losses.len() - 1]),
    ]);
    println!("{}", table.render());

    let json = format!(
        "{{\n  \"bench\": \"train_throughput\",\n  \"arch\": \"{arch}\",\n  \
         \"batch\": {},\n  \"steps\": {steps},\n  \
         \"ms_per_step\": {:.3},\n  \"steps_per_s\": {steps_per_s:.2},\n  \
         \"img_per_s\": {img_per_s:.2},\n  \"first_loss\": {:.6},\n  \
         \"final_loss\": {:.6}\n}}\n",
        spec.train_batch,
        1e3 * dt / steps as f64,
        losses[0],
        losses[losses.len() - 1],
    );
    // cargo runs bench executables with cwd = the package root (rust/);
    // anchor the report at the workspace root where CI picks it up
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_train.json");
    std::fs::write(&path, &json).expect("write BENCH_train.json");
    println!("wrote {}", path.display());

    if std::env::var("FXP_BENCH_ASSERT").is_ok() {
        assert!(
            losses.iter().all(|l| l.is_finite()),
            "non-finite training loss: {losses:?}"
        );
        assert!(steps_per_s > 0.0);
        println!(
            "FXP_BENCH_ASSERT ok: {steps_per_s:.1} steps/s, losses finite"
        );
    }
}
