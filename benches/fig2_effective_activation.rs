//! Regenerates **Figure 2**: the presumed (smooth) vs effective
//! (staircase) ReLU of a fixed-point layer, as plottable series plus an
//! ASCII rendering.  Writes results/fig2_effective_activation.csv.

use fxpnet::fixedpoint::vector::effective_relu_curve;
use fxpnet::fixedpoint::QFormat;

fn main() {
    let fmt = QFormat::new(4, 1).unwrap(); // 4-bit, step 0.5: a visible staircase
    let curve = effective_relu_curve(fmt, -1.0, 4.0, 101);

    // CSV for plotting (x, effective, presumed)
    std::fs::create_dir_all("results").unwrap();
    let mut csv = String::from("x,effective,presumed\n");
    for &(x, e, p) in &curve {
        csv.push_str(&format!("{x:.4},{e:.4},{p:.4}\n"));
    }
    std::fs::write("results/fig2_effective_activation.csv", &csv).unwrap();

    println!("Figure 2: presumed ReLU (.) vs effective fixed-point ReLU (#), {fmt}");
    // ASCII plot: y from 0..3.5 in steps, x across the curve
    let rows = 15;
    let ymax = 3.5f32;
    for r in (0..=rows).rev() {
        let y = ymax * r as f32 / rows as f32;
        let mut line = format!("{y:>5.2} |");
        for &(_, e, p) in curve.iter().step_by(1) {
            let de = (e - y).abs();
            let dp = (p - y).abs();
            let tol = ymax / rows as f32 / 2.0;
            line.push(if de <= tol {
                '#'
            } else if dp <= tol {
                '.'
            } else {
                ' '
            });
        }
        println!("{line}");
    }
    println!("      +{}", "-".repeat(curve.len()));
    println!("       x in [-1, 4]   (# = staircase the network actually computes,");
    println!("                       . = smooth ReLU the backward pass presumes)");
    println!();
    let n_levels = {
        let mut lv: Vec<i64> = curve.iter().map(|&(_, e, _)| (e / fmt.step()) as i64).collect();
        lv.sort();
        lv.dedup();
        lv.len()
    };
    let max_gap = curve
        .iter()
        .map(|&(_, e, p)| (e - p).abs())
        .fold(0f32, f32::max);
    println!(
        "levels: {n_levels} (4-bit positive codes), max |effective - presumed| = {max_gap} \
         (rounding contributes step/2 = {}; saturation above max_value {} the rest)",
        fmt.step() / 2.0,
        fmt.max_value()
    );
    println!("wrote results/fig2_effective_activation.csv");
}
