//! End-to-end train-step latency per architecture and configuration --
//! the headline L2/L3 performance numbers tracked in EXPERIMENTS.md
//! section Perf.  Float vs fully-quantized configs isolate the cost of
//! the in-graph quantizers; the integer engine gives the deployment-side
//! number.

use fxpnet::bench::{bench, Table};
use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::{FixedPointNet, Scratch};
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::runtime::Engine;

fn step_ms(
    engine: &Engine,
    arch: &str,
    nq: &NetQuant,
    iters: usize,
) -> (f64, usize) {
    let spec = engine.manifest.arch(arch).unwrap().clone();
    let params = ParamSet::init(&spec, 1);
    let data = Dataset::generate(
        spec.train_batch * 4,
        spec.input[0],
        spec.input[1],
        9,
    );
    let mut tr = Trainer::new(
        engine,
        arch,
        &params,
        nq,
        &upd_all(spec.num_layers),
        0.01,
        0.9,
        data,
        LoaderCfg { batch: spec.train_batch, augment: false, max_shift: 0, seed: 2 },
        1e9, // no divergence cutoff for timing
    )
    .unwrap();
    tr.step().unwrap(); // warm
    let s = bench(&format!("{arch} train_step"), 1, iters, || {
        tr.step().unwrap();
    });
    (s.mean_ms, spec.train_batch)
}

fn main() {
    fxpnet::util::logging::init();
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let engine = Engine::cpu(&artifacts).expect("run `make artifacts` first");

    let mut t = Table::new(
        "train-step latency (batch amortised)",
        &["arch", "config", "ms/step", "img/s"],
    );
    for arch in ["tiny", "shallow", "paper12"] {
        let spec = engine.manifest.arch(arch).unwrap().clone();
        let l = spec.num_layers;
        let iters = if arch == "paper12" { 8 } else { 20 };
        // float
        let (ms, b) = step_ms(&engine, arch, &NetQuant::all_float(l), iters);
        t.row(vec![
            arch.into(),
            "float (enables off)".into(),
            format!("{ms:.1}"),
            format!("{:.0}", b as f64 / (ms / 1e3)),
        ]);
        // fully quantized 8/8
        let params = ParamSet::init(&spec, 1);
        let data = Dataset::generate(256, spec.input[0], spec.input[1], 10);
        let a_stats = calibrate::activation_stats(&engine, arch, &params, &data, 1)
            .unwrap()
            .a_stats;
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(8),
            WidthSpec::Bits(8),
            &params.weight_stats(),
            &a_stats,
            CalibMethod::MinMax,
        )
        .unwrap();
        let (ms, b) = step_ms(&engine, arch, &nq, iters);
        t.row(vec![
            arch.into(),
            "8w/8a quantized".into(),
            format!("{ms:.1}"),
            format!("{:.0}", b as f64 / (ms / 1e3)),
        ]);
        // integer engine inference: batched GEMM path, warm scratch
        // (zero steady-state allocation), row-blocks over all cores
        let threads =
            std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
        let net =
            FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
                .unwrap();
        let imgs = data.images.gather_rows(&(0..64).collect::<Vec<_>>()).unwrap();
        let mut scratch = Scratch::for_net(&net, 64, threads);
        let mut logits = vec![0f32; 64 * spec.num_classes];
        let s = bench(&format!("{arch} int fwd"), 1, 5, || {
            net.forward_batch_into(&imgs, &mut scratch, threads, &mut logits).unwrap();
            std::hint::black_box(&logits);
        });
        t.row(vec![
            arch.into(),
            format!("integer engine fwd ({threads}t GEMM)"),
            format!("{:.1}", s.mean_ms / 64.0),
            format!("{:.0}", 64.0 / (s.mean_ms / 1e3)),
        ]);
    }
    println!("{}", t.render());
    std::fs::create_dir_all("results").unwrap();
    std::fs::write("results/e2e_throughput.txt", t.render()).unwrap();
}
