//! Multi-process sharding overhead: what does the cache/lock/merge
//! layer cost relative to the sweep work it coordinates?
//!
//! Three measurements, all engine-free so the bench runs offline:
//!
//! * per-shard sweep writes (`--shard-cache` path): the incremental
//!   locked cache saves that stream results to disk as cells finish;
//! * `grid merge` of N shard files into the full table (the CI merge
//!   job's hot path) -- strict parse, conflict scan, coverage;
//! * raw advisory lock acquire/release cycles.
//!
//! Scale via:
//! * `FXP_BENCH_MERGE_SHARDS` -- shard count (default 3)
//! * `FXP_BENCH_MERGE_ITERS`  -- merge iterations (default 200)
//!
//! `FXP_BENCH_ASSERT=1` additionally enforces the correctness gate: the
//! merged table must be bit-identical to the unsharded sweep.

use std::path::PathBuf;

use fxpnet::bench::fixtures::env_usize;
use fxpnet::bench::Table;
use fxpnet::coordinator::grid::{self, GridResult, SweepOpts};
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::shard::{self, FileLock, LockOpts};
use fxpnet::util::timer::Stopwatch;

fn sweep(opts: &SweepOpts) -> grid::SweepOutcome {
    grid::run_sweep_with(
        Regime::Vanilla,
        "bench",
        42,
        opts,
        |_wid| Ok(()),
        |_, job| grid::synthetic_cell(job),
    )
    .expect("sweep")
}

fn bits(g: &GridResult) -> Vec<Option<u64>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| c.eval.ok().map(|e| e.top1_err.to_bits()))
        .collect()
}

fn main() {
    fxpnet::util::logging::init();
    let shards = env_usize("FXP_BENCH_MERGE_SHARDS", 3);
    let iters = env_usize("FXP_BENCH_MERGE_ITERS", 200);
    let dir = std::env::temp_dir()
        .join(format!("fxp_bench_shard_merge_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("temp dir");
    let base = dir.join("cache.json");

    let mut t = Table::new(
        &format!("Multi-process sharding overhead ({shards} shards)"),
        &["stage", "total ms", "per-op us"],
    );

    // reference: unsharded in-process sweep, no cache
    let sw = Stopwatch::start();
    let reference = sweep(&SweepOpts { workers: 2, ..Default::default() });
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    t.row(vec!["unsharded sweep (no cache)".into(), format!("{ms:.1}"), "-".into()]);

    // per-shard sweeps with locked incremental cache writes
    let sw = Stopwatch::start();
    let files: Vec<PathBuf> = (0..shards)
        .map(|index| {
            let opts = SweepOpts {
                workers: 2,
                shard: Some((index, shards)),
                cache_path: Some(base.clone()),
                split_cache: true,
                ..Default::default()
            };
            let out = sweep(&opts);
            assert_eq!(out.computed + out.missing, 16);
            opts.cache_file().expect("cache path")
        })
        .collect();
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    t.row(vec![
        format!("{shards} sharded sweeps (locked cache writes)"),
        format!("{ms:.1}"),
        "-".into(),
    ]);

    // merge throughput
    let sw = Stopwatch::start();
    let mut merged = None;
    for _ in 0..iters {
        merged = Some(shard::merge_files(&files, None).expect("merge"));
    }
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    t.row(vec![
        format!("grid merge x{iters}"),
        format!("{ms:.1}"),
        format!("{:.1}", ms * 1e3 / iters as f64),
    ]);

    // raw lock acquire/release cycles
    let lock_target = dir.join("lock-bench.json");
    let opts = LockOpts::default();
    let sw = Stopwatch::start();
    for _ in 0..iters {
        let l = FileLock::acquire(&lock_target, &opts).expect("lock");
        drop(l);
    }
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    t.row(vec![
        format!("lock acquire+release x{iters}"),
        format!("{ms:.1}"),
        format!("{:.1}", ms * 1e3 / iters as f64),
    ]);
    println!("{}", t.render());

    // correctness gate: merged table == unsharded table, bit for bit
    let merged = merged.expect("at least one merge iteration");
    assert!(merged.is_complete(), "merge missing {:?}", merged.missing);
    let ok = bits(&merged.to_grid()) == bits(&reference.grid);
    println!(
        "merged table bit-identical to unsharded sweep: {}",
        if ok { "yes" } else { "NO" }
    );
    if !ok && std::env::var("FXP_BENCH_ASSERT").is_ok() {
        eprintln!("FAIL: merged table differs from the unsharded sweep");
        std::process::exit(1);
    }
    let _ = std::fs::remove_dir_all(&dir);
}
