//! Serve-path latency/throughput bench: starts the micro-batching
//! daemon in-process on the CIFAR-shaped fixture net, replays the
//! uniform and bursty traces against it, and writes `BENCH_serve.json`
//! (p50/p95/p99 latency, achieved throughput, batch-size mix).
//!
//! Offered rates derive from a measured serial (single closed-loop
//! client) baseline, so the numbers that gate CI are machine-independent
//! ratios:
//!
//! * `p95_ratio_uniform`      -- uniform-trace p95 over serial p50
//! * `throughput_ratio_bursty` -- bursty rate over serial rate (the
//!   batching win; a batch-of-1 server cannot exceed ~1.0)
//!
//! Scale via:
//! * `FXP_BENCH_SERVE_N`       -- requests per trace (default 400)
//! * `FXP_BENCH_SERVE_BATCH`   -- daemon --max-batch (default 8)
//! * `FXP_BENCH_SERVE_WAIT_US` -- daemon --max-wait-us (default 2000)
//! * `FXP_BENCH_SERVE_THREADS` -- daemon engine threads (default 2)
//! * `FXP_BENCH_ASSERT`        -- if set, enforce the `serve` ratio
//!   gates from BENCH_baseline.json
//!
//! The same traces can be replayed against an out-of-process daemon via
//! `fxpnet serve --replay` (what the CI serve-load job does).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use fxpnet::bench::fixtures::{env_usize, int_engine_fixture};
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::FixedPointNet;
use fxpnet::serve::{run_server, ReplayOpts, ServeOpts, TraceKind};

fn main() {
    fxpnet::util::logging::init();
    let n = env_usize("FXP_BENCH_SERVE_N", 400);
    let max_batch = env_usize("FXP_BENCH_SERVE_BATCH", 8);
    let max_wait_us = env_usize("FXP_BENCH_SERVE_WAIT_US", 2000);
    let threads = env_usize("FXP_BENCH_SERVE_THREADS", 2);

    let (spec, params, nq) = int_engine_fixture(8, 42).expect("fixture");
    let net = FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
        .expect("build");
    println!(
        "serve_latency: {} ({:.0} MMAC/img), max_batch {max_batch}, \
         max_wait {max_wait_us}us, {threads} engine threads, {n} req/trace",
        spec.name,
        net.macs_per_image() as f64 / 1e6
    );

    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&shutdown);
    let (tx, rx) = mpsc::channel();
    let server = std::thread::spawn(move || {
        let opts = ServeOpts {
            listen: "127.0.0.1:0".into(),
            port_file: None,
            max_batch,
            max_wait: Duration::from_micros(max_wait_us as u64),
            max_queue: 0, // unbounded: the bench measures latency, not rejects
            threads,
        };
        run_server(Arc::new(net), &opts, &flag, Some(tx))
    });
    let addr = rx.recv_timeout(Duration::from_secs(10)).expect("server up");

    let opts = ReplayOpts {
        requests: n,
        clients: 0, // 2 * max_batch
        seed: 42,
        traces: vec![TraceKind::Uniform, TraceKind::Bursty],
        out: None, // workspace-root BENCH_serve.json
        assert_floors: std::env::var("FXP_BENCH_ASSERT").is_ok(),
    };
    let result = fxpnet::serve::replay::run_suite(&addr.to_string(), &opts);

    shutdown.store(true, Ordering::SeqCst);
    let summary = server.join().expect("server thread").expect("server run");
    println!(
        "daemon summary: {} requests in {} batches ({} rejected)",
        summary.requests, summary.batches, summary.rejected
    );

    match result {
        Ok(report) => {
            if let Ok(gates) = report.get("gates") {
                println!("gates: {gates}");
            }
            if opts.assert_floors {
                println!("FXP_BENCH_ASSERT ok: serve ratio gates passed");
            }
        }
        Err(e) => {
            eprintln!("serve_latency: {e}");
            std::process::exit(1);
        }
    }
}
