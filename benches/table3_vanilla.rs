//! Regenerates **Table 3**: plain-vanilla fine-tuning under full
//! quantization.  The paper's signature result is the `n/a` pattern:
//! with fixed-point activations the deep network mostly *fails to
//! converge* (divergence detector -> n/a), while the float-activation
//! row fine-tunes fine -- low-precision weights are benign, low-precision
//! activations are not.
//!
//! Scale via FXP_BENCH_* (see rust/src/bench/fixtures.rs).

use fxpnet::bench::fixtures::bench_env;
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report;
use fxpnet::util::timer::Stopwatch;

fn main() {
    let env = bench_env().expect("bench env (run `make artifacts` first)");
    let mut runner = env.runner();
    let sw = Stopwatch::start();
    let grid = runner.run_grid(Regime::Vanilla).expect("grid");
    println!("{}", grid.render(env.cfg.topk));
    println!("table 3 regenerated in {:.1}s", sw.elapsed().as_secs_f64());
    report::save_grid(&grid, "results", env.cfg.topk).expect("save");
}
