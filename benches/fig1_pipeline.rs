//! Regenerates **Figure 1**: the three-step fixed-point pipeline
//! (multiply -> wide accumulate -> round/truncate), demonstrated
//! bit-exactly on the integer engine and micro-benchmarked step by step.

use fxpnet::bench::{bench, Table};
use fxpnet::fixedpoint::value::WideAcc;
use fxpnet::fixedpoint::{Fx, QFormat, RoundMode};
use fxpnet::inference::ops;
use fxpnet::util::rng::Rng;

fn main() {
    // ---- the worked pipeline (paper Figure 1, 8-bit operands) -----------
    let fmt8 = QFormat::new(8, 4).unwrap();
    let w = Fx::from_f32(1.1875, fmt8, RoundMode::NearestHalfUp, None);
    let g = Fx::from_f32(-0.8125, fmt8, RoundMode::NearestHalfUp, None);
    let prod = w.wide_mul(&g); // step 1: 8b x 8b -> 16b
    let mut acc = WideAcc::zero(prod.frac); // step 2: wide accumulator
    for _ in 0..64 {
        acc.add(prod);
    }
    acc.add_f32(0.5);
    let out = acc.requantize(fmt8, RoundMode::NearestHalfUp, None); // step 3
    let mut t = Table::new(
        "Figure 1: w * g(a) pipeline, 8-bit operands, 64-term dot product",
        &["step", "value", "representation"],
    );
    t.row(vec![
        "operand w".into(),
        format!("{}", w.to_f32()),
        format!("code {} in {}", w.code, w.fmt),
    ]);
    t.row(vec![
        "operand g(a)".into(),
        format!("{}", g.to_f32()),
        format!("code {} in {}", g.code, g.fmt),
    ]);
    t.row(vec![
        "1: multiply".into(),
        format!("{}", prod.to_f64()),
        format!("code {} @ frac {}  (16-bit product)", prod.acc, prod.frac),
    ]);
    t.row(vec![
        "2: accumulate x64 + bias".into(),
        format!("{}", acc.to_f64()),
        format!("code {} @ frac {}  (wide accumulator)", acc.acc, acc.frac),
    ]);
    t.row(vec![
        "3: round/truncate".into(),
        format!("{}", out.to_f32()),
        format!("code {} in {}  (saturated)", out.code, out.fmt),
    ]);
    println!("{}", t.render());

    // ---- microbench: per-step cost at layer scale ------------------------
    let mut rng = Rng::new(1);
    let n = 64 * 64; // one conv plane
    let cin = 32;
    let cout = 32;
    let xs: Vec<f32> = (0..n * cin).map(|_| rng.normal() as f32).collect();
    let ws: Vec<f32> = (0..9 * cin * cout).map(|_| rng.normal() as f32 * 0.1).collect();
    let x_codes = ops::encode(&xs, fmt8);
    let w_codes = ops::encode(&ws, fmt8);
    let bias = vec![0.01f32; cout];

    let s_enc = bench("step0 encode 128k f32 -> codes", 2, 10, || {
        std::hint::black_box(ops::encode(&xs, fmt8));
    });
    let mut acc_out: Vec<i64> = Vec::new();
    let s_conv = bench("step1+2 conv3x3 64x64x32->32 (i64 acc)", 1, 5, || {
        acc_out = ops::conv3x3_acc(&x_codes, 64, 64, cin, &w_codes, cout, &bias, 8);
        std::hint::black_box(&acc_out);
    });
    let s_req = bench("step3 requant+relu 128k accumulators", 2, 10, || {
        std::hint::black_box(ops::requant_relu(&acc_out, 8, fmt8, true));
    });
    println!("{s_enc}");
    println!("{s_conv}");
    println!("{s_req}");
    let macs = 64.0 * 64.0 * 9.0 * cin as f64 * cout as f64;
    println!(
        "conv throughput: {:.1} MMAC/s (integer path, single thread)",
        macs / (s_conv.mean_ms / 1e3) / 1e6
    );
}
