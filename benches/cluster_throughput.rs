//! Cluster scheduling overhead: the same CPU-bound synthetic sweep run
//! through the in-process worker pool (`grid::run_sweep_with`) and
//! through a real loopback TCP cluster (`fxpnet cluster` coordinator +
//! worker threads), at growing worker counts.
//!
//! Cells burn seeded stochastic-rounding work through the real
//! `fixedpoint::vector` path, so the bench runs in the offline build
//! and the comparison isolates what the wire protocol, heartbeats, and
//! pull-scheduling cost over a shared-memory pool.  Every cluster run's
//! cell cache must stay byte-identical to the pooled reference -- the
//! determinism contract is asserted on every bench run, not just in CI.
//!
//! Scale via:
//! * `FXP_BENCH_CELL_N`          -- floats quantized per round (default 100k)
//! * `FXP_BENCH_CELL_ROUNDS`     -- rounds per cell (default 10)
//! * `FXP_BENCH_CLUSTER_WORKERS` -- highest worker count tried (default 4)

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::time::{Duration, Instant};

use fxpnet::bench::fixtures::env_usize;
use fxpnet::bench::Table;
use fxpnet::cluster::{
    self, run_coordinator, run_worker, CellExec, ClusterOpts, HeartbeatCfg,
    WorkerOpts,
};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::grid::{self, CellJob, SweepOpts};
use fxpnet::coordinator::regimes::{CellEval, CellResult, Regime};
use fxpnet::fixedpoint::vector::quantize_slice;
use fxpnet::fixedpoint::{QFormat, RoundMode};
use fxpnet::util::rng::Rng;
use fxpnet::util::timer::Stopwatch;

const ARCH: &str = "bench";
const SEED: u64 = 42;

fn fp() -> u64 {
    cluster::sweep_fingerprint(ARCH, Regime::Vanilla, SEED, true, &RunCfg::smoke())
}

/// One CPU-bound cell: seeded rounding work folded into a result that
/// is a pure function of `job.seed` -- the property that makes the
/// pooled and clustered caches comparable byte for byte.
fn burn_cell(job: &CellJob, n: usize, rounds: usize) -> fxpnet::Result<CellResult> {
    let mut rng = Rng::new(job.seed);
    let fmt = QFormat::new(8, 4)?;
    let mut xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-6.0, 6.0)).collect();
    let mut acc = 0.0f64;
    for _ in 0..rounds {
        quantize_slice(&mut xs, fmt, RoundMode::Stochastic, Some(&mut rng));
        acc += xs.iter().map(|&v| v as f64).sum::<f64>();
        for v in xs.iter_mut() {
            *v += rng.uniform_in(-0.1, 0.1);
        }
    }
    Ok(CellEval::Ok(EvalResult {
        n,
        top1_err: (acc.abs() % 1.0).min(0.999),
        top5_err: 0.0,
        mean_loss: acc.abs() % 10.0,
    }))
}

struct BurnExec {
    n: usize,
    rounds: usize,
}

impl CellExec for BurnExec {
    fn run(
        &mut self,
        job: &CellJob,
    ) -> fxpnet::Result<(
        CellResult,
        Option<fxpnet::train::telemetry::TelemetrySummary>,
    )> {
        burn_cell(job, self.n, self.rounds).map(|r| (r, None))
    }
}

/// The in-process pooled sweep: the scheduling baseline.
fn timed_pool(dir: &Path, workers: usize, n: usize, rounds: usize) -> (f64, PathBuf) {
    let cache = dir.join("pool_cache.json");
    let sw = Stopwatch::start();
    let out = grid::run_sweep_with(
        Regime::Vanilla,
        ARCH,
        SEED,
        &SweepOpts {
            workers,
            cache_path: Some(cache.clone()),
            ..Default::default()
        },
        |_| Ok(()),
        |_, job| burn_cell(job, n, rounds),
    )
    .expect("pooled sweep");
    assert!(out.is_complete());
    (sw.elapsed().as_secs_f64() * 1e3, cache)
}

/// The same sweep through a real loopback TCP cluster.
fn timed_cluster(dir: &Path, workers: usize, n: usize, rounds: usize) -> (f64, PathBuf) {
    let cdir = dir.join(format!("cluster_{workers}"));
    std::fs::create_dir_all(&cdir).expect("mkdir");
    let opts = ClusterOpts {
        listen: "127.0.0.1:0".into(),
        port_file: Some(cdir.join("port")),
        hb: HeartbeatCfg {
            interval: Duration::from_millis(100),
            deadline: Duration::from_millis(2000),
        },
        cache_path: cdir.join("cache.json"),
        ..ClusterOpts::default()
    };
    let shutdown = AtomicBool::new(false);
    let sw = Stopwatch::start();
    let outcome = std::thread::scope(|s| {
        let coord = s.spawn(|| {
            run_coordinator(Regime::Vanilla, ARCH, SEED, fp(), &opts, &shutdown)
        });
        let port_file = cdir.join("port");
        let deadline = Instant::now() + Duration::from_secs(10);
        let addr = loop {
            if let Ok(a) = std::fs::read_to_string(&port_file) {
                let a = a.trim();
                if !a.is_empty() {
                    break a.to_string();
                }
            }
            assert!(Instant::now() < deadline, "no port file");
            std::thread::sleep(Duration::from_millis(5));
        };
        let handles: Vec<_> = (0..workers)
            .map(|i| {
                let connect = addr.clone();
                s.spawn(move || {
                    let wopts = WorkerOpts {
                        connect,
                        name: format!("bench-w{i}"),
                        ..WorkerOpts::default()
                    };
                    run_worker(
                        Regime::Vanilla,
                        SEED,
                        fp(),
                        &mut BurnExec { n, rounds },
                        &wopts,
                    )
                })
            })
            .collect();
        let outcome = coord.join().expect("coordinator thread").expect("coordinator");
        for h in handles {
            let report = h.join().expect("worker thread").expect("worker");
            assert!(report.sweep_complete);
        }
        outcome
    });
    let ms = sw.elapsed().as_secs_f64() * 1e3;
    assert!(outcome.summary.complete);
    assert_eq!(outcome.summary.redispatched, 0, "no faults injected");
    (ms, cdir.join("cache.json"))
}

fn main() {
    fxpnet::util::logging::init();
    let n = env_usize("FXP_BENCH_CELL_N", 100_000);
    let rounds = env_usize("FXP_BENCH_CELL_ROUNDS", 10);
    let max_workers = env_usize("FXP_BENCH_CLUSTER_WORKERS", 4).max(1);
    let dir = std::env::temp_dir().join(format!("fxp_bench_cluster_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("mkdir");
    println!(
        "cluster throughput: 16 cells x {rounds} rounds x {n} floats, \
         TCP loopback vs in-process pool"
    );

    // warm-up, then the pooled baseline at the top worker count
    // (the cache's advisory lock creates each run directory on open)
    let _ = timed_pool(&dir.join("warmup"), 1, n / 4, 2);
    let (pool_ms, pool_cache) = timed_pool(&dir.join("pool"), max_workers, n, rounds);
    let reference = std::fs::read(&pool_cache).expect("pool cache");

    let mut t = Table::new(
        "Cluster sweep vs in-process pool (16 cells)",
        &["topology", "ms", "vs pool"],
    );
    t.row(vec![
        format!("pool x{max_workers}"),
        format!("{pool_ms:.1}"),
        "1.00x".into(),
    ]);
    let mut w = 1usize;
    while w <= max_workers {
        let (ms, cache) = timed_cluster(&dir, w, n, rounds);
        // the determinism contract: scheduling topology is invisible in
        // the cache, byte for byte
        assert_eq!(
            std::fs::read(&cache).expect("cluster cache"),
            reference,
            "cluster cache (workers={w}) differs from the pooled reference"
        );
        t.row(vec![
            format!("cluster x{w}"),
            format!("{ms:.1}"),
            format!("{:.2}x", pool_ms / ms.max(1e-9)),
        ]);
        w *= 2;
    }
    println!("{}", t.render());
    println!("cache byte-identity: OK for every topology");
    let _ = std::fs::remove_dir_all(&dir);
}
