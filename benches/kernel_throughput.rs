//! L1/L3 kernel microbenches (the section Perf baseline numbers):
//! host-side quantizer throughput and the integer GEMM microkernel --
//! both run once per kernel path (the scalar reference always, plus the
//! detected SIMD ISA when the host has one; which paths ran is printed,
//! never silently skipped) -- then Tensor<->Literal conversion cost and
//! AOT executable latency for eval/stats on the tiny net (skipped with
//! a message when artifacts are absent).

use fxpnet::bench::bench;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::vector::quantize_slice;
use fxpnet::fixedpoint::{QFormat, RoundMode};
use fxpnet::inference::{Isa, Kernels};
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::NetQuant;
use fxpnet::runtime::literal::{to_literal, HostValue};
use fxpnet::runtime::Engine;
use fxpnet::tensor::Tensor;
use fxpnet::util::rng::Rng;

fn main() {
    fxpnet::util::logging::init();
    let fmt = QFormat::new(8, 4).unwrap();
    let mut rng = Rng::new(3);
    let n = 1 << 20;
    let xs: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
    let mut buf = xs.clone();

    // which kernel paths this host can run (scalar is the reference;
    // the SIMD section is the point of the dispatch layer)
    let detected = Kernels::detect();
    let mut isas = vec![Isa::Scalar];
    if detected == Isa::Scalar {
        println!(
            "kernel paths: scalar only (no AVX2/NEON on this host -- \
             SIMD sections cannot run)"
        );
    } else {
        isas.push(detected);
        println!("kernel paths: scalar + {}", detected.name());
    }

    for &isa in &isas {
        let kn = Kernels::for_isa(isa);
        println!("--- kernel path: {} ---", kn.name());

        // host quantizer (the L3 twin of the L1 Pallas kernel)
        let s = bench(&format!("quantize_nearest 1M f32 [{}]", kn.name()), 3, 20, || {
            buf.copy_from_slice(&xs);
            kn.quantize_nearest(&mut buf, fmt);
            std::hint::black_box(&buf);
        });
        println!("{s}  -> {:.1} Melem/s", s.throughput(n as f64) / 1e6);

        // integer GEMM microkernel (the conv engine's inner loop):
        // CIFAR-first-conv-shaped (k = 9*32, n = 32) over 4096 patch
        // rows, at the operand widths that select each panel storage
        // (Q8 -> i8 pair panels under SIMD, 8x12 -> i16, 16x12 -> i32)
        let (rows, k, ncol) = (4096usize, 288usize, 32usize);
        let mut irng = Rng::new(8);
        let a: Vec<i32> = (0..rows * k).map(|_| irng.below(255) as i32 - 127).collect();
        let w: Vec<i32> = (0..k * ncol).map(|_| irng.below(255) as i32 - 127).collect();
        let bias: Vec<i64> = (0..ncol).map(|i| i as i64 * 10).collect();
        let mut out = vec![0i32; rows * ncol];
        let macs = (rows * k * ncol) as f64;
        for (a_bits, w_bits) in [(8u8, 8u8), (8, 12), (16, 12)] {
            let pw = kn.pack_int(&w, k, ncol, a_bits, w_bits);
            let label = format!(
                "gemm_requant_relu 4096x288x32 {a_bits}bx{w_bits}b [{} {} panels]",
                kn.name(),
                pw.kind()
            );
            let s = bench(&label, 2, 20, || {
                kn.gemm_requant_relu(&a, rows, k, &pw, &bias, 9, fmt, true, &mut out);
                std::hint::black_box(&out);
            });
            println!("{s}  -> {:.2} GMAC/s", s.throughput(macs) / 1e9);
        }
    }

    // stochastic rounding stays scalar on every ISA (the dither RNG
    // stream is part of the pinned numerics), so bench it once
    let mut srng = Rng::new(4);
    let s = bench("quantize_slice 1M f32 (stochastic, scalar-only)", 3, 10, || {
        buf.copy_from_slice(&xs);
        quantize_slice(&mut buf, fmt, RoundMode::Stochastic, Some(&mut srng));
        std::hint::black_box(&buf);
    });
    println!("{s}  -> {:.1} Melem/s", s.throughput(n as f64) / 1e6);

    // Tensor -> Literal conversion (per-step host boundary cost)
    let t = Tensor::from_vec(&[64, 32, 32, 3], xs[..64 * 32 * 32 * 3].to_vec()).unwrap();
    let hv = HostValue::F32(t);
    let s = bench("to_literal 64x32x32x3 batch", 3, 50, || {
        std::hint::black_box(to_literal(&hv).unwrap());
    });
    println!("{s}");

    // AOT executable latency (tiny arch); needs built artifacts
    let artifacts = std::env::var("FXPNET_ARTIFACTS").unwrap_or("artifacts".into());
    let Ok(engine) = Engine::cpu(&artifacts) else {
        eprintln!("skipping AOT latency section: no {artifacts}/ (run `make artifacts`)");
        return;
    };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 1);
    let data = Dataset::generate(spec.eval_batch, spec.input[0], spec.input[1], 5);
    let nq = NetQuant::all_float(spec.num_layers);
    let exe = engine.executable("tiny", "eval_batch").unwrap();
    let v = nq.vectors();
    let mk = |x: &[f32]| to_literal(&HostValue::F32(Tensor::from_vec(&[x.len()], x.to_vec()).unwrap())).unwrap();
    let cfg = [
        mk(&v.w_step), mk(&v.w_lo), mk(&v.w_hi), mk(&v.w_en),
        mk(&v.a_step), mk(&v.a_lo), mk(&v.a_hi), mk(&v.a_en),
    ];
    let plits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(|t| to_literal(&HostValue::F32(t.clone())).unwrap())
        .collect();
    let x = to_literal(&HostValue::F32(data.images.clone())).unwrap();
    let y = to_literal(&HostValue::I32(data.labels.clone())).unwrap();
    let s = bench("tiny eval_batch executable (32 imgs)", 3, 30, || {
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(plits.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(cfg.iter());
        std::hint::black_box(exe.run_literals(&inputs).unwrap());
    });
    println!("{s}  -> {:.0} img/s", s.throughput(spec.eval_batch as f64));
}
