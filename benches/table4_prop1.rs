//! Regenerates **Table 4** (Proposal 1): networks fine-tuned with the
//! target *weight* precision but float activations, then run with
//! fixed-point activations switched on post-hoc.
//!
//! Paper shape to expect: every cell beats its Table 2 counterpart
//! (dramatically so for 4-bit weights), and loses modestly to the float-
//! activation row -- no training instability anywhere because no training
//! happens under quantized activations.
//!
//! Scale via FXP_BENCH_* (see rust/src/bench/fixtures.rs).

use fxpnet::bench::fixtures::bench_env;
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report;
use fxpnet::util::timer::Stopwatch;

fn main() {
    let env = bench_env().expect("bench env (run `make artifacts` first)");
    let mut runner = env.runner();
    let sw = Stopwatch::start();
    let grid = runner.run_grid(Regime::Prop1).expect("grid");
    println!("{}", grid.render(env.cfg.topk));
    println!("table 4 regenerated in {:.1}s", sw.elapsed().as_secs_f64());
    report::save_grid(&grid, "results", env.cfg.topk).expect("save");
}
