//! Regenerates **Table 6** (Proposal 3): bottom-to-top iterative
//! fine-tuning per the paper's Table 1 schedule, starting from the
//! Proposal-1 nets.
//!
//! Paper shape to expect: the best fixed-point numbers of all five
//! tables -- every cell trains stably (the gradient path never crosses a
//! quantized activation), 4w/4a becomes usable, and some cells match or
//! beat the float baseline (quantization noise as regularisation).
//!
//! Scale via FXP_BENCH_* (see rust/src/bench/fixtures.rs).

use fxpnet::bench::fixtures::bench_env;
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report;
use fxpnet::util::timer::Stopwatch;

fn main() {
    let env = bench_env().expect("bench env (run `make artifacts` first)");
    let mut runner = env.runner();
    let sw = Stopwatch::start();
    let grid = runner.run_grid(Regime::Prop3).expect("grid");
    println!("{}", grid.render(env.cfg.topk));
    println!("table 6 regenerated in {:.1}s", sw.elapsed().as_secs_f64());
    report::save_grid(&grid, "results", env.cfg.topk).expect("save");
}
