//! Batched integer-GEMM engine throughput vs the retained direct
//! per-image reference path, on the CIFAR-shaped fixture net (offline:
//! no artifacts needed).  Writes `BENCH_engine.json` for CI artifact
//! upload and asserts the speedup floor under `FXP_BENCH_ASSERT`.
//!
//! Scale via:
//! * `FXP_BENCH_ENGINE_N`       -- batch size (default 32)
//! * `FXP_BENCH_ENGINE_ITERS`   -- timed iterations per case (default 10)
//! * `FXP_BENCH_ENGINE_THREADS` -- worker count for the threaded case
//!   (default: all cores)
//! * `FXP_BENCH_ASSERT`         -- if set, require batched GEMM (1
//!   thread) >= 2x the per-image direct path

use fxpnet::bench::fixtures::{baseline_floor, env_usize, int_engine_fixture};
use fxpnet::bench::{bench, Table};
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::{FixedPointNet, Scratch};

fn main() {
    fxpnet::util::logging::init();
    let n = env_usize("FXP_BENCH_ENGINE_N", 32);
    let iters = env_usize("FXP_BENCH_ENGINE_ITERS", 10);
    let threads = env_usize(
        "FXP_BENCH_ENGINE_THREADS",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    let (spec, params, nq) = int_engine_fixture(8, 42).expect("fixture");
    let net = FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
        .expect("build");
    let data = Dataset::generate(n, 32, 32, 7);
    let img_len = 32 * 32 * 3;
    let nc = net.num_classes();

    // parity guard: the three timed cases must compute the same logits
    let mut reference = Vec::with_capacity(n * nc);
    for i in 0..n {
        reference.extend(
            net.forward(&data.images.data()[i * img_len..(i + 1) * img_len]).unwrap(),
        );
    }
    let batched = net.forward_batch_threaded(&data.images, threads.max(2)).unwrap();
    assert_eq!(batched.data(), &reference[..], "GEMM/direct parity");

    let s_direct = bench("direct conv, per image", 1, iters, || {
        for i in 0..n {
            std::hint::black_box(
                net.forward(&data.images.data()[i * img_len..(i + 1) * img_len])
                    .unwrap(),
            );
        }
    });

    let mut scratch = Scratch::for_net(&net, n, threads);
    let mut out = vec![0f32; n * nc];
    let s_gemm1 = bench("GEMM batch, 1 thread", 1, iters, || {
        net.forward_batch_into(&data.images, &mut scratch, 1, &mut out).unwrap();
        std::hint::black_box(&out);
    });
    let s_gemmt = bench(&format!("GEMM batch, {threads} threads"), 1, iters, || {
        net.forward_batch_into(&data.images, &mut scratch, threads, &mut out).unwrap();
        std::hint::black_box(&out);
    });

    let ips_direct = s_direct.throughput(n as f64);
    let ips_gemm1 = s_gemm1.throughput(n as f64);
    let ips_gemmt = s_gemmt.throughput(n as f64);
    let speedup_1t = ips_gemm1 / ips_direct.max(1e-12);
    let speedup_mt = ips_gemmt / ips_direct.max(1e-12);

    let mut t = Table::new(
        &format!("integer engine throughput (batch {n}, {} MMAC/img)",
            net.macs_per_image() / 1_000_000),
        &["path", "ms/batch", "img/s", "speedup"],
    );
    for (s, ips, sp) in [
        (&s_direct, ips_direct, 1.0),
        (&s_gemm1, ips_gemm1, speedup_1t),
        (&s_gemmt, ips_gemmt, speedup_mt),
    ] {
        t.row(vec![
            s.name.clone(),
            format!("{:.2}", s.mean_ms),
            format!("{ips:.0}"),
            format!("{sp:.2}x"),
        ]);
    }
    println!("{}", t.render());

    let json = format!(
        "{{\n  \"bench\": \"engine_throughput\",\n  \"arch\": \"{}\",\n  \
         \"batch\": {n},\n  \"threads\": {threads},\n  \"macs_per_image\": {},\n  \
         \"direct_img_per_s\": {ips_direct:.2},\n  \
         \"gemm_1t_img_per_s\": {ips_gemm1:.2},\n  \
         \"gemm_mt_img_per_s\": {ips_gemmt:.2},\n  \
         \"speedup_gemm_1t\": {speedup_1t:.3},\n  \
         \"speedup_gemm_mt\": {speedup_mt:.3}\n}}\n",
        spec.name,
        net.macs_per_image(),
    );
    // cargo runs bench executables with cwd = the package root (rust/);
    // anchor the report at the workspace root where CI picks it up
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_engine.json");
    std::fs::write(&path, &json).expect("write BENCH_engine.json");
    println!("wrote {}", path.display());

    // FXP_BENCH_ASSERT=1 gates at the committed perf-trajectory floor
    // (BENCH_baseline.json: engine_throughput.min_speedup_gemm_1t); a
    // numeric value sets the floor directly (e.g. FXP_BENCH_ASSERT=4
    // for the paper acceptance bar on a quiet box)
    if let Ok(v) = std::env::var("FXP_BENCH_ASSERT") {
        let floor: f64 = v.parse().ok().filter(|&f| f > 1.0).unwrap_or_else(
            || baseline_floor("engine_throughput", "min_speedup_gemm_1t", 2.0),
        );
        assert!(
            speedup_1t >= floor,
            "batched GEMM (1 thread) only {speedup_1t:.2}x the per-image \
             direct path (need >= {floor}x)"
        );
        println!(
            "FXP_BENCH_ASSERT ok: single-thread GEMM speedup {speedup_1t:.2}x \
             (floor {floor}x)"
        );
    }
}
