//! Batched integer-GEMM engine throughput vs the retained direct
//! per-image reference path, on the CIFAR-shaped fixture net (offline:
//! no artifacts needed) -- plus the SIMD dispatch win: the same Q8 net
//! built on the scalar facade vs the auto-detected kernels, after a
//! bit-identity guard between the two.  Writes `BENCH_engine.json` for
//! CI artifact upload and asserts the speedup floors under
//! `FXP_BENCH_ASSERT`.
//!
//! Scale via:
//! * `FXP_BENCH_ENGINE_N`       -- batch size (default 32)
//! * `FXP_BENCH_ENGINE_ITERS`   -- timed iterations per case (default 10)
//! * `FXP_BENCH_ENGINE_THREADS` -- worker count for the threaded case
//!   (default: all cores)
//! * `FXP_BENCH_ASSERT`         -- if set, gate against the
//!   BENCH_baseline.json floors: on SIMD hosts the dispatched GEMM must
//!   beat the direct path by `min_speedup_gemm_1t_simd` and the scalar
//!   facade by `min_simd_speedup_q8`; scalar-only hosts gate the legacy
//!   `min_speedup_gemm_1t`

use fxpnet::bench::fixtures::{baseline_floor, env_usize, int_engine_fixture};
use fxpnet::bench::{bench, Table};
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::{FixedPointNet, Isa, Kernels, Scratch};

fn main() {
    fxpnet::util::logging::init();
    let n = env_usize("FXP_BENCH_ENGINE_N", 32);
    let iters = env_usize("FXP_BENCH_ENGINE_ITERS", 10);
    let threads = env_usize(
        "FXP_BENCH_ENGINE_THREADS",
        std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1),
    );

    let (spec, params, nq) = int_engine_fixture(8, 42).expect("fixture");
    let in_fmt = QFormat::new(16, 14).unwrap();
    let net = FixedPointNet::build(&spec, &params, &nq, in_fmt).expect("build");
    let net_scalar = FixedPointNet::build_with_kernels(
        &spec,
        &params,
        &nq,
        in_fmt,
        Kernels::for_isa(Isa::Scalar),
    )
    .expect("build scalar");
    let simd = net.kernels().isa() != Isa::Scalar;
    println!(
        "kernel dispatch: {} (scalar comparison net alongside)",
        net.kernels().name()
    );
    let data = Dataset::generate(n, 32, 32, 7);
    let img_len = 32 * 32 * 3;
    let nc = net.num_classes();

    // parity guard: every timed case must compute the same logits, and
    // the dispatched kernels must match the scalar facade bit for bit
    let mut reference = Vec::with_capacity(n * nc);
    for i in 0..n {
        reference.extend(
            net.forward(&data.images.data()[i * img_len..(i + 1) * img_len]).unwrap(),
        );
    }
    let batched = net.forward_batch_threaded(&data.images, threads.max(2)).unwrap();
    assert_eq!(batched.data(), &reference[..], "GEMM/direct parity");
    let scalar_logits = net_scalar.forward_batch_threaded(&data.images, 1).unwrap();
    assert_eq!(
        scalar_logits.data(),
        &reference[..],
        "scalar-facade / dispatched-kernel bit parity"
    );

    let s_direct = bench("direct conv, per image", 1, iters, || {
        for i in 0..n {
            std::hint::black_box(
                net.forward(&data.images.data()[i * img_len..(i + 1) * img_len])
                    .unwrap(),
            );
        }
    });

    let mut scratch = Scratch::for_net(&net, n, threads);
    let mut out = vec![0f32; n * nc];
    let s_scalar1 = bench("GEMM batch, 1 thread, scalar kernels", 1, iters, || {
        net_scalar
            .forward_batch_into(&data.images, &mut scratch, 1, &mut out)
            .unwrap();
        std::hint::black_box(&out);
    });
    let s_gemm1 = bench(
        &format!("GEMM batch, 1 thread, {} kernels", net.kernels().name()),
        1,
        iters,
        || {
            net.forward_batch_into(&data.images, &mut scratch, 1, &mut out).unwrap();
            std::hint::black_box(&out);
        },
    );
    let s_gemmt = bench(&format!("GEMM batch, {threads} threads"), 1, iters, || {
        net.forward_batch_into(&data.images, &mut scratch, threads, &mut out).unwrap();
        std::hint::black_box(&out);
    });

    let ips_direct = s_direct.throughput(n as f64);
    let ips_scalar1 = s_scalar1.throughput(n as f64);
    let ips_gemm1 = s_gemm1.throughput(n as f64);
    let ips_gemmt = s_gemmt.throughput(n as f64);
    let speedup_1t = ips_gemm1 / ips_direct.max(1e-12);
    let speedup_mt = ips_gemmt / ips_direct.max(1e-12);
    // the dispatch win on this Q8 cell: dispatched kernels vs the scalar
    // facade, same engine, same thread count (1.0 on scalar-only hosts)
    let simd_speedup_q8 = ips_gemm1 / ips_scalar1.max(1e-12);

    let mut t = Table::new(
        &format!("integer engine throughput (batch {n}, {} MMAC/img)",
            net.macs_per_image() / 1_000_000),
        &["path", "ms/batch", "img/s", "speedup"],
    );
    for (s, ips, sp) in [
        (&s_direct, ips_direct, 1.0),
        (&s_scalar1, ips_scalar1, ips_scalar1 / ips_direct.max(1e-12)),
        (&s_gemm1, ips_gemm1, speedup_1t),
        (&s_gemmt, ips_gemmt, speedup_mt),
    ] {
        t.row(vec![
            s.name.clone(),
            format!("{:.2}", s.mean_ms),
            format!("{ips:.0}"),
            format!("{sp:.2}x"),
        ]);
    }
    println!("{}", t.render());
    println!(
        "SIMD dispatch win (Q8, 1 thread): {simd_speedup_q8:.2}x over the \
         scalar facade [{}]",
        net.kernels().name()
    );

    let json = format!(
        "{{\n  \"bench\": \"engine_throughput\",\n  \"arch\": \"{}\",\n  \
         \"batch\": {n},\n  \"threads\": {threads},\n  \"macs_per_image\": {},\n  \
         \"kernel_isa\": \"{}\",\n  \
         \"direct_img_per_s\": {ips_direct:.2},\n  \
         \"scalar_1t_img_per_s\": {ips_scalar1:.2},\n  \
         \"gemm_1t_img_per_s\": {ips_gemm1:.2},\n  \
         \"gemm_mt_img_per_s\": {ips_gemmt:.2},\n  \
         \"speedup_gemm_1t\": {speedup_1t:.3},\n  \
         \"speedup_gemm_mt\": {speedup_mt:.3},\n  \
         \"simd_speedup_q8\": {simd_speedup_q8:.3}\n}}\n",
        spec.name,
        net.macs_per_image(),
        net.kernels().name(),
    );
    // cargo runs bench executables with cwd = the package root (rust/);
    // anchor the report at the workspace root where CI picks it up
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_engine.json");
    std::fs::write(&path, &json).expect("write BENCH_engine.json");
    println!("wrote {}", path.display());

    // FXP_BENCH_ASSERT=1 gates at the committed perf-trajectory floors
    // (BENCH_baseline.json).  SIMD hosts gate the raised
    // min_speedup_gemm_1t_simd floor plus the dispatch win itself
    // (min_simd_speedup_q8); scalar-only hosts keep the legacy
    // min_speedup_gemm_1t floor.  A numeric value sets the direct-path
    // floor directly (e.g. FXP_BENCH_ASSERT=4 for the paper acceptance
    // bar on a quiet box).
    if let Ok(v) = std::env::var("FXP_BENCH_ASSERT") {
        let forced = v.parse::<f64>().ok().filter(|&f| f > 1.0);
        let floor = forced.unwrap_or_else(|| {
            if simd {
                baseline_floor("engine_throughput", "min_speedup_gemm_1t_simd", 2.5)
            } else {
                baseline_floor("engine_throughput", "min_speedup_gemm_1t", 2.0)
            }
        });
        assert!(
            speedup_1t >= floor,
            "batched GEMM (1 thread) only {speedup_1t:.2}x the per-image \
             direct path (need >= {floor}x)"
        );
        println!(
            "FXP_BENCH_ASSERT ok: single-thread GEMM speedup {speedup_1t:.2}x \
             (floor {floor}x)"
        );
        if simd {
            let q8_floor =
                baseline_floor("engine_throughput", "min_simd_speedup_q8", 1.5);
            assert!(
                simd_speedup_q8 >= q8_floor,
                "{} kernels only {simd_speedup_q8:.2}x the scalar facade on \
                 the Q8 cell (need >= {q8_floor}x)",
                net.kernels().name()
            );
            println!(
                "FXP_BENCH_ASSERT ok: Q8 SIMD dispatch win {simd_speedup_q8:.2}x \
                 (floor {q8_floor}x)"
            );
        }
    }
}
