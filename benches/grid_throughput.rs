//! Grid-sweep scaling: how the parallel work-queue engine behaves as the
//! worker count grows.
//!
//! Cells are synthetic but CPU-bound (seeded fixed-point quantization
//! rounds through the real `fixedpoint::vector` path), so the bench runs
//! in the offline build and isolates the pool/sharding overhead from
//! XLA compile/execute noise.  With 4 workers the sweep must complete
//! >= 2x faster than with 1 (the acceptance bar for the parallel
//! runner); expect near-linear scaling until cells outnumber cores.
//!
//! Scale via:
//! * `FXP_BENCH_CELL_N`      -- floats quantized per round (default 200k)
//! * `FXP_BENCH_CELL_ROUNDS` -- rounds per cell (default 30)
//! * `FXP_BENCH_MAX_WORKERS` -- highest worker count tried (default 8)

use fxpnet::bench::fixtures::env_usize;
use fxpnet::bench::Table;
use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::grid::{self, CellJob, SweepOpts};
use fxpnet::coordinator::regimes::{CellEval, CellResult, Regime};
use fxpnet::coordinator::trainer::AbortReason;
use fxpnet::fixedpoint::vector::quantize_slice;
use fxpnet::fixedpoint::{QFormat, RoundMode};
use fxpnet::quant::policy::WidthSpec;
use fxpnet::util::rng::Rng;
use fxpnet::util::timer::Stopwatch;

/// Burn `rounds` rounds of real stochastic-rounding work and fold the
/// results into a deterministic pseudo-eval.
fn burn(seed: u64, n: usize, rounds: usize) -> fxpnet::Result<EvalResult> {
    let mut rng = Rng::new(seed);
    let fmt = QFormat::new(8, 4)?;
    let mut xs: Vec<f32> = (0..n).map(|_| rng.uniform_in(-6.0, 6.0)).collect();
    let mut acc = 0.0f64;
    for _ in 0..rounds {
        quantize_slice(&mut xs, fmt, RoundMode::Stochastic, Some(&mut rng));
        acc += xs.iter().map(|&v| v as f64).sum::<f64>();
        // re-perturb so each round does fresh rounding work
        for v in xs.iter_mut() {
            *v += rng.uniform_in(-0.1, 0.1);
        }
    }
    Ok(EvalResult {
        n,
        top1_err: (acc.abs() % 1.0).min(0.999),
        top5_err: 0.0,
        mean_loss: acc.abs() % 10.0,
    })
}

fn synthetic_cell(job: &CellJob, n: usize, rounds: usize) -> fxpnet::Result<CellResult> {
    Ok(CellEval::Ok(burn(job.seed, n, rounds)?))
}

/// Divergence model for the early-abort comparison: the float-weight
/// column is doomed.  A full-budget sweep burns every round before
/// declaring those cells n/a; an early-abort sweep cuts them at
/// `abort_round` -- the wall-clock gap is what the abort policy buys.
fn doomed_cell(
    job: &CellJob,
    n: usize,
    rounds: usize,
    abort_round: Option<usize>,
) -> fxpnet::Result<CellResult> {
    if job.w != WidthSpec::Float {
        return Ok(CellEval::Ok(burn(job.seed, n, rounds)?));
    }
    let budget = abort_round.unwrap_or(rounds).min(rounds);
    burn(job.seed, n, budget)?;
    Ok(match abort_round {
        Some(step) => CellEval::Aborted { reason: AbortReason::NanLoss, step },
        None => CellEval::Na,
    })
}

fn timed_doomed_sweep(
    workers: usize,
    n: usize,
    rounds: usize,
    abort_round: Option<usize>,
) -> (f64, usize) {
    let sw = Stopwatch::start();
    let out = grid::run_sweep_with(
        Regime::Vanilla,
        "bench",
        42,
        &SweepOpts { workers, ..Default::default() },
        |_| Ok(()),
        |_, job| doomed_cell(job, n, rounds, abort_round),
    )
    .expect("sweep");
    assert!(out.is_complete());
    let aborted = out
        .grid
        .outcomes
        .iter()
        .flatten()
        .filter(|c| matches!(c.eval, CellEval::Aborted { .. }))
        .count();
    (sw.elapsed().as_secs_f64() * 1e3, aborted)
}

fn timed_sweep(workers: usize, n: usize, rounds: usize) -> (f64, usize) {
    let sw = Stopwatch::start();
    let out = grid::run_sweep_with(
        Regime::Vanilla,
        "bench",
        42,
        &SweepOpts { workers, ..Default::default() },
        |_| Ok(()),
        |_, job| synthetic_cell(job, n, rounds),
    )
    .expect("sweep");
    assert!(out.is_complete());
    (sw.elapsed().as_secs_f64() * 1e3, out.pool.workers)
}

fn main() {
    fxpnet::util::logging::init();
    let n = env_usize("FXP_BENCH_CELL_N", 200_000);
    let rounds = env_usize("FXP_BENCH_CELL_ROUNDS", 30);
    let max_workers = env_usize("FXP_BENCH_MAX_WORKERS", 8);
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!(
        "grid throughput: 16 synthetic cells x {rounds} rounds x {n} floats, \
         {cores} cores"
    );

    // warm-up (page in buffers, settle the allocator)
    let _ = timed_sweep(1, n / 4, 2);

    let mut t = Table::new(
        "Grid sweep scaling (16 cells)",
        &["workers", "ms", "speedup", "efficiency"],
    );
    let mut base_ms = 0.0f64;
    let mut w = 1usize;
    let mut speedup_at_4 = 0.0f64;
    while w <= max_workers {
        let (ms, used) = timed_sweep(w, n, rounds);
        if w == 1 {
            base_ms = ms;
        }
        let speedup = base_ms / ms;
        if w == 4 {
            speedup_at_4 = speedup;
        }
        t.row(vec![
            format!("{used}"),
            format!("{ms:.1}"),
            format!("{speedup:.2}x"),
            format!("{:.0}%", 100.0 * speedup / used as f64),
        ]);
        w *= 2;
    }
    println!("{}", t.render());

    // early-abort payoff: same grid, the 4 float-weight cells doomed;
    // the full-budget run burns every round to n/a, the abort run cuts
    // them at 1/8 of the budget (what the stability policy does to a
    // NaN-loss cell almost immediately in real sweeps)
    let workers = 4.min(max_workers.max(1));
    let (full_ms, full_aborts) = timed_doomed_sweep(workers, n, rounds, None);
    let abort_at = (rounds / 8).max(1);
    let (abort_ms, aborts) =
        timed_doomed_sweep(workers, n, rounds, Some(abort_at));
    assert_eq!(full_aborts, 0);
    assert_eq!(aborts, 4, "the doomed float-weight column");
    let mut t2 = Table::new(
        "Early abort vs full budget (16 cells, 4 doomed)",
        &["policy", "ms", "aborted cells", "sweep speedup"],
    );
    t2.row(vec![
        "full budget".into(),
        format!("{full_ms:.1}"),
        "0".into(),
        "1.00x".into(),
    ]);
    t2.row(vec![
        format!("abort @ round {abort_at}"),
        format!("{abort_ms:.1}"),
        format!("{aborts}"),
        format!("{:.2}x", full_ms / abort_ms.max(1e-9)),
    ]);
    println!("{}", t2.render());

    if speedup_at_4 > 0.0 {
        println!(
            "4-worker speedup: {speedup_at_4:.2}x (acceptance bar: >= 2x on \
             a >= 4-core machine)"
        );
        // enforce the bar when asked (CI sets FXP_BENCH_ASSERT=1); only
        // meaningful where 4 workers can actually run in parallel
        if std::env::var("FXP_BENCH_ASSERT").is_ok() && cores >= 4 && speedup_at_4 < 2.0
        {
            eprintln!(
                "FAIL: 4-worker speedup {speedup_at_4:.2}x < 2x on a \
                 {cores}-core machine"
            );
            std::process::exit(1);
        }
    }
}
