//! Offline stand-in for the `xla` (PJRT bindings) crate.
//!
//! The container this repo builds in has no XLA runtime and no network,
//! so this path crate supplies the subset of the real crate's API that
//! fxpnet touches:
//!
//! * [`Literal`] is **fully functional**: host buffers round-trip through
//!   it bit-for-bit (`runtime/literal.rs` unit tests exercise this), so
//!   everything up to the device boundary behaves exactly as with the
//!   real crate.
//! * Program loading/compilation ([`HloModuleProto`], [`XlaComputation`],
//!   [`PjRtClient::compile`]) succeeds structurally, but
//!   [`PjRtLoadedExecutable::execute`] returns an [`Error`]: the stub
//!   cannot run HLO.  Engine-dependent integration tests detect the
//!   missing `artifacts/` directory and skip themselves.
//!
//! Swapping the real bindings back in is a one-line change in
//! `rust/Cargo.toml` (point the `xla` dependency at the real crate); no
//! source file in fxpnet needs to change.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`'s role (message-only here).
#[derive(Clone, Debug)]
pub struct Error(pub String);

impl Error {
    fn msg(s: impl Into<String>) -> Error {
        Error(s.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types fxpnet uses (the real crate has many more).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    F32,
    S32,
}

impl ElementType {
    fn byte_size(self) -> usize {
        4
    }
}

mod sealed {
    pub trait Sealed {}
    impl Sealed for f32 {}
    impl Sealed for i32 {}
}

/// Scalar types storable in stub literals.
pub trait NativeType: Copy + sealed::Sealed {
    const TY: ElementType;
    fn read(bytes: &[u8]) -> Self;
    fn write(self, out: &mut Vec<u8>);
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn read(bytes: &[u8]) -> f32 {
        f32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn read(bytes: &[u8]) -> i32 {
        i32::from_le_bytes([bytes[0], bytes[1], bytes[2], bytes[3]])
    }
    fn write(self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.to_le_bytes());
    }
}

/// A host-side typed buffer with a shape; the only data carrier crossing
/// the (stub) device boundary.
#[derive(Clone, Debug)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    data: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = shape.iter().product();
        if n * ty.byte_size() != data.len() {
            return Err(Error::msg(format!(
                "literal shape {shape:?} needs {} bytes, got {}",
                n * ty.byte_size(),
                data.len()
            )));
        }
        Ok(Literal { ty, shape: shape.to_vec(), data: data.to_vec() })
    }

    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        Ok(self.data.chunks_exact(4).map(T::read).collect())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        if self.ty != T::TY {
            return Err(Error::msg(format!(
                "literal is {:?}, requested {:?}",
                self.ty,
                T::TY
            )));
        }
        if self.data.len() < 4 {
            return Err(Error::msg("empty literal"));
        }
        Ok(T::read(&self.data[..4]))
    }

    /// The real crate unpacks tuple literals returned by executables;
    /// stub literals are never tuples because the stub never executes.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::msg("stub literal is not a tuple"))
    }
}

/// Parsed HLO module (the stub only checks the file is readable).
pub struct HloModuleProto {
    #[allow(dead_code)]
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error::msg(format!("read {}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }
}

/// Computation handle (opaque in the stub).
pub struct XlaComputation {}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {}
    }
}

const NO_EXEC: &str = "offline `xla` stub cannot execute programs; point the \
                       `xla` dependency in rust/Cargo.toml at the real PJRT \
                       bindings to run compiled artifacts";

/// CPU client handle.  Construction succeeds (it is just a handle);
/// execution of compiled programs does not.
pub struct PjRtClient {}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient {})
    }

    pub fn platform_name(&self) -> String {
        "stub-cpu".to_string()
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable {})
    }
}

/// Compiled executable handle; `execute` always errors in the stub.
pub struct PjRtLoadedExecutable {}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::msg(NO_EXEC))
    }
}

/// Device buffer handle; never constructed by the stub.
pub struct PjRtBuffer {}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::msg(NO_EXEC))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trip_f32() {
        let xs = [1.0f32, -2.5, 3.25];
        let mut bytes = Vec::new();
        for &x in &xs {
            x.write(&mut bytes);
        }
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.element_count(), 3);
        assert_eq!(lit.to_vec::<f32>().unwrap(), xs);
        let first: f32 = lit.get_first_element().unwrap();
        assert_eq!(first, 1.0);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_round_trip_i32() {
        let xs = [7i32, -9, i32::MAX];
        let mut bytes = Vec::new();
        for &x in &xs {
            x.write(&mut bytes);
        }
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::S32,
            &[3],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), xs);
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2],
            &[0u8; 4],
        )
        .is_err());
    }

    #[test]
    fn execution_unavailable() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.device_count(), 1);
        let exe = client.compile(&XlaComputation::from_proto(&HloModuleProto {
            text: String::new(),
        }))
        .unwrap();
        let e = exe.execute::<Literal>(&[]).unwrap_err();
        assert!(e.to_string().contains("stub"));
    }
}
