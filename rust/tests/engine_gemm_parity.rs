//! Golden bit-parity: the batched GEMM engine (`forward_batch_into` /
//! `forward_batch_threaded`) must produce logits *bit-identical* to the
//! retained direct-convolution reference path (`forward`), on random
//! nets across formats {Q4, Q8, Q16} x batch sizes {1, 7, 32} x thread
//! counts {1, 4}.
//!
//! This is stronger than the float-path parity in inference_parity.rs:
//! both paths here are pure integer, so i64 accumulation is exact and
//! order-free and the two implementations must agree in every bit --
//! any deviation is a bug, not roundoff.  Runs in the offline build (no
//! artifacts needed).

use std::collections::BTreeMap;

use fxpnet::bench::fixtures::{int_engine_cell, int_engine_fixture};
use fxpnet::coordinator::evaluator::evaluate_int;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::{FixedPointNet, Scratch};
use fxpnet::model::manifest::ArchSpec;

/// Small conv/pool/fc arch (8x8x3 -> conv8 -> pool -> fc10) so the
/// direct reference stays fast across the whole grid.
fn small_arch() -> ArchSpec {
    ArchSpec {
        name: "parity-net".into(),
        input: [8, 8, 3],
        num_classes: 10,
        num_layers: 2,
        train_batch: 8,
        eval_batch: 8,
        layers: vec![
            ("conv".into(), 8),
            ("pool".into(), 0),
            ("fc".into(), 10),
        ],
        params: vec![
            ("l0.w".into(), vec![3, 3, 3, 8]),
            ("l0.b".into(), vec![8]),
            ("l1.w".into(), vec![4 * 4 * 8, 10]),
            ("l1.b".into(), vec![10]),
        ],
        artifacts: BTreeMap::new(),
    }
}

fn build_net(spec: &ArchSpec, bits: u8, seed: u64) -> FixedPointNet {
    let (params, nq) = int_engine_cell(spec, bits, seed).unwrap();
    FixedPointNet::build(spec, &params, &nq, QFormat::new(16, 14).unwrap()).unwrap()
}

/// Direct-path logits, one image at a time.
fn reference_logits(net: &FixedPointNet, images: &fxpnet::tensor::TensorF) -> Vec<f32> {
    let n = images.shape()[0];
    let img_len = images.len() / n;
    let mut out = Vec::with_capacity(n * 10);
    for i in 0..n {
        out.extend(net.forward(&images.data()[i * img_len..(i + 1) * img_len]).unwrap());
    }
    out
}

#[test]
fn gemm_batch_bit_identical_to_direct_reference() {
    let spec = small_arch();
    let full = Dataset::generate(32, 8, 8, 99);
    for (bi, &bits) in [4u8, 8, 16].iter().enumerate() {
        let net = build_net(&spec, bits, 1000 + bi as u64);
        for &batch in &[1usize, 7, 32] {
            let rows: Vec<usize> = (0..batch).collect();
            let images = full.images.gather_rows(&rows).unwrap();
            let want = reference_logits(&net, &images);
            for &threads in &[1usize, 4] {
                let got = net.forward_batch_threaded(&images, threads).unwrap();
                assert_eq!(got.shape(), &[batch, 10]);
                assert_eq!(
                    got.data(),
                    &want[..],
                    "bits={bits} batch={batch} threads={threads}"
                );
            }
        }
    }
}

#[test]
fn gemm_scratch_reuse_is_stable() {
    // a warm scratch reused across different batch sizes must not change
    // results (stale buffer contents are never read)
    let spec = small_arch();
    let full = Dataset::generate(32, 8, 8, 7);
    let net = build_net(&spec, 8, 5);
    let mut scratch = Scratch::for_net(&net, 32, 4);
    for &batch in &[32usize, 1, 7, 32, 3] {
        let rows: Vec<usize> = (0..batch).collect();
        let images = full.images.gather_rows(&rows).unwrap();
        let want = reference_logits(&net, &images);
        let mut out = vec![0f32; batch * 10];
        net.forward_batch_into(&images, &mut scratch, 4, &mut out).unwrap();
        assert_eq!(out, want, "batch={batch}");
    }
}

#[test]
fn cifar_fixture_parity_spot_check() {
    // the bench fixture net (two convs, two pools, fc) at batch 4
    let (spec, params, nq) = int_engine_fixture(8, 42).unwrap();
    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap()).unwrap();
    let data = Dataset::generate(4, 32, 32, 11);
    let want = reference_logits(&net, &data.images);
    for &threads in &[1usize, 4] {
        let got = net.forward_batch_threaded(&data.images, threads).unwrap();
        assert_eq!(got.data(), &want[..], "threads={threads}");
    }
}

#[test]
fn evaluate_int_is_thread_invariant() {
    let (spec, params, nq) = int_engine_fixture(8, 3).unwrap();
    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap()).unwrap();
    let data = Dataset::generate(16, 32, 32, 21);
    let e1 = evaluate_int(&net, &data, 1).unwrap();
    let e4 = evaluate_int(&net, &data, 4).unwrap();
    assert_eq!(e1, e4);
    assert_eq!(e1.n, 16);
    assert!((0.0..=1.0).contains(&e1.top1_err));
    assert!(e1.mean_loss.is_finite());
}

#[test]
fn batch_shape_errors() {
    let spec = small_arch();
    let net = build_net(&spec, 8, 2);
    // wrong image size
    let bad = fxpnet::tensor::Tensor::from_vec(&[2, 4, 4, 3], vec![0f32; 96]).unwrap();
    assert!(net.forward_batch(&bad).is_err());
    // wrong logit buffer
    let ok = Dataset::generate(2, 8, 8, 1);
    let mut scratch = Scratch::new();
    let mut out = vec![0f32; 7];
    assert!(net
        .forward_batch_into(&ok.images, &mut scratch, 1, &mut out)
        .is_err());
}
