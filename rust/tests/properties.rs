//! Property tests over the testutil harness: the fixed-point invariants
//! the whole stack rests on, randomised across formats/shapes/seeds.

use fxpnet::fixedpoint::vector::{
    quantize_slice, quantize_slice_counted, quantized, sqnr_db,
};
use fxpnet::fixedpoint::{Fx, QFormat, RoundMode};
use fxpnet::inference::ops;
use fxpnet::quant::calib::{sqnr_optimal_empirical, CalibMethod, LayerStats};
use fxpnet::testutil::{check, gen};
use fxpnet::util::rng::Rng;

#[test]
fn prop_quantize_idempotent() {
    check("q(q(x)) == q(x)", 200, |rng| {
        let fmt = gen::qformat(rng);
        let n = gen::len(rng, 200);
        let xs = gen::normal_vec(rng, n, 8.0);
        let q1 = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        let q2 = quantized(&q1, fmt, RoundMode::NearestHalfUp, None);
        if q1 != q2 {
            return Err(format!("not idempotent for {fmt}"));
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_bounded_error_in_range() {
    check("|x - q(x)| <= step/2 for in-range x", 200, |rng| {
        let fmt = gen::qformat(rng);
        let half_range = fmt.max_value().min(-fmt.min_value()) * 0.9;
        if half_range <= 0.0 {
            return Ok(());
        }
        let xs = gen::uniform_vec(rng, 100, -half_range, half_range);
        let q = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        for (&x, &xq) in xs.iter().zip(&q) {
            if (x - xq).abs() > fmt.step() * 0.5 + 1e-6 {
                return Err(format!("{fmt}: x={x} q={xq}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_monotone() {
    check("x <= y => q(x) <= q(y)", 100, |rng| {
        let fmt = gen::qformat(rng);
        let mut xs = gen::normal_vec(rng, 64, 16.0);
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let q = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        for w in q.windows(2) {
            if w[1] < w[0] {
                return Err(format!("{fmt}: {} > {}", w[0], w[1]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_quantize_saturates_to_format_bounds() {
    check("q(x) within [min_value, max_value]", 200, |rng| {
        let fmt = gen::qformat(rng);
        let xs = gen::normal_vec(rng, 100, 1e4);
        let q = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        for &v in &q {
            if v < fmt.min_value() - 1e-5 || v > fmt.max_value() + 1e-5 {
                return Err(format!("{fmt}: {v}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_scalar_vector_engine_agree() {
    check("Fx scalar == vector path == engine encode", 100, |rng| {
        let fmt = gen::qformat(rng);
        let xs = gen::normal_vec(rng, 50, 8.0);
        let v = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        let e = ops::encode(&xs, fmt);
        for ((&x, &xv), &code) in xs.iter().zip(&v).zip(&e) {
            let fx = Fx::from_f32(x, fmt, RoundMode::NearestHalfUp, None);
            if fx.to_f32() != xv {
                return Err(format!("{fmt}: scalar {x} -> {} vs {xv}", fx.to_f32()));
            }
            if fx.code != code as i64 {
                return Err(format!("{fmt}: code {x} -> {} vs {code}", fx.code));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_stochastic_rounding_unbiased() {
    check("E[q_st(x)] ~ x", 20, |rng| {
        let fmt = QFormat::new(8, 3).unwrap();
        let x = rng.uniform_in(-10.0, 10.0);
        let n = 4000;
        let mut xs = vec![x; n];
        quantize_slice(&mut xs, fmt, RoundMode::Stochastic, Some(rng));
        let mean: f64 = xs.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
        let clipped = (x).clamp(fmt.min_value(), fmt.max_value()) as f64;
        if (mean - clipped).abs() > fmt.step() as f64 * 0.15 {
            return Err(format!("x={x} mean={mean}"));
        }
        Ok(())
    });
}

#[test]
fn prop_stochastic_rounding_unbiased_clt() {
    // E[floor(s + u)] = s exactly for u ~ U[0,1); with >= 10k draws per
    // format the sample mean must sit within a CLT band around s.  The
    // per-draw variance is frac(s)(1 - frac(s)) <= 1/4 (code units), so
    // 5 sigma = 5 * 0.5 / sqrt(n) -- a < 1e-6 false-failure rate per
    // case.
    check("E[round_stochastic(x)] -> x within CLT bounds", 15, |rng| {
        let fmt = gen::qformat(rng);
        // stay well inside the representable range: the bound only holds
        // where clipping cannot bite
        let span = fmt.max_value().min(-fmt.min_value()) * 0.5;
        if span <= 0.0 {
            return Ok(());
        }
        let x = rng.uniform_in(-span, span);
        let scaled = (x / fmt.step()) as f64;
        let n = 10_000;
        let mut sum = 0i64;
        for _ in 0..n {
            sum += RoundMode::Stochastic.round(scaled, Some(&mut *rng));
        }
        let mean = sum as f64 / n as f64;
        let tol = 5.0 * 0.5 / (n as f64).sqrt();
        if (mean - scaled).abs() > tol {
            return Err(format!(
                "{fmt}: scaled {scaled} mean {mean} (|diff| {} > {tol})",
                (mean - scaled).abs()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_nearest_half_up_tie_behaviour_matches_ref_py() {
    // ref.py documents: round_half_up(x) = floor(x + 0.5) -- ties go
    // toward +inf ("half up"), NOT half-away-from-zero and NOT the
    // half-to-even of jnp.round.  The Rust scalar path, the vector path,
    // and that documented semantics must agree exactly.
    for k in -50i64..=50 {
        let tie = k as f64 + 0.5;
        assert_eq!(
            RoundMode::NearestHalfUp.round(tie, None),
            k + 1,
            "tie at {tie}"
        );
        // just below / above the tie resolve to the neighbours
        assert_eq!(RoundMode::NearestHalfUp.round(tie - 1e-9, None), k);
        assert_eq!(RoundMode::NearestHalfUp.round(tie + 1e-9, None), k + 1);
    }
    // through the vector quantizer: Q(4,1) has step 0.5, so +/-0.25 are
    // exact ties; half-up sends both *up* (toward +inf)
    let fmt = QFormat::new(4, 1).unwrap();
    assert_eq!(fmt.step(), 0.5);
    let q = quantized(&[0.25, -0.25, 0.75, -0.75], fmt, RoundMode::NearestHalfUp, None);
    assert_eq!(q, vec![0.5, 0.0, 1.0, -0.5]);
    // numpy reference (ref.py quantize_ref): same inputs, same codes
    // np.clip(np.floor(x / 0.5 + 0.5), -8, 7) * 0.5 -> [0.5, 0.0, 1.0, -0.5]
}

#[test]
fn prop_more_bits_never_hurt_sqnr() {
    check("sqnr(bits+2) >= sqnr(bits)", 60, |rng| {
        let scale = 1.0 + rng.uniform() as f32 * 4.0;
        let xs = gen::normal_vec(rng, 800, scale);
        let bits = 3 + rng.below(10) as u8;
        let a = sqnr_optimal_empirical(bits, &xs).unwrap();
        let b = sqnr_optimal_empirical(bits + 2, &xs).unwrap();
        let sa = sqnr_db(&xs, a);
        let sb = sqnr_db(&xs, b);
        if sb + 1e-9 < sa {
            return Err(format!("bits {bits}: {sa} dB vs {}+2: {sb} dB", bits));
        }
        Ok(())
    });
}

#[test]
fn prop_calib_covers_or_beats_minmax() {
    check("sqnr calib >= minmax - 2dB (Gaussian-fit model error bound)", 60, |rng| {
        let scale = 0.2 + rng.uniform() as f32 * 3.0;
        let xs = gen::normal_vec(rng, 2000, scale);
        let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let meansq = xs.iter().map(|&x| x * x).sum::<f32>() / xs.len() as f32;
        let stats = LayerStats { absmax, meanabs: 0.0, meansq };
        let bits = 4 + rng.below(5) as u8;
        let mm = CalibMethod::MinMax.choose(bits, &stats).unwrap();
        let sq = CalibMethod::SqnrGaussian.choose(bits, &stats).unwrap();
        let d = sqnr_db(&xs, sq) - sqnr_db(&xs, mm);
        if d < -2.0 {
            return Err(format!("bits {bits}: sqnr pick worse by {d} dB"));
        }
        Ok(())
    });
}

#[test]
fn prop_requant_i64_matches_wideacc() {
    check("ops::requant_i64 == WideAcc::requantize", 200, |rng| {
        let fmt = gen::qformat(rng);
        let acc_frac = fmt.frac as i32 + rng.below(8) as i32;
        let acc_val = (rng.normal() * 1e6) as i64;
        let a = ops::requant_i64(acc_val, acc_frac, fmt) as i64;
        let wa = fxpnet::fixedpoint::value::WideAcc { acc: acc_val as i128, frac: acc_frac };
        let b = wa.requantize(fmt, RoundMode::NearestHalfUp, None).code;
        if a != b {
            return Err(format!("{fmt} acc={acc_val}@{acc_frac}: {a} vs {b}"));
        }
        Ok(())
    });
}

// ---- saturation counters (training-stability telemetry) -------------------

/// The counted quantizer's saturation tally is *exact* on a hand-built
/// fixture: values pushed past the format bounds are counted, everything
/// in range is not.  A Q4 accumulator fed max-magnitude codes is the
/// paper's canonical saturating case.
#[test]
fn saturation_counter_exact_on_saturating_fixture() {
    let fmt = QFormat::new(4, 2).unwrap(); // range [-2.0, 1.75], step 0.25
    // 3 saturating values (beyond either bound), 4 in-range ones
    let mut xs = vec![
        fmt.max_value() * 2.0,
        fmt.min_value() * 2.0,
        fmt.max_value() + fmt.step(),
        0.0,
        fmt.max_value(),
        fmt.min_value(),
        0.5,
    ];
    let expect = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
    let sat = quantize_slice_counted(&mut xs, fmt, RoundMode::NearestHalfUp, None);
    assert_eq!(sat, 3);
    // the counted path is a pure observer: identical codes out
    assert_eq!(xs, expect);

    // non-saturating fixture: zero, exactly
    let mut ys = vec![0.0f32, fmt.step(), -fmt.step(), fmt.max_value()];
    assert_eq!(
        quantize_slice_counted(&mut ys, fmt, RoundMode::NearestHalfUp, None),
        0
    );
}

/// Counter totals are invariant under batch splitting: counting a slice
/// equals the sum over any split of it (u64 addition is associative, so
/// the threaded per-chunk tallies can never drift from the serial one).
#[test]
fn prop_saturation_count_invariant_under_batch_split() {
    check("sat(xs) == sat(xs[..k]) + sat(xs[k..])", 200, |rng| {
        let fmt = gen::qformat(rng);
        let n = 1 + gen::len(rng, 300);
        let xs = gen::normal_vec(rng, n, fmt.max_value().abs().max(1.0) * 2.0);
        let mut whole = xs.clone();
        let total =
            quantize_slice_counted(&mut whole, fmt, RoundMode::NearestHalfUp, None);
        let k = rng.below(n + 1);
        let (mut lo, mut hi) = (xs[..k].to_vec(), xs[k..].to_vec());
        let split = quantize_slice_counted(&mut lo, fmt, RoundMode::NearestHalfUp, None)
            + quantize_slice_counted(&mut hi, fmt, RoundMode::NearestHalfUp, None);
        if total != split {
            return Err(format!("{fmt}: whole {total} != split {split} (k={k})"));
        }
        if lo != whole[..k] || hi != whole[k..] {
            return Err(format!("{fmt}: split changed the quantized values"));
        }
        Ok(())
    });
}

/// The counted stochastic quantizer consumes exactly the same RNG stream
/// as the uncounted one -- counting must never shift any rounding draw
/// (the delegation `quantize_slice -> quantize_slice_counted` is only
/// sound if this holds).
#[test]
fn prop_counted_stochastic_quantizer_preserves_rng_stream() {
    check("counted and uncounted stochastic paths agree", 100, |rng| {
        let fmt = gen::qformat(rng);
        let n = 1 + gen::len(rng, 400);
        let xs = gen::normal_vec(rng, n, 4.0);
        let seed = rng.next_u64();
        let (mut a, mut b) = (xs.clone(), xs.clone());
        let mut ra = Rng::new(seed);
        let mut rb = Rng::new(seed);
        quantize_slice(&mut a, fmt, RoundMode::Stochastic, Some(&mut ra));
        quantize_slice_counted(&mut b, fmt, RoundMode::Stochastic, Some(&mut rb));
        if a != b {
            return Err(format!("{fmt}: outputs diverged"));
        }
        // the streams end in the same state too
        if ra.next_u64() != rb.next_u64() {
            return Err(format!("{fmt}: RNG stream shifted"));
        }
        Ok(())
    });
}

/// The counted accumulator requantizers agree with their uncounted
/// originals on both the code and the saturation verdict, and the
/// verdict is exact: saturated iff the unclamped code left the range.
#[test]
fn prop_counted_requantizers_agree_and_flag_exactly() {
    use fxpnet::fixedpoint::value::WideAcc;
    check("requant_i64_counted == requantize_counted", 200, |rng| {
        let fmt = gen::qformat(rng);
        let acc_frac = fmt.frac as i32 + rng.below(8) as i32;
        // mix magnitudes so both saturating and in-range cases occur
        let scale = 10f64.powi(rng.below(9) as i32);
        let acc_val = (rng.normal() * scale) as i64;
        let (code_i, sat_i) = ops::requant_i64_counted(acc_val, acc_frac, fmt);
        let wa = WideAcc { acc: acc_val as i128, frac: acc_frac };
        let (fx, sat_w) = wa.requantize_counted(fmt, RoundMode::NearestHalfUp, None);
        if code_i as i64 != fx.code || sat_i != sat_w {
            return Err(format!(
                "{fmt} acc={acc_val}@{acc_frac}: ({code_i}, {sat_i}) vs \
                 ({}, {sat_w})",
                fx.code
            ));
        }
        // uncounted paths unchanged
        if ops::requant_i64(acc_val, acc_frac, fmt) != code_i
            || wa.requantize(fmt, RoundMode::NearestHalfUp, None).code != fx.code
        {
            return Err(format!("{fmt}: counted/uncounted code mismatch"));
        }
        // exactness: the flag means the clamp actually bit
        let sat_expected = fx.code == fmt.qmin() || fx.code == fmt.qmax();
        if sat_i && !sat_expected {
            return Err(format!(
                "{fmt}: flagged saturated but code {} is interior",
                fx.code
            ));
        }
        Ok(())
    });
    // hand-built Q4 accumulator at max magnitude: provably saturating
    let fmt = QFormat::new(4, 2).unwrap();
    let (code, sat) = ops::requant_i64_counted(i64::MAX / 2, fmt.frac as i32, fmt);
    assert!(sat);
    assert_eq!(code as i64, fmt.qmax());
    let (code, sat) = ops::requant_i64_counted(i64::MIN / 2, fmt.frac as i32, fmt);
    assert!(sat);
    assert_eq!(code as i64, fmt.qmin());
    let (_, sat) = ops::requant_i64_counted(1, fmt.frac as i32, fmt);
    assert!(!sat);
}

#[test]
fn prop_dataset_batches_deterministic() {
    check("dataset generation independent of count", 10, |rng| {
        let seed = rng.next_u64();
        let a = fxpnet::data::synth::Dataset::generate(8, 8, 8, seed);
        let b = fxpnet::data::synth::Dataset::generate(16, 8, 8, seed);
        if a.images.data() != &b.images.data()[..a.images.len()] {
            return Err("prefix mismatch".into());
        }
        Ok(())
    });
}
