//! Integration tests for the native training backend: gradient
//! correctness against central finite differences, bit-identical sweep
//! results for any worker count, the on-disk Proposal-1 seed-net cache,
//! and `grid merge --prune` refusal semantics.
//!
//! Everything here runs in the offline build -- no artifacts, no XLA.

use std::path::{Path, PathBuf};

use fxpnet::coordinator::backend::{Backend, BackendSpec, SessionCfg};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::grid::{
    self, p1_net_path, GridResult, ParallelGridRunner, SweepOpts,
};
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::shard;
use fxpnet::coordinator::trainer::run_session;
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::model::zoo;
use fxpnet::quant::calib::{CalibMethod, LayerStats};
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::train::{NativeBackend, NativeNet};
use fxpnet::util::rng::Rng;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fxp_train_native_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Exact bit pattern of a grid (None = n/a cell).
fn bits(g: &GridResult) -> Vec<Option<(usize, u64, u64, u64)>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| {
            c.eval.map(|e| {
                (
                    e.n,
                    e.top1_err.to_bits(),
                    e.top5_err.to_bits(),
                    e.mean_loss.to_bits(),
                )
            })
        })
        .collect()
}

// ---- gradient checks ------------------------------------------------------

/// Directional finite-difference check, one direction per parameter
/// tensor: perturbing tensor `t` by +-eps*d must move the loss by
/// ~eps*<grad_t, d>.  Covers every layer type of the walk (conv with
/// ReLU, max-pool routing, fc head, softmax cross-entropy).
#[test]
fn gradients_match_finite_differences_per_layer() {
    let spec = zoo::make_arch(
        "gradcheck",
        [8, 8, 3],
        &[("conv", 4), ("pool", 0), ("fc", 10)],
        4,
        4,
    );
    let n = 4usize;
    let data = Dataset::generate(n, 8, 8, 17);
    let images = data.images.data();
    let labels = data.labels.data();
    let params = ParamSet::init(&spec, 23);
    let nq = NetQuant::all_float(spec.num_layers);

    let mut net = NativeNet::build(&spec, n).unwrap();
    net.set_weights(&params, &nq).unwrap();
    net.forward(images, n).unwrap();
    net.loss(labels, n).unwrap();
    let upd = vec![1.0f32; spec.num_layers];
    let mut grads: Vec<Vec<f32>> =
        params.tensors.iter().map(|t| vec![0f32; t.len()]).collect();
    net.backward(labels, n, &upd, &mut grads).unwrap();

    let mut rng = Rng::new(5);
    let eps = 1e-2f32;
    for (ti, tensor) in params.tensors.iter().enumerate() {
        // random direction supported on this tensor only
        let dir: Vec<f32> =
            (0..tensor.len()).map(|_| rng.normal() as f32).collect();
        let analytic: f64 = grads[ti]
            .iter()
            .zip(&dir)
            .map(|(&g, &d)| g as f64 * d as f64)
            .sum();
        let mut loss_at = |sign: f32| -> f64 {
            let mut p = params.clone();
            for (w, &d) in p.tensors[ti].data_mut().iter_mut().zip(&dir) {
                *w += sign * eps * d;
            }
            net.set_weights(&p, &nq).unwrap();
            net.forward(images, n).unwrap();
            net.loss(labels, n).unwrap() as f64
        };
        let numeric = (loss_at(1.0) - loss_at(-1.0)) / (2.0 * eps as f64);
        let tol = 0.08 * analytic.abs().max(numeric.abs()) + 1e-3;
        assert!(
            (numeric - analytic).abs() <= tol,
            "tensor {ti} ({}): numeric {numeric:.6} vs analytic {analytic:.6}",
            params.names[ti]
        );
    }
}

// ---- determinism across workers ------------------------------------------

fn native_runner(variant: u64) -> ParallelGridRunner {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let base = ParamSet::init(&spec, 77 + variant);
    let train = Dataset::generate(64, 16, 16, 201);
    let eval = Dataset::generate(32, 16, 16, 202);
    let a_stats = backend.activation_stats("tiny", &base, &train, 1).unwrap();
    let cfg = RunCfg {
        finetune_steps: 3,
        phase_steps: 2,
        calib_batches: 1,
        workers: 1,
        ..RunCfg::default()
    };
    ParallelGridRunner {
        backend: BackendSpec::Native,
        arch: "tiny".to_string(),
        base,
        a_stats,
        train_data: train,
        eval_data: eval,
        cfg,
    }
}

/// The tentpole acceptance property: a *real* (non-synthetic) native
/// sweep produces bit-identical tables for 1, 2 and 4 workers -- which
/// implies every cell's `TrainOutcome.history` replayed bit-for-bit
/// (the evaluated table is a deterministic function of it).
#[test]
fn native_sweep_bit_identical_across_workers() {
    let runner = native_runner(0);
    let reference = runner
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 1, ..Default::default() })
        .unwrap();
    assert!(reference.is_complete());
    assert_eq!(reference.computed, 16);
    for workers in [2usize, 4] {
        let out = runner
            .run_sweep(Regime::Vanilla, &SweepOpts { workers, ..Default::default() })
            .unwrap();
        assert_eq!(
            bits(&reference.grid),
            bits(&out.grid),
            "native sweep differs between 1 and {workers} workers"
        );
    }
}

/// `--threads` (GEMM/gradient workers *inside* each session) must be as
/// invisible to the results as `--workers` is: the same real sweep --
/// threaded training steps *and* threaded integer-engine evaluation --
/// produces bit-identical tables for any per-session thread count.
#[test]
fn native_sweep_bit_identical_across_session_threads() {
    let runner = native_runner(0);
    let reference = runner
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 1, ..Default::default() })
        .unwrap();
    let mut threaded = native_runner(0);
    threaded.cfg.threads = 2;
    let out = threaded
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    assert_eq!(
        bits(&reference.grid),
        bits(&out.grid),
        "native sweep differs between --threads 1 and --threads 2"
    );
}

/// Two sessions with identical seeds replay the same loss history; the
/// stochastic-rounding stream is live (different session seeds diverge).
#[test]
fn native_history_pinned_for_fixed_seed() {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 11);
    let w_stats = params.weight_stats();
    let a_stats: Vec<LayerStats> = (0..spec.num_layers)
        .map(|i| LayerStats { absmax: 2.0 + i as f32, meanabs: 0.4, meansq: 0.6 })
        .collect();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(4),
        WidthSpec::Bits(8),
        &w_stats,
        &a_stats,
        CalibMethod::MinMax,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    let data = Dataset::generate(64, 16, 16, 7);
    let run = |session_seed: u64, threads: usize| {
        let mut s = backend
            .new_session(SessionCfg {
                arch: "tiny",
                params: &params,
                nq: &nq,
                upd: &upd,
                lr: 0.02,
                momentum: 0.9,
                data: data.clone(),
                loader: LoaderCfg {
                    batch: 16,
                    augment: true,
                    max_shift: 2,
                    seed: 3,
                },
                max_loss: 30.0,
                seed: session_seed,
                threads,
            })
            .unwrap();
        run_session(&mut *s, 8, 1).unwrap()
    };
    let a = run(1, 1);
    let b = run(1, 1);
    assert_eq!(a.history, b.history);
    let c = run(2, 1);
    assert_ne!(
        a.history, c.history,
        "stochastic weight-update rounding stream appears dead"
    );
    // the tentpole acceptance pin: --threads 1/2/4 replay byte-identical
    // loss histories (fixed GEMM/gradient accumulation order + pre-split
    // per-(step, layer) rounding streams)
    for threads in [2usize, 4] {
        let t = run(1, threads);
        assert_eq!(
            a.history, t.history,
            "loss history differs between 1 and {threads} train threads"
        );
    }
}

/// The paper's core claim at smoke scale: fixed-point training with
/// stochastic weight-update rounding makes progress instead of stalling.
#[test]
fn fixed_point_training_reduces_loss() {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 42);
    let train = Dataset::generate(128, 16, 16, 91);
    let a_stats = backend.activation_stats("tiny", &params, &train, 2).unwrap();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    let mut s = backend
        .new_session(SessionCfg {
            arch: "tiny",
            params: &params,
            nq: &nq,
            upd: &upd,
            lr: 0.03,
            momentum: 0.9,
            data: train,
            loader: LoaderCfg { batch: 16, augment: false, max_shift: 0, seed: 1 },
            max_loss: 30.0,
            seed: 13,
            threads: 2,
        })
        .unwrap();
    let out = run_session(&mut *s, 40, 1).unwrap();
    assert!(!out.diverged, "{:?}", out.history);
    let first = out.history[0].1;
    let last = out.tail_mean(5);
    assert!(
        last < first,
        "8-bit training made no progress: {first} -> {last}"
    );
}

// ---- Proposal-1 seed-net disk cache --------------------------------------

#[test]
fn p1_net_cache_round_trips_and_marks_divergence() {
    let dir = temp_dir("p1cache");
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 3);
    let w = WidthSpec::Bits(8);
    let fp = 0xDEAD_BEEFu64;

    // nothing cached yet
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp).is_none());
    // trained net round-trips
    grid::save_p1_net(&dir, "tiny", w, 42, fp, 8, &Some(params.clone())).unwrap();
    let back = grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp)
        .expect("cache miss after save")
        .expect("cached net read back as diverged");
    for (a, b) in back.tensors.iter().zip(&params.tensors) {
        assert_eq!(a.data(), b.data());
    }
    // a different width/seed/fingerprint is a different cache entry
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, WidthSpec::Bits(4), 42, fp)
        .is_none());
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 43, fp).is_none());
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp + 1).is_none());
    // divergence marker round-trips
    grid::save_p1_net(&dir, "tiny", WidthSpec::Bits(4), 42, fp, 8, &None).unwrap();
    assert!(matches!(
        grid::load_p1_net(&dir, "tiny", &spec.params, WidthSpec::Bits(4), 42, fp),
        Some(None)
    ));
    // a corrupt cache file is a miss (retrain), not an error
    std::fs::write(p1_net_path(&dir, "tiny", w, 42, fp), b"garbage").unwrap();
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp).is_none());
}

/// The cache key fingerprints everything the seed net depends on: a
/// different base net, step budget, or dataset is a different entry.
#[test]
fn p1_fingerprint_tracks_training_inputs() {
    let runner = native_runner(9);
    let fp = grid::p1_fingerprint(
        &runner.base,
        &runner.a_stats,
        &runner.cfg,
        &runner.train_data,
    );
    // stable
    assert_eq!(
        fp,
        grid::p1_fingerprint(
            &runner.base,
            &runner.a_stats,
            &runner.cfg,
            &runner.train_data
        )
    );
    // different base params
    let spec = NativeBackend::new().arch("tiny").unwrap();
    let other = ParamSet::init(&spec, 999);
    assert_ne!(
        fp,
        grid::p1_fingerprint(&other, &runner.a_stats, &runner.cfg, &runner.train_data)
    );
    // different step budget
    let mut cfg2 = runner.cfg.clone();
    cfg2.finetune_steps += 1;
    assert_ne!(
        fp,
        grid::p1_fingerprint(&runner.base, &runner.a_stats, &cfg2, &runner.train_data)
    );
    // different training set
    let other_data = Dataset::generate(64, 16, 16, 999);
    assert_ne!(
        fp,
        grid::p1_fingerprint(&runner.base, &runner.a_stats, &runner.cfg, &other_data)
    );
}

/// A Prop1 sweep with a cell cache persists its seed nets next to the
/// cache; a second (cold-cell, warm-seed-net) run reuses them and still
/// produces the bit-identical table.
#[test]
fn p1_nets_persist_beside_cell_cache_and_replay() {
    let runner = native_runner(1);
    // reference: no caching at all
    let reference = runner
        .run_sweep(Regime::Prop1, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();

    let dir = temp_dir("p1sweep");
    let opts = SweepOpts {
        workers: 2,
        cache_path: Some(dir.join("cache.json")),
        ..Default::default()
    };
    let first = runner.run_sweep(Regime::Prop1, &opts).unwrap();
    assert_eq!(bits(&reference.grid), bits(&first.grid));
    // seed nets for every fixed-point width are now on disk
    let fp = runner.p1_cache_fingerprint();
    for w in [WidthSpec::Bits(4), WidthSpec::Bits(8), WidthSpec::Bits(16)] {
        let p = p1_net_path(&dir, "tiny", w, runner.cfg.seed, fp);
        assert!(
            p.exists() || p.with_extension("na").exists(),
            "seed net not cached: {}",
            p.display()
        );
    }
    // the Float "seed net" is the base itself: no file
    assert!(
        !p1_net_path(&dir, "tiny", WidthSpec::Float, runner.cfg.seed, fp).exists()
    );

    // second run with a fresh cell cache but warm seed nets
    let opts2 = SweepOpts {
        workers: 2,
        cache_path: Some(dir.join("cache2.json")),
        ..Default::default()
    };
    let second = runner.run_sweep(Regime::Prop1, &opts2).unwrap();
    assert_eq!(bits(&reference.grid), bits(&second.grid));
}

// ---- grid merge --prune ---------------------------------------------------

fn synthetic_shards(dir: &Path, count: usize) -> Vec<PathBuf> {
    let base = dir.join("cache.json");
    (0..count)
        .map(|index| {
            let opts = SweepOpts {
                workers: 2,
                shard: Some((index, count)),
                cache_path: Some(base.clone()),
                split_cache: true,
                ..Default::default()
            };
            grid::run_sweep_with(
                Regime::Vanilla,
                "tiny",
                42,
                &opts,
                |_wid| Ok(()),
                |_, job| grid::synthetic_cell(job),
            )
            .unwrap();
            opts.cache_file().unwrap()
        })
        .collect()
}

#[test]
fn prune_removes_shard_caches_only_after_complete_merge() {
    let dir = temp_dir("prune");
    let files = synthetic_shards(&dir, 3);

    // incomplete union (one shard withheld): prune must refuse and
    // delete nothing
    let partial = shard::merge_files(&files[..2], None).unwrap();
    assert!(!partial.is_complete());
    let err = shard::prune_shard_inputs(&partial).unwrap_err();
    assert!(err.to_string().contains("refusing to prune"), "{err}");
    for f in &files {
        assert!(f.exists(), "refused prune deleted {}", f.display());
    }

    // complete union: prune deletes exactly the merged shard files
    let complete = shard::merge_files(&files, None).unwrap();
    assert!(complete.is_complete());
    let removed = shard::prune_shard_inputs(&complete).unwrap();
    assert_eq!(removed.len(), 3);
    for f in &files {
        assert!(!f.exists(), "prune left {}", f.display());
    }

    // whole-sweep caches (no shard header) are never prune targets
    let whole = dir.join("whole.json");
    complete.save(&whole).unwrap();
    let merged = shard::merge_files(&[whole.clone()], None).unwrap();
    assert!(merged.is_complete());
    assert!(merged.shard_inputs.is_empty());
    assert!(shard::prune_shard_inputs(&merged).unwrap().is_empty());
    assert!(whole.exists());
}
