//! Integration tests for the native training backend: gradient
//! correctness against central finite differences, bit-identical sweep
//! results for any worker count, the on-disk Proposal-1 seed-net cache,
//! and `grid merge --prune` refusal semantics.
//!
//! Everything here runs in the offline build -- no artifacts, no XLA.

use std::path::{Path, PathBuf};

use fxpnet::coordinator::backend::{Backend, BackendSpec, SessionCfg};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::grid::{
    self, p1_net_path, GridResult, ParallelGridRunner, SweepOpts,
};
use fxpnet::coordinator::regimes::{CellEval, Regime};
use fxpnet::coordinator::report;
use fxpnet::coordinator::shard;
use fxpnet::coordinator::trainer::{
    run_session, run_session_with, AbortPolicy, AbortReason, TrainSession,
};
use fxpnet::train::telemetry::TelemetryLog;
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::model::zoo;
use fxpnet::quant::calib::{CalibMethod, LayerStats};
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::train::{NativeBackend, NativeNet};
use fxpnet::util::rng::Rng;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fxp_train_native_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Exact bit pattern of a grid (None = n/a or aborted cell).
fn bits(g: &GridResult) -> Vec<Option<(usize, u64, u64, u64)>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| {
            c.eval.ok().map(|e| {
                (
                    e.n,
                    e.top1_err.to_bits(),
                    e.top5_err.to_bits(),
                    e.mean_loss.to_bits(),
                )
            })
        })
        .collect()
}

/// Full per-cell outcomes of a grid, abort provenance included.
fn evals(g: &GridResult) -> Vec<CellEval> {
    g.outcomes.iter().flatten().map(|c| c.eval).collect()
}

// ---- gradient checks ------------------------------------------------------

/// Directional finite-difference check, one direction per parameter
/// tensor: perturbing tensor `t` by +-eps*d must move the loss by
/// ~eps*<grad_t, d>.  Covers every layer type of the walk (conv with
/// ReLU, max-pool routing, fc head, softmax cross-entropy).
#[test]
fn gradients_match_finite_differences_per_layer() {
    let spec = zoo::make_arch(
        "gradcheck",
        [8, 8, 3],
        &[("conv", 4), ("pool", 0), ("fc", 10)],
        4,
        4,
    );
    let n = 4usize;
    let data = Dataset::generate(n, 8, 8, 17);
    let images = data.images.data();
    let labels = data.labels.data();
    let params = ParamSet::init(&spec, 23);
    let nq = NetQuant::all_float(spec.num_layers);

    let mut net = NativeNet::build(&spec, n).unwrap();
    net.set_weights(&params, &nq).unwrap();
    net.forward(images, n).unwrap();
    net.loss(labels, n).unwrap();
    let upd = vec![1.0f32; spec.num_layers];
    let mut grads: Vec<Vec<f32>> =
        params.tensors.iter().map(|t| vec![0f32; t.len()]).collect();
    net.backward(labels, n, &upd, &mut grads).unwrap();

    let mut rng = Rng::new(5);
    let eps = 1e-2f32;
    for (ti, tensor) in params.tensors.iter().enumerate() {
        // random direction supported on this tensor only
        let dir: Vec<f32> =
            (0..tensor.len()).map(|_| rng.normal() as f32).collect();
        let analytic: f64 = grads[ti]
            .iter()
            .zip(&dir)
            .map(|(&g, &d)| g as f64 * d as f64)
            .sum();
        let mut loss_at = |sign: f32| -> f64 {
            let mut p = params.clone();
            for (w, &d) in p.tensors[ti].data_mut().iter_mut().zip(&dir) {
                *w += sign * eps * d;
            }
            net.set_weights(&p, &nq).unwrap();
            net.forward(images, n).unwrap();
            net.loss(labels, n).unwrap() as f64
        };
        let numeric = (loss_at(1.0) - loss_at(-1.0)) / (2.0 * eps as f64);
        let tol = 0.08 * analytic.abs().max(numeric.abs()) + 1e-3;
        assert!(
            (numeric - analytic).abs() <= tol,
            "tensor {ti} ({}): numeric {numeric:.6} vs analytic {analytic:.6}",
            params.names[ti]
        );
    }
}

// ---- determinism across workers ------------------------------------------

fn native_runner(variant: u64) -> ParallelGridRunner {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let base = ParamSet::init(&spec, 77 + variant);
    let train = Dataset::generate(64, 16, 16, 201);
    let eval = Dataset::generate(32, 16, 16, 202);
    let a_stats = backend.activation_stats("tiny", &base, &train, 1).unwrap();
    let cfg = RunCfg {
        finetune_steps: 3,
        phase_steps: 2,
        calib_batches: 1,
        workers: 1,
        ..RunCfg::default()
    };
    ParallelGridRunner {
        backend: BackendSpec::Native,
        arch: "tiny".to_string(),
        base,
        a_stats,
        train_data: train,
        eval_data: eval,
        cfg,
    }
}

/// The tentpole acceptance property: a *real* (non-synthetic) native
/// sweep produces bit-identical tables for 1, 2 and 4 workers -- which
/// implies every cell's `TrainOutcome.history` replayed bit-for-bit
/// (the evaluated table is a deterministic function of it).
#[test]
fn native_sweep_bit_identical_across_workers() {
    let runner = native_runner(0);
    let reference = runner
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 1, ..Default::default() })
        .unwrap();
    assert!(reference.is_complete());
    assert_eq!(reference.computed, 16);
    for workers in [2usize, 4] {
        let out = runner
            .run_sweep(Regime::Vanilla, &SweepOpts { workers, ..Default::default() })
            .unwrap();
        assert_eq!(
            bits(&reference.grid),
            bits(&out.grid),
            "native sweep differs between 1 and {workers} workers"
        );
    }
}

/// `--threads` (GEMM/gradient workers *inside* each session) must be as
/// invisible to the results as `--workers` is: the same real sweep --
/// threaded training steps *and* threaded integer-engine evaluation --
/// produces bit-identical tables for any per-session thread count.
#[test]
fn native_sweep_bit_identical_across_session_threads() {
    let runner = native_runner(0);
    let reference = runner
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 1, ..Default::default() })
        .unwrap();
    let mut threaded = native_runner(0);
    threaded.cfg.threads = 2;
    let out = threaded
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    assert_eq!(
        bits(&reference.grid),
        bits(&out.grid),
        "native sweep differs between --threads 1 and --threads 2"
    );
}

/// Two sessions with identical seeds replay the same loss history; the
/// stochastic-rounding stream is live (different session seeds diverge).
#[test]
fn native_history_pinned_for_fixed_seed() {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 11);
    let w_stats = params.weight_stats();
    let a_stats: Vec<LayerStats> = (0..spec.num_layers)
        .map(|i| LayerStats { absmax: 2.0 + i as f32, meanabs: 0.4, meansq: 0.6 })
        .collect();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(4),
        WidthSpec::Bits(8),
        &w_stats,
        &a_stats,
        CalibMethod::MinMax,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    let data = Dataset::generate(64, 16, 16, 7);
    let run = |session_seed: u64, threads: usize| {
        let mut s = backend
            .new_session(SessionCfg {
                arch: "tiny",
                params: &params,
                nq: &nq,
                upd: &upd,
                lr: 0.02,
                momentum: 0.9,
                data: data.clone(),
                loader: LoaderCfg {
                    batch: 16,
                    augment: true,
                    max_shift: 2,
                    seed: 3,
                },
                max_loss: 30.0,
                seed: session_seed,
                threads,
            })
            .unwrap();
        run_session(&mut *s, 8, 1).unwrap()
    };
    let a = run(1, 1);
    let b = run(1, 1);
    assert_eq!(a.history, b.history);
    let c = run(2, 1);
    assert_ne!(
        a.history, c.history,
        "stochastic weight-update rounding stream appears dead"
    );
    // the tentpole acceptance pin: --threads 1/2/4 replay byte-identical
    // loss histories (fixed GEMM/gradient accumulation order + pre-split
    // per-(step, layer) rounding streams)
    for threads in [2usize, 4] {
        let t = run(1, threads);
        assert_eq!(
            a.history, t.history,
            "loss history differs between 1 and {threads} train threads"
        );
    }
}

/// The paper's core claim at smoke scale: fixed-point training with
/// stochastic weight-update rounding makes progress instead of stalling.
#[test]
fn fixed_point_training_reduces_loss() {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 42);
    let train = Dataset::generate(128, 16, 16, 91);
    let a_stats = backend.activation_stats("tiny", &params, &train, 2).unwrap();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    let mut s = backend
        .new_session(SessionCfg {
            arch: "tiny",
            params: &params,
            nq: &nq,
            upd: &upd,
            lr: 0.03,
            momentum: 0.9,
            data: train,
            loader: LoaderCfg { batch: 16, augment: false, max_shift: 0, seed: 1 },
            max_loss: 30.0,
            seed: 13,
            threads: 2,
        })
        .unwrap();
    let out = run_session(&mut *s, 40, 1).unwrap();
    assert!(!out.diverged, "{:?}", out.history);
    let first = out.history[0].1;
    let last = out.tail_mean(5);
    assert!(
        last < first,
        "8-bit training made no progress: {first} -> {last}"
    );
}

// ---- Proposal-1 seed-net disk cache --------------------------------------

#[test]
fn p1_net_cache_round_trips_and_marks_divergence() {
    let dir = temp_dir("p1cache");
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 3);
    let w = WidthSpec::Bits(8);
    let fp = 0xDEAD_BEEFu64;

    // nothing cached yet
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp).is_none());
    // trained net round-trips
    grid::save_p1_net(&dir, "tiny", w, 42, fp, 8, &Some(params.clone())).unwrap();
    let back = grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp)
        .expect("cache miss after save")
        .expect("cached net read back as diverged");
    for (a, b) in back.tensors.iter().zip(&params.tensors) {
        assert_eq!(a.data(), b.data());
    }
    // a different width/seed/fingerprint is a different cache entry
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, WidthSpec::Bits(4), 42, fp)
        .is_none());
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 43, fp).is_none());
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp + 1).is_none());
    // divergence marker round-trips
    grid::save_p1_net(&dir, "tiny", WidthSpec::Bits(4), 42, fp, 8, &None).unwrap();
    assert!(matches!(
        grid::load_p1_net(&dir, "tiny", &spec.params, WidthSpec::Bits(4), 42, fp),
        Some(None)
    ));
    // a corrupt cache file is a miss (retrain), not an error
    std::fs::write(p1_net_path(&dir, "tiny", w, 42, fp), b"garbage").unwrap();
    assert!(grid::load_p1_net(&dir, "tiny", &spec.params, w, 42, fp).is_none());
}

/// The cache key fingerprints everything the seed net depends on: a
/// different base net, step budget, or dataset is a different entry.
#[test]
fn p1_fingerprint_tracks_training_inputs() {
    let runner = native_runner(9);
    let fp = grid::p1_fingerprint(
        &runner.base,
        &runner.a_stats,
        &runner.cfg,
        &runner.train_data,
    );
    // stable
    assert_eq!(
        fp,
        grid::p1_fingerprint(
            &runner.base,
            &runner.a_stats,
            &runner.cfg,
            &runner.train_data
        )
    );
    // different base params
    let spec = NativeBackend::new().arch("tiny").unwrap();
    let other = ParamSet::init(&spec, 999);
    assert_ne!(
        fp,
        grid::p1_fingerprint(&other, &runner.a_stats, &runner.cfg, &runner.train_data)
    );
    // different step budget
    let mut cfg2 = runner.cfg.clone();
    cfg2.finetune_steps += 1;
    assert_ne!(
        fp,
        grid::p1_fingerprint(&runner.base, &runner.a_stats, &cfg2, &runner.train_data)
    );
    // different training set
    let other_data = Dataset::generate(64, 16, 16, 999);
    assert_ne!(
        fp,
        grid::p1_fingerprint(&runner.base, &runner.a_stats, &runner.cfg, &other_data)
    );
}

/// A Prop1 sweep with a cell cache persists its seed nets next to the
/// cache; a second (cold-cell, warm-seed-net) run reuses them and still
/// produces the bit-identical table.
#[test]
fn p1_nets_persist_beside_cell_cache_and_replay() {
    let runner = native_runner(1);
    // reference: no caching at all
    let reference = runner
        .run_sweep(Regime::Prop1, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();

    let dir = temp_dir("p1sweep");
    let opts = SweepOpts {
        workers: 2,
        cache_path: Some(dir.join("cache.json")),
        ..Default::default()
    };
    let first = runner.run_sweep(Regime::Prop1, &opts).unwrap();
    assert_eq!(bits(&reference.grid), bits(&first.grid));
    // seed nets for every fixed-point width are now on disk
    let fp = runner.p1_cache_fingerprint();
    for w in [WidthSpec::Bits(4), WidthSpec::Bits(8), WidthSpec::Bits(16)] {
        let p = p1_net_path(&dir, "tiny", w, runner.cfg.seed, fp);
        assert!(
            p.exists() || p.with_extension("na").exists(),
            "seed net not cached: {}",
            p.display()
        );
    }
    // the Float "seed net" is the base itself: no file
    assert!(
        !p1_net_path(&dir, "tiny", WidthSpec::Float, runner.cfg.seed, fp).exists()
    );

    // second run with a fresh cell cache but warm seed nets
    let opts2 = SweepOpts {
        workers: 2,
        cache_path: Some(dir.join("cache2.json")),
        ..Default::default()
    };
    let second = runner.run_sweep(Regime::Prop1, &opts2).unwrap();
    assert_eq!(bits(&reference.grid), bits(&second.grid));
}

// ---- training-stability telemetry + early abort ---------------------------

/// Open a tiny fine-tuning session at cell (w, a): real calibration
/// statistics, fixed seeds -- only `lr` and `threads` vary per test.
fn tiny_session(
    lr: f32,
    threads: usize,
    w: WidthSpec,
    a: WidthSpec,
) -> Box<dyn TrainSession> {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 11);
    let train = Dataset::generate(64, 16, 16, 7);
    let a_stats = backend.activation_stats("tiny", &params, &train, 1).unwrap();
    let nq = NetQuant::for_cell(
        w,
        a,
        &params.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    backend
        .new_session(SessionCfg {
            arch: "tiny",
            params: &params,
            nq: &nq,
            upd: &upd,
            lr,
            momentum: 0.9,
            data: train,
            loader: LoaderCfg { batch: 16, augment: true, max_shift: 2, seed: 3 },
            max_loss: 20.0,
            seed: 13,
            threads,
        })
        .unwrap()
}

/// The telemetry determinism pin: the full per-layer stats stream -- not
/// just the loss history -- serialises byte-identically for any
/// `--threads` count.
#[test]
fn telemetry_stream_bit_identical_across_threads() {
    let run = |threads: usize| {
        let mut s =
            tiny_session(0.02, threads, WidthSpec::Bits(4), WidthSpec::Bits(8));
        let mut tlog = TelemetryLog::default();
        let out = run_session_with(&mut *s, 8, 1, None, Some(&mut tlog)).unwrap();
        (out, tlog)
    };
    let (ref_out, ref_log) = run(1);
    assert!(!ref_out.diverged);
    assert_eq!(ref_log.len(), 8);
    // the stream carries real per-layer content: a quantized layer with
    // elements flowing through both quantizer families
    let probe = &ref_log.steps[0];
    assert!(probe.layers.iter().any(|l| l.quantized && l.n_w > 0));
    assert!(probe.layers.iter().any(|l| l.n_a > 0));
    assert!(probe.min_upd_to_step().is_some());
    let ref_json = ref_log.to_json().to_string();
    for threads in [2usize, 4] {
        let (out, tlog) = run(threads);
        assert_eq!(ref_out.history, out.history);
        assert_eq!(
            ref_json,
            tlog.to_json().to_string(),
            "telemetry stream differs between 1 and {threads} threads"
        );
    }
}

/// Telemetry is a pure observer: attaching a sink must not change what
/// the session trains (it consumes no RNG draws, writes no tensors).
#[test]
fn telemetry_never_perturbs_training() {
    let mut plain =
        tiny_session(0.02, 2, WidthSpec::Bits(4), WidthSpec::Bits(8));
    let silent = run_session(&mut *plain, 8, 1).unwrap();
    let mut observed =
        tiny_session(0.02, 2, WidthSpec::Bits(4), WidthSpec::Bits(8));
    let mut tlog = TelemetryLog::default();
    let loud =
        run_session_with(&mut *observed, 8, 1, None, Some(&mut tlog)).unwrap();
    assert_eq!(silent.history, loud.history);
    assert_eq!(tlog.len(), 8);
    for (h, s) in loud.history.iter().zip(&tlog.steps) {
        assert_eq!(h.1.to_bits(), s.loss.to_bits());
    }
}

/// A doomed session aborts with the same reason at the same step for
/// every thread count, and its telemetry is bit-identical to the
/// reference (no-policy) run over every step both executed.
#[test]
fn abort_decision_deterministic_and_prefix_identical() {
    let policy = AbortPolicy::default();
    let run = |threads: usize, policy: Option<&AbortPolicy>| {
        let mut s =
            tiny_session(1000.0, threads, WidthSpec::Float, WidthSpec::Float);
        let mut tlog = TelemetryLog::default();
        let out =
            run_session_with(&mut *s, 30, 1, policy, Some(&mut tlog)).unwrap();
        (out, tlog)
    };
    let (aborted, alog) = run(1, Some(&policy));
    let (reason, step) = aborted.aborted.expect("lr=1000 run did not abort");
    assert_eq!(reason, AbortReason::NanLoss);
    assert!(aborted.diverged);
    assert!(step < 30, "abort saved no steps");
    for threads in [2usize, 4] {
        let (out, tlog) = run(threads, Some(&policy));
        assert_eq!(out.aborted, Some((reason, step)));
        assert_eq!(
            alog.to_json().to_string(),
            tlog.to_json().to_string(),
            "abort-path telemetry differs between 1 and {threads} threads"
        );
    }
    // re-run with the policy off: the trajectory is untouched -- the
    // legacy divergence check stops at the very same step with the very
    // same stats, the outcome just loses its abort provenance
    let (full, flog) = run(1, None);
    assert!(full.diverged);
    assert_eq!(full.aborted, None);
    assert_eq!(aborted.history, full.history);
    assert!(flog.len() >= alog.len());
    for (i, st) in alog.steps.iter().enumerate() {
        assert_eq!(st, &flog.steps[i], "stats diverge at step {i}");
    }
}

/// Regression pin for the CI gate: the healthy `fxpnet train --gate`
/// configuration (the `fixed_point_training_reduces_loss` cell) never
/// trips the default abort predicates.
#[test]
fn healthy_gate_run_never_trips_default_abort_policy() {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 42);
    let train = Dataset::generate(128, 16, 16, 91);
    let a_stats = backend.activation_stats("tiny", &params, &train, 2).unwrap();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let upd = vec![1.0; spec.num_layers];
    let mut s = backend
        .new_session(SessionCfg {
            arch: "tiny",
            params: &params,
            nq: &nq,
            upd: &upd,
            lr: 0.03,
            momentum: 0.9,
            data: train,
            loader: LoaderCfg { batch: 16, augment: false, max_shift: 0, seed: 1 },
            max_loss: 30.0,
            seed: 13,
            threads: 2,
        })
        .unwrap();
    let policy = AbortPolicy::default();
    let mut tlog = TelemetryLog::default();
    let out =
        run_session_with(&mut *s, 40, 1, Some(&policy), Some(&mut tlog)).unwrap();
    assert_eq!(out.aborted, None, "healthy run tripped {:?}", out.aborted);
    assert!(!out.diverged, "{:?}", out.history);
    assert_eq!(tlog.len(), 40);
    // and the margins are real, not accidental: saturation stays well
    // under the abort threshold on every step
    for st in &tlog.steps {
        assert!(
            st.sat_rate() < policy.sat_rate,
            "step {}: sat_rate {} >= {}",
            st.step,
            st.sat_rate(),
            policy.sat_rate
        );
    }
}

/// The end-to-end sweep contract: with early abort on (the default), a
/// known-divergent cell is cut short -- rendered `div@N`, persisted with
/// its reason in the cell cache -- while the published table stays
/// byte-identical to a `--no-early-abort` reference run and every
/// completed cell stays bit-identical.
#[test]
fn doomed_cells_abort_early_and_complete_cells_match_reference() {
    let dir = temp_dir("abortsweep");
    let mk = |early_abort: bool| {
        let mut r = native_runner(0);
        r.cfg.lr = 1000.0; // doom the float cells; quantized clamps survive
        r.cfg.finetune_steps = 12;
        r.cfg.early_abort = early_abort;
        r
    };
    let opts = SweepOpts {
        workers: 2,
        cache_path: Some(dir.join("cache.json")),
        ..Default::default()
    };
    let abort_on = mk(true).run_sweep(Regime::Vanilla, &opts).unwrap();
    let reference = mk(false)
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();

    let mut saw_abort = false;
    let cells = abort_on.grid.outcomes.iter().flatten();
    let ref_cells = reference.grid.outcomes.iter().flatten();
    for (cell, ref_cell) in cells.zip(ref_cells) {
        match cell.eval {
            CellEval::Aborted { reason, step } => {
                saw_abort = true;
                assert_eq!(reason, AbortReason::NanLoss);
                assert!(
                    step < 12,
                    "cell (w={:?}, a={:?}) aborted at step {step}, not early",
                    cell.w,
                    cell.a
                );
                assert_eq!(cell.cell_str(1), format!("div@{step}"));
                // the reference run burns the same trajectory to n/a
                assert_eq!(ref_cell.eval, CellEval::Na);
            }
            CellEval::Ok(e) => {
                let r = ref_cell
                    .eval
                    .ok()
                    .expect("reference run lost a completed cell");
                assert_eq!(e.n, r.n);
                assert_eq!(e.top1_err.to_bits(), r.top1_err.to_bits());
                assert_eq!(e.top5_err.to_bits(), r.top5_err.to_bits());
                assert_eq!(e.mean_loss.to_bits(), r.mean_loss.to_bits());
            }
            CellEval::Na => assert_eq!(ref_cell.eval, CellEval::Na),
        }
    }
    assert!(saw_abort, "no cell aborted under lr=1000");

    // published table JSON: byte-identical (Aborted and Na both render
    // as null metrics -- provenance lives in the cache + report only)
    assert_eq!(
        report::grid_to_json(&abort_on.grid).to_string(),
        report::grid_to_json(&reference.grid).to_string()
    );
    // abort provenance is in the cell cache...
    let cache_text = std::fs::read_to_string(dir.join("cache.json")).unwrap();
    assert!(cache_text.contains("aborted"), "{cache_text}");
    assert!(cache_text.contains(AbortReason::NanLoss.as_str()), "{cache_text}");
    // ...and in the stability report, which regenerates byte-identically
    let seed = RunCfg::default().seed;
    let report_json = report::stability_report_json(
        &abort_on.grid.arch,
        abort_on.grid.regime,
        seed,
        &abort_on.cells,
        &abort_on.telemetry,
    );
    assert!(report_json.get("summary").unwrap().get("aborted").unwrap().as_usize().unwrap() >= 1);
    assert_eq!(
        report_json.to_string(),
        report::stability_report_json(
            &abort_on.grid.arch,
            abort_on.grid.regime,
            seed,
            &abort_on.cells,
            &abort_on.telemetry,
        )
        .to_string()
    );
}

/// Abort decisions are a pure function of the cell, not of how the
/// sweep is scheduled: sharded halves merge to the exact unsharded
/// outcome (reasons and abort steps included), and `--threads 2`
/// reproduces it too.
#[test]
fn abort_decisions_identical_across_shards_and_threads() {
    let dir = temp_dir("abortshard");
    let mk = || {
        let mut r = native_runner(0);
        r.cfg.lr = 1000.0;
        r.cfg.finetune_steps = 12;
        r
    };
    let unsharded = mk()
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    assert!(
        evals(&unsharded.grid)
            .iter()
            .any(|e| matches!(e, CellEval::Aborted { .. })),
        "fixture stopped producing aborts"
    );

    let base = dir.join("cache.json");
    let files: Vec<PathBuf> = (0..2)
        .map(|index| {
            let opts = SweepOpts {
                workers: 2,
                shard: Some((index, 2)),
                cache_path: Some(base.clone()),
                split_cache: true,
                ..Default::default()
            };
            mk().run_sweep(Regime::Vanilla, &opts).unwrap();
            opts.cache_file().unwrap()
        })
        .collect();
    let merged = shard::merge_files(&files, None).unwrap();
    assert!(merged.is_complete());
    assert_eq!(evals(&unsharded.grid), evals(&merged.to_grid()));

    let mut threaded = mk();
    threaded.cfg.threads = 2;
    let out = threaded
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    assert_eq!(evals(&unsharded.grid), evals(&out.grid));
}

// ---- grid merge --prune ---------------------------------------------------

fn synthetic_shards(dir: &Path, count: usize) -> Vec<PathBuf> {
    let base = dir.join("cache.json");
    (0..count)
        .map(|index| {
            let opts = SweepOpts {
                workers: 2,
                shard: Some((index, count)),
                cache_path: Some(base.clone()),
                split_cache: true,
                ..Default::default()
            };
            grid::run_sweep_with(
                Regime::Vanilla,
                "tiny",
                42,
                &opts,
                |_wid| Ok(()),
                |_, job| grid::synthetic_cell(job),
            )
            .unwrap();
            opts.cache_file().unwrap()
        })
        .collect()
}

#[test]
fn prune_removes_shard_caches_only_after_complete_merge() {
    let dir = temp_dir("prune");
    let files = synthetic_shards(&dir, 3);

    // incomplete union (one shard withheld): prune must refuse and
    // delete nothing
    let partial = shard::merge_files(&files[..2], None).unwrap();
    assert!(!partial.is_complete());
    let err = shard::prune_shard_inputs(&partial).unwrap_err();
    assert!(err.to_string().contains("refusing to prune"), "{err}");
    for f in &files {
        assert!(f.exists(), "refused prune deleted {}", f.display());
    }

    // complete union: prune deletes exactly the merged shard files
    let complete = shard::merge_files(&files, None).unwrap();
    assert!(complete.is_complete());
    let removed = shard::prune_shard_inputs(&complete).unwrap();
    assert_eq!(removed.len(), 3);
    for f in &files {
        assert!(!f.exists(), "prune left {}", f.display());
    }

    // whole-sweep caches (no shard header) are never prune targets
    let whole = dir.join("whole.json");
    complete.save(&whole).unwrap();
    let merged = shard::merge_files(&[whole.clone()], None).unwrap();
    assert!(merged.is_complete());
    assert!(merged.shard_inputs.is_empty());
    assert!(shard::prune_shard_inputs(&merged).unwrap().is_empty());
    assert!(whole.exists());
}
