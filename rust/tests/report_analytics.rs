//! Integration: the `fxpnet report` analytics pipeline end-to-end on
//! real native sweeps -- byte-identical analytics JSON across thread
//! counts, shard splits, and cache-vs-report provenance; property
//! coverage for empty/aborted-only/single-cell inputs and quantile
//! edges; and the acceptance pin for `--suggest-thresholds`: a policy
//! learned from a sweep never aborts a cell that converged in it.
//!
//! Everything here runs in the offline build -- no artifacts, no XLA.

use std::collections::BTreeMap;
use std::path::PathBuf;

use fxpnet::coordinator::analytics::Analytics;
use fxpnet::coordinator::backend::{Backend, BackendSpec};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::grid::{ParallelGridRunner, SweepOpts};
use fxpnet::coordinator::regimes::{CellEval, Regime};
use fxpnet::coordinator::report;
use fxpnet::coordinator::trainer::{AbortOverlay, AbortReason};
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::train::telemetry::TelemetrySummary;
use fxpnet::train::NativeBackend;
use fxpnet::util::json::Json;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fxp_report_analytics_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The doomed native fixture from `train_native.rs`: lr=1000 NaNs the
/// float cells while quantized clamps keep most fixed-point cells
/// converging, so one real sweep yields Ok, Na and Aborted cells plus
/// their telemetry digests.
fn doomed_runner() -> ParallelGridRunner {
    let backend = NativeBackend::new();
    let spec = backend.arch("tiny").unwrap();
    let base = ParamSet::init(&spec, 77);
    let train = Dataset::generate(64, 16, 16, 201);
    let eval = Dataset::generate(32, 16, 16, 202);
    let a_stats = backend.activation_stats("tiny", &base, &train, 1).unwrap();
    let cfg = RunCfg {
        finetune_steps: 12,
        phase_steps: 2,
        calib_batches: 1,
        workers: 1,
        lr: 1000.0,
        ..RunCfg::default()
    };
    ParallelGridRunner {
        backend: BackendSpec::Native,
        arch: "tiny".to_string(),
        base,
        a_stats,
        train_data: train,
        eval_data: eval,
        cfg,
    }
}

fn report_text(sweep_cells: &BTreeMap<String, CellEval>,
               telemetry: &BTreeMap<String, TelemetrySummary>,
               seed: u64) -> String {
    report::stability_report_json(
        "tiny",
        Regime::Vanilla,
        seed,
        sweep_cells,
        telemetry,
    )
    .to_string()
}

/// The analytics JSON must be a pure function of the sweep: the same
/// bytes whether the inputs were produced with `--threads 2`, as two
/// shard halves, or read back from cell caches instead of stability
/// reports -- and regardless of ingestion order.
#[test]
fn analytics_bytes_identical_across_threads_shards_and_provenance() {
    let dir = temp_dir("provenance");
    let runner = doomed_runner();
    let seed = runner.cfg.seed;
    let full_cache = dir.join("cache.json");
    let reference = runner
        .run_sweep(
            Regime::Vanilla,
            &SweepOpts {
                workers: 1,
                cache_path: Some(full_cache.clone()),
                ..Default::default()
            },
        )
        .unwrap();
    assert!(reference.is_complete());
    assert!(!reference.telemetry.is_empty(), "no telemetry digests");
    let ref_report = report_text(&reference.cells, &reference.telemetry, seed);

    let mut a = Analytics::new();
    a.ingest_text("ref", &ref_report).unwrap();
    let want = a.to_json().to_string();
    assert!(!a.is_empty());

    // --threads 2 + 2 workers: byte-identical stability report, hence
    // byte-identical analytics
    let mut threaded = doomed_runner();
    threaded.cfg.threads = 2;
    let t2 = threaded
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    assert_eq!(
        ref_report,
        report_text(&t2.cells, &t2.telemetry, seed),
        "stability report differs between --threads 1 and --threads 2"
    );

    // two shard halves: each emits a partial stability report and a
    // split cell cache
    let base = dir.join("shard_cache.json");
    let mut shard_inputs: Vec<String> = Vec::new();
    for index in 0..2usize {
        let opts = SweepOpts {
            workers: 2,
            shard: Some((index, 2)),
            cache_path: Some(base.clone()),
            split_cache: true,
            ..Default::default()
        };
        let half = doomed_runner().run_sweep(Regime::Vanilla, &opts).unwrap();
        shard_inputs.push(report_text(&half.cells, &half.telemetry, seed));
        shard_inputs
            .push(std::fs::read_to_string(opts.cache_file().unwrap()).unwrap());
    }
    // plus the full-run cache: every provenance at once, strict-unioned
    shard_inputs.push(std::fs::read_to_string(&full_cache).unwrap());
    shard_inputs.push(ref_report.clone());

    // any ingestion order produces the same bytes
    for order in [vec![0usize, 1, 2, 3, 4, 5], vec![5, 3, 1, 4, 2, 0], vec![2, 4, 0, 5, 1, 3]] {
        let mut b = Analytics::new();
        for &i in &order {
            b.ingest_text(&format!("input{i}"), &shard_inputs[i]).unwrap();
        }
        assert_eq!(b.sweep_count(), 1, "inputs split into multiple sweeps");
        assert_eq!(
            want,
            b.to_json().to_string(),
            "analytics bytes differ for ingestion order {order:?}"
        );
    }

    // the human table is deterministic too, and non-trivial
    let rendered = a.render();
    assert!(rendered.contains("vanilla"), "{rendered}");
    assert_eq!(rendered, a.render());
}

/// The acceptance pin: thresholds learned from a sweep, written through
/// the overlay JSON round-trip and fed back via `RunCfg.abort_overlay`,
/// never abort a cell that converged in that sweep -- and the re-swept
/// published table reproduces the reference byte-for-byte.
#[test]
fn learned_policy_never_aborts_converged_cells() {
    let runner = doomed_runner();
    let seed = runner.cfg.seed;
    let first = runner
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();
    let n_ok = first.cells.values().filter(|e| e.is_ok()).count();
    let n_aborted = first
        .cells
        .values()
        .filter(|e| matches!(e, CellEval::Aborted { .. }))
        .count();
    assert!(n_ok >= 1, "fixture produced no converged cells");
    assert!(n_aborted >= 1, "fixture produced no aborted cells");

    let text = report_text(&first.cells, &first.telemetry, seed);
    let mut a = Analytics::new();
    a.ingest_text("sweep", &text).unwrap();
    let overlay = a.suggest_thresholds();
    assert!(
        overlay.regimes.contains_key("vanilla"),
        "no policy fitted for the swept regime"
    );

    // deterministic: re-ingesting the same report refits the same bytes
    let mut b = Analytics::new();
    b.ingest_text("again", &text).unwrap();
    assert_eq!(
        overlay.to_json().to_string(),
        b.suggest_thresholds().to_json().to_string()
    );
    // and the overlay survives its own serialization exactly
    let parsed = AbortOverlay::parse(&overlay.to_json().to_string()).unwrap();
    assert_eq!(parsed, overlay);

    let mut under_policy = doomed_runner();
    under_policy.cfg.abort_overlay = Some(parsed);
    let second = under_policy
        .run_sweep(Regime::Vanilla, &SweepOpts { workers: 2, ..Default::default() })
        .unwrap();

    for (key, eval) in &first.cells {
        if let CellEval::Ok(e) = eval {
            match second.cells.get(key) {
                Some(CellEval::Ok(s)) => {
                    assert_eq!(e.n, s.n, "{key}");
                    assert_eq!(e.top1_err.to_bits(), s.top1_err.to_bits(), "{key}");
                    assert_eq!(e.top5_err.to_bits(), s.top5_err.to_bits(), "{key}");
                    assert_eq!(e.mean_loss.to_bits(), s.mean_loss.to_bits(), "{key}");
                }
                other => panic!(
                    "cell {key} converged in the sweep the policy was \
                     learned from but re-ran as {other:?} under it"
                ),
            }
        }
    }
    // aborted/na cells both publish null metrics, so the table -- the
    // artifact CI compares -- reproduces byte-for-byte
    assert_eq!(
        report::grid_to_json(&first.grid).to_string(),
        report::grid_to_json(&second.grid).to_string()
    );
}

/// Degenerate inputs: an aborted-only sweep yields a default policy
/// (nothing safe to fit against), and a single-cell sweep exercises the
/// n=1 quantile edge -- every quantile equals the one observation.
#[test]
fn aborted_only_and_single_cell_sweeps() {
    let tele = TelemetrySummary {
        steps: 9,
        loss_start: 2.0,
        loss_peak: 40.0,
        loss_final: f32::NAN,
        sat_final: 0.75,
        sat_peak: 0.75,
        ratio_min: Some(1e-6),
        ratio_final: Some(1e-6),
        windows: Vec::new(),
    };
    let mut cells = BTreeMap::new();
    cells.insert(
        "w=4,a=4".to_string(),
        CellEval::Aborted { reason: AbortReason::NanLoss, step: 9 },
    );
    let mut telemetry = BTreeMap::new();
    telemetry.insert("w=4,a=4".to_string(), tele.clone());

    let mut a = Analytics::new();
    a.ingest_text("aborted-only", &report_text(&cells, &telemetry, 11))
        .unwrap();
    let j = a.to_json();
    let sweep = &j.get("sweeps").unwrap().as_arr().unwrap()[0];
    let summary = sweep.get("summary").unwrap();
    assert_eq!(summary.get("ok").unwrap().as_usize().unwrap(), 0);
    assert_eq!(summary.get("aborted").unwrap().as_usize().unwrap(), 1);
    // no converged telemetry -> the overlay falls back to the defaults
    let p = a.suggest_thresholds().resolve("vanilla");
    assert_eq!(p, fxpnet::coordinator::trainer::AbortPolicy::default());
    assert!(a.render().contains("nan-loss"), "{}", a.render());

    // single converged cell: all-equal quantile edge
    let mut cells = BTreeMap::new();
    cells.insert(
        "w=8,a=8".to_string(),
        CellEval::Ok(EvalResult { n: 32, top1_err: 0.25, top5_err: 0.0, mean_loss: 1.5 }),
    );
    let mut telemetry = BTreeMap::new();
    telemetry.insert("w=8,a=8".to_string(), TelemetrySummary { sat_peak: 0.25, ..tele });
    let mut a = Analytics::new();
    a.ingest_text("single", &report_text(&cells, &telemetry, 12)).unwrap();
    let j = a.to_json();
    let sweep = &j.get("sweeps").unwrap().as_arr().unwrap()[0];
    let widths = sweep.get("widths").unwrap();
    let agg = widths.get("8").unwrap();
    for key in ["sat_final_q", "sat_peak_q"] {
        let q = agg.get(key).unwrap().as_arr().unwrap();
        assert!(!q.is_empty(), "{key} empty for a telemetry-bearing cell");
        for v in q {
            assert_eq!(
                v.as_f64().unwrap(),
                q[0].as_f64().unwrap(),
                "n=1 {key} quantiles must all equal the observation"
            );
        }
    }
}

/// File-level refusals: missing files, version mismatches and
/// unrecognized shapes error with actionable messages, and an empty
/// analytics still renders and serializes.
#[test]
fn bad_files_are_refused_and_empty_analytics_degrade_gracefully() {
    let dir = temp_dir("badfiles");
    let mut a = Analytics::new();

    let err = a.ingest_file(dir.join("nope.json")).unwrap_err().to_string();
    assert!(err.contains("nope.json"), "{err}");

    let stale = dir.join("stale.json");
    std::fs::write(&stale, r#"{"report_version": 1, "kind": "stability"}"#)
        .unwrap();
    let err = a.ingest_file(&stale).unwrap_err().to_string();
    assert!(err.contains("report_version 1"), "{err}");
    assert!(err.contains("stale.json"), "{err}");

    let legacy = dir.join("legacy.json");
    std::fs::write(&legacy, r#"{"table": 3, "cells": {}}"#).unwrap();
    let err = a.ingest_file(&legacy).unwrap_err().to_string();
    assert!(err.contains("unrecognized input"), "{err}");

    // nothing partial leaked in: still empty, still renders
    assert!(a.is_empty());
    assert_eq!(
        a.to_json().get("sweeps").unwrap().as_arr().unwrap().len(),
        0
    );
    assert!(a.render().contains("stability analytics"));
    assert!(Json::parse(&a.to_json().to_string()).is_ok());
}
