//! End-to-end cluster tests: a real coordinator and real workers over
//! loopback TCP, in one process.
//!
//! The contract under test is the one the module docs promise: cluster
//! execution is a *scheduling* change only.  Whatever the workers do --
//! die mid-cell, drop frames, reconnect, get rejected -- the final cell
//! cache and table must be byte-identical to a single-process
//! `run_sweep_with` reference, and every failure mode must land in the
//! summary accounting rather than in the results.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use fxpnet::cluster::{
    self, run_coordinator, run_worker, CellExec, ClusterOpts, ClusterOutcome,
    FaultSpec, HeartbeatCfg, SyntheticExec, WorkerOpts,
};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::grid::{self, CellJob, GridResult, SweepOpts};
use fxpnet::coordinator::regimes::{CellResult, Regime};
use fxpnet::coordinator::report::save_grid;
use fxpnet::coordinator::shard::{LockOpts, ShardedCache};
use fxpnet::error::Result;
use fxpnet::train::telemetry::TelemetrySummary;

const ARCH: &str = "tiny";
const SEED: u64 = 42;

fn fp() -> u64 {
    cluster::sweep_fingerprint(ARCH, Regime::Vanilla, SEED, true, &RunCfg::smoke())
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("fxp_cluster_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Timings tuned for tests: fast heartbeats, fast death detection, fast
/// re-dispatch -- the same code paths as production defaults, sooner.
fn fast_opts(dir: &Path) -> ClusterOpts {
    ClusterOpts {
        listen: "127.0.0.1:0".into(),
        port_file: Some(dir.join("port")),
        hb: HeartbeatCfg {
            interval: Duration::from_millis(50),
            deadline: Duration::from_millis(400),
        },
        backoff_base: Duration::from_millis(10),
        summary_path: Some(dir.join("summary.json")),
        cache_path: dir.join("cache.json"),
        ..ClusterOpts::default()
    }
}

fn worker_opts(addr: &str, name: &str) -> WorkerOpts {
    WorkerOpts {
        connect: addr.to_string(),
        name: name.to_string(),
        reconnect_backoff: Duration::from_millis(10),
        ..WorkerOpts::default()
    }
}

/// The `--workers 1` single-process reference every cluster run must
/// reproduce byte-for-byte.
fn reference(dir: &Path) -> (grid::SweepOutcome, PathBuf) {
    std::fs::create_dir_all(dir).unwrap();
    let cache = dir.join("cache.json");
    let opts = SweepOpts {
        workers: 1,
        cache_path: Some(cache.clone()),
        ..SweepOpts::default()
    };
    let out = grid::run_sweep_with(
        Regime::Vanilla,
        ARCH,
        SEED,
        &opts,
        |_wid| Ok(()),
        |_, job| grid::synthetic_cell(job),
    )
    .unwrap();
    assert!(out.is_complete());
    (out, cache)
}

/// Exact bit pattern of a grid (None = n/a or aborted cell).
fn bits(g: &GridResult) -> Vec<Option<(usize, u64, u64, u64)>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| {
            c.eval.ok().map(|e| {
                (
                    e.n,
                    e.top1_err.to_bits(),
                    e.top5_err.to_bits(),
                    e.mean_loss.to_bits(),
                )
            })
        })
        .collect()
}

fn read_bytes(p: &Path) -> Vec<u8> {
    std::fs::read(p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

struct Cluster {
    handle: JoinHandle<Result<ClusterOutcome>>,
    addr: String,
    shutdown: Arc<AtomicBool>,
}

fn start_coordinator(opts: ClusterOpts, fp: u64) -> Cluster {
    let shutdown = Arc::new(AtomicBool::new(false));
    let flag = Arc::clone(&shutdown);
    let port_file = opts.port_file.clone().expect("tests rendezvous via port file");
    // a restarted coordinator must not hand out its predecessor's port
    let _ = std::fs::remove_file(&port_file);
    let handle = std::thread::spawn(move || {
        run_coordinator(Regime::Vanilla, ARCH, SEED, fp, &opts, &flag)
    });
    // poll the atomically-written port file, exactly like a launcher
    let deadline = Instant::now() + Duration::from_secs(10);
    let addr = loop {
        if let Ok(s) = std::fs::read_to_string(&port_file) {
            let s = s.trim();
            if !s.is_empty() {
                break s.to_string();
            }
        }
        assert!(
            Instant::now() < deadline,
            "coordinator never wrote {}",
            port_file.display()
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    Cluster { handle, addr, shutdown }
}

fn spawn_worker(opts: WorkerOpts) -> JoinHandle<Result<cluster::WorkerReport>> {
    std::thread::spawn(move || {
        run_worker(Regime::Vanilla, SEED, fp(), &mut SyntheticExec, &opts)
    })
}

/// Synthetic cells slowed by a fixed pace, so multi-worker sweeps last
/// long enough for every worker to join (and for drains/kills to land
/// mid-sweep) without changing any cell's result.
struct PacedExec(Duration);

impl CellExec for PacedExec {
    fn run(
        &mut self,
        job: &CellJob,
    ) -> Result<(CellResult, Option<TelemetrySummary>)> {
        std::thread::sleep(self.0);
        grid::synthetic_cell(job).map(|r| (r, None))
    }
}

fn spawn_paced_worker(
    opts: WorkerOpts,
    pace: Duration,
) -> JoinHandle<Result<cluster::WorkerReport>> {
    std::thread::spawn(move || {
        run_worker(Regime::Vanilla, SEED, fp(), &mut PacedExec(pace), &opts)
    })
}

/// Artifacts (cache file, table txt+json, grid bits) must be
/// byte-identical to the single-process reference.
fn assert_matches_reference(
    outcome: &ClusterOutcome,
    cache: &Path,
    reference: &grid::SweepOutcome,
    ref_cache: &Path,
    scratch: &Path,
) {
    assert_eq!(bits(&outcome.grid), bits(&reference.grid));
    assert_eq!(
        read_bytes(cache),
        read_bytes(ref_cache),
        "cluster cache differs from the single-process reference"
    );
    let (a, b) = (scratch.join("cluster_out"), scratch.join("ref_out"));
    save_grid(&outcome.grid, &a, 3).unwrap();
    save_grid(&reference.grid, &b, 3).unwrap();
    let n = outcome.grid.regime.table_number();
    for f in [format!("table{n}_{ARCH}.txt"), format!("table{n}_{ARCH}.json")] {
        assert_eq!(
            read_bytes(&a.join(&f)),
            read_bytes(&b.join(&f)),
            "{f} differs from the reference"
        );
    }
}

#[test]
fn three_workers_match_the_single_process_reference() {
    let dir = temp_dir("basic");
    let (reference, ref_cache) = reference(&dir.join("ref"));
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    let c = start_coordinator(fast_opts(&cdir), fp());
    let workers: Vec<_> = (0..3)
        .map(|i| {
            spawn_paced_worker(
                worker_opts(&c.addr, &format!("w{i}")),
                Duration::from_millis(20),
            )
        })
        .collect();

    let outcome = c.handle.join().unwrap().unwrap();
    for w in workers {
        let report = w.join().unwrap().unwrap();
        assert!(report.sweep_complete);
    }
    assert!(outcome.summary.complete);
    assert!(!outcome.summary.drained);
    assert_eq!(outcome.summary.cached, 0);
    assert_eq!(outcome.summary.computed, outcome.summary.cells);
    assert_eq!(outcome.summary.workers, 3);
    assert_matches_reference(&outcome, &cdir.join("cache.json"), &reference, &ref_cache, &dir);

    // summary JSON landed too
    let summary = std::fs::read_to_string(cdir.join("summary.json")).unwrap();
    assert!(summary.contains("\"complete\":true"), "{summary}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn chaos_killed_and_flaky_workers_leave_artifacts_byte_identical() {
    let dir = temp_dir("chaos");
    let (reference, ref_cache) = reference(&dir.join("ref"));
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    let c = start_coordinator(fast_opts(&cdir), fp());
    // one worker killed mid-cell (computes its 2nd cell, dies before
    // sending the result), one dropping/delaying frames, one steady
    let pace = Duration::from_millis(20);
    let victim = spawn_paced_worker(
        WorkerOpts {
            fault: FaultSpec::parse("kill-after=2").unwrap(),
            ..worker_opts(&c.addr, "victim")
        },
        pace,
    );
    let flaky = spawn_paced_worker(
        WorkerOpts {
            fault: FaultSpec::parse("drop=0.15,delay=5").unwrap(),
            reconnect_cap: 40,
            ..worker_opts(&c.addr, "flaky")
        },
        pace,
    );
    let steady = spawn_paced_worker(worker_opts(&c.addr, "steady"), pace);

    let outcome = c.handle.join().unwrap().unwrap();
    let victim_err = victim.join().unwrap().expect_err("victim must die");
    assert!(victim_err.to_string().contains("kill-after"), "{victim_err}");
    // flaky may end drained or lose its last connection to a drop; both
    // are fine -- the sweep's artifacts are what matters
    let _ = flaky.join().unwrap();
    let steady_report = steady.join().unwrap().unwrap();
    assert!(steady_report.sweep_complete);

    assert!(outcome.summary.complete);
    assert!(
        outcome.summary.redispatched >= 1,
        "the mid-cell kill must force a re-dispatch: {:?}",
        outcome.summary
    );
    assert!(outcome.summary.worker_deaths >= 1);
    assert_matches_reference(&outcome, &cdir.join("cache.json"), &reference, &ref_cache, &dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn garbage_peers_are_dropped_without_derailing_the_sweep() {
    let dir = temp_dir("garbage");
    let (reference, ref_cache) = reference(&dir.join("ref"));
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    let c = start_coordinator(fast_opts(&cdir), fp());

    // a peer whose length prefix exceeds MAX_FRAME, and one that sends
    // a well-framed non-JSON payload: both must be dropped cleanly
    let oversized = ((cluster::proto::MAX_FRAME + 1) as u32).to_le_bytes().to_vec();
    let mut not_json = 5u32.to_le_bytes().to_vec();
    not_json.extend_from_slice(b"hello");
    for (what, wire) in [("oversized prefix", oversized), ("not json", not_json)] {
        let mut s = TcpStream::connect(&c.addr).unwrap();
        s.write_all(&wire).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 64];
        loop {
            match s.read(&mut buf) {
                Ok(0) => break, // coordinator closed on us: dropped
                Ok(_) => {}
                Err(e) => panic!("{what}: expected clean close, got {e}"),
            }
        }
    }

    // the sweep still completes through a well-behaved worker
    let w = spawn_worker(worker_opts(&c.addr, "good"));
    let outcome = c.handle.join().unwrap().unwrap();
    assert!(w.join().unwrap().unwrap().sweep_complete);
    assert!(outcome.summary.complete);
    assert_matches_reference(&outcome, &cdir.join("cache.json"), &reference, &ref_cache, &dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn coordinator_resumes_from_a_partial_cache() {
    let dir = temp_dir("resume");
    let (reference, ref_cache) = reference(&dir.join("ref"));
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    // a previous coordinator "crashed" after 5 cells: seed the cache
    let jobs = grid::grid_jobs(Regime::Vanilla, SEED);
    {
        let mut cache = ShardedCache::open(
            &cdir.join("cache.json"),
            ARCH,
            Regime::Vanilla,
            SEED,
            None,
            &LockOpts::default(),
        )
        .unwrap();
        for job in &jobs[..5] {
            let eval = grid::synthetic_cell(job).unwrap();
            cache.put(job, &eval);
        }
        cache.save().unwrap();
    } // advisory lock released here

    let c = start_coordinator(fast_opts(&cdir), fp());
    let w = spawn_worker(worker_opts(&c.addr, "w0"));
    let outcome = c.handle.join().unwrap().unwrap();
    assert!(w.join().unwrap().unwrap().sweep_complete);

    assert!(outcome.summary.complete);
    assert_eq!(outcome.summary.cached, 5);
    assert_eq!(outcome.summary.computed, outcome.summary.cells - 5);
    assert_matches_reference(&outcome, &cdir.join("cache.json"), &reference, &ref_cache, &dir);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn mismatched_fingerprints_are_rejected_at_handshake() {
    let dir = temp_dir("fingerprint");
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    let c = start_coordinator(fast_opts(&cdir), fp());

    // a worker whose flags describe a different sweep must be refused
    let bad_opts = worker_opts(&c.addr, "misflagged");
    let bad = std::thread::spawn(move || {
        run_worker(Regime::Vanilla, SEED, fp() ^ 1, &mut SyntheticExec, &bad_opts)
    });
    let err = bad.join().unwrap().expect_err("wrong fingerprint must fail");
    assert!(err.to_string().contains("rejected"), "{err}");

    // an invalid shard pin fails before it even connects
    let err = run_worker(
        Regime::Vanilla,
        SEED,
        fp(),
        &mut SyntheticExec,
        &WorkerOpts { shard: Some((5, 3)), ..worker_opts(&c.addr, "badshard") },
    )
    .expect_err("shard 5/3 must fail validation");
    assert!(err.to_string().contains("index"), "{err}");

    let w = spawn_worker(worker_opts(&c.addr, "good"));
    let outcome = c.handle.join().unwrap().unwrap();
    assert!(w.join().unwrap().unwrap().sweep_complete);
    assert!(outcome.summary.complete);
    assert_eq!(outcome.summary.rejected, 1);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_cell_that_keeps_killing_workers_exceeds_the_retry_cap() {
    let dir = temp_dir("retrycap");
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    let opts = ClusterOpts { retry_cap: 2, ..fast_opts(&cdir) };
    let c = start_coordinator(opts, fp());

    // two suicide workers in sequence, both pinned to cell flat=0 via a
    // 1-cell shard: attempt 1 dies, attempt 2 dies, cap of 2 exceeded
    for i in 0..2 {
        let w = spawn_worker(WorkerOpts {
            shard: Some((0, 16)),
            fault: FaultSpec::parse("kill-after=1").unwrap(),
            reconnect_cap: 2,
            ..worker_opts(&c.addr, &format!("suicide{i}"))
        });
        let err = w.join().unwrap().expect_err("suicide worker must die");
        assert!(err.to_string().contains("kill-after"), "{err}");
    }

    let err = c.handle.join().unwrap().expect_err("cap exhaustion is fatal");
    assert!(err.to_string().contains("retry cap"), "{err}");

    // the summary still lands, with the deaths accounted
    let summary = std::fs::read_to_string(cdir.join("summary.json")).unwrap();
    assert!(summary.contains("\"worker_deaths\":2"), "{summary}");
    assert!(summary.contains("\"complete\":false"), "{summary}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn drain_mid_sweep_then_resume_completes_byte_identically() {
    let dir = temp_dir("drain");
    let (reference, ref_cache) = reference(&dir.join("ref"));
    let cdir = dir.join("cluster");
    std::fs::create_dir_all(&cdir).unwrap();

    // phase 1: drain (as a SIGTERM handler would) partway through
    let c = start_coordinator(fast_opts(&cdir), fp());
    let w = spawn_paced_worker(worker_opts(&c.addr, "slow"), Duration::from_millis(40));
    std::thread::sleep(Duration::from_millis(150));
    c.shutdown.store(true, Ordering::SeqCst);

    let outcome = c.handle.join().unwrap().unwrap();
    let report = w.join().unwrap().unwrap();
    assert!(!report.sweep_complete);
    assert!(outcome.summary.drained);
    assert!(!outcome.summary.complete);
    assert!(
        outcome.summary.computed >= 1
            && outcome.summary.computed < outcome.summary.cells,
        "drain must land mid-sweep: {:?}",
        outcome.summary
    );

    // phase 2: a fresh coordinator resumes from the cache and finishes
    let c2 = start_coordinator(fast_opts(&cdir), fp());
    let w2 = spawn_worker(worker_opts(&c2.addr, "finisher"));
    let outcome2 = c2.handle.join().unwrap().unwrap();
    assert!(w2.join().unwrap().unwrap().sweep_complete);
    assert!(outcome2.summary.complete);
    assert_eq!(outcome2.summary.cached, outcome.summary.computed);
    assert_matches_reference(&outcome2, &cdir.join("cache.json"), &reference, &ref_cache, &dir);
    let _ = std::fs::remove_dir_all(&dir);
}
