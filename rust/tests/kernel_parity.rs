//! Cross-ISA bit-parity for the runtime-dispatched kernel layer
//! (`inference::kernels`): whatever `Kernels::detect()` finds on this
//! host must agree with the scalar reference *bit for bit* --
//!
//! * raw integer GEMM over every panel storage (i32, and the narrow
//!   i16/i8 pair panels the SIMD paths widen exactly), fuzzed over odd
//!   shapes straddling the MR=4 / NR=8 tile edges;
//! * the fused epilogues (`gemm_requant_relu`, `gemm_decode`);
//! * the f32 GEMM (same per-element reduction order, never fused);
//! * the nearest-half-up quantize pass (same f64 pipeline per lane,
//!   including NaN and the saturation tally);
//! * whole engines: `build_with_kernels(scalar)` vs
//!   `build_with_kernels(auto)` logits over bit widths x thread counts.
//!
//! On a scalar-only host every comparison degenerates to scalar vs
//! scalar and passes trivially; the CI kernel-matrix job additionally
//! pins `FXP_KERNEL=scalar` vs auto across *processes* by byte-comparing
//! sweep outputs.

use fxpnet::bench::fixtures::int_engine_cell;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::kernels::gemm_pair_scalar;
use fxpnet::inference::packing::{IntPanels, PackedPanels, PairPanels};
use fxpnet::inference::{gemm, FixedPointNet, Isa, Kernels};
use fxpnet::model::manifest::ArchSpec;
use fxpnet::util::rng::Rng;
use std::collections::BTreeMap;

/// Shapes that straddle the microkernel tile edges: MR=4 row blocks
/// (3/4/5), NR=8 column panels (7/8/9/17), and odd/even depths (the
/// pair kernels consume k two at a time, so odd k exercises the
/// guarded last pair).
const ROWS: [usize; 5] = [1, 3, 4, 5, 9];
const DEPTHS: [usize; 5] = [1, 7, 8, 9, 27];
const COLS: [usize; 5] = [1, 7, 8, 9, 17];

fn random_codes(rng: &mut Rng, len: usize, bits: u8) -> Vec<i32> {
    let max = 1i64 << (bits - 1);
    (0..len)
        .map(|_| (rng.below((2 * max - 1) as usize) as i64 - (max - 1)) as i32)
        .collect()
}

fn random_bias(rng: &mut Rng, n: usize) -> Vec<i64> {
    (0..n).map(|_| rng.below(2001) as i64 - 1000).collect()
}

/// The oracle: naive triple loop in i64 (exact, order-free).
fn naive(a: &[i32], rows: usize, k: usize, w: &[i32], n: usize, bias: &[i64]) -> Vec<i64> {
    let mut out = vec![0i64; rows * n];
    for r in 0..rows {
        for j in 0..n {
            let mut acc = bias[j];
            for p in 0..k {
                acc += a[r * k + p] as i64 * w[p * n + j] as i64;
            }
            out[r * n + j] = acc;
        }
    }
    out
}

/// Fuzz the dispatched integer GEMM against both the naive oracle and
/// the scalar facade, across shapes and operand widths that force each
/// panel storage (i8, i16, i32) under SIMD.
#[test]
fn dispatched_int_gemm_is_bit_identical_across_tile_edges() {
    let kd = Kernels::for_isa(Kernels::detect());
    let ks = Kernels::for_isa(Isa::Scalar);
    let mut rng = Rng::new(0xBEEF);
    let mut cases = 0usize;
    // (a_bits, w_bits) -> panel kind under SIMD: i8, i16, i32
    for (a_bits, w_bits) in [(8u8, 8u8), (8, 12), (16, 12)] {
        for rows in ROWS {
            for k in DEPTHS {
                for n in COLS {
                    let a = random_codes(&mut rng, rows * k, a_bits);
                    let w = random_codes(&mut rng, k * n, w_bits);
                    let bias = random_bias(&mut rng, n);
                    let want = naive(&a, rows, k, &w, n, &bias);

                    let pw_s = ks.pack_int(&w, k, n, a_bits, w_bits);
                    assert_eq!(pw_s.kind(), "i32", "scalar always packs i32");
                    let mut scalar = vec![0i64; rows * n];
                    ks.gemm_int(&a, rows, k, &pw_s, &bias, |i, acc| scalar[i] = acc);
                    assert_eq!(scalar, want, "scalar facade vs naive oracle");

                    let pw_d = kd.pack_int(&w, k, n, a_bits, w_bits);
                    let mut got = vec![0i64; rows * n];
                    kd.gemm_int(&a, rows, k, &pw_d, &bias, |i, acc| got[i] = acc);
                    assert_eq!(
                        got, want,
                        "{} ({}) rows={rows} k={k} n={n} {a_bits}b x {w_bits}b",
                        kd.name(),
                        pw_d.kind(),
                    );
                    cases += 1;
                }
            }
        }
    }
    assert_eq!(cases, 3 * ROWS.len() * DEPTHS.len() * COLS.len());
}

/// The narrow-panel scalar walk (`gemm_pair_scalar`) is itself an
/// oracle-grade reference: pin it against naive on the same shape grid,
/// and pin the dispatched kernel against an explicitly-built narrow
/// panel (so the narrow SIMD paths are exercised even when `pack_int`
/// would have chosen differently).
#[test]
fn narrow_pair_panels_match_naive_on_every_shape() {
    let kd = Kernels::for_isa(Kernels::detect());
    let mut rng = Rng::new(0xF00D);
    for rows in ROWS {
        for k in DEPTHS {
            for n in COLS {
                let a = random_codes(&mut rng, rows * k, 8);
                let w = random_codes(&mut rng, k * n, 8);
                let bias = random_bias(&mut rng, n);
                let want = naive(&a, rows, k, &w, n, &bias);

                let p16: PairPanels<i16> = PairPanels::pack(&w, k, n, 8, 8);
                let mut got = vec![0i64; rows * n];
                gemm_pair_scalar(&a, rows, k, &p16, &bias, |i, acc| got[i] = acc);
                assert_eq!(got, want, "scalar i16 walk rows={rows} k={k} n={n}");

                let mut got = vec![0i64; rows * n];
                kd.gemm_int(&a, rows, k, &IntPanels::I16(p16), &bias, |i, acc| {
                    got[i] = acc
                });
                assert_eq!(got, want, "{} i16 rows={rows} k={k} n={n}", kd.name());

                let p8: PairPanels<i8> = PairPanels::pack(&w, k, n, 8, 8);
                let mut got = vec![0i64; rows * n];
                kd.gemm_int(&a, rows, k, &IntPanels::I8(p8), &bias, |i, acc| {
                    got[i] = acc
                });
                assert_eq!(got, want, "{} i8 rows={rows} k={k} n={n}", kd.name());
            }
        }
    }
}

/// The fused epilogues must agree too: requantize(+ReLU) to activation
/// codes and decode-to-f32 logits, scalar facade vs detected facade.
#[test]
fn fused_epilogues_are_bit_identical() {
    let kd = Kernels::for_isa(Kernels::detect());
    let ks = Kernels::for_isa(Isa::Scalar);
    let fmt = QFormat::new(8, 4).unwrap();
    let acc_frac = 9;
    let mut rng = Rng::new(0xCAFE);
    for (rows, k, n) in [(1usize, 9usize, 7usize), (5, 27, 17), (8, 16, 8)] {
        let a = random_codes(&mut rng, rows * k, 8);
        let w = random_codes(&mut rng, k * n, 8);
        let bias = random_bias(&mut rng, n);
        let pw_s = ks.pack_int(&w, k, n, 8, 8);
        let pw_d = kd.pack_int(&w, k, n, 8, 8);
        for relu in [false, true] {
            let mut want = vec![0i32; rows * n];
            ks.gemm_requant_relu(&a, rows, k, &pw_s, &bias, acc_frac, fmt, relu, &mut want);
            let mut got = vec![0i32; rows * n];
            kd.gemm_requant_relu(&a, rows, k, &pw_d, &bias, acc_frac, fmt, relu, &mut got);
            assert_eq!(got, want, "{} requant relu={relu} {rows}x{k}x{n}", kd.name());
        }
        let mut want = vec![0f32; rows * n];
        ks.gemm_decode(&a, rows, k, &pw_s, &bias, acc_frac, &mut want);
        let mut got = vec![0f32; rows * n];
        kd.gemm_decode(&a, rows, k, &pw_d, &bias, acc_frac, &mut got);
        let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
        let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
        assert_eq!(gb, wb, "{} decode {rows}x{k}x{n}", kd.name());
    }
}

/// f32 GEMM: SIMD vectorizes across columns only, so every output
/// element sees the scalar reduction order and rounds identically.
#[test]
fn f32_gemm_is_bit_identical_across_tile_edges() {
    let kd = Kernels::for_isa(Kernels::detect());
    let mut rng = Rng::new(0xD1CE);
    for rows in ROWS {
        for k in DEPTHS {
            for n in COLS {
                let a: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
                let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
                let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
                let pw = PackedPanels::pack(&w, k, n);
                let mut want = vec![0f32; rows * n];
                gemm::gemm_bias_f32(&a, rows, k, &pw, &bias, &mut want);
                let mut got = vec![0f32; rows * n];
                kd.gemm_bias_f32(&a, rows, k, &pw, &bias, &mut got);
                let wb: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                let gb: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "{} rows={rows} k={k} n={n}", kd.name());
            }
        }
    }
}

fn small_arch() -> ArchSpec {
    ArchSpec {
        name: "kernel-parity-net".into(),
        input: [8, 8, 3],
        num_classes: 10,
        num_layers: 2,
        train_batch: 8,
        eval_batch: 8,
        layers: vec![("conv".into(), 8), ("pool".into(), 0), ("fc".into(), 10)],
        params: vec![
            ("l0.w".into(), vec![3, 3, 3, 8]),
            ("l0.b".into(), vec![8]),
            ("l1.w".into(), vec![4 * 4 * 8, 10]),
            ("l1.b".into(), vec![10]),
        ],
        artifacts: BTreeMap::new(),
    }
}

/// The whole-engine contract: a net built on the scalar facade and one
/// built on the auto facade (same params, same quantization) produce
/// bit-identical logits, across bit widths (4-bit cells keep i8 panels,
/// 16-bit falls back to i32) and engine thread counts (sharding must
/// not perturb the per-row kernels).
#[test]
fn engines_built_on_scalar_and_auto_kernels_agree_bit_for_bit() {
    let spec = small_arch();
    let data = Dataset::generate(9, 8, 8, 55);
    let in_fmt = QFormat::new(16, 14).unwrap();
    for &bits in &[4u8, 8, 16] {
        let (params, nq) = int_engine_cell(&spec, bits, 700 + bits as u64).unwrap();
        let net_s = FixedPointNet::build_with_kernels(
            &spec,
            &params,
            &nq,
            in_fmt,
            Kernels::for_isa(Isa::Scalar),
        )
        .unwrap();
        assert_eq!(net_s.kernels().isa(), Isa::Scalar);
        let net_a =
            FixedPointNet::build(&spec, &params, &nq, in_fmt).unwrap();
        for &threads in &[1usize, 4] {
            let want = net_s.forward_batch_threaded(&data.images, threads).unwrap();
            let got = net_a.forward_batch_threaded(&data.images, threads).unwrap();
            let wb: Vec<u32> = want.data().iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> = got.data().iter().map(|v| v.to_bits()).collect();
            assert_eq!(
                gb,
                wb,
                "bits={bits} threads={threads}: {} engine deviates from scalar",
                net_a.kernels().name()
            );
        }
    }
}

/// Quantize parity on adversarial values (NaN, infinities, signed
/// zero, values exactly on the .5 rounding boundary and the clamp
/// edges) -- plus the saturation tallies the training loop records.
#[test]
fn quantize_pass_parity_on_adversarial_values() {
    use fxpnet::inference::kernels::quantize_nearest_scalar;
    let kd = Kernels::for_isa(Kernels::detect());
    for fmt in [
        QFormat::new(8, 4).unwrap(),
        QFormat::new(4, 2).unwrap(),
        QFormat::new(16, 12).unwrap(),
    ] {
        let step = fmt.step();
        let mut xs: Vec<f32> = vec![
            f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            0.0,
            -0.0,
            step * 0.5,          // exactly on the round-half-up boundary
            -step * 0.5,
            step * 1.5,
            (fmt.qmax() as f32 + 1.0) * step, // just past the clamp edge
            (fmt.qmin() as f32 - 1.0) * step,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
        ];
        let mut rng = Rng::new(fmt.bits as u64);
        xs.extend((0..509).map(|_| rng.uniform_in(-30.0, 30.0)));
        let mut want = xs.clone();
        let sat_want = quantize_nearest_scalar(&mut want, fmt);
        let mut got = xs.clone();
        let sat_got = kd.quantize_nearest(&mut got, fmt);
        assert_eq!(sat_got, sat_want, "{} sat tally {fmt}", kd.name());
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            let same = g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan());
            assert!(same, "{} {fmt} elem {i}: {g:?} vs {w:?}", kd.name());
        }
    }
}
