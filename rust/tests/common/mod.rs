//! Shared helpers for integration tests (require `make artifacts`).

use std::path::PathBuf;

use fxpnet::runtime::Engine;

/// Locate the artifacts directory (repo root / artifacts).
pub fn artifacts_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts/manifest.json missing -- run `make artifacts` before \
         `cargo test` (the Makefile `test` target does this)"
    );
    dir
}

pub fn engine() -> Engine {
    Engine::cpu(artifacts_dir()).expect("engine")
}
