//! Shared helpers for integration tests.
//!
//! Engine-backed tests need the AOT artifacts (`make artifacts`, which
//! requires the Python/JAX toolchain) *and* a real PJRT runtime.  In the
//! offline build (xla stub, no artifacts/) those tests skip themselves
//! via [`engine_opt`]; everything else -- the parallel grid engine, the
//! fixed-point stack, the property tests -- runs everywhere.

use std::path::PathBuf;

use fxpnet::runtime::Engine;

/// Locate the artifacts directory (package root / artifacts), if built.
pub fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        None
    }
}

/// An engine over the artifacts, or `None` (with a note) when the
/// artifacts are absent -- callers `return` early, skipping the test.
pub fn engine_opt() -> Option<Engine> {
    let Some(dir) = artifacts_dir() else {
        eprintln!(
            "skipping engine-backed test: artifacts/manifest.json missing \
             (run `make artifacts` with the real xla crate linked)"
        );
        return None;
    };
    Some(Engine::cpu(dir).expect("engine over existing artifacts"))
}
