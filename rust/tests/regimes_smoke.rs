//! Integration: the five regimes and the grid runner at smoke scale on
//! the tiny architecture (engine-backed tests skip without artifacts),
//! plus engine-free divergence-isolation tests of the parallel sweep:
//! a cell whose trainer panics or diverges must become "n/a" while the
//! rest of the grid completes.

mod common;

use fxpnet::coordinator::backend::{Backend, XlaBackend};
use fxpnet::coordinator::config::RunCfg;
use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::grid::{self, GridRunner, SweepOpts};
use fxpnet::coordinator::regimes::{self, CellCtx, CellEval, Regime};
use fxpnet::coordinator::trainer::{upd_all, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::error::FxpError;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::{NetQuant, WidthSpec};

struct Fixture {
    backend: XlaBackend,
    base: ParamSet,
    a_stats: Vec<fxpnet::quant::calib::LayerStats>,
    train: Dataset,
    eval: Dataset,
    cfg: RunCfg,
}

/// Pretrain a tiny float net briefly so regimes have a sensible base.
/// `None` => artifacts absent; the caller skips.
fn fixture(seed: u64) -> Option<Fixture> {
    let engine = common::engine_opt()?;
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let train = Dataset::generate(512, spec.input[0], spec.input[1], seed + 1);
    let eval = Dataset::generate(128, spec.input[0], spec.input[1], seed + 2);
    let params = ParamSet::init(&spec, seed);
    let nq = NetQuant::all_float(spec.num_layers);
    let mut tr = Trainer::new(
        &engine,
        "tiny",
        &params,
        &nq,
        &upd_all(spec.num_layers),
        0.05,
        0.9,
        train.clone(),
        LoaderCfg { batch: spec.train_batch, augment: false, max_shift: 0, seed },
        30.0,
    )
    .unwrap();
    tr.run(60, 10).unwrap();
    let base = tr.params().unwrap();
    let backend = XlaBackend::new(engine);
    let a_stats = backend.activation_stats("tiny", &base, &train, 2).unwrap();
    Some(Fixture { backend, base, a_stats, train, eval, cfg: RunCfg::smoke() })
}

impl Fixture {
    fn ctx(&self) -> CellCtx<'_> {
        CellCtx {
            backend: &self.backend,
            arch: "tiny",
            train_data: &self.train,
            eval_data: &self.eval,
            a_stats: &self.a_stats,
            cfg: &self.cfg,
            cell_seed: self.cfg.seed,
        }
    }
}

#[test]
fn all_regimes_produce_outcomes() {
    let Some(f) = fixture(21) else { return };
    let ctx = f.ctx();
    let w = WidthSpec::Bits(8);
    let a = WidthSpec::Bits(8);

    let noft =
        regimes::run_no_finetune(&ctx, &f.base, w, a).unwrap().ok().unwrap();
    assert!(noft.top1_err <= 1.0 && noft.mean_loss.is_finite());

    // training regimes return (outcome, telemetry digest); a cell that
    // actually trained always carries its digest
    let (vanilla, tele) = regimes::run_vanilla(&ctx, &f.base, w, a).unwrap();
    assert!(vanilla.is_ok());
    assert!(tele.is_some(), "vanilla trained but produced no telemetry");

    let p1net = regimes::train_float_act_net(&ctx, &f.base, w).unwrap().unwrap();
    let p1 = regimes::run_prop1(&ctx, &p1net, w, a).unwrap().ok().unwrap();
    assert!(p1.mean_loss.is_finite());

    let (p2, tele) = regimes::run_prop2(&ctx, &p1net, w, a, 1).unwrap();
    assert!(p2.is_ok());
    assert!(tele.is_some(), "prop2 trained but produced no telemetry");

    let (p3, tele) = regimes::run_prop3(&ctx, &p1net, w, a).unwrap();
    assert!(p3.is_ok());
    assert!(tele.is_some(), "prop3 trained but produced no telemetry");
}

#[test]
fn float_cell_is_identity_for_prop1() {
    let Some(f) = fixture(22) else { return };
    let ctx = f.ctx();
    // with float weights the p1 seed net is the base itself
    let p1net = regimes::train_float_act_net(&ctx, &f.base, WidthSpec::Float)
        .unwrap()
        .unwrap();
    for (a, b) in p1net.tensors.iter().zip(&f.base.tensors) {
        assert_eq!(a.data(), b.data());
    }
}

#[test]
fn grid_runner_single_cells_and_cache() {
    let Some(f) = fixture(23) else { return };
    let cfg = f.cfg.clone();
    let mut runner = GridRunner::new(
        &f.backend,
        "tiny",
        f.base.clone(),
        f.a_stats.clone(),
        f.train.clone(),
        f.eval.clone(),
        cfg,
    );
    let c1 = runner
        .run_cell(Regime::NoFinetune, WidthSpec::Bits(4), WidthSpec::Bits(4))
        .unwrap();
    assert!(c1.eval.is_ok());
    // prop1 twice with the same weight width: cache must avoid retraining
    let t0 = std::time::Instant::now();
    runner
        .run_cell(Regime::Prop1, WidthSpec::Bits(8), WidthSpec::Bits(8))
        .unwrap();
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    runner
        .run_cell(Regime::Prop1, WidthSpec::Bits(8), WidthSpec::Bits(4))
        .unwrap();
    let second = t1.elapsed();
    assert!(
        second < first,
        "p1 cache miss? first {first:?} second {second:?}"
    );
}

#[test]
fn outcome_cell_strings() {
    let Some(f) = fixture(24) else { return };
    let ctx = f.ctx();
    let out = regimes::run_no_finetune(
        &ctx,
        &f.base,
        WidthSpec::Float,
        WidthSpec::Float,
    )
    .unwrap()
    .ok()
    .unwrap();
    // 60-step tiny net: better than chance (90%)
    assert!(out.top1_err < 0.9, "{out}");
}

// ---- divergence / panic isolation (engine-free: synthetic executors) ----

fn fake_eval(seed: u64) -> EvalResult {
    EvalResult {
        n: 64,
        top1_err: (seed % 97) as f64 / 97.0,
        top5_err: (seed % 31) as f64 / 310.0,
        mean_loss: 1.0 + (seed % 7) as f64,
    }
}

/// A cell whose trainer panics must render "n/a" while every other cell
/// of the grid still completes -- the paper's divergence semantics
/// applied to infrastructure failure.
#[test]
fn panicked_and_diverged_cells_are_isolated() {
    let opts = SweepOpts { workers: 4, ..Default::default() };
    let sweep = grid::run_sweep_with(
        Regime::NoFinetune,
        "tiny",
        7,
        &opts,
        |_| Ok(()),
        |_, job| {
            if job.w == WidthSpec::Bits(8) && job.a == WidthSpec::Bits(8) {
                panic!("trainer exploded mid-step");
            }
            if job.w == WidthSpec::Bits(4) && job.a == WidthSpec::Bits(16) {
                return Err(FxpError::config("simulated infra failure"));
            }
            if job.w == WidthSpec::Bits(4) && job.a == WidthSpec::Bits(4) {
                return Ok(CellEval::Na); // ordinary divergence
            }
            Ok(CellEval::Ok(fake_eval(job.seed)))
        },
    )
    .unwrap();

    assert!(sweep.is_complete());
    assert_eq!(sweep.computed, 16);
    assert_eq!(sweep.failed, 2, "panic + error cells");
    let g = &sweep.grid;
    for dead in [
        (WidthSpec::Bits(8), WidthSpec::Bits(8)),
        (WidthSpec::Bits(4), WidthSpec::Bits(16)),
        (WidthSpec::Bits(4), WidthSpec::Bits(4)),
    ] {
        let c = g.cell(dead.0, dead.1).unwrap();
        assert_eq!(c.eval, CellEval::Na, "{dead:?} should be n/a");
        assert_eq!(c.cell_str(1), "n/a");
    }
    let mut alive = 0;
    for row in &g.outcomes {
        alive += row.iter().filter(|c| c.eval.is_ok()).count();
    }
    assert_eq!(alive, 13);
}

/// Even a worker whose context dies with the panic keeps draining the
/// queue afterwards (the pool re-creates the context).
#[test]
fn single_worker_survives_repeated_panics() {
    let opts = SweepOpts { workers: 1, ..Default::default() };
    let sweep = grid::run_sweep_with(
        Regime::Vanilla,
        "tiny",
        9,
        &opts,
        |_| Ok(()),
        |_, job| {
            if job.a == WidthSpec::Bits(4) {
                panic!("whole row dies");
            }
            Ok(CellEval::Ok(fake_eval(job.seed)))
        },
    )
    .unwrap();
    assert!(sweep.is_complete());
    assert_eq!(sweep.failed, 4, "the a=4 row");
    for row in &sweep.grid.outcomes {
        for c in row {
            assert_eq!(!c.eval.is_ok(), c.a == WidthSpec::Bits(4));
        }
    }
}
