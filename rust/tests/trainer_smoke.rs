//! Integration: the training loop end-to-end on the tiny architecture.

mod common;

use fxpnet::coordinator::trainer::{upd_all, upd_single, upd_top, Trainer};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::model::checkpoint::{save_params, Checkpoint};
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::NetQuant;

/// `None` => artifacts absent; the caller skips.
fn setup(
    seed: u64,
) -> Option<(fxpnet::runtime::Engine, ParamSet, Dataset, LoaderCfg)> {
    let engine = common::engine_opt()?;
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, seed);
    let data = Dataset::generate(256, spec.input[0], spec.input[1], seed);
    let cfg = LoaderCfg {
        batch: spec.train_batch,
        augment: false,
        max_shift: 0,
        seed,
    };
    Some((engine, params, data, cfg))
}

#[test]
fn float_training_reduces_loss() {
    let Some((engine, params, data, lcfg)) = setup(1) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let nq = NetQuant::all_float(spec.num_layers);
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_all(spec.num_layers),
        0.05, 0.9, data, lcfg, 30.0,
    )
    .unwrap();
    let out = tr.run(40, 1).unwrap();
    assert!(!out.diverged);
    assert_eq!(out.steps, 40);
    let first = out.history[0].1;
    let last = out.tail_mean(5);
    assert!(last < first, "loss did not decrease: {first} -> {last}");
}

#[test]
fn update_mask_freezes_layers_through_runtime() {
    let Some((engine, params, data, lcfg)) = setup(2) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let l = spec.num_layers;
    let nq = NetQuant::all_float(l);
    // only the top layer updates
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_top(l, 1), 0.05, 0.9, data, lcfg,
        30.0,
    )
    .unwrap();
    tr.run(5, 1).unwrap();
    let tuned = tr.params().unwrap();
    for li in 0..l {
        let changed = tuned.weight(li).data() != params.weight(li).data();
        assert_eq!(changed, li == l - 1, "layer {li}");
    }
}

#[test]
fn upd_single_only_touches_one_layer() {
    let Some((engine, params, data, lcfg)) = setup(3) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let l = spec.num_layers;
    let nq = NetQuant::all_float(l);
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_single(l, 1), 0.05, 0.0, data,
        lcfg, 30.0,
    )
    .unwrap();
    tr.run(3, 1).unwrap();
    let tuned = tr.params().unwrap();
    for li in 0..l {
        let changed = tuned.weight(li).data() != params.weight(li).data();
        assert_eq!(changed, li == 1, "layer {li}");
    }
}

#[test]
fn set_config_mid_run_preserves_state() {
    let Some((engine, params, data, lcfg)) = setup(4) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let l = spec.num_layers;
    let nq = NetQuant::all_float(l);
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_all(l), 0.05, 0.9, data, lcfg,
        30.0,
    )
    .unwrap();
    tr.run(5, 1).unwrap();
    let mid = tr.params().unwrap();
    // freeze everything: params must stop changing
    tr.set_config(&nq, &vec![0.0; l], 0.05, 0.9).unwrap();
    tr.reset_momenta().unwrap();
    tr.run(5, 1).unwrap();
    let end = tr.params().unwrap();
    for (a, b) in mid.tensors.iter().zip(&end.tensors) {
        assert_eq!(a.data(), b.data());
    }
    assert_eq!(tr.global_step(), 10);
}

#[test]
fn divergence_detector_fires() {
    let Some((engine, params, data, lcfg)) = setup(5) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let nq = NetQuant::all_float(spec.num_layers);
    // absurd lr -> loss blows up
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_all(spec.num_layers),
        1e4, 0.9, data, lcfg, 30.0,
    )
    .unwrap();
    let out = tr.run(50, 1).unwrap();
    assert!(out.diverged, "expected divergence: {:?}", out.history);
    assert!(out.steps < 50);
}

#[test]
fn checkpoint_round_trip_through_trainer() {
    let Some((engine, params, data, lcfg)) = setup(6) else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let nq = NetQuant::all_float(spec.num_layers);
    let mut tr = Trainer::new(
        &engine, "tiny", &params, &nq, &upd_all(spec.num_layers),
        0.05, 0.9, data, lcfg, 30.0,
    )
    .unwrap();
    tr.run(4, 1).unwrap();
    let tuned = tr.params().unwrap();
    let dir = std::env::temp_dir().join("fxp_trainer_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("t.ckpt");
    save_params(&path, "tiny", 4, &tuned).unwrap();
    let back = Checkpoint::load(&path).unwrap();
    back.check_matches("tiny", &spec.params).unwrap();
    for (a, b) in back.params.tensors.iter().zip(&tuned.tensors) {
        assert_eq!(a.data(), b.data());
    }
}
