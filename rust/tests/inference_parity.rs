//! Integration: the pure-integer engine tracks the XLA simulated-
//! quantization path (same grid points up to f32 accumulator roundoff).

mod common;

use fxpnet::cli::commands::evaluate_logits;
use fxpnet::coordinator::calibrate;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::verify::parity_report;
use fxpnet::inference::FixedPointNet;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::{NetQuant, WidthSpec};

fn cell(
    engine: &fxpnet::runtime::Engine,
    params: &ParamSet,
    data: &Dataset,
    bits: u8,
) -> NetQuant {
    let calib =
        calibrate::activation_stats(engine, "tiny", params, data, 2).unwrap();
    NetQuant::for_cell(
        WidthSpec::Bits(bits),
        WidthSpec::Bits(bits),
        &params.weight_stats(),
        &calib.a_stats,
        CalibMethod::MinMax,
    )
    .unwrap()
}

#[test]
fn engine_matches_xla_path_8bit() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 3);
    let data = Dataset::generate(64, spec.input[0], spec.input[1], 11);
    let nq = cell(&engine, &params, &data, 8);

    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
            .unwrap();
    let int_logits = net.forward_batch(&data.images).unwrap();
    let xla_logits = evaluate_logits(&engine, "tiny", &params, &nq, &data).unwrap();

    let p = parity_report(&int_logits, &xla_logits).unwrap();
    // predictions match; logit differences stay below one hidden-layer LSB
    // (a 1-LSB hidden difference -- f32 accumulator roundoff at a rounding
    // tie -- propagates to the logits scaled by downstream weights)
    assert!(p.top1_agreement >= 0.95, "{p}");
    let hidden_step = nq.acts[..nq.acts.len() - 1]
        .iter()
        .map(|a| a.unwrap().step())
        .fold(0f32, f32::max);
    assert!(p.linf <= hidden_step, "{p} (hidden step {hidden_step})");
    assert!(p.l1 <= hidden_step * 0.05, "{p}");
}

#[test]
fn engine_matches_xla_path_4bit() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 4);
    let data = Dataset::generate(64, spec.input[0], spec.input[1], 12);
    let nq = cell(&engine, &params, &data, 4);
    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
            .unwrap();
    let int_logits = net.forward_batch(&data.images).unwrap();
    let xla_logits = evaluate_logits(&engine, "tiny", &params, &nq, &data).unwrap();
    let p = parity_report(&int_logits, &xla_logits).unwrap();
    // coarser grid -> coarser agreement, but predictions still track
    assert!(p.top1_agreement >= 0.90, "{p}");
}

#[test]
fn engine_rejects_float_hidden_layers() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 5);
    let nq = NetQuant::all_float(spec.num_layers);
    assert!(FixedPointNet::build(
        &spec,
        &params,
        &nq,
        QFormat::new(16, 14).unwrap()
    )
    .is_err());
}

#[test]
fn macs_counter_is_positive() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 6);
    let data = Dataset::generate(32, spec.input[0], spec.input[1], 13);
    let nq = cell(&engine, &params, &data, 8);
    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
            .unwrap();
    assert!(net.macs_per_image() > 10_000);
}
