//! Regression: serial/parallel/multi-process equivalence of the grid
//! sweep engine.
//!
//! The determinism contract under test: a sweep's `CellOutcome` table is
//! a pure function of `(base_seed, regime, arch)` -- worker count,
//! scheduling order, sharding, resume-from-cache, per-shard cache files
//! and `grid merge` must all be invisible in the results, bit for bit.
//!
//! Cells are synthetic (`grid::synthetic_cell`: seeded RNG work, no XLA
//! engine) so the tests run in the offline build; the real regimes feed
//! every stochastic stream from the same per-cell seeds
//! (`grid::cell_seed`), which is exactly the property exercised here.

use std::path::{Path, PathBuf};
use std::time::Duration;

use fxpnet::coordinator::grid::{self, GridResult, SweepOpts};
use fxpnet::coordinator::regimes::Regime;
use fxpnet::coordinator::report::CACHE_VERSION;
use fxpnet::coordinator::shard::{
    self, lock_path, FileLock, LockOpts, SweepManifest,
};

fn sweep(base_seed: u64, opts: &SweepOpts) -> grid::SweepOutcome {
    grid::run_sweep_with(
        Regime::Vanilla,
        "tiny",
        base_seed,
        opts,
        |_wid| Ok(()),
        |_, job| grid::synthetic_cell(job),
    )
    .unwrap()
}

/// Exact bit pattern of a grid (None = n/a or aborted cell).
fn bits(g: &GridResult) -> Vec<Option<(usize, u64, u64, u64)>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| {
            c.eval.ok().map(|e| {
                (
                    e.n,
                    e.top1_err.to_bits(),
                    e.top5_err.to_bits(),
                    e.mean_loss.to_bits(),
                )
            })
        })
        .collect()
}

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fxp_grid_parallel_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run all `count` shards of a sweep into per-shard cache files and
/// return the shard file paths.
fn run_split_shards(dir: &Path, base_seed: u64, count: usize) -> Vec<PathBuf> {
    let base = dir.join("cache.json");
    (0..count)
        .map(|index| {
            let opts = SweepOpts {
                workers: 2,
                shard: Some((index, count)),
                cache_path: Some(base.clone()),
                split_cache: true,
                ..Default::default()
            };
            let out = sweep(base_seed, &opts);
            assert!(!out.is_complete() || count == 1);
            let path = opts.cache_file().unwrap();
            assert!(path.exists(), "{} missing", path.display());
            path
        })
        .collect()
}

#[test]
fn worker_count_is_invisible_in_results() {
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    assert!(reference.is_complete());
    assert_eq!(reference.computed, 16);
    // the synthetic divergence rate must actually exercise the n/a path
    let nas = bits(&reference.grid).iter().filter(|b| b.is_none()).count();
    assert!(nas > 0, "no n/a cells; raise the synthetic divergence rate");
    assert!(nas < 16, "every cell n/a; synthetic executor broken");

    for workers in [2, 4] {
        let out = sweep(42, &SweepOpts { workers, ..Default::default() });
        assert_eq!(
            bits(&reference.grid),
            bits(&out.grid),
            "results differ between 1 and {workers} workers"
        );
        assert_eq!(out.pool.workers, workers);
    }
}

#[test]
fn different_base_seeds_differ() {
    let a = sweep(42, &SweepOpts { workers: 4, ..Default::default() });
    let b = sweep(43, &SweepOpts { workers: 4, ..Default::default() });
    assert_ne!(bits(&a.grid), bits(&b.grid));
}

#[test]
fn shards_union_to_the_unsharded_result() {
    let reference = sweep(42, &SweepOpts { workers: 4, ..Default::default() });
    let dir = temp_dir("shards");
    let cache = dir.join("cache.json");

    // run 3 shards sequentially against one shared cache
    let mut last = None;
    for index in 0..3 {
        let out = sweep(
            42,
            &SweepOpts {
                workers: 2,
                shard: Some((index, 3)),
                cache_path: Some(cache.clone()),
                ..Default::default()
            },
        );
        // a shard computes ~1/3 of the 16 cells
        assert!((5..=6).contains(&out.computed), "{}", out.computed);
        if index < 2 {
            assert!(!out.is_complete());
        }
        last = Some(out);
    }
    let last = last.unwrap();
    // after the final shard, earlier shards' cells come from the cache
    assert!(last.is_complete(), "missing {}", last.missing);
    assert_eq!(last.cached, 16 - last.computed);
    assert_eq!(
        bits(&reference.grid),
        bits(&last.grid),
        "sharded union differs from the unsharded sweep"
    );
    // the sweep released its advisory lock on completion
    assert!(!lock_path(&cache).exists());
}

#[test]
fn resume_skips_cached_cells_and_preserves_bits() {
    let dir = temp_dir("resume");
    let cache = dir.join("cache.json");
    let opts = SweepOpts {
        workers: 4,
        cache_path: Some(cache.clone()),
        resume: true,
        ..Default::default()
    };
    let first = sweep(42, &opts);
    assert_eq!(first.computed, 16);
    assert_eq!(first.cached, 0);
    assert!(cache.exists());

    // second run: everything (including n/a cells) comes from the cache
    let second = sweep(42, &opts);
    assert_eq!(second.computed, 0, "resume recomputed cells");
    assert_eq!(second.cached, 16);
    assert_eq!(bits(&first.grid), bits(&second.grid));

    // a different base seed must not accept the stale cache
    let third = sweep(43, &opts);
    assert_eq!(third.computed, 16, "stale cache was reused across seeds");
}

#[test]
fn sharding_without_cache_is_partial_but_ordered() {
    let out = sweep(
        42,
        &SweepOpts { workers: 2, shard: Some((1, 4)), ..Default::default() },
    );
    assert_eq!(out.computed, 4);
    assert_eq!(out.missing, 12);
    assert!(!out.is_complete());
    // computed cells sit exactly at flat % 4 == 1
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    let full = bits(&reference.grid);
    for (flat, cell) in bits(&out.grid).iter().enumerate() {
        if flat % 4 == 1 {
            assert_eq!(cell, &full[flat], "cell {flat}");
        } else {
            assert!(cell.is_none(), "cell {flat} should be missing/n-a");
        }
    }
}

// -- multi-process sharding: per-shard caches + merge -------------------------

#[test]
fn merged_shard_caches_equal_the_serial_table_bit_exactly() {
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    for count in [2usize, 3] {
        let dir = temp_dir(&format!("merge{count}"));
        let files = run_split_shards(&dir, 42, count);
        let manifest = SweepManifest::new("tiny", Regime::Vanilla, 42, count).unwrap();

        // merge without and with the manifest; both must be complete
        for m in [None, Some(&manifest)] {
            let merged = shard::merge_files(&files, m).unwrap();
            assert!(
                merged.is_complete(),
                "{count} shards: missing {:?}",
                merged.missing
            );
            assert_eq!(merged.merged_files, count);
            assert_eq!(merged.duplicates, 0);
            assert_eq!(
                bits(&reference.grid),
                bits(&merged.to_grid()),
                "{count}-shard merge differs from the serial sweep"
            );
        }

        // the saved union is a valid whole-sweep cache: resuming from it
        // computes nothing and reproduces the same table
        let out = dir.join("merged.json");
        shard::merge_files(&files, Some(&manifest)).unwrap().save(&out).unwrap();
        let resumed = sweep(
            42,
            &SweepOpts {
                workers: 2,
                cache_path: Some(out),
                resume: true,
                ..Default::default()
            },
        );
        assert_eq!(resumed.computed, 0);
        assert_eq!(bits(&reference.grid), bits(&resumed.grid));
    }
}

#[test]
fn merge_reports_missing_cells_of_a_partial_union() {
    let dir = temp_dir("merge_partial");
    let files = run_split_shards(&dir, 42, 3);
    let merged = shard::merge_files(&files[..2], None).unwrap();
    assert!(!merged.is_complete());
    // shard 2 of 3 owns flat = 2, 5, 8, 11, 14
    assert_eq!(merged.missing.len(), 5);
    let manifest = SweepManifest::new("tiny", Regime::Vanilla, 42, 3).unwrap();
    let mut expected: Vec<String> = manifest.shards[2].clone();
    let mut got = merged.missing.clone();
    expected.sort();
    got.sort();
    assert_eq!(got, expected);
    assert!(merged.summary().contains("11/16"));
}

#[test]
fn merge_rejects_shards_from_different_sweeps_and_versions() {
    let dir = temp_dir("merge_reject");
    let a = run_split_shards(&dir.join("a"), 42, 2);
    let b = run_split_shards(&dir.join("b"), 43, 2);

    // different base seed => different sweep
    let err =
        shard::merge_files(&[a[0].clone(), b[1].clone()], None).unwrap_err();
    assert!(err.to_string().contains("different sweeps"), "{err}");

    // version tampering => hard error naming the file and version
    let text = std::fs::read_to_string(&a[0]).unwrap();
    let tampered_path = dir.join("tampered.json");
    let tampered =
        text.replace(&format!("\"version\":{CACHE_VERSION}"), "\"version\":1");
    assert_ne!(text, tampered, "version field not found to tamper");
    std::fs::write(&tampered_path, tampered).unwrap();
    let err = shard::merge_files(&[tampered_path.clone()], None).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("version 1"), "{msg}");
    assert!(msg.contains("tampered.json"), "{msg}");

    // unparseable file => hard error (merge is strict, unlike --resume)
    std::fs::write(&tampered_path, "{not json").unwrap();
    assert!(shard::merge_files(&[tampered_path], None).is_err());

    // manifest mismatch: files from seed 43 against a seed-42 manifest
    let manifest = SweepManifest::new("tiny", Regime::Vanilla, 42, 2).unwrap();
    let err = shard::merge_files(&b, Some(&manifest)).unwrap_err();
    assert!(err.to_string().contains("does not belong"), "{err}");
}

#[test]
fn merge_conflict_on_one_cell_is_a_hard_error_naming_it() {
    let dir = temp_dir("merge_conflict");
    let files = run_split_shards(&dir, 42, 2);
    // forge a copy of shard 1 claiming different bits for one cell
    let text = std::fs::read_to_string(&files[1]).unwrap();
    let forged = text.replacen("\"top1_err\":0.", "\"top1_err\":0.99", 1);
    assert_ne!(text, forged, "no ok cell found to forge");
    let forged_path = dir.join("cache.shard-1-of-2.forged.json");
    std::fs::write(&forged_path, forged).unwrap();

    let err = shard::merge_files(&[files[1].clone(), forged_path], None)
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("merge conflict at cell 'w="), "{msg}");
    assert!(msg.contains("forged"), "{msg}");

    // identical duplicate inputs, by contrast, merge fine
    let merged =
        shard::merge_files(&[files[1].clone(), files[1].clone()], None).unwrap();
    assert!(merged.duplicates > 0);
}

#[test]
fn merge_skips_tmp_and_lock_litter() {
    let dir = temp_dir("merge_litter");
    let files = run_split_shards(&dir, 42, 2);
    // crash litter: an interrupted save and an abandoned lock file
    let tmp = dir.join(".cache.json.12345-0.tmp");
    std::fs::write(&tmp, "{half a json").unwrap();
    let lock = dir.join("cache.json.lock");
    std::fs::write(&lock, "{\"pid\": 1, \"host\": \"gone\"}").unwrap();

    let mut inputs = files.clone();
    inputs.push(tmp.clone());
    inputs.push(lock.clone());
    let merged = shard::merge_files(&inputs, None).unwrap();
    assert!(merged.is_complete());
    assert_eq!(merged.skipped, vec![tmp.clone(), lock.clone()]);

    // but merging *only* litter is an error, not an empty success
    assert!(shard::merge_files(&[tmp, lock], None).is_err());
}

// -- cross-process lock protection --------------------------------------------

#[test]
fn second_opener_of_a_locked_cache_errors_cleanly() {
    let dir = temp_dir("lock_contention");
    let cache = dir.join("cache.json");
    let _held = FileLock::acquire(&cache, &LockOpts::default()).unwrap();
    let opts = SweepOpts {
        workers: 1,
        cache_path: Some(cache.clone()),
        lock: LockOpts {
            wait: Duration::from_millis(100),
            poll: Duration::from_millis(10),
        },
        ..Default::default()
    };
    let err = grid::run_sweep_with(
        Regime::Vanilla,
        "tiny",
        42,
        &opts,
        |_wid| Ok(()),
        |_, job| grid::synthetic_cell(job),
    )
    .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("held by"), "{msg}");
    assert!(msg.contains(&std::process::id().to_string()), "{msg}");
}

#[test]
fn waiting_opener_proceeds_once_the_lock_is_released() {
    let dir = temp_dir("lock_wait");
    let cache = dir.join("cache.json");
    let held = FileLock::acquire(&cache, &LockOpts::default()).unwrap();
    let release = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        drop(held);
    });
    let opts = SweepOpts {
        workers: 2,
        cache_path: Some(cache.clone()),
        lock: LockOpts {
            wait: Duration::from_secs(30),
            poll: Duration::from_millis(10),
        },
        ..Default::default()
    };
    let out = sweep(42, &opts);
    assert!(out.is_complete());
    release.join().unwrap();
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    assert_eq!(bits(&reference.grid), bits(&out.grid));
}

#[test]
fn stale_lock_from_a_dead_pid_is_reclaimed_by_a_sweep() {
    if !std::path::Path::new("/proc/self").exists() {
        return; // liveness is undecidable without procfs
    }
    let dir = temp_dir("lock_stale");
    let cache = dir.join("cache.json");
    // pid_max on Linux caps at 2^22, so this owner cannot exist
    std::fs::write(
        lock_path(&cache),
        format!(
            "{{\"pid\": 4194305, \"host\": \"{}\", \"instance\": \"{}\"}}",
            shard::hostname(),
            shard::instance_id()
        ),
    )
    .unwrap();
    let opts = SweepOpts {
        workers: 2,
        cache_path: Some(cache.clone()),
        lock: LockOpts {
            wait: Duration::from_millis(500),
            poll: Duration::from_millis(10),
        },
        ..Default::default()
    };
    let out = sweep(42, &opts);
    assert!(out.is_complete(), "stale lock was not reclaimed");
    assert!(!lock_path(&cache).exists());
}
