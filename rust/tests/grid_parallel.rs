//! Regression: serial/parallel equivalence of the grid sweep engine.
//!
//! The determinism contract under test: a sweep's `CellOutcome` table is
//! a pure function of `(base_seed, regime, arch)` -- worker count,
//! scheduling order, sharding, and resume-from-cache must all be
//! invisible in the results, bit for bit.
//!
//! Cells here are synthetic (seeded RNG work, no XLA engine) so the test
//! runs in the offline build; the real regimes feed every stochastic
//! stream from the same per-cell seeds (`grid::cell_seed`), which is
//! exactly the property exercised here.

use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::grid::{self, CellJob, GridResult, SweepOpts};
use fxpnet::coordinator::regimes::{CellResult, Regime};
use fxpnet::util::rng::Rng;

/// Deterministic synthetic cell: a few thousand RNG draws (stand-in for
/// training) whose outcome -- including the "diverged -> n/a" case --
/// depends only on the job's derived seed.
fn fake_cell(job: &CellJob) -> fxpnet::Result<CellResult> {
    let mut rng = Rng::new(job.seed);
    let mut acc = 0.0f64;
    for _ in 0..2000 {
        acc += rng.uniform();
    }
    if rng.uniform() < 0.2 {
        return Ok(None); // this cell "fails to converge"
    }
    Ok(Some(EvalResult {
        n: 1000 + rng.below(1000),
        top1_err: rng.uniform(),
        top5_err: rng.uniform() * 0.5,
        mean_loss: acc / 1000.0,
    }))
}

fn sweep(base_seed: u64, opts: &SweepOpts) -> grid::SweepOutcome {
    grid::run_sweep_with(
        Regime::Vanilla,
        "tiny",
        base_seed,
        opts,
        |_wid| Ok(()),
        |_, job| fake_cell(job),
    )
    .unwrap()
}

/// Exact bit pattern of a grid (None = n/a cell).
fn bits(g: &GridResult) -> Vec<Option<(usize, u64, u64, u64)>> {
    g.outcomes
        .iter()
        .flatten()
        .map(|c| {
            c.eval.map(|e| {
                (
                    e.n,
                    e.top1_err.to_bits(),
                    e.top5_err.to_bits(),
                    e.mean_loss.to_bits(),
                )
            })
        })
        .collect()
}

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fxp_grid_parallel_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn worker_count_is_invisible_in_results() {
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    assert!(reference.is_complete());
    assert_eq!(reference.computed, 16);
    // the synthetic divergence rate must actually exercise the n/a path
    let nas = bits(&reference.grid).iter().filter(|b| b.is_none()).count();
    assert!(nas > 0, "no n/a cells; raise the synthetic divergence rate");
    assert!(nas < 16, "every cell n/a; synthetic executor broken");

    for workers in [2, 4] {
        let out = sweep(42, &SweepOpts { workers, ..Default::default() });
        assert_eq!(
            bits(&reference.grid),
            bits(&out.grid),
            "results differ between 1 and {workers} workers"
        );
        assert_eq!(out.pool.workers, workers);
    }
}

#[test]
fn different_base_seeds_differ() {
    let a = sweep(42, &SweepOpts { workers: 4, ..Default::default() });
    let b = sweep(43, &SweepOpts { workers: 4, ..Default::default() });
    assert_ne!(bits(&a.grid), bits(&b.grid));
}

#[test]
fn shards_union_to_the_unsharded_result() {
    let reference = sweep(42, &SweepOpts { workers: 4, ..Default::default() });
    let dir = temp_dir("shards");
    let cache = dir.join("cache.json");

    // run 3 shards sequentially against one shared cache
    let mut last = None;
    for index in 0..3 {
        let out = sweep(
            42,
            &SweepOpts {
                workers: 2,
                shard: Some((index, 3)),
                cache_path: Some(cache.clone()),
                resume: false,
            },
        );
        // a shard computes ~1/3 of the 16 cells
        assert!((5..=6).contains(&out.computed), "{}", out.computed);
        if index < 2 {
            assert!(!out.is_complete());
        }
        last = Some(out);
    }
    let last = last.unwrap();
    // after the final shard, earlier shards' cells come from the cache
    assert!(last.is_complete(), "missing {}", last.missing);
    assert_eq!(last.cached, 16 - last.computed);
    assert_eq!(
        bits(&reference.grid),
        bits(&last.grid),
        "sharded union differs from the unsharded sweep"
    );
}

#[test]
fn resume_skips_cached_cells_and_preserves_bits() {
    let dir = temp_dir("resume");
    let cache = dir.join("cache.json");
    let opts = SweepOpts {
        workers: 4,
        shard: None,
        cache_path: Some(cache.clone()),
        resume: true,
    };
    let first = sweep(42, &opts);
    assert_eq!(first.computed, 16);
    assert_eq!(first.cached, 0);
    assert!(cache.exists());

    // second run: everything (including n/a cells) comes from the cache
    let second = sweep(42, &opts);
    assert_eq!(second.computed, 0, "resume recomputed cells");
    assert_eq!(second.cached, 16);
    assert_eq!(bits(&first.grid), bits(&second.grid));

    // a different base seed must not accept the stale cache
    let third = sweep(43, &opts);
    assert_eq!(third.computed, 16, "stale cache was reused across seeds");
}

#[test]
fn sharding_without_cache_is_partial_but_ordered() {
    let out = sweep(
        42,
        &SweepOpts {
            workers: 2,
            shard: Some((1, 4)),
            cache_path: None,
            resume: false,
        },
    );
    assert_eq!(out.computed, 4);
    assert_eq!(out.missing, 12);
    assert!(!out.is_complete());
    // computed cells sit exactly at flat % 4 == 1
    let reference = sweep(42, &SweepOpts { workers: 1, ..Default::default() });
    let full = bits(&reference.grid);
    for (flat, cell) in bits(&out.grid).iter().enumerate() {
        if flat % 4 == 1 {
            assert_eq!(cell, &full[flat], "cell {flat}");
        } else {
            assert!(cell.is_none(), "cell {flat} should be missing/n-a");
        }
    }
}
