//! End-to-end tests for the `fxpnet serve` daemon: reply-bit
//! determinism across batch configurations, latency-budget flushes,
//! graceful drain with no silently dropped requests, and
//! malformed-frame handling over a real TCP connection (reusing the
//! codec-level corpus from cluster_proto.rs against the shared
//! `netio` framing).
//!
//! Runs entirely offline: the model is a small random fixture net
//! (8x8x3 -> conv8 -> pool -> fc10), no artifacts needed.

use std::collections::BTreeMap;
use std::io::Write as _;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use fxpnet::bench::fixtures::int_engine_cell;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::{FixedPointNet, InferSession};
use fxpnet::model::manifest::ArchSpec;
use fxpnet::serve::proto::{
    read_serve_frame, write_serve_frame, ServeFrame, ServeMsg, SERVE_PROTO_VERSION,
};
use fxpnet::serve::{run_server, ServeOpts, ServeSummary};
use fxpnet::util::rng::Rng;

const PX: usize = 8 * 8 * 3;
const CLASSES: usize = 10;

fn small_arch() -> ArchSpec {
    ArchSpec {
        name: "serve-net".into(),
        input: [8, 8, 3],
        num_classes: CLASSES,
        num_layers: 2,
        train_batch: 8,
        eval_batch: 8,
        layers: vec![
            ("conv".into(), 8),
            ("pool".into(), 0),
            ("fc".into(), CLASSES),
        ],
        params: vec![
            ("l0.w".into(), vec![3, 3, 3, 8]),
            ("l0.b".into(), vec![8]),
            ("l1.w".into(), vec![4 * 4 * 8, CLASSES]),
            ("l1.b".into(), vec![CLASSES]),
        ],
        artifacts: BTreeMap::new(),
    }
}

fn fixture_net() -> Arc<FixedPointNet> {
    let spec = small_arch();
    let (params, nq) = int_engine_cell(&spec, 8, 42).unwrap();
    Arc::new(
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
            .unwrap(),
    )
}

fn test_images(n: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| (0..PX).map(|_| rng.uniform() as f32).collect())
        .collect()
}

/// A running daemon + the handle to stop it.
struct TestServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<fxpnet::Result<ServeSummary>>,
}

impl TestServer {
    fn start(max_batch: usize, max_wait: Duration, threads: usize) -> TestServer {
        TestServer::start_with_queue(max_batch, max_wait, threads, 0)
    }

    fn start_with_queue(
        max_batch: usize,
        max_wait: Duration,
        threads: usize,
        max_queue: usize,
    ) -> TestServer {
        let net = fixture_net();
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::spawn(move || {
            let opts = ServeOpts {
                listen: "127.0.0.1:0".into(),
                port_file: None,
                max_batch,
                max_wait,
                max_queue,
                threads,
            };
            run_server(net, &opts, &flag, Some(tx))
        });
        let addr = rx.recv_timeout(Duration::from_secs(10)).expect("server up");
        TestServer { addr, shutdown, handle }
    }

    fn stop(self) -> ServeSummary {
        self.shutdown.store(true, Ordering::SeqCst);
        self.handle.join().unwrap().unwrap()
    }
}

fn connect(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    s
}

fn send(s: &mut TcpStream, msg: &ServeMsg) {
    write_serve_frame(s, msg).unwrap();
}

fn recv(s: &mut TcpStream) -> ServeMsg {
    match read_serve_frame(s, Some(Instant::now() + Duration::from_secs(20))).unwrap()
    {
        ServeFrame::Msg(m) => m,
        other => panic!("expected a message, got {other:?}"),
    }
}

fn infer_ok(s: &mut TcpStream, id: u64, image: &[f32]) -> (Vec<f32>, usize, usize) {
    send(s, &ServeMsg::Infer { id, image: image.to_vec() });
    match recv(s) {
        ServeMsg::Logits { id: rid, logits, argmax, batch_n, .. } => {
            assert_eq!(rid, id);
            (logits, argmax, batch_n)
        }
        other => panic!("expected logits for {id}, got {other:?}"),
    }
}

#[test]
fn ping_and_info_round_trip() {
    let srv = TestServer::start(4, Duration::from_millis(5), 1);
    let mut c = connect(srv.addr);
    send(&mut c, &ServeMsg::Ping);
    assert_eq!(recv(&mut c), ServeMsg::Pong);
    send(&mut c, &ServeMsg::Info);
    match recv(&mut c) {
        ServeMsg::InfoReply { proto, h, w, c: ch, classes, max_batch, .. } => {
            assert_eq!(proto, SERVE_PROTO_VERSION);
            assert_eq!((h, w, ch), (8, 8, 3));
            assert_eq!(classes, CLASSES);
            assert_eq!(max_batch, 4);
        }
        other => panic!("{other:?}"),
    }
    drop(c);
    srv.stop();
}

/// The tentpole determinism contract: a request's logits are
/// bit-identical whatever batch it coalesces into -- across servers
/// configured with max_batch 1, 4, and 8, concurrent clients, and
/// multi-threaded GEMM -- and equal to an offline batch-of-1 reference.
#[test]
fn replies_are_bit_identical_for_any_batching() {
    let images = test_images(16, 9);

    // offline reference: warm session, one image at a time
    let net = fixture_net();
    let mut reference = InferSession::new(net, 1, 1);
    let want: Vec<Vec<u32>> = images
        .iter()
        .map(|img| {
            reference.run(img, 1).unwrap().iter().map(|v| v.to_bits()).collect()
        })
        .collect();

    for (max_batch, threads) in [(1, 1), (4, 2), (8, 2)] {
        // a wait budget long enough that concurrent requests really
        // coalesce into multi-row batches
        let srv = TestServer::start(max_batch, Duration::from_millis(40), threads);
        let mut batch_sizes = Vec::new();
        std::thread::scope(|s| {
            let handles: Vec<_> = images
                .iter()
                .enumerate()
                .map(|(i, img)| {
                    let addr = srv.addr;
                    s.spawn(move || {
                        let mut c = connect(addr);
                        let (logits, argmax, batch_n) =
                            infer_ok(&mut c, i as u64, img);
                        (i, logits, argmax, batch_n)
                    })
                })
                .collect();
            for h in handles {
                let (i, logits, argmax, batch_n) = h.join().unwrap();
                let got: Vec<u32> = logits.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    got, want[i],
                    "image {i}: logits differ under max_batch={max_batch}"
                );
                // the argmax must match a scan of the reference bits too
                let ref_argmax = want[i]
                    .iter()
                    .map(|&b| f32::from_bits(b))
                    .enumerate()
                    .fold(0usize, |best, (k, v)| {
                        if v > f32::from_bits(want[i][best]) { k } else { best }
                    });
                assert_eq!(argmax, ref_argmax, "image {i} argmax");
                batch_sizes.push(batch_n);
            }
        });
        assert!(
            batch_sizes.iter().all(|&b| (1..=max_batch).contains(&b)),
            "batch sizes out of range: {batch_sizes:?}"
        );
        let summary = srv.stop();
        assert_eq!(summary.requests, 16);
        assert!(summary.drained);
    }
}

#[test]
fn lone_request_flushes_at_the_latency_budget_not_never() {
    let srv = TestServer::start(8, Duration::from_millis(30), 1);
    let images = test_images(1, 3);
    let mut c = connect(srv.addr);
    let t0 = Instant::now();
    let (_, _, batch_n) = infer_ok(&mut c, 0, &images[0]);
    let waited = t0.elapsed();
    assert_eq!(batch_n, 1, "a lone request rides a batch of 1");
    assert!(
        waited < Duration::from_secs(10),
        "single request took {waited:?}: the budget flush never fired"
    );
    drop(c);
    srv.stop();
}

#[test]
fn wrong_sized_image_is_rejected_without_killing_the_connection() {
    let srv = TestServer::start(4, Duration::from_millis(5), 1);
    let images = test_images(1, 5);
    let mut c = connect(srv.addr);
    send(&mut c, &ServeMsg::Infer { id: 77, image: vec![0.5; 5] });
    match recv(&mut c) {
        ServeMsg::Error { id, reason } => {
            assert_eq!(id, Some(77), "error must echo the request id");
            assert!(reason.contains("5"), "unhelpful reason: {reason}");
        }
        other => panic!("{other:?}"),
    }
    // the same connection still serves valid requests
    let (logits, _, _) = infer_ok(&mut c, 78, &images[0]);
    assert_eq!(logits.len(), CLASSES);
    drop(c);
    srv.stop();
}

/// Drain contract: everything admitted before the signal still gets its
/// logits; requests arriving during the drain get an explicit
/// `Error{"draining"}`; the daemon then exits cleanly with an accurate
/// summary.
#[test]
fn drain_answers_every_admitted_request_and_rejects_late_ones() {
    // max_batch larger than the request count and a long budget: nothing
    // flushes until the drain itself, so every request is provably
    // queued when the signal lands
    let n = 12;
    let srv = TestServer::start(16, Duration::from_secs(5), 1);
    let images = test_images(n, 21);

    let mut conns: Vec<TcpStream> = (0..n).map(|_| connect(srv.addr)).collect();
    for (i, c) in conns.iter_mut().enumerate() {
        send(c, &ServeMsg::Infer { id: i as u64, image: images[i].clone() });
    }
    // wait until the server has admitted all n (they sit in the queue;
    // none can have flushed)
    std::thread::sleep(Duration::from_millis(300));
    srv.shutdown.store(true, Ordering::SeqCst);

    let mut answered = 0;
    for (i, c) in conns.iter_mut().enumerate() {
        match recv(c) {
            ServeMsg::Logits { id, batch_n, .. } => {
                assert_eq!(id, i as u64);
                assert_eq!(batch_n, n, "drain should flush all {n} as one batch");
                answered += 1;
            }
            other => panic!("request {i}: {other:?}"),
        }
    }
    assert_eq!(answered, n, "an admitted request was dropped in the drain");

    let summary = srv.handle.join().unwrap().unwrap();
    assert_eq!(summary.requests, n as u64);
    assert!(summary.drained);
    assert_eq!(
        summary.batch_hist[n], 1,
        "summary histogram should show the one drain batch"
    );
}

/// Backpressure contract: with the admission queue bounded, overflow
/// requests get an explicit `Busy{id}` reply (not an error, not a
/// hangup), already-admitted requests are unaffected, and the summary
/// counts the rejects separately from protocol errors.
#[test]
fn full_queue_replies_busy_and_admitted_requests_still_answer() {
    // max_batch above the queue bound and a long wait budget: admitted
    // requests provably sit in the queue, so the third push overflows
    let srv =
        TestServer::start_with_queue(16, Duration::from_secs(5), 1, 2);
    let images = test_images(3, 33);

    let mut conns: Vec<TcpStream> = (0..3).map(|_| connect(srv.addr)).collect();
    for (i, c) in conns.iter_mut().enumerate().take(2) {
        send(c, &ServeMsg::Infer { id: i as u64, image: images[i].clone() });
    }
    // let both handler threads admit before overflowing
    std::thread::sleep(Duration::from_millis(300));
    send(&mut conns[2], &ServeMsg::Infer { id: 2, image: images[2].clone() });
    match recv(&mut conns[2]) {
        ServeMsg::Busy { id } => assert_eq!(id, 2, "busy must echo the id"),
        other => panic!("expected busy, got {other:?}"),
    }

    // the rejected client's connection survives: once the queue drains
    // (here: via shutdown flush), admitted requests answer normally
    srv.shutdown.store(true, Ordering::SeqCst);
    for (i, c) in conns.iter_mut().enumerate().take(2) {
        match recv(c) {
            ServeMsg::Logits { id, .. } => assert_eq!(id, i as u64),
            other => panic!("request {i}: {other:?}"),
        }
    }
    let summary = srv.handle.join().unwrap().unwrap();
    assert_eq!(summary.requests, 2);
    assert_eq!(summary.busy, 1, "one busy reject in the summary");
    assert_eq!(summary.rejected, 0, "busy is not a drain reject");
}

/// The codec-level malformed corpus from cluster_proto.rs, fired at the
/// serve daemon over real TCP: each must produce a clean per-connection
/// failure (an `Error` reply and/or a hangup -- never a panic), and the
/// daemon must keep serving other clients afterwards.
#[test]
fn malformed_frames_never_kill_the_daemon() {
    let max = fxpnet::cluster::proto::MAX_FRAME;
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("oversized length prefix", ((max + 1) as u32).to_le_bytes().to_vec()),
        ("huge length prefix", u32::MAX.to_le_bytes().to_vec()),
        ("truncated length prefix", vec![9, 0]),
        ("truncated payload", {
            let mut v = 100u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"{\"type\":\"ping\"}");
            v
        }),
        ("not json", {
            let mut v = 5u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"hello");
            v
        }),
        ("not utf8", {
            let mut v = 4u32.to_le_bytes().to_vec();
            v.extend_from_slice(&[0xFF, 0xFE, 0xFD, 0xFC]);
            v
        }),
        ("json but not an object", {
            let payload = b"[1,2,3]";
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("object without type", {
            let payload = br#"{"id":3}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("unknown type", {
            let payload = br#"{"type":"subspace-anomaly"}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("infer with string id", {
            let payload = br#"{"type":"infer","id":"x","image":[]}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("server-to-client message from a client", {
            let payload = br#"{"type":"pong"}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
    ];

    let srv = TestServer::start(4, Duration::from_millis(5), 1);
    let images = test_images(1, 13);
    for (what, bytes) in &cases {
        let mut c = connect(srv.addr);
        c.write_all(bytes).unwrap();
        // closing our write side turns truncated frames into mid-frame
        // EOF server-side (a fast, clean rejection rather than a
        // deadline stall)
        c.shutdown(std::net::Shutdown::Write).unwrap();
        // the server replies Error where it can, then hangs up; all we
        // require is no hang and no panic
        let deadline = Some(Instant::now() + Duration::from_secs(10));
        match read_serve_frame(&mut c, deadline) {
            Ok(ServeFrame::Msg(ServeMsg::Error { .. })) | Ok(ServeFrame::Eof) => {}
            Ok(other) => panic!("{what}: unexpected {other:?}"),
            Err(_) => {} // connection reset mid-reply is acceptable too
        }
        drop(c);
        // liveness probe: a well-formed client still gets served
        let mut ok = connect(srv.addr);
        let (logits, _, _) = infer_ok(&mut ok, 1, &images[0]);
        assert_eq!(logits.len(), CLASSES, "{what}: daemon damaged");
        drop(ok);
    }
    let summary = srv.stop();
    assert_eq!(summary.requests, cases.len() as u64, "one probe per case");
}
