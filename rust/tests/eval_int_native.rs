//! The native backend's two evaluation paths must agree: fully
//! quantized cells run on the pure-integer batched GEMM engine (the
//! deployment-grade number grid tables now report), while the
//! simulated-quantization float forward remains the training-time
//! semantics.  The paths share the weight/activation grids but differ in
//! arithmetic -- exact integer accumulation + Q16.14 input codes vs f32
//! rounding -- so agreement is pinned to a tolerance, not bit-exact
//! (cf. `inference::verify::parity_report` for the XLA-side analogue).
//!
//! Everything here runs in the offline build -- no artifacts, no XLA.

use fxpnet::coordinator::backend::{Backend, SessionCfg};
use fxpnet::coordinator::evaluator::evaluate_int_batched;
use fxpnet::coordinator::trainer::{run_session, upd_all};
use fxpnet::data::loader::LoaderCfg;
use fxpnet::data::synth::Dataset;
use fxpnet::fixedpoint::QFormat;
use fxpnet::inference::FixedPointNet;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::calib::CalibMethod;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::train::NativeBackend;

/// Pinned agreement tolerances: top-1/top-5 error within 5 points and
/// mean NLL within 0.25 on a *trained* net (borderline rows can flip
/// when one hidden activation lands on a rounding boundary; wholesale
/// disagreement means one of the paths is wrong).
const TRAINED_ERR_TOL: f64 = 0.05;
const TRAINED_LOSS_TOL: f64 = 0.25;

/// Looser smoke tolerance for *untrained* He-init nets, whose logits
/// have no margin anywhere.
const SMOKE_ERR_TOL: f64 = 0.15;

#[test]
fn integer_eval_matches_simulated_eval_on_trained_tiny() {
    let backend = NativeBackend::new().with_threads(2);
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 42);
    let train = Dataset::generate(256, 16, 16, 51);
    let eval = Dataset::generate(256, 16, 16, 52);
    let a_stats = backend.activation_stats("tiny", &params, &train, 2).unwrap();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let mut s = backend
        .new_session(SessionCfg {
            arch: "tiny",
            params: &params,
            nq: &nq,
            upd: &upd_all(spec.num_layers),
            lr: 0.03,
            momentum: 0.9,
            data: train,
            loader: LoaderCfg { batch: 16, augment: false, max_shift: 0, seed: 5 },
            max_loss: 30.0,
            seed: 9,
            threads: 2,
        })
        .unwrap();
    let out = run_session(&mut *s, 30, 5).unwrap();
    assert!(!out.diverged, "{:?}", out.history);
    let tuned = s.params().unwrap();

    // re-resolve weight formats against the tuned weights (the grid's
    // eval convention) and compare the two paths
    let nq_eval = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &tuned.weight_stats(),
        &a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    assert!(nq_eval.integer_deployable());
    let int_ev = backend.evaluate("tiny", &tuned, &nq_eval, &eval).unwrap();
    let sim_ev = backend
        .evaluate_simulated("tiny", &tuned, &nq_eval, &eval)
        .unwrap();
    assert_eq!(int_ev.n, 256);
    assert_eq!(sim_ev.n, 256);
    assert!(
        (int_ev.top1_err - sim_ev.top1_err).abs() <= TRAINED_ERR_TOL,
        "top-1 disagrees: integer {:.4} vs simulated {:.4}",
        int_ev.top1_err,
        sim_ev.top1_err
    );
    assert!(
        (int_ev.top5_err - sim_ev.top5_err).abs() <= TRAINED_ERR_TOL,
        "top-5 disagrees: integer {:.4} vs simulated {:.4}",
        int_ev.top5_err,
        sim_ev.top5_err
    );
    assert!(
        (int_ev.mean_loss - sim_ev.mean_loss).abs() <= TRAINED_LOSS_TOL,
        "loss disagrees: integer {:.4} vs simulated {:.4}",
        int_ev.mean_loss,
        sim_ev.mean_loss
    );
    // and the integer path is deterministic
    let again = backend.evaluate("tiny", &tuned, &nq_eval, &eval).unwrap();
    assert_eq!(int_ev, again);
}

/// Smoke-check every arch in the zoo: the two paths agree on He-init
/// nets too (paper12 exercises the deep walk; shallow the CIFAR shape).
#[test]
fn integer_eval_agreement_smoke_all_zoo_archs() {
    for arch in ["tiny", "shallow", "paper12"] {
        let backend = NativeBackend::new().with_threads(2);
        let spec = backend.arch(arch).unwrap();
        let params = ParamSet::init(&spec, 7);
        // one small calibration batch + a small eval slice: paper12 is
        // ~150 MMAC/image, so the smoke stays cheap
        let calib = Dataset::generate(16, spec.input[0], spec.input[1], 61);
        let eval = Dataset::generate(32, spec.input[0], spec.input[1], 62);
        let a_stats = backend.activation_stats(arch, &params, &calib, 1).unwrap();
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(8),
            WidthSpec::Bits(8),
            &params.weight_stats(),
            &a_stats,
            CalibMethod::MinMax,
        )
        .unwrap();
        assert!(nq.integer_deployable(), "{arch}");
        let int_ev = backend.evaluate(arch, &params, &nq, &eval).unwrap();
        let sim_ev = backend.evaluate_simulated(arch, &params, &nq, &eval).unwrap();
        assert_eq!(int_ev.n, 32, "{arch}");
        assert_eq!(sim_ev.n, 32, "{arch}");
        assert!(
            (int_ev.top1_err - sim_ev.top1_err).abs() <= SMOKE_ERR_TOL,
            "{arch}: top-1 disagrees: integer {:.4} vs simulated {:.4}",
            int_ev.top1_err,
            sim_ev.top1_err
        );
        assert!(
            int_ev.mean_loss.is_finite() && sim_ev.mean_loss.is_finite(),
            "{arch}: non-finite loss"
        );
    }
}

/// `Backend::evaluate` routing is pinned: fully quantized cells return
/// exactly the integer engine's numbers; cells the integer engine cannot
/// express return exactly the simulated float forward's.
#[test]
fn evaluate_routes_between_integer_and_simulated() {
    let backend = NativeBackend::new().with_threads(2);
    let spec = backend.arch("tiny").unwrap();
    let params = ParamSet::init(&spec, 3);
    let calib = Dataset::generate(64, 16, 16, 71);
    let eval = Dataset::generate(96, 16, 16, 72);
    let a_stats = backend.activation_stats("tiny", &params, &calib, 1).unwrap();

    // quantized cell -> bit-equal to the integer engine run directly
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &a_stats,
        CalibMethod::MinMax,
    )
    .unwrap();
    let via_backend = backend.evaluate("tiny", &params, &nq, &eval).unwrap();
    let net =
        FixedPointNet::build(&spec, &params, &nq, QFormat::new(16, 14).unwrap())
            .unwrap();
    let direct =
        evaluate_int_batched(&net, &eval, spec.eval_batch.max(1), 2).unwrap();
    assert_eq!(via_backend, direct);

    // float-activation cell -> bit-equal to the simulated path
    let nq_float = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Float,
        &params.weight_stats(),
        &a_stats,
        CalibMethod::MinMax,
    )
    .unwrap();
    assert!(!nq_float.integer_deployable());
    let via_backend = backend.evaluate("tiny", &params, &nq_float, &eval).unwrap();
    let direct = backend
        .evaluate_simulated("tiny", &params, &nq_float, &eval)
        .unwrap();
    assert_eq!(via_backend, direct);
}
