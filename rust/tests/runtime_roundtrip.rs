//! Integration: artifact load + execute round-trips with correct numerics.

mod common;

use fxpnet::coordinator::calibrate;
use fxpnet::coordinator::evaluator::evaluate;
use fxpnet::data::synth::Dataset;
use fxpnet::model::params::ParamSet;
use fxpnet::quant::policy::{NetQuant, WidthSpec};
use fxpnet::quant::calib::CalibMethod;

#[test]
fn eval_batch_runs_and_loss_is_chance() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 0);
    let data = Dataset::generate(64, spec.input[0], spec.input[1], 7);
    let nq = NetQuant::all_float(spec.num_layers);
    let ev = evaluate(&engine, "tiny", &params, &nq, &data).unwrap();
    assert_eq!(ev.n, 64);
    // untrained network: loss ~ ln(10), top-1 error ~ 90%
    assert!((ev.mean_loss - (10f64).ln()).abs() < 0.8, "{ev}");
    assert!(ev.top1_err > 0.6, "{ev}");
    assert!(ev.top5_err < ev.top1_err + 1e-9);
}

#[test]
fn executable_cache_hits() {
    let Some(engine) = common::engine_opt() else { return };
    let a = engine.executable("tiny", "eval_batch").unwrap();
    let b = engine.executable("tiny", "eval_batch").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    engine.clear_cache();
    let c = engine.executable("tiny", "eval_batch").unwrap();
    assert!(!std::rc::Rc::ptr_eq(&a, &c));
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(engine) = common::engine_opt() else { return };
    let exe = engine.executable("tiny", "eval_batch").unwrap();
    assert!(exe.run_literals(&[]).is_err());
    assert!(exe.run(&[]).is_err());
}

#[test]
fn stats_batch_collects_positive_ranges() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 1);
    let data = Dataset::generate(64, spec.input[0], spec.input[1], 8);
    let calib =
        calibrate::activation_stats(&engine, "tiny", &params, &data, 2).unwrap();
    assert_eq!(calib.a_stats.len(), spec.num_layers);
    for s in &calib.a_stats {
        assert!(s.absmax > 0.0 && s.absmax.is_finite());
        assert!(s.meansq > 0.0);
        assert!(s.meanabs <= s.absmax);
    }
}

#[test]
fn quantized_eval_differs_from_float_but_is_sane() {
    let Some(engine) = common::engine_opt() else { return };
    let spec = engine.manifest.arch("tiny").unwrap().clone();
    let params = ParamSet::init(&spec, 2);
    let data = Dataset::generate(64, spec.input[0], spec.input[1], 9);
    let calib =
        calibrate::activation_stats(&engine, "tiny", &params, &data, 2).unwrap();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(8),
        WidthSpec::Bits(8),
        &params.weight_stats(),
        &calib.a_stats,
        CalibMethod::SqnrGaussian,
    )
    .unwrap();
    let ev_q = evaluate(&engine, "tiny", &params, &nq, &data).unwrap();
    let ev_f = evaluate(
        &engine,
        "tiny",
        &params,
        &NetQuant::all_float(spec.num_layers),
        &data,
    )
    .unwrap();
    // 8-bit quantization at random init: loss shifts slightly, stays finite
    assert!(ev_q.mean_loss.is_finite());
    assert!((ev_q.mean_loss - ev_f.mean_loss).abs() < 1.0, "{ev_q} vs {ev_f}");
}
