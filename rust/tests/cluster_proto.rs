//! Wire-protocol property tests: every message round-trips through the
//! frame codec -- including maximum-size frames and arbitrarily split
//! reads -- and every malformed frame is a clean `Err` (the peer is
//! dropped with an error, never a panic).

use fxpnet::cluster::proto::{
    read_frame, write_frame, Frame, Msg, MAX_FRAME, PROTO_VERSION,
};
use fxpnet::coordinator::evaluator::EvalResult;
use fxpnet::coordinator::regimes::CellEval;
use fxpnet::coordinator::trainer::AbortReason;
use fxpnet::train::telemetry::{TelemetrySummary, WindowSummary};
use fxpnet::util::rng::Rng;

/// A reader that hands out bytes in seeded random-size chunks, modeling
/// TCP's freedom to split a frame at any byte boundary.
struct SplitReader {
    data: Vec<u8>,
    pos: usize,
    rng: Rng,
}

impl SplitReader {
    fn new(data: Vec<u8>, seed: u64) -> Self {
        SplitReader { data, pos: 0, rng: Rng::new(seed) }
    }
}

impl std::io::Read for SplitReader {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        if self.pos >= self.data.len() {
            return Ok(0);
        }
        let left = self.data.len() - self.pos;
        // 1..=7 byte chunks: every frame gets split many ways
        let n = (1 + self.rng.below(7)).min(left).min(buf.len());
        buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

fn all_messages() -> Vec<Msg> {
    let evals = [
        CellEval::Na,
        CellEval::Aborted { reason: AbortReason::LossBlowup, step: 123 },
        CellEval::Ok(EvalResult {
            n: 2048,
            top1_err: 0.1 + 0.2, // not exactly representable: bit test
            top5_err: f64::MIN_POSITIVE,
            mean_loss: 12345.6789,
        }),
    ];
    let mut msgs = vec![
        Msg::Request,
        Msg::Heartbeat,
        Msg::Wait { ms: 0 },
        Msg::Wait { ms: u32::MAX as u64 },
        Msg::Drain { complete: false },
        Msg::Drain { complete: true },
        Msg::Reject { reason: "fingerprint mismatch \"quoted\" \\ and\nnewline".into() },
        Msg::Fatal { reason: "cell flat=3 exceeded retry cap".into() },
        Msg::Welcome { heartbeat_ms: 50, deadline_ms: 400 },
        Msg::Assign { flat: 15, key: "w=float,a=16".into(), attempt: 7 },
        Msg::Hello {
            proto: PROTO_VERSION,
            cache_version: 4,
            name: "worker-0".into(),
            pid: u64::MAX,
            host: "host.example".into(),
            fp: u64::MAX - 1,
            shard: None,
        },
        Msg::Hello {
            proto: PROTO_VERSION,
            cache_version: 4,
            name: "w".into(),
            pid: 1,
            host: "h".into(),
            fp: 0,
            shard: Some((2, 3)),
        },
    ];
    for (i, eval) in evals.into_iter().enumerate() {
        msgs.push(Msg::Result {
            flat: i,
            key: format!("w=8,a={i}"),
            attempt: i + 1,
            eval,
            telemetry: None,
        });
    }
    // a Result carrying its stability digest (proto v2)
    msgs.push(Msg::Result {
        flat: 9,
        key: "w=4,a=Float".into(),
        attempt: 2,
        eval: CellEval::Na,
        telemetry: Some(TelemetrySummary {
            steps: 40,
            loss_start: 2.5,
            loss_peak: 0.1f32 + 0.2, // not exactly representable: bit test
            loss_final: 3.25,
            sat_final: 0.0625,
            sat_peak: 1.0 / 3.0,
            ratio_min: Some(f32::MIN_POSITIVE),
            ratio_final: None,
            windows: vec![WindowSummary {
                start_step: 0,
                end_step: 25,
                count: 25,
                ratio_q: vec![1e-4, 2e-4, 3e-4, 4e-4, 5e-4],
            }],
        }),
    });
    msgs
}

#[test]
fn every_message_round_trips_through_split_reads() {
    for (i, msg) in all_messages().into_iter().enumerate() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &msg).unwrap();
        // several different split patterns per message
        for seed in 0..8u64 {
            let mut r = SplitReader::new(wire.clone(), seed * 1000 + i as u64);
            match read_frame(&mut r, None).unwrap() {
                Frame::Msg(back) => assert_eq!(back, msg, "msg #{i} seed {seed}"),
                other => panic!("msg #{i}: expected message, got {other:?}"),
            }
            // and the stream then ends cleanly
            assert!(matches!(read_frame(&mut r, None).unwrap(), Frame::Eof));
        }
    }
}

#[test]
fn many_messages_on_one_stream() {
    let msgs = all_messages();
    let mut wire = Vec::new();
    for m in &msgs {
        write_frame(&mut wire, m).unwrap();
    }
    let mut r = SplitReader::new(wire, 0xFEED);
    for (i, want) in msgs.iter().enumerate() {
        match read_frame(&mut r, None).unwrap() {
            Frame::Msg(got) => assert_eq!(&got, want, "stream position {i}"),
            other => panic!("position {i}: {other:?}"),
        }
    }
    assert!(matches!(read_frame(&mut r, None).unwrap(), Frame::Eof));
}

#[test]
fn max_size_frame_exact_fit_round_trips_and_one_more_byte_fails() {
    // find the reason length whose frame payload is exactly MAX_FRAME
    let overhead = {
        let m = Msg::Fatal { reason: String::new() };
        m.to_json().to_string().len()
    };
    let exact = Msg::Fatal { reason: "x".repeat(MAX_FRAME - overhead) };
    let mut wire = Vec::new();
    write_frame(&mut wire, &exact).unwrap();
    assert_eq!(wire.len(), 4 + MAX_FRAME);
    let mut r = SplitReader::new(wire, 7);
    match read_frame(&mut r, None).unwrap() {
        Frame::Msg(back) => assert_eq!(back, exact),
        other => panic!("{other:?}"),
    }

    let too_big = Msg::Fatal { reason: "x".repeat(MAX_FRAME - overhead + 1) };
    let mut buf = Vec::new();
    assert!(write_frame(&mut buf, &too_big).is_err());
    assert!(buf.is_empty(), "an oversized frame must not hit the wire");
}

#[test]
fn malformed_frames_error_cleanly_never_panic() {
    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("oversized length prefix", {
            ((MAX_FRAME + 1) as u32).to_le_bytes().to_vec()
        }),
        ("huge length prefix", u32::MAX.to_le_bytes().to_vec()),
        ("truncated length prefix", vec![9, 0]),
        ("truncated payload", {
            let mut v = 100u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"{\"type\":\"request\"}");
            v
        }),
        ("not json", {
            let mut v = 5u32.to_le_bytes().to_vec();
            v.extend_from_slice(b"hello");
            v
        }),
        ("not utf8", {
            let mut v = 4u32.to_le_bytes().to_vec();
            v.extend_from_slice(&[0xFF, 0xFE, 0xFD, 0xFC]);
            v
        }),
        ("json but not an object", {
            let payload = b"[1,2,3]";
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("object without type", {
            let payload = br#"{"flat":3}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("unknown type", {
            let payload = br#"{"type":"subspace-anomaly"}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("result with bad cell status", {
            let payload = br#"{"type":"result","flat":0,"key":"w=8,a=8","attempt":1,"cell":{"status":"meh"}}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("hello with half a shard", {
            let payload = br#"{"type":"hello","proto":1,"cache_version":4,"name":"w","pid":"1","host":"h","fp":"2","shard_index":1}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
        ("hello with non-numeric pid string", {
            let payload = br#"{"type":"hello","proto":1,"cache_version":4,"name":"w","pid":"ten","host":"h","fp":"2"}"#;
            let mut v = (payload.len() as u32).to_le_bytes().to_vec();
            v.extend_from_slice(payload);
            v
        }),
    ];
    for (what, wire) in cases {
        // direct read and split read must both fail cleanly
        assert!(
            read_frame(&mut wire.as_slice(), None).is_err(),
            "{what}: expected an error"
        );
        let mut r = SplitReader::new(wire, 42);
        assert!(
            read_frame(&mut r, None).is_err(),
            "{what}: expected an error through split reads"
        );
    }
}

#[test]
fn float_bits_survive_the_wire_exactly() {
    // the duplicate-result check compares to_bits(); the wire must not
    // perturb a single bit of any representable double
    // (-0.0 is excluded: the cache's shortest-integer rendering folds it
    // to 0, and the wire deliberately matches the cache encoding)
    let awkward = [
        0.1f64 + 0.2,
        1.0 / 3.0,
        f64::MIN_POSITIVE,
        f64::MAX,
        1e-300,
        -7.25e9,
        12345.678901234567,
    ];
    for (i, &v) in awkward.iter().enumerate() {
        let msg = Msg::Result {
            flat: i,
            key: "w=8,a=8".into(),
            attempt: 1,
            eval: CellEval::Ok(EvalResult {
                n: 1,
                top1_err: v.abs().min(1.0),
                top5_err: 0.0,
                mean_loss: v,
            }),
            telemetry: None,
        };
        let mut wire = Vec::new();
        write_frame(&mut wire, &msg).unwrap();
        match read_frame(&mut wire.as_slice(), None).unwrap() {
            Frame::Msg(Msg::Result { eval: CellEval::Ok(e), .. }) => {
                assert_eq!(e.mean_loss.to_bits(), v.to_bits(), "case {i}");
            }
            other => panic!("case {i}: {other:?}"),
        }
    }
}
