//! Offline stand-in for the `log` facade crate.
//!
//! Call sites use the standard `log::{error,warn,info,debug,trace}!`
//! macros unchanged.  Instead of the facade's pluggable `Log` trait, the
//! sink is built in: timestamped lines on stderr, filtered by a global
//! level (default Info).  `fxpnet::util::logging::init()` sets the level
//! from the `FXPNET_LOG` environment variable.
//!
//! The subset is deliberately small; swapping the real `log` +
//! `env_logger` pair back in only requires restoring `util/logging.rs`'s
//! `Log`-trait backend.

use std::fmt::Arguments;
use std::io::Write;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Verbosity of one message.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

/// Global filter: messages with `level as usize` above this are dropped.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(usize)]
pub enum LevelFilter {
    Off = 0,
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
    Trace = 5,
}

static MAX_LEVEL: AtomicUsize = AtomicUsize::new(LevelFilter::Info as usize);
static START: OnceLock<Instant> = OnceLock::new();

pub fn set_max_level(filter: LevelFilter) {
    MAX_LEVEL.store(filter as usize, Ordering::Relaxed);
}

pub fn max_level() -> LevelFilter {
    match MAX_LEVEL.load(Ordering::Relaxed) {
        0 => LevelFilter::Off,
        1 => LevelFilter::Error,
        2 => LevelFilter::Warn,
        3 => LevelFilter::Info,
        4 => LevelFilter::Debug,
        _ => LevelFilter::Trace,
    }
}

#[doc(hidden)]
pub fn __enabled(level: Level) -> bool {
    level as usize <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// The built-in sink: `[  12.345s I target] message` on stderr.
#[doc(hidden)]
pub fn __log(level: Level, target: &str, args: Arguments<'_>) {
    if !__enabled(level) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let lvl = match level {
        Level::Error => "E",
        Level::Warn => "W",
        Level::Info => "I",
        Level::Debug => "D",
        Level::Trace => "T",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {lvl} {target}] {args}");
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Error, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Warn, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Info, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Debug, module_path!(), format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {
        $crate::__log($crate::Level::Trace, module_path!(), format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // single test: the level filter is process-global, so splitting these
    // into separate #[test]s would race under the parallel test runner
    #[test]
    fn levels_and_macros() {
        set_max_level(LevelFilter::Warn);
        assert!(__enabled(Level::Error));
        assert!(__enabled(Level::Warn));
        assert!(!__enabled(Level::Info));
        set_max_level(LevelFilter::Trace);
        assert!(__enabled(Level::Trace));
        assert_eq!(max_level(), LevelFilter::Trace);
        set_max_level(LevelFilter::Info);
        info!("smoke {} {}", 1, "two");
        warn!("warn path");
        debug!("filtered out at default level");
    }
}
