//! Latency/throughput accounting for the serve load bench.
//!
//! One [`TraceStats`] summarises one replayed trace: client-observed
//! latency percentiles, achieved throughput, and the server-side batch
//! -size distribution (from the `batch_n` field each `Logits` reply
//! carries).  The numbers that matter for CI gating are *ratios* between
//! traces (see `serve::replay::run_suite`), never absolute wall times,
//! so the gates survive machine changes.

use crate::util::json::Json;
use crate::util::{mean, percentile};
use std::time::Duration;

/// Summary of one replayed trace.
#[derive(Clone, Debug)]
pub struct TraceStats {
    pub name: String,
    /// Requests that got a `Logits` reply.
    pub requests: usize,
    /// Requests that got an `Error` reply or a transport failure.
    pub errors: usize,
    /// Requests the server refused with `Busy` (admission queue full).
    /// Expected behaviour under deliberate overload -- reported (with
    /// `reject_rate`), never a gate violation.
    pub rejected: usize,
    pub wall_s: f64,
    /// `requests / wall_s`.
    pub achieved_rps: f64,
    /// Scheduled arrival rate; 0 for closed-loop traces (no schedule).
    pub offered_rps: f64,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Mean GEMM batch size over replies (the batching win, directly).
    pub mean_batch: f64,
    /// Sparse `(batch size, reply count)` histogram, ascending by size.
    pub batch_hist: Vec<(usize, u64)>,
}

impl TraceStats {
    /// Aggregate raw per-request samples.  `latencies_us` and
    /// `batch_ns` are parallel arrays over successful requests.
    pub fn from_samples(
        name: &str,
        offered_rps: f64,
        wall: Duration,
        latencies_us: &[f64],
        batch_ns: &[usize],
        errors: usize,
        rejected: usize,
    ) -> TraceStats {
        let wall_s = wall.as_secs_f64().max(1e-9);
        let mut hist: Vec<(usize, u64)> = Vec::new();
        for &n in batch_ns {
            match hist.iter_mut().find(|(sz, _)| *sz == n) {
                Some((_, cnt)) => *cnt += 1,
                None => hist.push((n, 1)),
            }
        }
        hist.sort_by_key(|&(sz, _)| sz);
        let mean_batch = if batch_ns.is_empty() {
            0.0
        } else {
            batch_ns.iter().sum::<usize>() as f64 / batch_ns.len() as f64
        };
        TraceStats {
            name: name.to_string(),
            requests: latencies_us.len(),
            errors,
            rejected,
            wall_s,
            achieved_rps: latencies_us.len() as f64 / wall_s,
            offered_rps,
            mean_us: mean(latencies_us),
            p50_us: percentile(latencies_us, 50.0),
            p95_us: percentile(latencies_us, 95.0),
            p99_us: percentile(latencies_us, 99.0),
            mean_batch,
            batch_hist: hist,
        }
    }

    /// Fraction of attempted requests the server refused with `Busy`.
    pub fn reject_rate(&self) -> f64 {
        let attempted = self.requests + self.errors + self.rejected;
        if attempted == 0 {
            0.0
        } else {
            self.rejected as f64 / attempted as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::Str(self.name.clone())),
            ("requests", Json::from(self.requests)),
            ("errors", Json::from(self.errors)),
            ("rejected", Json::from(self.rejected)),
            ("reject_rate", Json::Num(self.reject_rate())),
            ("wall_s", Json::Num(self.wall_s)),
            ("achieved_rps", Json::Num(self.achieved_rps)),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("mean_us", Json::Num(self.mean_us)),
            ("p50_us", Json::Num(self.p50_us)),
            ("p95_us", Json::Num(self.p95_us)),
            ("p99_us", Json::Num(self.p99_us)),
            ("mean_batch", Json::Num(self.mean_batch)),
            (
                "batch_hist",
                Json::Obj(
                    self.batch_hist
                        .iter()
                        .map(|&(sz, cnt)| (sz.to_string(), Json::Num(cnt as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aggregates_percentiles_and_histogram() {
        let lats: Vec<f64> = (1..=100).map(|i| i as f64 * 10.0).collect();
        let batches = [1usize, 4, 4, 8, 8, 8];
        let st = TraceStats::from_samples(
            "uniform",
            50.0,
            Duration::from_secs(2),
            &lats,
            &batches,
            3,
            22,
        );
        assert_eq!(st.requests, 100);
        assert_eq!(st.errors, 3);
        assert_eq!(st.rejected, 22);
        assert!((st.reject_rate() - 22.0 / 125.0).abs() < 1e-12);
        assert_eq!(st.achieved_rps, 50.0);
        assert!(st.p50_us <= st.p95_us && st.p95_us <= st.p99_us);
        assert!((st.p99_us - 1000.0).abs() < 20.0, "p99 near the max");
        assert_eq!(st.batch_hist, vec![(1, 1), (4, 2), (8, 3)]);
        assert!((st.mean_batch - 33.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_does_not_divide_by_zero() {
        let st = TraceStats::from_samples(
            "empty",
            0.0,
            Duration::from_secs(0),
            &[],
            &[],
            0,
            0,
        );
        assert_eq!(st.requests, 0);
        assert_eq!(st.mean_batch, 0.0);
        assert!(st.achieved_rps.is_finite());
        assert_eq!(st.reject_rate(), 0.0, "no attempts, no division by zero");
    }

    #[test]
    fn json_has_the_gate_inputs() {
        let st = TraceStats::from_samples(
            "bursty",
            100.0,
            Duration::from_secs(1),
            &[100.0, 200.0],
            &[2, 2],
            0,
            5,
        );
        let j = st.to_json();
        for key in [
            "achieved_rps",
            "p95_us",
            "mean_batch",
            "batch_hist",
            "rejected",
            "reject_rate",
        ] {
            assert!(j.opt(key).is_some(), "missing {key}");
        }
    }
}
