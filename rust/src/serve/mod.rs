//! `fxpnet serve`: a micro-batching inference daemon for the
//! pure-integer engine, plus the trace-replay load bench that gates it
//! in CI.
//!
//! The deployment story the paper implies -- a fixed-point network
//! small enough for a DSP/NPU -- is a *serving* story: many concurrent
//! low-latency classification requests against one resident model.
//! This module provides that last mile:
//!
//! * [`proto`] -- the wire protocol: length-prefixed JSON frames on the
//!   shared [`crate::netio`] codec (same framing as the cluster
//!   protocol), `Infer`/`Logits` plus `Ping`/`Info` introspection;
//! * [`queue`] -- the admission queue: concurrent requests coalesce
//!   into one GEMM batch under a latency budget (`--max-batch`,
//!   `--max-wait-us`), strict FIFO, drain-aware, with bounded depth
//!   (`--max-queue`) rejecting overload with an explicit `Busy` reply;
//! * [`server`] -- the daemon: nonblocking accept loop, handler thread
//!   per connection, one batcher thread over a warm
//!   [`crate::inference::InferSession`] (zero steady-state allocation),
//!   graceful SIGINT/SIGTERM drain;
//! * [`replay`] -- the load generator: seeded uniform / bursty /
//!   diurnal / adversarial arrival processes, machine-independent ratio
//!   gates against a measured serial baseline, `BENCH_serve.json`;
//! * [`stats`] -- latency/throughput/batch-mix aggregation.
//!
//! Batching never changes answers: the integer engine computes each row
//! independently, so a request's logits are bit-identical whether it
//! rode a batch of 1 or of `max_batch` (pinned by rust/tests/serve.rs).

pub mod proto;
pub mod queue;
pub mod replay;
pub mod server;
pub mod stats;

pub use queue::{AdmissionQueue, Pending, PushOutcome};
pub use replay::{ReplayOpts, TraceKind};
pub use server::{run_server, ServeOpts, ServeSummary};
pub use stats::TraceStats;
