//! The `fxpnet serve` daemon: accept loop, per-connection handler
//! threads, and the single batcher thread that drains the
//! [`AdmissionQueue`] through a warm [`InferSession`].
//!
//! ## Thread shape
//!
//! ```text
//! accept loop (main)          handler per conn            batcher (one)
//!   nonblocking accept   -->   read Infer frames   -->     next_batch()
//!   poll shutdown flag         push() to queue             copy rows, run()
//!   begin_drain on signal      reply Ping/Info inline      reply Logits per
//!   exit when batcher done     reject while draining         request via the
//!                                                            conn registry
//! ```
//!
//! Backpressure: the queue depth is bounded (`--max-queue`, 0 =
//! unbounded); a push against a full queue is answered with an explicit
//! `Busy{id}` reject -- the request is never enqueued, so overload
//! degrades into fast rejections instead of unbounded queue latency.
//!
//! Handlers never touch the engine; the batcher never touches a read
//! half.  Replies go through a per-connection `Arc<Mutex<TcpStream>>`
//! write half (registry keyed by connection id), so a handler's inline
//! `Pong` and the batcher's `Logits` can never interleave mid-frame.
//!
//! ## Drain (SIGINT/SIGTERM)
//!
//! The shutdown flag (hook it to signals via
//! [`crate::cluster::install_drain_handler`]) triggers
//! [`AdmissionQueue::begin_drain`]: queued requests still execute and
//! reply, *new* requests get `Error{id, "draining"}` (never silence),
//! new connections are refused, and once the batcher drains the queue
//! the accept loop exits 0.  No request that was admitted is dropped --
//! pinned by rust/tests/serve.rs.
//!
//! ## Determinism
//!
//! Replies are bit-deterministic: the integer engine computes each
//! image's logits independently of its batch neighbours (row-blocked
//! integer GEMM, no cross-row reduction), so whatever batch a request
//! coalesces into, its logits -- and the deterministic first-maximum
//! argmax -- are identical to a batch-of-1 run.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use crate::error::Result;
use crate::inference::{FixedPointNet, InferSession};
use crate::serve::proto::{
    read_serve_frame, write_serve_frame, ServeFrame, ServeMsg, SERVE_PROTO_VERSION,
};
use crate::serve::queue::{AdmissionQueue, Pending, PushOutcome};
use crate::util::json::Json;

/// Accept-loop poll period and handler socket read timeout (one boundary
/// "tick"; see [`crate::netio`] timeout semantics).
const TICK: Duration = Duration::from_millis(20);

/// Per-frame budget once a client has started sending bytes: bounds how
/// long a mid-frame stall can hold a handler thread (and thus shutdown).
const FRAME_DEADLINE: Duration = Duration::from_secs(10);

/// `fxpnet serve` knobs.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Bind address; port 0 picks a free port (see `port_file`).
    pub listen: String,
    /// File to write the bound `host:port` to once listening -- the same
    /// rendezvous mechanism as the cluster coordinator.
    pub port_file: Option<PathBuf>,
    /// Largest GEMM batch one flush may form (admission queue capacity
    /// per batch, and the warm scratch sizing).
    pub max_batch: usize,
    /// Latency budget: a queued request waits at most this long before a
    /// partial batch flushes.
    pub max_wait: Duration,
    /// Admission-queue depth bound (0 = unbounded): requests arriving
    /// while `max_queue` are already queued get an explicit `Busy`
    /// reject instead of piling up behind the batcher.
    pub max_queue: usize,
    /// Engine threads for the batched forward.
    pub threads: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            listen: "127.0.0.1:0".into(),
            port_file: None,
            max_batch: 8,
            max_wait: Duration::from_micros(2000),
            max_queue: 64,
            threads: 1,
        }
    }
}

/// What the daemon did over its lifetime (returned on clean drain).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ServeSummary {
    /// Requests answered with `Logits`.
    pub requests: u64,
    /// GEMM batches executed.
    pub batches: u64,
    /// Requests refused with `Error{"draining"}`.
    pub rejected: u64,
    /// Requests refused with `Busy` (queue at `max_queue` depth).
    pub busy: u64,
    /// `batch_hist[n]` = batches of size `n` (index 0 unused).
    pub batch_hist: Vec<u64>,
    /// Always true on a normal exit (the only way out is a drain).
    pub drained: bool,
}

impl ServeSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::Num(self.requests as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("rejected", Json::Num(self.rejected as f64)),
            ("busy", Json::Num(self.busy as f64)),
            (
                "batch_hist",
                Json::Arr(
                    self.batch_hist.iter().map(|&c| Json::Num(c as f64)).collect(),
                ),
            ),
            ("drained", Json::from(self.drained)),
        ])
    }
}

struct StatsInner {
    requests: u64,
    batches: u64,
    rejected: u64,
    busy: u64,
    hist: Vec<u64>,
}

/// State shared between the accept loop, handlers, and the batcher.
struct Shared {
    /// Write halves by connection id; a handler removes its entry on
    /// exit, after which the batcher drops that conn's replies.
    conns: Mutex<HashMap<u64, Arc<Mutex<TcpStream>>>>,
    stats: Mutex<StatsInner>,
    /// Set once the batcher has drained: handlers exit on their next tick.
    done: AtomicBool,
}

/// Run the daemon until `shutdown` is observed and the queue drains.
///
/// `ready` (used by tests and the replay bench's in-process mode)
/// receives the bound address once the listener is up -- the in-process
/// equivalent of `port_file`.
pub fn run_server(
    net: Arc<FixedPointNet>,
    opts: &ServeOpts,
    shutdown: &AtomicBool,
    ready: Option<mpsc::Sender<SocketAddr>>,
) -> Result<ServeSummary> {
    let listener = TcpListener::bind(&opts.listen)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    log::info!(
        "serve: listening on {addr} (max_batch {}, max_wait {:?}, max_queue {}, \
         threads {})",
        opts.max_batch,
        opts.max_wait,
        opts.max_queue,
        opts.threads
    );
    if let Some(pf) = &opts.port_file {
        // atomic write: a polling client never sees a partial address
        let tmp = pf.with_extension("tmp");
        crate::util::durable::write_atomic(pf, &tmp, format!("{addr}\n").as_bytes())?;
    }
    if let Some(tx) = ready {
        let _ = tx.send(addr);
    }

    let queue = AdmissionQueue::new(opts.max_batch, opts.max_wait, opts.max_queue);
    let shared = Shared {
        conns: Mutex::new(HashMap::new()),
        stats: Mutex::new(StatsInner {
            requests: 0,
            batches: 0,
            rejected: 0,
            busy: 0,
            hist: vec![0; opts.max_batch + 1],
        }),
        done: AtomicBool::new(false),
    };

    std::thread::scope(|s| {
        let batcher_net = net.clone();
        let batcher = s.spawn({
            let queue = &queue;
            let shared = &shared;
            let threads = opts.threads;
            move || batcher_loop(batcher_net, queue, shared, threads)
        });

        let mut next_conn: u64 = 0;
        loop {
            if shutdown.load(Ordering::SeqCst) && !queue.is_draining() {
                log::info!("serve: drain requested; flushing in-flight requests");
                queue.begin_drain();
            }
            if queue.is_draining() && batcher.is_finished() {
                break;
            }
            match listener.accept() {
                Ok((stream, peer)) => {
                    if queue.is_draining() {
                        log::info!("serve: refusing {peer} (draining)");
                        drop(stream);
                        continue;
                    }
                    let conn = next_conn;
                    next_conn += 1;
                    let queue = &queue;
                    let shared = &shared;
                    let net = &net;
                    let sopts = opts;
                    s.spawn(move || handle_conn(conn, stream, queue, shared, net, sopts));
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(TICK);
                }
                Err(e) => {
                    log::warn!("serve: accept: {e}");
                    std::thread::sleep(TICK);
                }
            }
        }
        // Queue is drained and the batcher has exited; tell handlers to
        // go (they observe `done` within one tick) and let the scope
        // join them -- bounded by TICK + FRAME_DEADLINE even for a
        // mid-frame straggler.
        shared.done.store(true, Ordering::SeqCst);
        let _ = batcher.join();
    });

    let st = shared.stats.into_inner().unwrap();
    let summary = ServeSummary {
        requests: st.requests,
        batches: st.batches,
        rejected: st.rejected,
        busy: st.busy,
        batch_hist: st.hist,
        drained: true,
    };
    log::info!(
        "serve: drained cleanly ({} requests in {} batches, {} rejected, \
         {} busy)",
        summary.requests,
        summary.batches,
        summary.rejected,
        summary.busy
    );
    Ok(summary)
}

/// Send one reply on a connection's write half; errors mean the client
/// is gone, which is the client's problem, not the server's.
fn reply(half: &Arc<Mutex<TcpStream>>, msg: &ServeMsg) -> Result<()> {
    let mut w = half.lock().unwrap();
    write_serve_frame(&mut *w, msg)
}

/// Reply via the registry (the batcher's path: it has no stream of its
/// own).  Silently drops the message if the connection has closed.
fn reply_to(shared: &Shared, conn: u64, msg: &ServeMsg) {
    let half = shared.conns.lock().unwrap().get(&conn).cloned();
    if let Some(half) = half {
        let _ = reply(&half, msg);
    }
}

fn handle_conn(
    conn: u64,
    mut stream: TcpStream,
    queue: &AdmissionQueue,
    shared: &Shared,
    net: &FixedPointNet,
    opts: &ServeOpts,
) {
    if stream.set_read_timeout(Some(TICK)).is_err() {
        return;
    }
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => Arc::new(Mutex::new(s)),
        Err(_) => return,
    };
    shared.conns.lock().unwrap().insert(conn, write_half.clone());
    let (h, w, c) = net.input_shape();
    let px = h * w * c;

    loop {
        if shared.done.load(Ordering::SeqCst) {
            break;
        }
        match read_serve_frame(&mut stream, Some(Instant::now() + FRAME_DEADLINE)) {
            // boundary tick: nothing arrived, go poll `done`
            Ok(ServeFrame::TimedOut) => continue,
            Ok(ServeFrame::Eof) => break,
            Ok(ServeFrame::Msg(ServeMsg::Ping)) => {
                if reply(&write_half, &ServeMsg::Pong).is_err() {
                    break;
                }
            }
            Ok(ServeFrame::Msg(ServeMsg::Info)) => {
                let msg = ServeMsg::InfoReply {
                    proto: SERVE_PROTO_VERSION,
                    h,
                    w,
                    c,
                    classes: net.num_classes(),
                    max_batch: opts.max_batch,
                    max_wait_us: opts.max_wait.as_micros() as u64,
                    max_queue: opts.max_queue,
                };
                if reply(&write_half, &msg).is_err() {
                    break;
                }
            }
            Ok(ServeFrame::Msg(ServeMsg::Infer { id, image })) => {
                if image.len() != px {
                    // a shape mistake is per-request, not fatal to the conn
                    let msg = ServeMsg::Error {
                        id: Some(id),
                        reason: format!(
                            "image has {} values, model wants {h}x{w}x{c} = {px}",
                            image.len()
                        ),
                    };
                    if reply(&write_half, &msg).is_err() {
                        break;
                    }
                    continue;
                }
                let p = Pending { conn, id, image, enqueued: Instant::now() };
                match queue.push(p) {
                    PushOutcome::Admitted => {}
                    PushOutcome::Busy => {
                        shared.stats.lock().unwrap().busy += 1;
                        if reply(&write_half, &ServeMsg::Busy { id }).is_err() {
                            break;
                        }
                    }
                    PushOutcome::Draining => {
                        shared.stats.lock().unwrap().rejected += 1;
                        let msg =
                            ServeMsg::Error { id: Some(id), reason: "draining".into() };
                        if reply(&write_half, &msg).is_err() {
                            break;
                        }
                    }
                }
            }
            Ok(ServeFrame::Msg(other)) => {
                // server->client vocabulary coming *from* a client
                let _ = reply(
                    &write_half,
                    &ServeMsg::Error {
                        id: None,
                        reason: format!("unexpected message from client: {other:?}"),
                    },
                );
                break;
            }
            Err(e) => {
                // malformed frame / not-JSON / oversize / mid-frame stall:
                // tell the client why, then hang up
                let _ = reply(
                    &write_half,
                    &ServeMsg::Error { id: None, reason: format!("bad frame: {e}") },
                );
                break;
            }
        }
    }
    shared.conns.lock().unwrap().remove(&conn);
}

/// The single batcher: pulls FIFO batches from the queue, runs them
/// through one warm [`InferSession`] (zero steady-state allocation --
/// scratch, output, and the input staging buffer are all reused), and
/// fans replies back out through the connection registry.
fn batcher_loop(
    net: Arc<FixedPointNet>,
    queue: &AdmissionQueue,
    shared: &Shared,
    threads: usize,
) {
    let (h, w, c) = net.input_shape();
    let px = h * w * c;
    let nc = net.num_classes();
    let max_batch = queue.max_batch();
    let mut session = InferSession::new(net, max_batch, threads);
    let mut input = vec![0f32; max_batch * px];
    let mut batch: Vec<Pending> = Vec::with_capacity(max_batch);

    while queue.next_batch(&mut batch) {
        let n = batch.len();
        for (i, p) in batch.iter().enumerate() {
            input[i * px..(i + 1) * px].copy_from_slice(&p.image);
        }
        let dispatched = Instant::now();
        let out = match session.run(&input[..n * px], n) {
            Ok(out) => out,
            Err(e) => {
                log::warn!("serve: engine error on a batch of {n}: {e}");
                for p in &batch {
                    reply_to(
                        shared,
                        p.conn,
                        &ServeMsg::Error {
                            id: Some(p.id),
                            reason: format!("engine: {e}"),
                        },
                    );
                }
                continue;
            }
        };
        let gemm_us = dispatched.elapsed().as_micros() as u64;
        {
            let mut st = shared.stats.lock().unwrap();
            st.batches += 1;
            st.requests += n as u64;
            st.hist[n] += 1;
        }
        for (i, p) in batch.iter().enumerate() {
            let row = &out[i * nc..(i + 1) * nc];
            // deterministic first-maximum scan (ties break to the lower
            // class index, independent of batch layout)
            let mut argmax = 0;
            for (k, &v) in row.iter().enumerate() {
                if v > row[argmax] {
                    argmax = k;
                }
            }
            reply_to(
                shared,
                p.conn,
                &ServeMsg::Logits {
                    id: p.id,
                    logits: row.to_vec(),
                    argmax,
                    queue_us: dispatched.duration_since(p.enqueued).as_micros() as u64,
                    batch_n: n,
                    gemm_us,
                },
            );
        }
    }
}
