//! Wire protocol for `fxpnet serve`: length-prefixed JSON frames over
//! TCP, on the same shared codec ([`crate::netio`]) as the cluster
//! protocol -- one framing implementation, two message vocabularies.
//!
//! ## Message flow
//!
//! Clients send; the server replies (possibly out of request order
//! across connections -- `id` correlates):
//!
//! ```text
//! client                         server
//!   Info                     ->
//!                            <-  InfoReply{h,w,c,classes,...}
//!   Infer{id, image}         ->
//!                            <-  Logits{id, logits, argmax,
//!                                       queue_us, batch_n, gemm_us}
//!                                | Busy{id}
//!                                | Error{id, reason}
//!   Ping                     ->
//!                            <-  Pong
//! ```
//!
//! `Busy` is the backpressure reject (proto v2): the admission queue is
//! at its `--max-queue` depth, nothing was enqueued, and the client
//! should back off and retry -- distinct from `Error` so well-behaved
//! load generators can count rejects without string-matching reasons.
//!
//! `image` is `h*w*c` row-major floats in [0,1]; `logits` are the
//! engine's f32 logits.  Both ride as JSON numbers: an f32 widened to
//! f64 is exact, and the codec's shortest-round-trip rendering returns
//! the identical f64, so logits cross the wire bit-for-bit -- the
//! reply-determinism test compares `to_bits()` across batch
//! configurations *through* this encoding.

use std::io::{Read, Write};
use std::time::Instant;

use crate::error::{FxpError, Result};
use crate::netio::{self, JsonFrame};
use crate::util::json::Json;

/// Serve-protocol revision; independent of the cluster protocol's.
/// v2: `Busy` reject + `max_queue` in `InfoReply`.
pub const SERVE_PROTO_VERSION: usize = 2;

/// One serve-protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum ServeMsg {
    /// Classify one image.  `id` is client-chosen and echoed in the
    /// reply; clients pipelining requests on one connection use it to
    /// correlate.
    Infer { id: u64, image: Vec<f32> },
    /// Liveness probe.
    Ping,
    /// Ask for the model/batching contract (shape, classes, knobs).
    Info,
    /// Per-request reply: logits row, argmax, and server-side timing --
    /// microseconds spent in the admission queue, the GEMM batch size
    /// this request rode in, and the batch's engine microseconds.
    Logits {
        id: u64,
        logits: Vec<f32>,
        argmax: usize,
        queue_us: u64,
        batch_n: usize,
        gemm_us: u64,
    },
    /// Backpressure reject: the admission queue is at `max_queue` depth;
    /// the request was *not* enqueued.  Back off and retry.
    Busy { id: u64 },
    Pong,
    InfoReply {
        proto: usize,
        h: usize,
        w: usize,
        c: usize,
        classes: usize,
        max_batch: usize,
        max_wait_us: u64,
        /// Admission-queue depth bound (0 = unbounded).
        max_queue: usize,
    },
    /// Per-request failure (`id` echoes the request) or connection-level
    /// protocol complaint (`id` absent).
    Error { id: Option<u64>, reason: String },
}

fn f32s_to_json(xs: &[f32]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn f32s_from_json(j: &Json) -> Result<Vec<f32>> {
    j.as_arr()?.iter().map(|v| v.as_f64().map(|f| f as f32)).collect()
}

fn u64_num(j: &Json, key: &str) -> Result<u64> {
    // ids/timings are counters well within 2^53; plain JSON numbers
    let n = j.get(key)?.as_f64()?;
    if !(n >= 0.0 && n.fract() == 0.0) {
        return Err(FxpError::Json(format!("bad u64 {n} for '{key}'")));
    }
    Ok(n as u64)
}

impl ServeMsg {
    pub fn to_json(&self) -> Json {
        match self {
            ServeMsg::Infer { id, image } => Json::obj(vec![
                ("type", Json::from("infer")),
                ("id", Json::Num(*id as f64)),
                ("image", f32s_to_json(image)),
            ]),
            ServeMsg::Ping => Json::obj(vec![("type", Json::from("ping"))]),
            ServeMsg::Info => Json::obj(vec![("type", Json::from("info"))]),
            ServeMsg::Logits { id, logits, argmax, queue_us, batch_n, gemm_us } => {
                Json::obj(vec![
                    ("type", Json::from("logits")),
                    ("id", Json::Num(*id as f64)),
                    ("logits", f32s_to_json(logits)),
                    ("argmax", Json::from(*argmax)),
                    ("queue_us", Json::Num(*queue_us as f64)),
                    ("batch_n", Json::from(*batch_n)),
                    ("gemm_us", Json::Num(*gemm_us as f64)),
                ])
            }
            ServeMsg::Busy { id } => Json::obj(vec![
                ("type", Json::from("busy")),
                ("id", Json::Num(*id as f64)),
            ]),
            ServeMsg::Pong => Json::obj(vec![("type", Json::from("pong"))]),
            ServeMsg::InfoReply {
                proto,
                h,
                w,
                c,
                classes,
                max_batch,
                max_wait_us,
                max_queue,
            } => Json::obj(vec![
                ("type", Json::from("info_reply")),
                ("proto", Json::from(*proto)),
                ("h", Json::from(*h)),
                ("w", Json::from(*w)),
                ("c", Json::from(*c)),
                ("classes", Json::from(*classes)),
                ("max_batch", Json::from(*max_batch)),
                ("max_wait_us", Json::Num(*max_wait_us as f64)),
                ("max_queue", Json::from(*max_queue)),
            ]),
            ServeMsg::Error { id, reason } => {
                let mut pairs = vec![
                    ("type", Json::from("error")),
                    ("reason", Json::Str(reason.clone())),
                ];
                if let Some(id) = id {
                    pairs.push(("id", Json::Num(*id as f64)));
                }
                Json::obj(pairs)
            }
        }
    }

    pub fn from_json(j: &Json) -> Result<ServeMsg> {
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "infer" => ServeMsg::Infer {
                id: u64_num(j, "id")?,
                image: f32s_from_json(j.get("image")?)?,
            },
            "ping" => ServeMsg::Ping,
            "info" => ServeMsg::Info,
            "logits" => ServeMsg::Logits {
                id: u64_num(j, "id")?,
                logits: f32s_from_json(j.get("logits")?)?,
                argmax: j.get("argmax")?.as_usize()?,
                queue_us: u64_num(j, "queue_us")?,
                batch_n: j.get("batch_n")?.as_usize()?,
                gemm_us: u64_num(j, "gemm_us")?,
            },
            "busy" => ServeMsg::Busy { id: u64_num(j, "id")? },
            "pong" => ServeMsg::Pong,
            "info_reply" => ServeMsg::InfoReply {
                proto: j.get("proto")?.as_usize()?,
                h: j.get("h")?.as_usize()?,
                w: j.get("w")?.as_usize()?,
                c: j.get("c")?.as_usize()?,
                classes: j.get("classes")?.as_usize()?,
                max_batch: j.get("max_batch")?.as_usize()?,
                max_wait_us: u64_num(j, "max_wait_us")?,
                max_queue: j.get("max_queue")?.as_usize()?,
            },
            "error" => ServeMsg::Error {
                id: match j.opt("id") {
                    Some(_) => Some(u64_num(j, "id")?),
                    None => None,
                },
                reason: j.get("reason")?.as_str()?.to_string(),
            },
            other => {
                return Err(FxpError::Json(format!(
                    "unknown serve message type '{other}'"
                )))
            }
        })
    }
}

/// What one read attempt produced (same contract as
/// [`crate::cluster::proto::Frame`]).
#[derive(Debug)]
pub enum ServeFrame {
    Msg(ServeMsg),
    Eof,
    TimedOut,
}

/// Encode `msg` as one frame (errors, nothing on the wire, if the
/// payload would exceed [`netio::MAX_FRAME`]).
pub fn write_serve_frame(w: &mut impl Write, msg: &ServeMsg) -> Result<()> {
    netio::write_json_frame(w, &msg.to_json())
}

/// Read one serve-protocol frame (timeout semantics per [`crate::netio`]).
pub fn read_serve_frame(
    r: &mut impl Read,
    deadline: Option<Instant>,
) -> Result<ServeFrame> {
    Ok(match netio::read_json_frame(r, deadline)? {
        JsonFrame::Msg(j) => ServeFrame::Msg(ServeMsg::from_json(&j)?),
        JsonFrame::Eof => ServeFrame::Eof,
        JsonFrame::TimedOut => ServeFrame::TimedOut,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip(m: &ServeMsg) -> ServeMsg {
        let mut buf = Vec::new();
        write_serve_frame(&mut buf, m).unwrap();
        match read_serve_frame(&mut buf.as_slice(), None).unwrap() {
            ServeFrame::Msg(back) => back,
            other => panic!("expected a message, got {other:?}"),
        }
    }

    #[test]
    fn all_messages_round_trip() {
        let msgs = vec![
            ServeMsg::Ping,
            ServeMsg::Pong,
            ServeMsg::Info,
            ServeMsg::Infer { id: 0, image: vec![] },
            ServeMsg::Infer { id: u64::MAX >> 12, image: vec![0.0, 0.25, 1.0] },
            ServeMsg::Logits {
                id: 7,
                logits: vec![-1.5, 0.1 + 0.2, 3.25e-3],
                argmax: 2,
                queue_us: 1234,
                batch_n: 8,
                gemm_us: 567,
            },
            ServeMsg::InfoReply {
                proto: SERVE_PROTO_VERSION,
                h: 32,
                w: 32,
                c: 3,
                classes: 10,
                max_batch: 8,
                max_wait_us: 2000,
                max_queue: 64,
            },
            ServeMsg::Busy { id: 41 },
            ServeMsg::Error { id: None, reason: "bad \"frame\"\n".into() },
            ServeMsg::Error { id: Some(3), reason: "draining".into() },
        ];
        for m in &msgs {
            assert_eq!(&round_trip(m), m);
        }
    }

    #[test]
    fn f32_bits_survive_the_wire_exactly() {
        // awkward values: not exactly representable in decimal, subnormal,
        // extreme exponents -- to_bits must match after JSON round-trip
        let awkward = [
            0.1f32 + 0.2,
            1.0 / 3.0,
            f32::MIN_POSITIVE,
            f32::MAX,
            1e-40, // subnormal
            -7.25e9,
            core::f32::consts::PI,
        ];
        let m = ServeMsg::Logits {
            id: 1,
            logits: awkward.to_vec(),
            argmax: 0,
            queue_us: 0,
            batch_n: 1,
            gemm_us: 0,
        };
        match round_trip(&m) {
            ServeMsg::Logits { logits, .. } => {
                for (i, (a, b)) in awkward.iter().zip(&logits).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "logit {i}");
                }
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_messages_error_cleanly() {
        for (what, payload) in [
            ("missing type", r#"{"id":3}"#),
            ("unknown type", r#"{"type":"teleport"}"#),
            ("infer without image", r#"{"type":"infer","id":1}"#),
            ("infer with string id", r#"{"type":"infer","id":"x","image":[]}"#),
            ("infer with fractional id", r#"{"type":"infer","id":1.5,"image":[]}"#),
            ("infer with non-numeric pixel", r#"{"type":"infer","id":1,"image":["a"]}"#),
            ("busy without id", r#"{"type":"busy"}"#),
            ("error without reason", r#"{"type":"error"}"#),
        ] {
            let mut wire = Vec::new();
            netio::write_frame_bytes(&mut wire, payload.as_bytes()).unwrap();
            assert!(
                read_serve_frame(&mut wire.as_slice(), None).is_err(),
                "{what}: expected an error"
            );
        }
    }
}
