//! The admission queue: where concurrent requests coalesce into GEMM
//! batches under a latency budget.
//!
//! Connection handler threads [`push`] requests; the single batcher
//! thread blocks in [`next_batch`], which releases a batch when the
//! first of three conditions holds:
//!
//! 1. **full batch** -- `max_batch` requests are queued (no waiting);
//! 2. **latency budget** -- the *oldest* queued request has waited
//!    `max_wait`; whatever is queued flushes (so a lone request's extra
//!    latency is bounded by the budget, not by traffic);
//! 3. **drain** -- [`begin_drain`] was called; everything still queued
//!    flushes immediately, and once the queue is empty `next_batch`
//!    returns `false` (the batcher exits).
//!
//! Backpressure: the queue depth is bounded by `max_queue` (0 =
//! unbounded).  A [`push`] against a full queue is refused with
//! [`PushOutcome::Busy`] -- the 503-style explicit reject -- so a
//! traffic burst degrades into fast, visible rejections instead of an
//! unbounded memory/latency pile-up behind the batcher.
//!
//! Ordering is strict FIFO: requests leave in arrival order, and a batch
//! is always a contiguous prefix of the queue.  Determinism note: *which*
//! batch a request lands in depends on timing, but the integer engine's
//! row-independence makes the resulting logits bit-identical regardless
//! (pinned by tests/serve.rs).
//!
//! [`push`]: AdmissionQueue::push
//! [`next_batch`]: AdmissionQueue::next_batch
//! [`begin_drain`]: AdmissionQueue::begin_drain

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// One admitted request, waiting for a batch slot.
#[derive(Debug)]
pub struct Pending {
    /// Connection the reply goes back to.
    pub conn: u64,
    /// Client-chosen request id (echoed in the reply).
    pub id: u64,
    /// `h*w*c` row-major pixels.
    pub image: Vec<f32>,
    /// Admission instant (the latency-budget clock, and the source of
    /// the reply's `queue_us`).
    pub enqueued: Instant,
}

/// Why a [`AdmissionQueue::push`] did or did not enqueue.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    /// Enqueued; the batcher will reply.
    Admitted,
    /// Refused: the queue is at `max_queue` depth.  The caller must send
    /// an explicit busy reject so the client can back off and retry.
    Busy,
    /// Refused: the server is draining and admits nothing new.
    Draining,
}

struct Inner {
    q: VecDeque<Pending>,
    draining: bool,
}

/// The shared queue between connection handlers and the batcher.
pub struct AdmissionQueue {
    inner: Mutex<Inner>,
    cv: Condvar,
    max_batch: usize,
    max_wait: Duration,
    max_queue: usize,
}

impl AdmissionQueue {
    /// `max_queue` bounds the admitted-but-unbatched depth (0 =
    /// unbounded); see the module docs for the backpressure contract.
    pub fn new(max_batch: usize, max_wait: Duration, max_queue: usize) -> AdmissionQueue {
        AdmissionQueue {
            inner: Mutex::new(Inner { q: VecDeque::new(), draining: false }),
            cv: Condvar::new(),
            max_batch: max_batch.max(1),
            max_wait,
            max_queue,
        }
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    pub fn max_queue(&self) -> usize {
        self.max_queue
    }

    /// Admit a request.  Refusals do *not* enqueue: the caller must
    /// reply with the matching reject ([`PushOutcome::Busy`] /
    /// [`PushOutcome::Draining`]) instead, so no request is ever
    /// silently dropped.  (The checks and the enqueue share one lock
    /// acquisition, so an admitted push is guaranteed to be seen by the
    /// batcher before it exits, and the depth bound is never raced
    /// past.)
    pub fn push(&self, p: Pending) -> PushOutcome {
        let mut g = self.inner.lock().unwrap();
        if g.draining {
            return PushOutcome::Draining;
        }
        if self.max_queue > 0 && g.q.len() >= self.max_queue {
            return PushOutcome::Busy;
        }
        g.q.push_back(p);
        drop(g);
        self.cv.notify_all();
        PushOutcome::Admitted
    }

    /// Stop admitting; flush what remains.  Idempotent.
    pub fn begin_drain(&self) {
        self.inner.lock().unwrap().draining = true;
        self.cv.notify_all();
    }

    pub fn is_draining(&self) -> bool {
        self.inner.lock().unwrap().draining
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Block until a batch is due (see the module docs for the three
    /// release conditions), filling `out` (cleared first) with up to
    /// `max_batch` requests in FIFO order.  Returns `false` exactly once
    /// the queue is draining *and* empty -- the batcher's exit signal.
    pub fn next_batch(&self, out: &mut Vec<Pending>) -> bool {
        out.clear();
        let mut g = self.inner.lock().unwrap();
        loop {
            if g.q.len() >= self.max_batch || (g.draining && !g.q.is_empty()) {
                break;
            }
            match g.q.front() {
                Some(front) => {
                    let deadline = front.enqueued + self.max_wait;
                    let now = Instant::now();
                    if now >= deadline {
                        break; // budget exhausted: flush a partial batch
                    }
                    let (g2, _) = self.cv.wait_timeout(g, deadline - now).unwrap();
                    g = g2;
                }
                None => {
                    if g.draining {
                        return false;
                    }
                    g = self.cv.wait(g).unwrap();
                }
            }
        }
        let take = self.max_batch.min(g.q.len());
        out.extend(g.q.drain(..take));
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64) -> Pending {
        Pending { conn: 0, id, image: vec![], enqueued: Instant::now() }
    }

    #[test]
    fn full_batch_releases_without_waiting() {
        let q = AdmissionQueue::new(4, Duration::from_secs(60), 0);
        for id in 0..4 {
            assert_eq!(q.push(req(id)), PushOutcome::Admitted);
        }
        let mut batch = Vec::new();
        let t0 = Instant::now();
        assert!(q.next_batch(&mut batch));
        assert!(t0.elapsed() < Duration::from_secs(5), "must not wait the budget");
        let ids: Vec<u64> = batch.iter().map(|p| p.id).collect();
        assert_eq!(ids, [0, 1, 2, 3], "strict FIFO");
    }

    #[test]
    fn latency_budget_flushes_a_partial_batch_in_order() {
        let q = AdmissionQueue::new(8, Duration::from_millis(30), 0);
        for id in 0..3 {
            assert_eq!(q.push(req(id)), PushOutcome::Admitted);
        }
        let mut batch = Vec::new();
        let t0 = Instant::now();
        assert!(q.next_batch(&mut batch));
        let waited = t0.elapsed();
        let ids: Vec<u64> = batch.iter().map(|p| p.id).collect();
        assert_eq!(ids, [0, 1, 2], "partial flush keeps arrival order");
        assert!(
            waited < Duration::from_secs(5),
            "budget flush took {waited:?}"
        );
    }

    #[test]
    fn oversize_backlog_leaves_in_fifo_chunks() {
        let q = AdmissionQueue::new(4, Duration::from_millis(5), 0);
        for id in 0..10 {
            assert_eq!(q.push(req(id)), PushOutcome::Admitted);
        }
        let mut seen = Vec::new();
        let mut sizes = Vec::new();
        let mut batch = Vec::new();
        q.begin_drain();
        while q.next_batch(&mut batch) {
            sizes.push(batch.len());
            seen.extend(batch.iter().map(|p| p.id));
        }
        assert_eq!(sizes, [4, 4, 2], "chunked at max_batch, remainder last");
        assert_eq!(seen, (0..10).collect::<Vec<u64>>(), "global FIFO order");
    }

    #[test]
    fn drain_rejects_new_but_flushes_queued() {
        let q = AdmissionQueue::new(8, Duration::from_secs(60), 0);
        assert_eq!(q.push(req(0)), PushOutcome::Admitted);
        q.begin_drain();
        assert_eq!(
            q.push(req(1)),
            PushOutcome::Draining,
            "push after drain must be rejected"
        );
        let mut batch = Vec::new();
        assert!(q.next_batch(&mut batch), "queued work still flushes");
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].id, 0);
        assert!(!q.next_batch(&mut batch), "empty + draining = exit signal");
        assert!(batch.is_empty());
    }

    #[test]
    fn full_queue_pushes_back_until_a_batch_leaves() {
        let q = AdmissionQueue::new(2, Duration::from_secs(60), 3);
        for id in 0..3 {
            assert_eq!(q.push(req(id)), PushOutcome::Admitted);
        }
        assert_eq!(q.push(req(3)), PushOutcome::Busy, "depth bound hit");
        assert_eq!(q.len(), 3, "busy push must not enqueue");
        let mut batch = Vec::new();
        assert!(q.next_batch(&mut batch));
        assert_eq!(batch.len(), 2);
        assert_eq!(
            q.push(req(4)),
            PushOutcome::Admitted,
            "capacity frees as batches leave"
        );
    }

    #[test]
    fn zero_max_queue_means_unbounded() {
        let q = AdmissionQueue::new(2, Duration::from_secs(60), 0);
        for id in 0..100 {
            assert_eq!(q.push(req(id)), PushOutcome::Admitted);
        }
        assert_eq!(q.len(), 100);
    }

    #[test]
    fn drain_wakes_a_blocked_batcher() {
        let q = AdmissionQueue::new(8, Duration::from_secs(60), 0);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let mut batch = Vec::new();
                q.next_batch(&mut batch) // blocks on the empty queue
            });
            std::thread::sleep(Duration::from_millis(20));
            q.begin_drain();
            assert!(!h.join().unwrap(), "drain must wake and release the batcher");
        });
    }
}
