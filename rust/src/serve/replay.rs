//! Trace-replay load generator for the serve daemon (`fxpnet serve
//! --replay`, and the `serve_latency` bench).
//!
//! Replays deterministic, seeded arrival processes against a running
//! daemon and reports client-observed latency percentiles, achieved
//! throughput, and the server-side batch-size mix:
//!
//! * **uniform** -- evenly spaced arrivals with +-20% jitter, offered at
//!   half the measured serial rate (the "healthy load" tail-latency
//!   probe);
//! * **bursty** -- Poisson-spaced bursts of 4..=12 simultaneous
//!   arrivals, offered at 2x the serial rate (batching must coalesce or
//!   drown -- the throughput probe);
//! * **diurnal** -- a sinusoidal rate profile (3 cycles over the trace)
//!   between 0.3x and 1.7x the base rate;
//! * **adversarial** -- closed-loop saturation: every client fires its
//!   next request the moment the previous reply lands (no schedule).
//!
//! ## Machine-independent gating
//!
//! Absolute rates mean nothing across machines, so offered rates are
//! derived at runtime from a *serial baseline* -- one closed-loop client
//! against the same daemon -- and the CI gates are ratios against that
//! baseline (`serve` keys in `BENCH_baseline.json`, asserted under
//! `FXP_BENCH_ASSERT` / `--assert`):
//!
//! * `max_p95_ratio_uniform`: uniform-trace p95 latency over serial p50;
//! * `min_throughput_ratio_bursty`: bursty achieved rate over serial
//!   rate -- the number that proves micro-batching actually buys
//!   throughput (a batch-of-1 server cannot exceed ~1.0).

use std::net::TcpStream;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::bench::fixtures::baseline_floor;
use crate::error::{FxpError, Result};
use crate::serve::proto::{read_serve_frame, write_serve_frame, ServeFrame, ServeMsg};
use crate::serve::stats::TraceStats;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// How long a replay client waits for any single reply before declaring
/// the server hung (generous: covers a cold first batch on a loaded box).
const REPLY_DEADLINE: Duration = Duration::from_secs(30);

/// One arrival process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    Uniform,
    Bursty,
    Diurnal,
    Adversarial,
}

impl TraceKind {
    pub fn name(&self) -> &'static str {
        match self {
            TraceKind::Uniform => "uniform",
            TraceKind::Bursty => "bursty",
            TraceKind::Diurnal => "diurnal",
            TraceKind::Adversarial => "adversarial",
        }
    }

    pub fn parse(s: &str) -> Result<TraceKind> {
        match s {
            "uniform" => Ok(TraceKind::Uniform),
            "bursty" => Ok(TraceKind::Bursty),
            "diurnal" => Ok(TraceKind::Diurnal),
            "adversarial" => Ok(TraceKind::Adversarial),
            other => Err(FxpError::config(format!(
                "unknown trace '{other}' (uniform|bursty|diurnal|adversarial)"
            ))),
        }
    }

    /// Offered rate as a multiple of the measured serial rate
    /// (closed-loop traces have no schedule and return 0).
    fn rate_factor(&self) -> f64 {
        match self {
            TraceKind::Uniform => 0.5,
            TraceKind::Bursty => 2.0,
            TraceKind::Diurnal => 1.0,
            TraceKind::Adversarial => 0.0,
        }
    }
}

/// Replay knobs (`fxpnet serve --replay` flags).
#[derive(Clone, Debug)]
pub struct ReplayOpts {
    /// Requests per trace.
    pub requests: usize,
    /// Concurrent client connections; 0 = `2 * server max_batch`.
    pub clients: usize,
    /// Seed for arrival jitter and the image pool.
    pub seed: u64,
    pub traces: Vec<TraceKind>,
    /// Report path; `None` = `BENCH_serve.json` at the workspace root.
    pub out: Option<PathBuf>,
    /// Gate the ratio floors/ceilings (CI sets this via
    /// `FXP_BENCH_ASSERT`); violations return `Err`.
    pub assert_floors: bool,
}

impl Default for ReplayOpts {
    fn default() -> Self {
        ReplayOpts {
            requests: 400,
            clients: 0,
            seed: 42,
            traces: vec![TraceKind::Uniform, TraceKind::Bursty],
            out: None,
            assert_floors: false,
        }
    }
}

/// One synchronous client connection (a single request in flight).
struct Client {
    stream: TcpStream,
}

impl Client {
    fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)?;
        let _ = stream.set_nodelay(true);
        Ok(Client { stream })
    }

    fn request(&mut self, msg: &ServeMsg) -> Result<ServeMsg> {
        write_serve_frame(&mut self.stream, msg)?;
        match read_serve_frame(&mut self.stream, Some(Instant::now() + REPLY_DEADLINE))? {
            ServeFrame::Msg(reply) => Ok(reply),
            ServeFrame::Eof => {
                Err(FxpError::config("server closed the connection"))
            }
            ServeFrame::TimedOut => {
                Err(FxpError::config("no reply within the deadline"))
            }
        }
    }

    fn info(&mut self) -> Result<(usize, usize, usize, usize, usize, u64)> {
        match self.request(&ServeMsg::Info)? {
            ServeMsg::InfoReply { h, w, c, classes, max_batch, max_wait_us, .. } => {
                Ok((h, w, c, classes, max_batch, max_wait_us))
            }
            other => Err(FxpError::config(format!("expected info_reply, got {other:?}"))),
        }
    }

    /// Classify one image; distinguishes success, an explicit `Busy`
    /// backpressure reject (expected under deliberate overload -- not a
    /// failure), and genuine errors.
    fn infer(&mut self, id: u64, image: &[f32]) -> Result<InferOutcome> {
        let t0 = Instant::now();
        match self.request(&ServeMsg::Infer { id, image: image.to_vec() })? {
            ServeMsg::Logits { id: rid, batch_n, .. } => {
                if rid != id {
                    return Err(FxpError::config(format!(
                        "reply id {rid} for request {id} (one in flight per conn)"
                    )));
                }
                Ok(InferOutcome::Replied(t0.elapsed(), batch_n))
            }
            ServeMsg::Busy { id: rid } => {
                if rid != id {
                    return Err(FxpError::config(format!(
                        "busy reply id {rid} for request {id}"
                    )));
                }
                Ok(InferOutcome::Busy)
            }
            ServeMsg::Error { reason, .. } => {
                Err(FxpError::config(format!("server error: {reason}")))
            }
            other => Err(FxpError::config(format!("unexpected reply {other:?}"))),
        }
    }
}

/// What one replayed request came back as.
enum InferOutcome {
    /// `Logits` reply: client-observed latency and the batch it rode in.
    Replied(Duration, usize),
    /// `Busy` backpressure reject: counted, never latency-sampled.
    Busy,
}

/// Arrival offsets from trace start (empty for closed-loop kinds).
fn arrivals(kind: TraceKind, n: usize, rate_rps: f64, rng: &mut Rng) -> Vec<Duration> {
    let mean_gap = 1.0 / rate_rps.max(1e-9);
    let mut out = Vec::with_capacity(n);
    let mut t = 0.0f64;
    match kind {
        TraceKind::Adversarial => {}
        TraceKind::Uniform => {
            for _ in 0..n {
                out.push(Duration::from_secs_f64(t));
                t += mean_gap * (0.8 + 0.4 * rng.uniform());
            }
        }
        TraceKind::Bursty => {
            while out.len() < n {
                let burst = 4 + rng.below(9); // 4..=12 simultaneous
                for _ in 0..burst.min(n - out.len()) {
                    out.push(Duration::from_secs_f64(t));
                }
                // exponential burst gap with the mean that preserves the
                // offered rate: burst_size / rate
                t += -(1.0 - rng.uniform()).ln() * mean_gap * burst as f64;
            }
        }
        TraceKind::Diurnal => {
            for i in 0..n {
                out.push(Duration::from_secs_f64(t));
                let phase = 2.0 * std::f64::consts::PI * 3.0 * i as f64 / n as f64;
                let factor = 0.3 + 1.4 * (0.5 + 0.5 * phase.sin());
                t += mean_gap / factor * (0.9 + 0.2 * rng.uniform());
            }
        }
    }
    out
}

/// Replay one trace: `clients` connections, request `i` owned by client
/// `i % clients`.  Open-loop traces sleep each request until its
/// scheduled offset (from a shared start instant) and then send; a
/// connection whose previous reply overran the next slot sends
/// immediately, so sustained overload degrades gracefully instead of
/// piling unbounded requests onto one socket.  Latency is measured from
/// the actual send.
fn run_trace(
    addr: &str,
    kind: TraceKind,
    n: usize,
    offered_rps: f64,
    clients: usize,
    seed: u64,
    images: &[Vec<f32>],
) -> Result<TraceStats> {
    let clients = clients.max(1);
    let sched = arrivals(kind, n, offered_rps, &mut Rng::new(seed ^ 0x5eed));
    let t_start = Instant::now();
    // (latency_us, batch_n) per success; error and busy-reject counts --
    // one bucket per client
    type ClientTally = (Vec<(f64, usize)>, usize, usize);
    let mut results: Vec<Result<ClientTally>> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|k| {
                let sched = &sched;
                s.spawn(move || -> Result<ClientTally> {
                    let mut cl = Client::connect(addr)?;
                    let mut ok = Vec::new();
                    let mut errors = 0usize;
                    let mut busy = 0usize;
                    let mut i = k;
                    while i < n {
                        if let Some(due) = sched.get(i) {
                            let due = t_start + *due;
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                        }
                        let img = &images[i % images.len()];
                        match cl.infer(i as u64, img) {
                            Ok(InferOutcome::Replied(lat, batch_n)) => {
                                ok.push((lat.as_secs_f64() * 1e6, batch_n))
                            }
                            Ok(InferOutcome::Busy) => busy += 1,
                            Err(e) => {
                                log::warn!("replay: request {i}: {e}");
                                errors += 1;
                            }
                        }
                        i += clients;
                    }
                    Ok((ok, errors, busy))
                })
            })
            .collect();
        for h in handles {
            results.push(h.join().unwrap_or_else(|_| {
                Err(FxpError::config("replay client panicked"))
            }));
        }
    });
    let wall = t_start.elapsed();

    let mut lats = Vec::with_capacity(n);
    let mut batches = Vec::with_capacity(n);
    let mut errors = 0usize;
    let mut rejected = 0usize;
    for r in results {
        let (ok, errs, busy) = r?;
        errors += errs;
        rejected += busy;
        for (lat, b) in ok {
            lats.push(lat);
            batches.push(b);
        }
    }
    Ok(TraceStats::from_samples(
        kind.name(),
        offered_rps,
        wall,
        &lats,
        &batches,
        errors,
        rejected,
    ))
}

/// Full replay session: serial baseline, the requested traces at rates
/// derived from it, `BENCH_serve.json`, and (optionally) the ratio
/// gates.  Returns the report JSON.
pub fn run_suite(addr: &str, opts: &ReplayOpts) -> Result<Json> {
    let (h, w, c, classes, max_batch, max_wait_us) = Client::connect(addr)?.info()?;
    let px = h * w * c;
    log::info!(
        "replay: server model {h}x{w}x{c} -> {classes} classes, \
         max_batch {max_batch}, max_wait {max_wait_us}us"
    );
    let clients = if opts.clients == 0 { 2 * max_batch } else { opts.clients };

    // shape-correct image pool, seeded
    let mut rng = Rng::new(opts.seed);
    let images: Vec<Vec<f32>> = (0..32)
        .map(|_| (0..px).map(|_| rng.uniform() as f32).collect())
        .collect();

    // serial baseline: one closed-loop client against the same daemon
    // (includes the max_wait batching budget -- it is the latency a
    // single-request deployment of *this* config actually sees)
    let n_serial = (opts.requests / 4).max(64);
    let serial = run_trace(
        addr,
        TraceKind::Adversarial,
        n_serial,
        0.0,
        1,
        opts.seed,
        &images,
    )?;
    let serial = TraceStats { name: "serial".into(), ..serial };
    log::info!(
        "replay: serial baseline {:.1} req/s, p50 {:.0}us",
        serial.achieved_rps,
        serial.p50_us
    );
    if serial.requests == 0 {
        return Err(FxpError::config("serial baseline produced no replies"));
    }

    let mut traces = Vec::new();
    for &kind in &opts.traces {
        let rate = serial.achieved_rps * kind.rate_factor();
        let st = run_trace(addr, kind, opts.requests, rate, clients, opts.seed, &images)?;
        log::info!(
            "replay: {} @ {:.1} req/s offered: {:.1} req/s achieved, \
             p95 {:.0}us, mean batch {:.2}, {} errors, {} busy-rejected \
             ({:.1}% reject rate)",
            st.name,
            st.offered_rps,
            st.achieved_rps,
            st.p95_us,
            st.mean_batch,
            st.errors,
            st.rejected,
            100.0 * st.reject_rate()
        );
        traces.push(st);
    }

    // ratio gates (machine-independent: both sides measured on this box)
    let mut gates: Vec<(&str, Json)> = Vec::new();
    let mut violations = Vec::new();
    for st in &traces {
        // busy rejects are deliberate backpressure under overload, never
        // a violation; genuine errors still fail the gate
        if st.errors > 0 {
            violations.push(format!("{}: {} request errors", st.name, st.errors));
        }
        match st.name.as_str() {
            "uniform" => {
                let ratio = st.p95_us / serial.p50_us.max(1.0);
                // baseline_floor is a plain numeric lookup; this key is a
                // ceiling, not a floor
                let cap = baseline_floor("serve", "max_p95_ratio_uniform", 25.0);
                gates.push(("p95_ratio_uniform", Json::Num(ratio)));
                gates.push(("max_p95_ratio_uniform", Json::Num(cap)));
                if ratio > cap {
                    violations.push(format!(
                        "uniform p95 is {ratio:.2}x serial p50 (cap {cap}x)"
                    ));
                }
            }
            "bursty" => {
                let ratio = st.achieved_rps / serial.achieved_rps;
                let floor = baseline_floor("serve", "min_throughput_ratio_bursty", 1.1);
                gates.push(("throughput_ratio_bursty", Json::Num(ratio)));
                gates.push(("min_throughput_ratio_bursty", Json::Num(floor)));
                if ratio < floor {
                    violations.push(format!(
                        "bursty throughput only {ratio:.2}x serial (floor {floor}x)"
                    ));
                }
            }
            _ => {}
        }
    }

    let report = Json::obj(vec![
        (
            "model",
            Json::obj(vec![
                ("h", Json::from(h)),
                ("w", Json::from(w)),
                ("c", Json::from(c)),
                ("classes", Json::from(classes)),
                ("max_batch", Json::from(max_batch)),
                ("max_wait_us", Json::Num(max_wait_us as f64)),
            ]),
        ),
        ("clients", Json::from(clients)),
        ("seed", Json::Num(opts.seed as f64)),
        ("serial", serial.to_json()),
        (
            "traces",
            Json::Obj(
                traces.iter().map(|st| (st.name.clone(), st.to_json())).collect(),
            ),
        ),
        ("gates", Json::obj(gates)),
    ]);

    let path = opts.out.clone().unwrap_or_else(|| {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("..")
            .join("BENCH_serve.json")
    });
    let tmp = path.with_extension("json.tmp");
    crate::util::durable::write_atomic(&path, &tmp, report.to_string().as_bytes())?;
    log::info!("replay: wrote {}", path.display());

    if opts.assert_floors && !violations.is_empty() {
        return Err(FxpError::config(format!(
            "serve gates failed: {}",
            violations.join("; ")
        )));
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrival_schedules_are_deterministic_and_sized() {
        for kind in [TraceKind::Uniform, TraceKind::Bursty, TraceKind::Diurnal] {
            let a = arrivals(kind, 100, 500.0, &mut Rng::new(7));
            let b = arrivals(kind, 100, 500.0, &mut Rng::new(7));
            assert_eq!(a, b, "{kind:?} must be seed-deterministic");
            assert_eq!(a.len(), 100);
            assert!(a.windows(2).all(|w| w[0] <= w[1]), "{kind:?} must be sorted");
        }
        assert!(arrivals(TraceKind::Adversarial, 100, 500.0, &mut Rng::new(7))
            .is_empty());
    }

    #[test]
    fn bursty_schedule_actually_bursts() {
        let a = arrivals(TraceKind::Bursty, 200, 1000.0, &mut Rng::new(11));
        // simultaneous arrivals: many zero gaps
        let zero_gaps =
            a.windows(2).filter(|w| w[1] - w[0] == Duration::ZERO).count();
        assert!(zero_gaps >= 100, "only {zero_gaps} simultaneous pairs");
    }

    #[test]
    fn uniform_schedule_respects_the_offered_rate() {
        let rate = 200.0;
        let a = arrivals(TraceKind::Uniform, 400, rate, &mut Rng::new(3));
        let span = a.last().unwrap().as_secs_f64();
        let measured = 399.0 / span;
        assert!(
            (measured - rate).abs() / rate < 0.15,
            "offered {rate} req/s but schedule encodes {measured:.1}"
        );
    }

    #[test]
    fn trace_kind_parse_round_trips() {
        for kind in [
            TraceKind::Uniform,
            TraceKind::Bursty,
            TraceKind::Diurnal,
            TraceKind::Adversarial,
        ] {
            assert_eq!(TraceKind::parse(kind.name()).unwrap(), kind);
        }
        assert!(TraceKind::parse("weekly").is_err());
    }
}
