//! fxpnet CLI entrypoint.  Everything substantial lives in the library
//! (rust/src/); this is arg parsing + dispatch + error formatting.

use fxpnet::cli::{commands, Args, USAGE};
use fxpnet::util::logging;

fn main() {
    logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(2);
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = commands::dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
