//! fxpnet CLI entrypoint.  Everything substantial lives in the library
//! (rust/src/); this is arg parsing + dispatch + error formatting.

use fxpnet::cli::{commands, Args, USAGE};
use fxpnet::util::logging;

fn main() {
    logging::init();
    // exit-code contract: 0 = success (for `grid merge --check`: sweep
    // complete), 1 = any error including bad usage, 2 = reserved for
    // `--check`'s "incomplete sweep" -- scripts gating on coverage must
    // never confuse a mangled command line with missing cells
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{USAGE}");
        std::process::exit(1);
    }
    let args = match Args::parse(argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(1);
        }
    };
    match commands::dispatch(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}
