//! Cross-validation of the integer engine against the float-simulated
//! quantization the AOT executables run.
//!
//! The two paths cannot agree bit-for-bit: XLA accumulates quantized
//! operand products in f32 (24-bit mantissa) while the engine uses exact
//! i64 accumulators, so pre-activations that land within f32 roundoff of
//! a rounding boundary may step by one LSB.  What must hold -- and what
//! `parity_report` measures -- is (a) logits close in units of the head
//! step, and (b) near-total top-1 agreement.

use crate::error::Result;
use crate::tensor::TensorF;

/// Parity metrics between two logit matrices (n, classes).
#[derive(Clone, Copy, Debug)]
pub struct ParityReport {
    pub n: usize,
    /// max |a-b| over all logits
    pub linf: f32,
    /// mean |a-b|
    pub l1: f32,
    /// fraction of rows with identical argmax
    pub top1_agreement: f64,
}

pub fn parity_report(a: &TensorF, b: &TensorF) -> Result<ParityReport> {
    assert_eq!(a.shape(), b.shape(), "parity: shape mismatch");
    let n = a.shape()[0];
    let ta = a.topk_rows(1)?;
    let tb = b.topk_rows(1)?;
    let agree = ta
        .iter()
        .zip(&tb)
        .filter(|(x, y)| x[0] == y[0])
        .count() as f64
        / n.max(1) as f64;
    let mut linf = 0f32;
    let mut l1 = 0f64;
    for (&x, &y) in a.data().iter().zip(b.data()) {
        let d = (x - y).abs();
        linf = linf.max(d);
        l1 += d as f64;
    }
    Ok(ParityReport {
        n,
        linf,
        l1: (l1 / a.len().max(1) as f64) as f32,
        top1_agreement: agree,
    })
}

impl std::fmt::Display for ParityReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} linf={:.5} l1={:.5} top1-agree={:.2}%",
            self.n,
            self.linf,
            self.l1,
            self.top1_agreement * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn identical_logits() {
        let a = Tensor::from_vec(&[2, 3], vec![1.0, 2.0, 3.0, 3.0, 2.0, 1.0]).unwrap();
        let r = parity_report(&a, &a).unwrap();
        assert_eq!(r.linf, 0.0);
        assert_eq!(r.top1_agreement, 1.0);
    }

    #[test]
    fn detects_disagreement() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let b = Tensor::from_vec(&[2, 2], vec![0.0, 1.0, 0.0, 1.0]).unwrap();
        let r = parity_report(&a, &b).unwrap();
        assert_eq!(r.top1_agreement, 0.5);
        assert_eq!(r.linf, 1.0);
    }
}
