//! Pure-integer fixed-point inference engine: the *deployment* semantics
//! of the paper's Figure 1, with no floating point anywhere on the
//! per-layer compute path.
//!
//! * operands are integer codes in per-layer Q-formats,
//! * step 1: widening integer multiplies,
//! * step 2: i64 "wide accumulator" sums (+ bias on the accumulator grid),
//! * step 3: round/truncate back to the activation format.
//!
//! The engine exists for two reasons: (a) it is the system a user would
//! actually ship to a DSP/NPU after fine-tuning with this library; and
//! (b) it cross-validates the simulated quantization of the AOT
//! executables -- `verify::parity_report` measures how closely the float
//! -simulated path tracks true integer arithmetic (they agree up to f32
//! accumulator roundoff; see rust/tests/inference_parity.rs).

pub mod engine;
pub mod ops;
pub mod verify;

pub use engine::FixedPointNet;
