//! Pure-integer fixed-point inference engine: the *deployment* semantics
//! of the paper's Figure 1, with no floating point anywhere on the
//! per-layer compute path.
//!
//! * operands are integer codes in per-layer Q-formats,
//! * step 1: widening integer multiplies,
//! * step 2: i64 "wide accumulator" sums (+ bias on the accumulator grid),
//! * step 3: round/truncate back to the activation format.
//!
//! The engine exists for two reasons: (a) it is the system a user would
//! actually ship to a DSP/NPU after fine-tuning with this library; and
//! (b) it cross-validates the simulated quantization of the AOT
//! executables -- `verify::parity_report` measures how closely the float
//! -simulated path tracks true integer arithmetic (they agree up to f32
//! accumulator roundoff; see rust/tests/inference_parity.rs).

//! Layer map:
//!
//! * [`ops`] -- scalar/per-plane primitives and the direct per-image
//!   reference convolution (the semantic ground truth),
//! * [`packing`] -- build-time weight panel packing (i32 panels plus
//!   i16/i8 pair panels for narrow cells) + forward-time im2col into
//!   reusable scratch,
//! * [`gemm`] -- the scalar reference microkernel: tiled i32xi32->i64
//!   with fused bias/requantize/ReLU (or f32-decode) epilogues,
//! * [`kernels`] -- the runtime-dispatched SIMD layer: one [`Kernels`]
//!   facade over the scalar reference and the AVX2/NEON kernels
//!   (selected once per process, `FXP_KERNEL` override, bit-identical
//!   to scalar by contract) -- every engine GEMM and quantize pass goes
//!   through it,
//! * [`engine`] -- the network-level driver: batched, zero-allocation,
//!   row-block-threaded execution over a [`Scratch`] arena, pinned
//!   bit-for-bit to the reference path.

pub mod engine;
pub mod gemm;
pub mod kernels;
pub mod ops;
pub mod packing;
pub mod verify;

pub use engine::{FixedPointNet, InferSession, Scratch};
pub use kernels::{Isa, Kernels};
