//! Data layout for the batched integer GEMM engine: weight panel packing
//! (done once at `FixedPointNet::build`) and im2col patch extraction
//! (done into reusable scratch, block by block, at forward time).
//!
//! A 3x3 SAME stride-1 convolution over an NHWC code tensor is exactly a
//! GEMM: each output pixel is one row of an `(N*H*W, 9*Cin)` patch matrix
//! multiplied by the `(9*Cin, Cout)` weight matrix.  The HWIO weight
//! layout `(3, 3, cin, cout)` already *is* that matrix row-major, with
//! row index `(ky*3 + kx)*cin + ci` -- the same order `im2col_rows`
//! emits patch elements -- so packing is a pure relayout, no transpose.
//!
//! Out-of-image taps are emitted as zero codes.  An integer multiply by
//! zero contributes exactly nothing to the i64 accumulator, so the
//! padded GEMM is bit-identical to the tap-skipping direct convolution
//! in `ops::conv3x3_acc`.

/// Panel width of the packed weight layout (columns per panel).  The
/// microkernel in `gemm.rs` holds `MR x NR` i64 accumulators in
/// registers; 8 columns of i64 is one or two SIMD registers per row on
/// common targets.
pub const NR: usize = 8;

/// Weights relayouted into `NR`-column panels, each panel contiguous and
/// k-major: element `(p, j)` of panel `jp` lives at `p*NR + j`.  Columns
/// past `n` are zero-padded so the microkernel never branches on width.
///
/// Generic over the element type: `i32` codes for the integer inference
/// engine, `f32` for the native training engine (which repacks the
/// quantized weights every step and therefore reuses the buffer via
/// [`PackedPanels::pack_into`]).
#[derive(Clone, Debug)]
pub struct PackedPanels<T = i32> {
    data: Vec<T>,
    /// reduction length (rows of the unpacked matrix)
    pub k: usize,
    /// logical column count (output channels / units)
    pub n: usize,
}

impl<T: Copy + Default> PackedPanels<T> {
    /// Pack a row-major `(k, n)` weight matrix.
    pub fn pack(w: &[T], k: usize, n: usize) -> PackedPanels<T> {
        let mut p = PackedPanels { data: Vec::new(), k: 0, n: 0 };
        p.pack_into(w, k, n);
        p
    }

    /// Repack in place, reusing the existing buffer (the native trainer
    /// repacks per step, so steady-state packing must not allocate once
    /// warm).  Every slot -- including the zero padding -- is rewritten.
    pub fn pack_into(&mut self, w: &[T], k: usize, n: usize) {
        debug_assert_eq!(w.len(), k * n);
        let panels = n.div_ceil(NR);
        self.data.clear();
        self.data.resize(panels * k * NR, T::default());
        self.k = k;
        self.n = n;
        for jp in 0..panels {
            let j0 = jp * NR;
            let jw = NR.min(n - j0);
            let dst = &mut self.data[jp * k * NR..(jp + 1) * k * NR];
            for p in 0..k {
                for j in 0..jw {
                    dst[p * NR + j] = w[p * n + j0 + j];
                }
            }
        }
    }

    /// Repack the *transpose* of a row-major `(k, n)` matrix, i.e. the
    /// panels of the `(n, k)` matrix whose element `(j, p)` is
    /// `w[p * n + j]` -- without materialising the transpose.  The
    /// native trainer packs every layer's weights both ways each step
    /// (forward and input-gradient GEMMs), so skipping the intermediate
    /// buffer removes an O(k*n) copy per layer per step.
    pub fn pack_transposed_into(&mut self, w: &[T], k: usize, n: usize) {
        debug_assert_eq!(w.len(), k * n);
        // packed matrix is (n, k): reduction length n, logical columns k
        let panels = k.div_ceil(NR);
        self.data.clear();
        self.data.resize(panels * n * NR, T::default());
        self.k = n;
        self.n = k;
        for jp in 0..panels {
            let j0 = jp * NR;
            let jw = NR.min(k - j0);
            let dst = &mut self.data[jp * n * NR..(jp + 1) * n * NR];
            for p in 0..n {
                for j in 0..jw {
                    // element (p, j0 + j) of the transpose = w[(j0+j), p]
                    dst[p * NR + j] = w[(j0 + j) * n + p];
                }
            }
        }
    }

    #[inline]
    pub fn num_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Panel `jp` as a contiguous `k * NR` slice.
    #[inline]
    pub fn panel(&self, jp: usize) -> &[T] {
        &self.data[jp * self.k * NR..(jp + 1) * self.k * NR]
    }
}

/// A narrow storage type for packed weight codes: `i16` or `i8` panels
/// let the SIMD kernels process 2x/4x the lanes per instruction while
/// the products still widen into the same i64 accumulators as the i32
/// reference kernel (exact integer adds are order-free, so regrouping
/// never changes the result bits).
pub trait NarrowCode: Copy + Default {
    /// Narrow an i32 code.  Only called on codes the format guarantees
    /// fit (`QFormat::bits` bounds the magnitude), so this never wraps.
    fn from_code(c: i32) -> Self;
    /// Widen back for the scalar reference walk of a narrow panel.
    fn widen(self) -> i64;
}

impl NarrowCode for i16 {
    #[inline(always)]
    fn from_code(c: i32) -> i16 {
        debug_assert!(i16::try_from(c).is_ok(), "code {c} does not fit i16");
        c as i16
    }
    #[inline(always)]
    fn widen(self) -> i64 {
        self as i64
    }
}

impl NarrowCode for i8 {
    #[inline(always)]
    fn from_code(c: i32) -> i8 {
        debug_assert!(i8::try_from(c).is_ok(), "code {c} does not fit i8");
        c as i8
    }
    #[inline(always)]
    fn widen(self) -> i64 {
        self as i64
    }
}

/// Narrow weight panels in *pair-interleaved* layout for widening
/// multiply-add kernels (AVX2 `_mm256_madd_epi16` and friends consume
/// two adjacent reduction elements per lane).
///
/// Reduction rows are grouped in pairs: pair-row `p2` of panel `jp`
/// stores `2 * NR` values, laid out as
///
/// ```text
/// dst[p2*2*NR + 2*j]     = w[(2*p2)    * n + j0 + j]   // even k row
/// dst[p2*2*NR + 2*j + 1] = w[(2*p2 + 1)* n + j0 + j]   // odd  k row
/// ```
///
/// with the odd slot zero when `k` is odd and `2*p2 + 1 == k` (a zero
/// code multiplies to exactly zero, so padding never changes the sum).
/// Columns past `n` are zero like [`PackedPanels`].
#[derive(Clone, Debug)]
pub struct PairPanels<T> {
    data: Vec<T>,
    /// reduction length of the *unpacked* matrix
    pub k: usize,
    /// logical column count
    pub n: usize,
    /// pair-row count: `k.div_ceil(2)`
    pub k2: usize,
    /// How many pair-sums an i32 lane can accumulate before it must be
    /// flushed into the i64 accumulator without risking i32 overflow.
    /// Each pair-sum is bounded by `2^(a_bits + w_bits - 1)` in
    /// magnitude, so `(i32::MAX >> (a_bits + w_bits - 1)).max(1)` of
    /// them always fit.
    pub chunk_pairs: usize,
}

impl<T: NarrowCode> PairPanels<T> {
    /// Pack a row-major `(k, n)` i32 code matrix into narrow pair
    /// panels.  `a_bits`/`w_bits` are the operand formats' bit widths,
    /// used only to size the overflow-safe accumulation chunk.
    pub fn pack(w: &[i32], k: usize, n: usize, a_bits: u8, w_bits: u8) -> PairPanels<T> {
        debug_assert_eq!(w.len(), k * n);
        let k2 = k.div_ceil(2);
        let panels = n.div_ceil(NR);
        let mut data = vec![T::default(); panels * k2 * 2 * NR];
        for jp in 0..panels {
            let j0 = jp * NR;
            let jw = NR.min(n - j0);
            let dst = &mut data[jp * k2 * 2 * NR..(jp + 1) * k2 * 2 * NR];
            for p2 in 0..k2 {
                for j in 0..jw {
                    dst[p2 * 2 * NR + 2 * j] = T::from_code(w[(2 * p2) * n + j0 + j]);
                    if 2 * p2 + 1 < k {
                        dst[p2 * 2 * NR + 2 * j + 1] =
                            T::from_code(w[(2 * p2 + 1) * n + j0 + j]);
                    }
                }
            }
        }
        let shift = (a_bits as u32 + w_bits as u32 - 1).min(30);
        let chunk_pairs = ((i32::MAX >> shift) as usize).max(1);
        PairPanels { data, k, n, k2, chunk_pairs }
    }

    #[inline]
    pub fn num_panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Panel `jp` as a contiguous `k2 * 2 * NR` slice.
    #[inline]
    pub fn panel(&self, jp: usize) -> &[T] {
        &self.data[jp * self.k2 * 2 * NR..(jp + 1) * self.k2 * 2 * NR]
    }
}

/// The integer engine's packed-weight storage: one of three physical
/// layouts behind a single logical `(k, n)` code matrix.  Which variant
/// a layer gets is the [`crate::inference::kernels::Kernels`] facade's
/// packing policy (`pack_int`): narrow panels only when the active ISA
/// has a kernel for them and the operand widths make the widening
/// arithmetic exact.
#[derive(Clone, Debug)]
pub enum IntPanels {
    I32(PackedPanels<i32>),
    I16(PairPanels<i16>),
    I8(PairPanels<i8>),
}

impl IntPanels {
    /// Reduction length of the packed matrix.
    #[inline]
    pub fn k(&self) -> usize {
        match self {
            IntPanels::I32(p) => p.k,
            IntPanels::I16(p) => p.k,
            IntPanels::I8(p) => p.k,
        }
    }

    /// Logical column count of the packed matrix.
    #[inline]
    pub fn n(&self) -> usize {
        match self {
            IntPanels::I32(p) => p.n,
            IntPanels::I16(p) => p.n,
            IntPanels::I8(p) => p.n,
        }
    }

    /// Storage kind, for logs and tests.
    #[inline]
    pub fn kind(&self) -> &'static str {
        match self {
            IntPanels::I32(_) => "i32",
            IntPanels::I16(_) => "i16",
            IntPanels::I8(_) => "i8",
        }
    }
}

/// Extract im2col patch rows `row0..row0+rows` of a batched NHWC code
/// tensor into `out` (row-major `(rows, 9*cin)`).
///
/// Global row index `r` maps to output pixel `(img, y, x)` with
/// `img = r / (h*w)`, `y = (r / w) % h`, `x = r % w`.  Patch element
/// order is `(ky, kx, ci)` -- matching the HWIO weight matrix rows.
/// Taps outside the image are written as zero codes.
#[allow(clippy::too_many_arguments)]
pub fn im2col_rows<T: Copy + Default>(
    input: &[T],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    row0: usize,
    rows: usize,
    out: &mut [T],
) {
    let k = 9 * cin;
    debug_assert_eq!(input.len(), n * h * w * cin);
    debug_assert!(row0 + rows <= n * h * w);
    debug_assert!(out.len() >= rows * k);
    for ri in 0..rows {
        let r = row0 + ri;
        let img = r / (h * w);
        let y = (r / w) % h;
        let x = r % w;
        let img_base = img * h * w * cin;
        let dst_row = &mut out[ri * k..(ri + 1) * k];
        for ky in 0..3usize {
            let dst = &mut dst_row[ky * 3 * cin..(ky + 1) * 3 * cin];
            let sy = y as isize + ky as isize - 1;
            if sy < 0 || sy >= h as isize {
                dst.fill(T::default());
                continue;
            }
            let src_row = img_base + sy as usize * w * cin;
            if x >= 1 && x + 1 < w {
                // interior column: the three taps are contiguous in NHWC
                let s = src_row + (x - 1) * cin;
                dst.copy_from_slice(&input[s..s + 3 * cin]);
            } else {
                for kx in 0..3usize {
                    let d = &mut dst[kx * cin..(kx + 1) * cin];
                    let sx = x as isize + kx as isize - 1;
                    if sx < 0 || sx >= w as isize {
                        d.fill(T::default());
                    } else {
                        let s = src_row + sx as usize * cin;
                        d.copy_from_slice(&input[s..s + cin]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_layout_round_trip() {
        // (k=2, n=NR+3): values encode (p, j) so positions are checkable
        let k = 2;
        let n = NR + 3;
        let w: Vec<i32> = (0..k * n).map(|i| i as i32 + 1).collect();
        let pw = PackedPanels::pack(&w, k, n);
        assert_eq!(pw.num_panels(), 2);
        for jp in 0..pw.num_panels() {
            let panel = pw.panel(jp);
            for p in 0..k {
                for j in 0..NR {
                    let col = jp * NR + j;
                    let want = if col < n { w[p * n + col] } else { 0 };
                    assert_eq!(panel[p * NR + j], want, "jp={jp} p={p} j={j}");
                }
            }
        }
    }

    #[test]
    fn pack_transposed_matches_explicit_transpose() {
        // (k, n) both crossing the NR panel edge
        let (k, n) = (NR + 5, NR + 2);
        let w: Vec<i32> = (0..k * n).map(|i| i as i32 + 1).collect();
        let mut wt = vec![0i32; k * n];
        for p in 0..k {
            for j in 0..n {
                wt[j * k + p] = w[p * n + j];
            }
        }
        let want = PackedPanels::pack(&wt, n, k);
        let mut got = PackedPanels::pack(&[0i32; 0], 0, 0);
        got.pack_transposed_into(&w, k, n);
        assert_eq!(got.k, want.k);
        assert_eq!(got.n, want.n);
        assert_eq!(got.num_panels(), want.num_panels());
        for jp in 0..want.num_panels() {
            assert_eq!(got.panel(jp), want.panel(jp), "panel {jp}");
        }
    }

    #[test]
    fn pair_pack_layout_interleaves_reduction_pairs() {
        // odd k exercises the zero-padded trailing pair slot; n crosses
        // the panel edge
        let (k, n) = (5usize, NR + 3);
        let w: Vec<i32> = (0..k * n).map(|i| (i as i32 % 251) - 125).collect();
        let pw: PairPanels<i16> = PairPanels::pack(&w, k, n, 8, 8);
        assert_eq!(pw.k, k);
        assert_eq!(pw.n, n);
        assert_eq!(pw.k2, 3);
        assert_eq!(pw.num_panels(), 2);
        for jp in 0..pw.num_panels() {
            let panel = pw.panel(jp);
            for p2 in 0..pw.k2 {
                for j in 0..NR {
                    let col = jp * NR + j;
                    let even = if col < n { w[(2 * p2) * n + col] } else { 0 };
                    let odd = if col < n && 2 * p2 + 1 < k {
                        w[(2 * p2 + 1) * n + col]
                    } else {
                        0
                    };
                    assert_eq!(
                        panel[p2 * 2 * NR + 2 * j] as i32,
                        even,
                        "jp={jp} p2={p2} j={j} even"
                    );
                    assert_eq!(
                        panel[p2 * 2 * NR + 2 * j + 1] as i32,
                        odd,
                        "jp={jp} p2={p2} j={j} odd"
                    );
                }
            }
        }
    }

    #[test]
    fn pair_pack_chunk_budget_bounds_i32_accumulation() {
        let w = vec![0i32; 4];
        // Q8 x Q8: pair-sums bounded by 2^15, so 2^31/2^15 = 65535 fit
        let p8: PairPanels<i8> = PairPanels::pack(&w, 2, 2, 8, 8);
        assert_eq!(p8.chunk_pairs, 65535);
        // 16+8 bit operands: pair-sums up to 2^23 -> 255 fit
        let p16: PairPanels<i16> = PairPanels::pack(&w, 2, 2, 16, 8);
        assert_eq!(p16.chunk_pairs, 255);
        // worst allowed case still accumulates at least one pair
        let pw: PairPanels<i16> = PairPanels::pack(&w, 2, 2, 16, 16);
        assert!(pw.chunk_pairs >= 1);
    }

    #[test]
    fn int_panels_report_shape_and_kind() {
        let w: Vec<i32> = (0..6).collect();
        let p = IntPanels::I32(PackedPanels::pack(&w, 2, 3));
        assert_eq!((p.k(), p.n(), p.kind()), (2, 3, "i32"));
        let p = IntPanels::I16(PairPanels::pack(&w, 2, 3, 8, 8));
        assert_eq!((p.k(), p.n(), p.kind()), (2, 3, "i16"));
        let p = IntPanels::I8(PairPanels::pack(&w, 2, 3, 8, 4));
        assert_eq!((p.k(), p.n(), p.kind()), (2, 3, "i8"));
    }

    /// Reference patch extraction straight from the definition.
    fn patch_ref(
        input: &[i32],
        h: usize,
        w: usize,
        cin: usize,
        img: usize,
        y: usize,
        x: usize,
    ) -> Vec<i32> {
        let mut row = Vec::with_capacity(9 * cin);
        for ky in 0..3isize {
            for kx in 0..3isize {
                let (sy, sx) = (y as isize + ky - 1, x as isize + kx - 1);
                for ci in 0..cin {
                    if sy < 0 || sy >= h as isize || sx < 0 || sx >= w as isize {
                        row.push(0);
                    } else {
                        row.push(
                            input[((img * h + sy as usize) * w + sx as usize) * cin
                                + ci],
                        );
                    }
                }
            }
        }
        row
    }

    #[test]
    fn im2col_matches_reference() {
        let (n, h, w, cin) = (2usize, 4usize, 5usize, 3usize);
        let input: Vec<i32> = (0..n * h * w * cin).map(|i| i as i32 - 40).collect();
        let k = 9 * cin;
        let total = n * h * w;
        // extract in two uneven blocks to exercise row0 offsets
        for (row0, rows) in [(0usize, 13usize), (13, total - 13)] {
            let mut out = vec![99i32; rows * k];
            im2col_rows(&input, n, h, w, cin, row0, rows, &mut out);
            for ri in 0..rows {
                let r = row0 + ri;
                let (img, y, x) = (r / (h * w), (r / w) % h, r % w);
                let want = patch_ref(&input, h, w, cin, img, y, x);
                assert_eq!(&out[ri * k..(ri + 1) * k], &want[..], "row {r}");
            }
        }
    }
}
