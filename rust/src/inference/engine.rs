//! The network-level integer engine: build from (arch, params, formats),
//! run images to logits.

use crate::error::{FxpError, Result};
use crate::fixedpoint::QFormat;
use crate::inference::ops;
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;
use crate::tensor::{Tensor, TensorF};

enum Layer {
    Conv {
        w_codes: Vec<i32>,
        cin: usize,
        cout: usize,
        bias: Vec<f32>,
        w_fmt: QFormat,
        a_fmt: Option<QFormat>,
        relu: bool,
    },
    Pool,
    Fc {
        w_codes: Vec<i32>,
        n_in: usize,
        n_out: usize,
        bias: Vec<f32>,
        w_fmt: QFormat,
        a_fmt: Option<QFormat>,
        relu: bool,
    },
}

/// A fully-quantized network ready for integer-only inference.
pub struct FixedPointNet {
    layers: Vec<Layer>,
    input_fmt: QFormat,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    num_classes: usize,
}

fn encode_weights(w: &TensorF, fmt: QFormat) -> Vec<i32> {
    ops::encode(w.data(), fmt)
}

impl FixedPointNet {
    /// Build the engine.  All *weights* must be quantized in `nq`; hidden
    /// *activations* must be quantized too (that is what "deployed in
    /// fixed point" means); the final layer's activation format may be
    /// anything -- logits are returned as f32 either way.
    ///
    /// `input_fmt` is the format input pixels are encoded with (images in
    /// [0,1]; Q16.14 keeps the input quantization error negligible
    /// relative to the 4-16 bit layer formats under study).
    pub fn build(
        arch: &ArchSpec,
        params: &ParamSet,
        nq: &NetQuant,
        input_fmt: QFormat,
    ) -> Result<FixedPointNet> {
        if nq.num_layers() != arch.num_layers {
            return Err(FxpError::config(format!(
                "NetQuant has {} layers, arch {}",
                nq.num_layers(),
                arch.num_layers
            )));
        }
        let mut layers = Vec::new();
        let mut li = 0usize;
        let l_last = arch.num_layers - 1;
        for (kind, _out) in &arch.layers {
            match kind.as_str() {
                "pool" => layers.push(Layer::Pool),
                "conv" | "fc" => {
                    let w = params.weight(li);
                    let b = params.bias(li);
                    let w_fmt = nq.weights[li].ok_or_else(|| {
                        FxpError::config(format!(
                            "layer {li}: weights must be quantized for integer \
                             inference"
                        ))
                    })?;
                    let a_fmt = nq.acts[li];
                    if li < l_last && a_fmt.is_none() {
                        return Err(FxpError::config(format!(
                            "layer {li}: hidden activations must be quantized \
                             for integer inference"
                        )));
                    }
                    let relu = li < l_last;
                    let w_codes = encode_weights(w, w_fmt);
                    if kind == "conv" {
                        let s = w.shape();
                        layers.push(Layer::Conv {
                            w_codes,
                            cin: s[2],
                            cout: s[3],
                            bias: b.data().to_vec(),
                            w_fmt,
                            a_fmt,
                            relu,
                        });
                    } else {
                        let s = w.shape();
                        layers.push(Layer::Fc {
                            w_codes,
                            n_in: s[0],
                            n_out: s[1],
                            bias: b.data().to_vec(),
                            w_fmt,
                            a_fmt,
                            relu,
                        });
                    }
                    li += 1;
                }
                other => {
                    return Err(FxpError::config(format!("unknown layer kind '{other}'")))
                }
            }
        }
        Ok(FixedPointNet {
            layers,
            input_fmt,
            in_h: arch.input[0],
            in_w: arch.input[1],
            in_c: arch.input[2],
            num_classes: arch.num_classes,
        })
    }

    /// Forward one image (h*w*c floats in [0,1]) to f32 logits.
    pub fn forward(&self, image: &[f32]) -> Result<Vec<f32>> {
        if image.len() != self.in_h * self.in_w * self.in_c {
            return Err(FxpError::shape(format!(
                "image len {} != {}x{}x{}",
                image.len(),
                self.in_h,
                self.in_w,
                self.in_c
            )));
        }
        let mut codes = ops::encode(image, self.input_fmt);
        let mut fmt = self.input_fmt;
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut flat = false;
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    let c = codes.len() / (h * w);
                    let (o, oh, ow) = ops::maxpool2(&codes, h, w, c);
                    codes = o;
                    h = oh;
                    w = ow;
                }
                Layer::Conv { w_codes, cin, cout, bias, w_fmt, a_fmt, relu } => {
                    debug_assert!(!flat);
                    let acc_frac = fmt.frac as i32 + w_fmt.frac as i32;
                    let acc = ops::conv3x3_acc(
                        &codes, h, w, *cin, w_codes, *cout, bias, acc_frac,
                    );
                    match a_fmt {
                        Some(af) => {
                            codes = ops::requant_relu(&acc, acc_frac, *af, *relu);
                            fmt = *af;
                        }
                        None => {
                            // float head on a conv would need f32 logits;
                            // only valid as the last layer (checked in build)
                            return Ok(ops::decode_acc(&acc, acc_frac));
                        }
                    }
                }
                Layer::Fc { w_codes, n_in, n_out, bias, w_fmt, a_fmt, relu } => {
                    if !flat {
                        flat = true; // NHWC flatten order matches jnp.reshape
                    }
                    if codes.len() != *n_in {
                        return Err(FxpError::shape(format!(
                            "fc expects {n_in} inputs, got {}",
                            codes.len()
                        )));
                    }
                    let acc_frac = fmt.frac as i32 + w_fmt.frac as i32;
                    let acc = ops::fc_acc(&codes, w_codes, *n_out, bias, acc_frac);
                    match a_fmt {
                        Some(af) => {
                            codes = ops::requant_relu(&acc, acc_frac, *af, *relu);
                            fmt = *af;
                        }
                        None => return Ok(ops::decode_acc(&acc, acc_frac)),
                    }
                }
            }
        }
        // all layers quantized including head: decode final codes
        Ok(ops::decode(&codes, fmt))
    }

    /// Forward a batch tensor (n, h, w, c); returns (n, classes) logits.
    pub fn forward_batch(&self, images: &TensorF) -> Result<TensorF> {
        let n = images.shape()[0];
        let img_len = self.in_h * self.in_w * self.in_c;
        let mut out = Vec::with_capacity(n * self.num_classes);
        for i in 0..n {
            let logits = self.forward(&images.data()[i * img_len..(i + 1) * img_len])?;
            if logits.len() != self.num_classes {
                return Err(FxpError::shape(format!(
                    "engine produced {} logits, expected {}",
                    logits.len(),
                    self.num_classes
                )));
            }
            out.extend_from_slice(&logits);
        }
        Tensor::from_vec(&[n, self.num_classes], out)
    }

    /// Rough multiply count per image (for the Figure 1 bench).
    pub fn macs_per_image(&self) -> usize {
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut macs = 0usize;
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    h /= 2;
                    w /= 2;
                }
                Layer::Conv { cin, cout, .. } => {
                    macs += h * w * 9 * cin * cout;
                }
                Layer::Fc { n_in, n_out, .. } => {
                    macs += n_in * n_out;
                }
            }
        }
        macs
    }
}
