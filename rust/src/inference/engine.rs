//! The network-level integer engine: build from (arch, params, formats),
//! run images to logits.
//!
//! Two execution paths share one set of quantized weights:
//!
//! * [`FixedPointNet::forward`] -- the retained direct-convolution
//!   reference: one image, naive 3x3 loops, allocating.  It exists as
//!   the semantic ground truth the fast path is pinned against
//!   (rust/tests/engine_gemm_parity.rs) and as the baseline the
//!   engine-throughput bench measures speedups over.
//! * [`FixedPointNet::forward_batch_into`] -- the batched GEMM engine:
//!   the whole (N, H, W, C) batch runs layer-by-layer, each conv as one
//!   im2col + panel-packed GEMM over `N*H*W` patch rows with a fused
//!   bias/requantize/ReLU epilogue, each FC as a GEMM over `N` rows.
//!   All working memory lives in a caller-owned [`Scratch`] arena, so
//!   steady-state forwards do zero heap allocation, and GEMM row-blocks
//!   shard across `std::thread::scope` workers.  The path is pure
//!   integer, so results are bit-identical for any batch size, block
//!   size, or thread count.
//!
//! Weight panels are packed once at [`FixedPointNet::build`]; biases are
//! converted to the i64 accumulator grid once per layer (the per-layer
//! accumulator fractional length is a build-time constant: input format
//! and every activation format are fixed at build).

use crate::error::{FxpError, Result};
use crate::fixedpoint::QFormat;
use crate::inference::kernels::Kernels;
use crate::inference::ops;
use crate::inference::packing::{self, IntPanels};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;
use crate::tensor::{Tensor, TensorF};

/// Patch rows extracted per im2col + GEMM block: bounds the per-thread
/// scratch to `ROW_BLOCK * 9 * cin` codes and keeps a block resident in
/// L2 while its GEMM runs.
const ROW_BLOCK: usize = 64;

/// One weighted (conv or fc) layer, ready for both paths.
struct Dense {
    /// raw weight codes -- (3, 3, cin, cout) for conv, (n_in, n_out) for
    /// fc -- used by the direct reference path
    w_codes: Vec<i32>,
    /// the same codes as NR-column panels for the GEMM path; the kernel
    /// facade narrows them to i16/i8 pair panels when the cell's operand
    /// widths keep the SIMD arithmetic exact
    packed: IntPanels,
    /// GEMM reduction length: 9*cin (conv) or n_in (fc)
    k: usize,
    /// output channels / units
    n_out: usize,
    /// input channels (conv only; 0 for fc)
    cin: usize,
    /// float bias (reference path re-derives the accumulator bias)
    bias: Vec<f32>,
    /// bias on the i64 accumulator grid (fused into the GEMM epilogue)
    bias_acc: Vec<i64>,
    /// accumulator fractional length: in_fmt.frac + w_fmt.frac
    acc_frac: i32,
    a_fmt: Option<QFormat>,
    relu: bool,
}

enum Layer {
    Conv(Dense),
    Pool,
    Fc(Dense),
}

/// A fully-quantized network ready for integer-only inference.
pub struct FixedPointNet {
    layers: Vec<Layer>,
    /// the kernel set every GEMM of this net runs on, captured at build
    /// (weight panels are packed for it, so it cannot change afterwards)
    kernels: &'static Kernels,
    input_fmt: QFormat,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    num_classes: usize,
}

/// Reusable working memory for [`FixedPointNet::forward_batch_into`]:
/// two ping-pong activation planes and per-thread im2col patch blocks.
/// Buffers grow on first use (or via [`Scratch::for_net`]) and are
/// reused verbatim afterwards -- a warm scratch makes
/// `forward_batch_into` allocation-free.
#[derive(Default)]
pub struct Scratch {
    act_a: Vec<i32>,
    act_b: Vec<i32>,
    patches: Vec<i32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// Pre-size every buffer for `batch`-image forwards of `net` with
    /// `threads` workers, so the first forward is already allocation-free.
    pub fn for_net(net: &FixedPointNet, batch: usize, threads: usize) -> Scratch {
        let mut s = Scratch::new();
        s.ensure(net, batch, threads);
        s
    }

    fn ensure(&mut self, net: &FixedPointNet, batch: usize, threads: usize) {
        let acts = net.act_capacity(batch);
        if self.act_a.len() < acts {
            self.act_a.resize(acts, 0);
        }
        if self.act_b.len() < acts {
            self.act_b.resize(acts, 0);
        }
        let patches = threads.max(1) * ROW_BLOCK * net.max_conv_k();
        if self.patches.len() < patches {
            self.patches.resize(patches, 0);
        }
    }
}

fn encode_weights(w: &TensorF, fmt: QFormat) -> Vec<i32> {
    ops::encode(w.data(), fmt)
}

impl FixedPointNet {
    /// Build the engine.  All *weights* must be quantized in `nq`; hidden
    /// *activations* must be quantized too (that is what "deployed in
    /// fixed point" means); the final layer's activation format may be
    /// anything -- logits are returned as f32 either way.
    ///
    /// `input_fmt` is the format input pixels are encoded with (images in
    /// [0,1]; Q16.14 keeps the input quantization error negligible
    /// relative to the 4-16 bit layer formats under study).
    pub fn build(
        arch: &ArchSpec,
        params: &ParamSet,
        nq: &NetQuant,
        input_fmt: QFormat,
    ) -> Result<FixedPointNet> {
        Self::build_with_kernels(arch, params, nq, input_fmt, Kernels::auto())
    }

    /// [`build`](Self::build) against an explicit kernel set instead of
    /// the process-wide auto-detected one.  Weight panels are packed for
    /// that set (scalar keeps plain i32 panels; SIMD narrows eligible
    /// cells to i16/i8 pair panels) and every GEMM of the net dispatches
    /// through it -- which is how tests and benches hold a scalar net
    /// and a SIMD net in one process and compare logits bit-for-bit.
    pub fn build_with_kernels(
        arch: &ArchSpec,
        params: &ParamSet,
        nq: &NetQuant,
        input_fmt: QFormat,
        kernels: &'static Kernels,
    ) -> Result<FixedPointNet> {
        if nq.num_layers() != arch.num_layers {
            return Err(FxpError::config(format!(
                "NetQuant has {} layers, arch {}",
                nq.num_layers(),
                arch.num_layers
            )));
        }
        let mut layers = Vec::new();
        let mut li = 0usize;
        let l_last = arch.num_layers - 1;
        let mut fmt = input_fmt;
        for (kind, _out) in &arch.layers {
            match kind.as_str() {
                "pool" => layers.push(Layer::Pool),
                "conv" | "fc" => {
                    let w = params.weight(li);
                    let b = params.bias(li);
                    let w_fmt = nq.weights[li].ok_or_else(|| {
                        FxpError::config(format!(
                            "layer {li}: weights must be quantized for integer \
                             inference"
                        ))
                    })?;
                    let a_fmt = nq.acts[li];
                    if li < l_last && a_fmt.is_none() {
                        return Err(FxpError::config(format!(
                            "layer {li}: hidden activations must be quantized \
                             for integer inference"
                        )));
                    }
                    let relu = li < l_last;
                    let w_codes = encode_weights(w, w_fmt);
                    let s = w.shape().to_vec();
                    let (k, n_out, cin) = if kind == "conv" {
                        (9 * s[2], s[3], s[2])
                    } else {
                        (s[0], s[1], 0)
                    };
                    let acc_frac = fmt.frac as i32 + w_fmt.frac as i32;
                    let bias_acc: Vec<i64> = b
                        .data()
                        .iter()
                        .map(|&bv| ops::bias_to_acc(bv, acc_frac))
                        .collect();
                    // `fmt` is still this layer's *input* format here --
                    // its bit width is the GEMM A-operand width the
                    // narrow-panel eligibility check needs
                    let packed =
                        kernels.pack_int(&w_codes, k, n_out, fmt.bits, w_fmt.bits);
                    let dense = Dense {
                        w_codes,
                        packed,
                        k,
                        n_out,
                        cin,
                        bias: b.data().to_vec(),
                        bias_acc,
                        acc_frac,
                        a_fmt,
                        relu,
                    };
                    layers.push(if kind == "conv" {
                        Layer::Conv(dense)
                    } else {
                        Layer::Fc(dense)
                    });
                    if let Some(af) = a_fmt {
                        fmt = af;
                    }
                    li += 1;
                }
                other => {
                    return Err(FxpError::config(format!("unknown layer kind '{other}'")))
                }
            }
        }
        Ok(FixedPointNet {
            layers,
            kernels,
            input_fmt,
            in_h: arch.input[0],
            in_w: arch.input[1],
            in_c: arch.input[2],
            num_classes: arch.num_classes,
        })
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// The kernel set this net was built against.
    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    /// Input image shape (h, w, c).
    pub fn input_shape(&self) -> (usize, usize, usize) {
        (self.in_h, self.in_w, self.in_c)
    }

    /// Largest activation plane (in codes) any layer boundary needs for a
    /// `batch`-image forward.
    fn act_capacity(&self, batch: usize) -> usize {
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut c = self.in_c;
        let mut cap = batch * h * w * c;
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    h /= 2;
                    w /= 2;
                }
                Layer::Conv(d) => c = d.n_out,
                Layer::Fc(d) => {
                    h = 1;
                    w = 1;
                    c = d.n_out;
                }
            }
            cap = cap.max(batch * h * w * c);
        }
        cap
    }

    /// Widest im2col row (9*cin) over the conv layers.
    fn max_conv_k(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv(d) => d.k,
                _ => 0,
            })
            .max()
            .unwrap_or(0)
    }

    /// Forward one image (h*w*c floats in [0,1]) to f32 logits via the
    /// direct per-image reference path (naive convolution, allocating).
    /// The batched GEMM path is pinned bit-for-bit against this.
    pub fn forward(&self, image: &[f32]) -> Result<Vec<f32>> {
        if image.len() != self.in_h * self.in_w * self.in_c {
            return Err(FxpError::shape(format!(
                "image len {} != {}x{}x{}",
                image.len(),
                self.in_h,
                self.in_w,
                self.in_c
            )));
        }
        let mut codes = ops::encode(image, self.input_fmt);
        let mut fmt = self.input_fmt;
        let (mut h, mut w) = (self.in_h, self.in_w);
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    let c = codes.len() / (h * w);
                    let (o, oh, ow) = ops::maxpool2(&codes, h, w, c);
                    codes = o;
                    h = oh;
                    w = ow;
                }
                Layer::Conv(d) => {
                    if codes.len() != h * w * d.cin {
                        return Err(FxpError::shape(format!(
                            "conv expects {}x{}x{} codes, got {}",
                            h,
                            w,
                            d.cin,
                            codes.len()
                        )));
                    }
                    let acc = ops::conv3x3_acc(
                        &codes,
                        h,
                        w,
                        d.cin,
                        &d.w_codes,
                        d.n_out,
                        &d.bias,
                        d.acc_frac,
                    );
                    match d.a_fmt {
                        Some(af) => {
                            codes = ops::requant_relu(&acc, d.acc_frac, af, d.relu);
                            fmt = af;
                        }
                        None => {
                            // float head on a conv would need f32 logits;
                            // only valid as the last layer (checked in build)
                            return Ok(ops::decode_acc(&acc, d.acc_frac));
                        }
                    }
                }
                Layer::Fc(d) => {
                    if codes.len() != d.k {
                        return Err(FxpError::shape(format!(
                            "fc expects {} inputs, got {}",
                            d.k,
                            codes.len()
                        )));
                    }
                    let acc = ops::fc_acc(&codes, &d.w_codes, d.n_out, &d.bias, d.acc_frac);
                    match d.a_fmt {
                        Some(af) => {
                            codes = ops::requant_relu(&acc, d.acc_frac, af, d.relu);
                            fmt = af;
                        }
                        None => return Ok(ops::decode_acc(&acc, d.acc_frac)),
                    }
                }
            }
        }
        // all layers quantized including head: decode final codes
        Ok(ops::decode(&codes, fmt))
    }

    /// Forward a batch tensor (n, h, w, c); returns (n, classes) logits.
    /// Runs the batched GEMM engine single-threaded with a throwaway
    /// scratch; for steady-state/threaded use, hold a [`Scratch`] and
    /// call [`forward_batch_into`](Self::forward_batch_into) or
    /// [`forward_batch_threaded`](Self::forward_batch_threaded).
    pub fn forward_batch(&self, images: &TensorF) -> Result<TensorF> {
        self.forward_batch_threaded(images, 1)
    }

    /// Forward a batch with `threads` GEMM row-block workers.  Results
    /// are bit-identical for every thread count (pure integer path).
    pub fn forward_batch_threaded(
        &self,
        images: &TensorF,
        threads: usize,
    ) -> Result<TensorF> {
        let n = images.shape().first().copied().unwrap_or(0);
        let mut scratch = Scratch::new();
        let mut out = vec![0f32; n * self.num_classes];
        self.forward_batch_into(images, &mut scratch, threads, &mut out)?;
        Tensor::from_vec(&[n, self.num_classes], out)
    }

    /// The zero-allocation batched forward: whole-batch layer-by-layer
    /// GEMM execution into caller-owned buffers.  `out` receives the
    /// (n, classes) logits row-major.  With a warm `scratch` this
    /// performs no heap allocation.
    pub fn forward_batch_into(
        &self,
        images: &TensorF,
        scratch: &mut Scratch,
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let shape = images.shape();
        if shape.is_empty() {
            return Err(FxpError::shape("forward_batch: scalar input"));
        }
        self.forward_slice_into(images.data(), shape[0], scratch, threads, out)
    }

    /// [`forward_batch_into`](Self::forward_batch_into) over a raw
    /// row-major `(n, h, w, c)` image slice -- lets callers feed a
    /// contiguous row range of a dataset tensor directly, without
    /// copying it into a fresh tensor first (the chunked integer
    /// evaluator's hot path).
    pub fn forward_slice_into(
        &self,
        images: &[f32],
        n: usize,
        scratch: &mut Scratch,
        threads: usize,
        out: &mut [f32],
    ) -> Result<()> {
        let img_len = self.in_h * self.in_w * self.in_c;
        if images.len() != n * img_len {
            return Err(FxpError::shape(format!(
                "batch len {} != {n}x{}x{}x{}",
                images.len(),
                self.in_h,
                self.in_w,
                self.in_c
            )));
        }
        if out.len() != n * self.num_classes {
            return Err(FxpError::shape(format!(
                "logit buffer len {} != {n}x{}",
                out.len(),
                self.num_classes
            )));
        }
        if n == 0 {
            return Ok(());
        }
        let threads = threads.max(1);
        scratch.ensure(self, n, threads);
        let Scratch { act_a, act_b, patches } = scratch;
        let (mut src, mut dst): (&mut [i32], &mut [i32]) =
            (&mut act_a[..], &mut act_b[..]);

        ops::encode_into(images, self.input_fmt, &mut src[..n * img_len]);
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut c = self.in_c;
        let mut fmt = self.input_fmt;
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    let (oh, ow) = ops::maxpool2_batch_into(
                        &src[..n * h * w * c],
                        n,
                        h,
                        w,
                        c,
                        &mut dst[..n * (h / 2) * (w / 2) * c],
                    );
                    h = oh;
                    w = ow;
                    std::mem::swap(&mut src, &mut dst);
                }
                Layer::Conv(d) => {
                    if c != d.cin {
                        return Err(FxpError::shape(format!(
                            "conv expects {} channels, got {c}",
                            d.cin
                        )));
                    }
                    let rows = n * h * w;
                    match d.a_fmt {
                        Some(af) => {
                            conv_gemm(
                                d,
                                self.kernels,
                                &src[..rows * c],
                                n,
                                h,
                                w,
                                threads,
                                &mut patches[..],
                                ConvOut::Codes {
                                    out: &mut dst[..rows * d.n_out],
                                    fmt: af,
                                },
                            );
                            c = d.n_out;
                            fmt = af;
                            std::mem::swap(&mut src, &mut dst);
                        }
                        None => {
                            // float conv head: only shape-valid when the
                            // remaining plane is exactly the logit matrix
                            if rows * d.n_out != n * self.num_classes {
                                return Err(FxpError::shape(format!(
                                    "conv head produces {} logits/image, \
                                     expected {}",
                                    h * w * d.n_out,
                                    self.num_classes
                                )));
                            }
                            conv_gemm(
                                d,
                                self.kernels,
                                &src[..rows * c],
                                n,
                                h,
                                w,
                                threads,
                                &mut patches[..],
                                ConvOut::Floats(&mut out[..]),
                            );
                            return Ok(());
                        }
                    }
                }
                Layer::Fc(d) => {
                    let k = h * w * c;
                    if k != d.k {
                        return Err(FxpError::shape(format!(
                            "fc expects {} inputs, got {k}",
                            d.k
                        )));
                    }
                    match d.a_fmt {
                        Some(af) => {
                            fc_gemm(
                                d,
                                self.kernels,
                                &src[..n * k],
                                n,
                                threads,
                                ConvOut::Codes {
                                    out: &mut dst[..n * d.n_out],
                                    fmt: af,
                                },
                            );
                            h = 1;
                            w = 1;
                            c = d.n_out;
                            fmt = af;
                            std::mem::swap(&mut src, &mut dst);
                        }
                        None => {
                            if d.n_out != self.num_classes {
                                return Err(FxpError::shape(format!(
                                    "fc head produces {} logits, expected {}",
                                    d.n_out, self.num_classes
                                )));
                            }
                            fc_gemm(
                                d,
                                self.kernels,
                                &src[..n * k],
                                n,
                                threads,
                                ConvOut::Floats(&mut out[..]),
                            );
                            return Ok(());
                        }
                    }
                }
            }
        }
        // all layers quantized including head: decode final codes
        if n * h * w * c != n * self.num_classes {
            return Err(FxpError::shape(format!(
                "network leaves {} values/image, expected {} logits",
                h * w * c,
                self.num_classes
            )));
        }
        ops::decode_into(&src[..n * self.num_classes], fmt, out);
        Ok(())
    }

    /// Rough multiply count per image (for the Figure 1 bench).
    pub fn macs_per_image(&self) -> usize {
        let (mut h, mut w) = (self.in_h, self.in_w);
        let mut macs = 0usize;
        for layer in &self.layers {
            match layer {
                Layer::Pool => {
                    h /= 2;
                    w /= 2;
                }
                Layer::Conv(d) => {
                    macs += h * w * d.k * d.n_out;
                }
                Layer::Fc(d) => {
                    macs += d.k * d.n_out;
                }
            }
        }
        macs
    }
}

/// A handle-based inference session for concurrent callers: shared
/// quantized weights behind an `Arc`, a private warm [`Scratch`] plus a
/// logit buffer pre-sized for `max_batch`, so steady-state [`run`]
/// calls do zero heap allocation.  Each concurrent caller (the serving
/// daemon's batcher thread, a bench client, a test) holds its own
/// session; the packed weight panels are shared read-only, and the
/// integer path keeps logits bit-identical whichever session -- and
/// whichever batch size -- computes them.
///
/// [`run`]: InferSession::run
pub struct InferSession {
    net: std::sync::Arc<FixedPointNet>,
    scratch: Scratch,
    out: Vec<f32>,
    threads: usize,
    max_batch: usize,
}

impl InferSession {
    /// Pre-size buffers for forwards of up to `max_batch` images with
    /// `threads` GEMM row-block workers.
    pub fn new(
        net: std::sync::Arc<FixedPointNet>,
        max_batch: usize,
        threads: usize,
    ) -> InferSession {
        let max_batch = max_batch.max(1);
        let threads = threads.max(1);
        let scratch = Scratch::for_net(&net, max_batch, threads);
        let out = vec![0f32; max_batch * net.num_classes()];
        InferSession { net, scratch, out, threads, max_batch }
    }

    pub fn net(&self) -> &FixedPointNet {
        &self.net
    }

    pub fn max_batch(&self) -> usize {
        self.max_batch
    }

    /// Forward `n` images (row-major `(n, h, w, c)` floats) and return
    /// the `(n, classes)` logits slice.  `n` must not exceed
    /// `max_batch`: the pre-sized buffers are deliberately never grown
    /// (growth would silently break the zero-steady-state-allocation
    /// contract the serving daemon's latency budget relies on).
    pub fn run(&mut self, images: &[f32], n: usize) -> Result<&[f32]> {
        if n > self.max_batch {
            return Err(FxpError::config(format!(
                "batch {n} exceeds session max_batch {}",
                self.max_batch
            )));
        }
        let nc = self.net.num_classes();
        self.net.forward_slice_into(
            images,
            n,
            &mut self.scratch,
            self.threads,
            &mut self.out[..n * nc],
        )?;
        Ok(&self.out[..n * nc])
    }
}

/// Where a GEMM layer writes: requantized codes or decoded f32 logits.
enum ConvOut<'a> {
    Codes { out: &'a mut [i32], fmt: QFormat },
    Floats(&'a mut [f32]),
}

/// Split `total` rows into per-worker contiguous ranges and run `work`
/// on each (inline when a single worker suffices).  `work` receives
/// `(first_row, out_chunk, patch_chunk)`.
#[allow(clippy::too_many_arguments)]
fn shard_rows<E: Send, W>(
    total: usize,
    n_out: usize,
    threads: usize,
    patch_per: usize,
    out: &mut [E],
    patches: &mut [i32],
    work: W,
) where
    W: Fn(usize, &mut [E], &mut [i32]) + Sync,
{
    let threads = threads.max(1).min(total.max(1));
    let rows_per = total.div_ceil(threads);
    if threads == 1 {
        work(0, &mut out[..total * n_out], &mut patches[..patch_per]);
        return;
    }
    std::thread::scope(|s| {
        let mut out_rem: &mut [E] = out;
        let mut patch_rem: &mut [i32] = patches;
        let mut row0 = 0usize;
        while row0 < total {
            let rows = rows_per.min(total - row0);
            let (out_chunk, orest) = out_rem.split_at_mut(rows * n_out);
            out_rem = orest;
            let (patch_chunk, prest) = patch_rem.split_at_mut(patch_per);
            patch_rem = prest;
            let r0 = row0;
            row0 += rows;
            if row0 < total {
                let work = &work;
                s.spawn(move || work(r0, out_chunk, patch_chunk));
            } else {
                // last chunk runs on the calling thread, which would
                // otherwise idle at the scope join -- one fewer spawn
                // per layer
                work(r0, out_chunk, patch_chunk);
            }
        }
    });
}

/// One worker's share of a conv layer: walk `ROW_BLOCK`-row blocks,
/// im2col each into the worker's patch scratch, and hand the block to
/// the fused-epilogue GEMM `g`.
#[allow(clippy::too_many_arguments)]
fn conv_worker<E, G: Fn(&[i32], usize, &mut [E])>(
    d: &Dense,
    src: &[i32],
    n: usize,
    h: usize,
    w: usize,
    row0: usize,
    out: &mut [E],
    patch: &mut [i32],
    g: &G,
) {
    let rows = out.len() / d.n_out;
    let mut r = 0usize;
    while r < rows {
        let block = ROW_BLOCK.min(rows - r);
        let pb = &mut patch[..block * d.k];
        packing::im2col_rows(src, n, h, w, d.cin, row0 + r, block, pb);
        g(pb, block, &mut out[r * d.n_out..(r + block) * d.n_out]);
        r += block;
    }
}

/// One conv layer over the whole batch: blocked im2col + GEMM with the
/// fused epilogue, sharded over row-blocks of the (n*h*w) patch matrix.
#[allow(clippy::too_many_arguments)]
fn conv_gemm(
    d: &Dense,
    kernels: &Kernels,
    src: &[i32],
    n: usize,
    h: usize,
    w: usize,
    threads: usize,
    patches: &mut [i32],
    out: ConvOut<'_>,
) {
    let total = n * h * w;
    let patch_per = ROW_BLOCK * d.k;
    match out {
        ConvOut::Codes { out, fmt } => {
            let g = |pb: &[i32], block: usize, ob: &mut [i32]| {
                kernels.gemm_requant_relu(
                    pb,
                    block,
                    d.k,
                    &d.packed,
                    &d.bias_acc,
                    d.acc_frac,
                    fmt,
                    d.relu,
                    ob,
                );
            };
            shard_rows(total, d.n_out, threads, patch_per, out, patches, |row0, o, p| {
                conv_worker(d, src, n, h, w, row0, o, p, &g);
            });
        }
        ConvOut::Floats(out) => {
            let g = |pb: &[i32], block: usize, ob: &mut [f32]| {
                kernels.gemm_decode(
                    pb,
                    block,
                    d.k,
                    &d.packed,
                    &d.bias_acc,
                    d.acc_frac,
                    ob,
                );
            };
            shard_rows(total, d.n_out, threads, patch_per, out, patches, |row0, o, p| {
                conv_worker(d, src, n, h, w, row0, o, p, &g);
            });
        }
    }
}

/// One fc layer over the whole batch: the activation matrix is already
/// the GEMM A operand (NHWC flatten == row-major), so workers slice it
/// directly -- no im2col, no patch scratch.
fn fc_gemm(
    d: &Dense,
    kernels: &Kernels,
    src: &[i32],
    n: usize,
    threads: usize,
    out: ConvOut<'_>,
) {
    let mut no_patches: [i32; 0] = [];
    match out {
        ConvOut::Codes { out, fmt } => {
            shard_rows(n, d.n_out, threads, 0, out, &mut no_patches[..], |row0, o, _| {
                let rows = o.len() / d.n_out;
                kernels.gemm_requant_relu(
                    &src[row0 * d.k..(row0 + rows) * d.k],
                    rows,
                    d.k,
                    &d.packed,
                    &d.bias_acc,
                    d.acc_frac,
                    fmt,
                    d.relu,
                    o,
                );
            });
        }
        ConvOut::Floats(out) => {
            shard_rows(n, d.n_out, threads, 0, out, &mut no_patches[..], |row0, o, _| {
                let rows = o.len() / d.n_out;
                kernels.gemm_decode(
                    &src[row0 * d.k..(row0 + rows) * d.k],
                    rows,
                    d.k,
                    &d.packed,
                    &d.bias_acc,
                    d.acc_frac,
                    o,
                );
            });
        }
    }
}
