//! Integer layer primitives (single image, NHWC codes).

use crate::fixedpoint::vector::{NoCount, SatCount, SatSink};
use crate::fixedpoint::{QFormat, RoundMode};

/// Requantize a wide accumulator value (frac = acc_frac) into `fmt`,
/// nearest-half-up, saturating.  Mirrors fixedpoint::value::WideAcc but
/// specialised to i64 for the conv/fc inner loops.
#[inline]
pub fn requant_i64(acc: i64, acc_frac: i32, fmt: QFormat) -> i32 {
    requant_i64_counted(acc, acc_frac, fmt).0
}

/// [`requant_i64`] plus a saturation flag: true iff the rounded code
/// overflowed `fmt`'s range and was clipped.  `requant_i64` delegates
/// here, so the code returned is definitionally identical with or
/// without the flag (pinned by tests/properties.rs against
/// `WideAcc::requantize_counted`).
#[inline]
pub fn requant_i64_counted(acc: i64, acc_frac: i32, fmt: QFormat) -> (i32, bool) {
    let shift = acc_frac - fmt.frac as i32;
    let code = if shift == 0 {
        acc
    } else if shift > 0 {
        (acc + (1i64 << (shift - 1))) >> shift
    } else {
        acc << (-shift)
    };
    let saturated = code < fmt.qmin() || code > fmt.qmax();
    (code.clamp(fmt.qmin(), fmt.qmax()) as i32, saturated)
}

/// Encode a float bias onto the accumulator grid.
#[inline]
pub fn bias_to_acc(b: f32, acc_frac: i32) -> i64 {
    ((b as f64) * (acc_frac as f64).exp2() + 0.5).floor() as i64
}

/// 3x3 SAME-padded stride-1 integer convolution.
///
/// `input`: (h, w, cin) codes; `weights`: (3, 3, cin, cout) codes;
/// `bias`: float, added on the accumulator grid.  Output: per-pixel wide
/// accumulators (h, w, cout) with fractional length
/// `in_fmt.frac + w_fmt.frac`.
#[allow(clippy::too_many_arguments)]
pub fn conv3x3_acc(
    input: &[i32],
    h: usize,
    w: usize,
    cin: usize,
    weights: &[i32],
    cout: usize,
    bias: &[f32],
    acc_frac: i32,
) -> Vec<i64> {
    debug_assert_eq!(input.len(), h * w * cin);
    debug_assert_eq!(weights.len(), 9 * cin * cout);
    debug_assert_eq!(bias.len(), cout);
    let bias_acc: Vec<i64> = bias.iter().map(|&b| bias_to_acc(b, acc_frac)).collect();
    let mut out = vec![0i64; h * w * cout];
    for y in 0..h {
        for x in 0..w {
            let o_base = (y * w + x) * cout;
            out[o_base..o_base + cout].copy_from_slice(&bias_acc);
            for ky in 0..3usize {
                let sy = y as i64 + ky as i64 - 1;
                if sy < 0 || sy >= h as i64 {
                    continue;
                }
                for kx in 0..3usize {
                    let sx = x as i64 + kx as i64 - 1;
                    if sx < 0 || sx >= w as i64 {
                        continue;
                    }
                    let i_base = (sy as usize * w + sx as usize) * cin;
                    let w_base = (ky * 3 + kx) * cin * cout;
                    for ci in 0..cin {
                        let iv = input[i_base + ci] as i64;
                        if iv == 0 {
                            continue;
                        }
                        let wrow = &weights[w_base + ci * cout..w_base + (ci + 1) * cout];
                        let orow = &mut out[o_base..o_base + cout];
                        for (o, &wv) in orow.iter_mut().zip(wrow) {
                            *o += iv * wv as i64;
                        }
                    }
                }
            }
        }
    }
    out
}

/// Fully-connected: input (n,) codes x weights (n, m) codes + bias.
pub fn fc_acc(
    input: &[i32],
    weights: &[i32],
    m: usize,
    bias: &[f32],
    acc_frac: i32,
) -> Vec<i64> {
    let n = input.len();
    debug_assert_eq!(weights.len(), n * m);
    debug_assert_eq!(bias.len(), m);
    let mut out: Vec<i64> = bias.iter().map(|&b| bias_to_acc(b, acc_frac)).collect();
    for (i, &iv) in input.iter().enumerate() {
        if iv == 0 {
            continue;
        }
        let iv = iv as i64;
        let wrow = &weights[i * m..(i + 1) * m];
        for (o, &wv) in out.iter_mut().zip(wrow) {
            *o += iv * wv as i64;
        }
    }
    out
}

/// Requantize + ReLU a whole accumulator plane into activation codes.
pub fn requant_relu(acc: &[i64], acc_frac: i32, fmt: QFormat, relu: bool) -> Vec<i32> {
    let mut out = vec![0i32; acc.len()];
    requant_relu_pass(acc, acc_frac, fmt, relu, &mut out, &mut NoCount);
    out
}

/// [`requant_relu`] plus the number of saturated (clipped) elements.
pub fn requant_relu_counted(
    acc: &[i64],
    acc_frac: i32,
    fmt: QFormat,
    relu: bool,
) -> (Vec<i32>, u64) {
    let mut out = vec![0i32; acc.len()];
    let mut sink = SatCount(0);
    requant_relu_pass(acc, acc_frac, fmt, relu, &mut out, &mut sink);
    (out, sink.0)
}

/// The one requantize-plane pass both entry points share: the saturation
/// sink is a generic parameter (`NoCount` for the plain path, `SatCount`
/// for telemetry), so the counted and uncounted variants are the same
/// code and definitionally bit-identical.
pub fn requant_relu_pass<S: SatSink>(
    acc: &[i64],
    acc_frac: i32,
    fmt: QFormat,
    relu: bool,
    out: &mut [i32],
    sink: &mut S,
) {
    debug_assert_eq!(acc.len(), out.len());
    let mut sat = 0u64;
    for (o, &a) in out.iter_mut().zip(acc) {
        let (c, clipped) = requant_i64_counted(a, acc_frac, fmt);
        sat += clipped as u64;
        *o = if relu { c.max(0) } else { c };
    }
    sink.clipped(sat);
}

/// 2x2 max-pool on codes (VALID, stride 2).
pub fn maxpool2(input: &[i32], h: usize, w: usize, c: usize) -> (Vec<i32>, usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    let mut out = vec![i32::MIN; oh * ow * c];
    for y in 0..oh {
        for x in 0..ow {
            for ch in 0..c {
                let mut m = i32::MIN;
                for dy in 0..2 {
                    for dx in 0..2 {
                        let v = input[((2 * y + dy) * w + 2 * x + dx) * c + ch];
                        m = m.max(v);
                    }
                }
                out[(y * ow + x) * c + ch] = m;
            }
        }
    }
    (out, oh, ow)
}

/// Encode a float slice into codes of `fmt` (nearest).
pub fn encode(xs: &[f32], fmt: QFormat) -> Vec<i32> {
    let mut out = vec![0i32; xs.len()];
    encode_into(xs, fmt, &mut out);
    out
}

/// Encode into a caller-provided buffer (the zero-allocation path of the
/// batched engine).  Bit-identical to [`encode`].
pub fn encode_into(xs: &[f32], fmt: QFormat, out: &mut [i32]) {
    debug_assert_eq!(xs.len(), out.len());
    let mode = RoundMode::NearestHalfUp;
    for (o, &x) in out.iter_mut().zip(xs) {
        *o = mode
            .round(x as f64 / fmt.step() as f64, None)
            .clamp(fmt.qmin(), fmt.qmax()) as i32;
    }
}

/// Decode codes to float.
pub fn decode(codes: &[i32], fmt: QFormat) -> Vec<f32> {
    codes.iter().map(|&c| c as f32 * fmt.step()).collect()
}

/// Decode codes into a caller-provided buffer.  Bit-identical to
/// [`decode`].
pub fn decode_into(codes: &[i32], fmt: QFormat, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * fmt.step();
    }
}

/// 2x2 max-pool (VALID, stride 2) over a whole NHWC batch into a
/// caller-provided buffer.  Per-image semantics identical to
/// [`maxpool2`].
pub fn maxpool2_batch_into(
    input: &[i32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    out: &mut [i32],
) -> (usize, usize) {
    let oh = h / 2;
    let ow = w / 2;
    debug_assert_eq!(input.len(), n * h * w * c);
    debug_assert_eq!(out.len(), n * oh * ow * c);
    for img in 0..n {
        let src = &input[img * h * w * c..(img + 1) * h * w * c];
        let dst = &mut out[img * oh * ow * c..(img + 1) * oh * ow * c];
        for y in 0..oh {
            for x in 0..ow {
                let o_base = (y * ow + x) * c;
                let i00 = ((2 * y) * w + 2 * x) * c;
                let i01 = i00 + c;
                let i10 = ((2 * y + 1) * w + 2 * x) * c;
                let i11 = i10 + c;
                for ch in 0..c {
                    let m = src[i00 + ch]
                        .max(src[i01 + ch])
                        .max(src[i10 + ch])
                        .max(src[i11 + ch]);
                    dst[o_base + ch] = m;
                }
            }
        }
    }
    (oh, ow)
}

/// Decode wide accumulators to float (for float-activation heads).
pub fn decode_acc(acc: &[i64], acc_frac: i32) -> Vec<f32> {
    let s = (-(acc_frac as f64)).exp2();
    acc.iter().map(|&a| (a as f64 * s) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(bits: u8, frac: i8) -> QFormat {
        QFormat::new(bits, frac).unwrap()
    }

    #[test]
    fn requant_matches_float_model() {
        // acc 2.5 at frac 8 -> Q8.0 rounds half-up to 3
        let acc = (2.5f64 * 256.0) as i64;
        assert_eq!(requant_i64(acc, 8, q(8, 0)), 3);
        // saturation
        assert_eq!(requant_i64(1 << 30, 8, q(8, 4)), 127);
        assert_eq!(requant_i64(-(1 << 30), 8, q(8, 4)), -128);
        // gaining precision is exact
        assert_eq!(requant_i64(5, 0, q(16, 4)), 80);
    }

    #[test]
    fn fc_simple() {
        // [1, 2] codes (fmt Q8.1 -> 0.5, 1.0) x identity-ish weights
        let input = vec![1i32, 2];
        // weights 2x2 = [[2, 0], [0, 2]] codes (Q8.1 -> 1.0)
        let weights = vec![2i32, 0, 0, 2];
        let bias = vec![0.25f32, 0.0];
        let acc = fc_acc(&input, &weights, 2, &bias, 2);
        // acc frac 2: products at frac 2: 1*2=2, 2*2=4; bias 0.25 -> 1
        assert_eq!(acc, vec![3, 4]);
    }

    #[test]
    fn conv_center_pixel() {
        // 3x3 single-channel input all ones (codes), center weight 1 others 0
        let input = vec![1i32; 9];
        let mut weights = vec![0i32; 9];
        weights[4] = 1; // (ky=1,kx=1,ci=0,co=0)
        let acc = conv3x3_acc(&input, 3, 3, 1, &weights, 1, &[0.0], 0);
        assert_eq!(acc, vec![1i64; 9]);
    }

    #[test]
    fn conv_same_padding_edges() {
        // sum-kernel over all-ones input counts valid taps: corner 4, edge 6, center 9
        let input = vec![1i32; 9];
        let weights = vec![1i32; 9];
        let acc = conv3x3_acc(&input, 3, 3, 1, &weights, 1, &[0.0], 0);
        assert_eq!(acc, vec![4, 6, 4, 6, 9, 6, 4, 6, 4]);
    }

    #[test]
    fn maxpool() {
        let input = vec![1i32, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16];
        let (out, oh, ow) = maxpool2(&input, 4, 4, 1);
        assert_eq!((oh, ow), (2, 2));
        assert_eq!(out, vec![6, 8, 14, 16]);
    }

    #[test]
    fn maxpool_batch_matches_per_image() {
        let (n, h, w, c) = (3usize, 4usize, 6usize, 2usize);
        let input: Vec<i32> = (0..n * h * w * c)
            .map(|i| ((i as i64 * 2_654_435_761) % 97 - 48) as i32)
            .collect();
        let mut got = vec![0i32; n * (h / 2) * (w / 2) * c];
        let (oh, ow) = maxpool2_batch_into(&input, n, h, w, c, &mut got);
        assert_eq!((oh, ow), (2, 3));
        for img in 0..n {
            let (want, _, _) =
                maxpool2(&input[img * h * w * c..(img + 1) * h * w * c], h, w, c);
            assert_eq!(
                &got[img * oh * ow * c..(img + 1) * oh * ow * c],
                &want[..],
                "img {img}"
            );
        }
    }

    #[test]
    fn encode_decode_into_match_allocating() {
        let fmt = q(8, 4);
        let xs = vec![0.5f32, -1.25, 7.9375, 100.0, -100.0, 0.03125];
        let codes = encode(&xs, fmt);
        let mut buf = vec![0i32; xs.len()];
        encode_into(&xs, fmt, &mut buf);
        assert_eq!(codes, buf);
        let floats = decode(&codes, fmt);
        let mut fbuf = vec![0f32; codes.len()];
        decode_into(&codes, fmt, &mut fbuf);
        assert_eq!(floats, fbuf);
    }

    #[test]
    fn encode_decode_round_trip() {
        let fmt = q(8, 4);
        let xs = vec![0.5f32, -1.25, 7.9375, 100.0];
        let codes = encode(&xs, fmt);
        assert_eq!(codes, vec![8, -20, 127, 127]);
        assert_eq!(decode(&codes, fmt)[0], 0.5);
    }

    #[test]
    fn relu_on_codes() {
        let out = requant_relu(&[-100, 50], 4, q(8, 2), true);
        assert_eq!(out[0], 0);
        assert!(out[1] > 0);
        let out = requant_relu(&[-100, 50], 4, q(8, 2), false);
        assert!(out[0] < 0);
    }

    #[test]
    fn counted_requant_plane_matches_plain_and_counts_clips() {
        let fmt = q(8, 2);
        let acc: Vec<i64> = (-40..40).map(|i| i * 173).collect();
        for relu in [false, true] {
            let plain = requant_relu(&acc, 4, fmt, relu);
            let (counted, sat) = requant_relu_counted(&acc, 4, fmt, relu);
            assert_eq!(plain, counted);
            let want_sat = acc
                .iter()
                .filter(|&&a| requant_i64_counted(a, 4, fmt).1)
                .count() as u64;
            assert_eq!(sat, want_sat);
            assert!(sat > 0, "fixture should exercise saturation");
        }
    }
}
