//! Runtime-dispatched SIMD kernel layer: one [`Kernels`] facade in
//! front of the scalar reference microkernels (`gemm.rs`), the AVX2
//! implementations ([`avx2`], x86-64) and the NEON implementations
//! ([`neon`], aarch64).
//!
//! ## Selection
//!
//! The ISA is picked **once** per process by [`Kernels::auto`] --
//! `std::arch` feature detection, overridable with
//! `FXP_KERNEL={scalar,avx2,neon}` -- and nets capture the facade at
//! build time ([`crate::inference::FixedPointNet::build_with_kernels`]),
//! so a net built against one ISA keeps using it for its whole life
//! (tests exploit this to compare scalar and SIMD nets in one process).
//! Requesting an ISA the host cannot run normalizes to scalar with a
//! warning; consequently an `&Kernels` whose ISA is `Avx2`/`Neon` is
//! only obtainable when detection passed, which is what makes the
//! `unsafe` `#[target_feature]` calls below sound.
//!
//! ## The bit-parity contract
//!
//! Every SIMD kernel computes *exactly* the scalar reference result:
//!
//! * integer GEMM: products widen into i64 accumulators; integer adds
//!   are exact and order-free, so any lane regrouping is bit-identical
//!   as long as no intermediate overflows (the narrow-panel kernels
//!   bound their i32 madd chunks by `PairPanels::chunk_pairs`);
//! * f32 GEMM: each output element accumulates in the same reduction
//!   order as the scalar kernel with separate (never fused)
//!   multiply/add, so per-element rounding is identical -- SIMD only
//!   vectorizes *across* the `NR` independent columns;
//! * quantize: the same f64 pipeline (`x*inv + 0.5 -> floor -> clamp ->
//!   *step`) per lane, including NaN propagation and the saturation
//!   tally.
//!
//! `engine_gemm_parity`, `rust/tests/kernel_parity.rs`, and the
//! CI `FXP_KERNEL=scalar`-vs-auto sweep comparison pin this contract.

use std::sync::OnceLock;

use crate::fixedpoint::QFormat;
use crate::inference::gemm;
use crate::inference::ops::requant_i64;
use crate::inference::packing::{IntPanels, NarrowCode, PackedPanels, PairPanels, NR};

#[cfg(target_arch = "x86_64")]
pub mod avx2;
#[cfg(target_arch = "aarch64")]
pub mod neon;

/// Instruction sets the kernel layer can dispatch to.  All variants
/// exist on every target (so `FXP_KERNEL` parsing and cross-ISA tests
/// are portable); unsupported ones normalize to `Scalar` at lookup.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    Scalar,
    Avx2,
    Neon,
}

impl Isa {
    pub fn name(&self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Avx2 => "avx2",
            Isa::Neon => "neon",
        }
    }

    pub fn parse(s: &str) -> Option<Isa> {
        match s {
            "scalar" => Some(Isa::Scalar),
            "avx2" => Some(Isa::Avx2),
            "neon" => Some(Isa::Neon),
            _ => None,
        }
    }
}

/// The kernel facade: every GEMM and elementwise quantize pass in the
/// inference and training engines goes through one of these methods,
/// making this the single seam future ISAs plug into.
#[derive(Debug)]
pub struct Kernels {
    isa: Isa,
}

static SCALAR: Kernels = Kernels { isa: Isa::Scalar };
#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels { isa: Isa::Avx2 };
#[cfg(target_arch = "aarch64")]
static NEON: Kernels = Kernels { isa: Isa::Neon };

static AUTO: OnceLock<&'static Kernels> = OnceLock::new();

impl Kernels {
    /// The process-wide kernel set: `FXP_KERNEL` override when set (an
    /// unknown value warns and falls back to detection), else the best
    /// ISA `detect` finds.  Read once; later env changes are ignored.
    pub fn auto() -> &'static Kernels {
        AUTO.get_or_init(|| {
            let forced = match std::env::var("FXP_KERNEL") {
                Ok(v) => {
                    let want = v.trim().to_ascii_lowercase();
                    let isa = Isa::parse(&want);
                    if isa.is_none() {
                        log::warn!(
                            "kernels: unknown FXP_KERNEL '{want}' \
                             (scalar|avx2|neon); auto-detecting"
                        );
                    }
                    isa
                }
                Err(_) => None,
            };
            let k = Kernels::for_isa(forced.unwrap_or_else(Kernels::detect));
            log::info!("kernels: using the {} path", k.name());
            k
        })
    }

    /// The facade for one ISA, normalized to what the host supports:
    /// asking for AVX2/NEON on a host without it warns and returns the
    /// scalar set.  This is the only constructor, so holding a SIMD
    /// `&Kernels` proves feature detection passed.
    pub fn for_isa(isa: Isa) -> &'static Kernels {
        match isa {
            Isa::Scalar => &SCALAR,
            Isa::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                {
                    if is_x86_feature_detected!("avx2") {
                        return &AVX2;
                    }
                }
                log::warn!("kernels: avx2 unavailable on this host; using scalar");
                &SCALAR
            }
            Isa::Neon => {
                #[cfg(target_arch = "aarch64")]
                {
                    if std::arch::is_aarch64_feature_detected!("neon") {
                        return &NEON;
                    }
                }
                log::warn!("kernels: neon unavailable on this host; using scalar");
                &SCALAR
            }
        }
    }

    /// Best ISA this host supports.
    pub fn detect() -> Isa {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Isa::Neon;
            }
        }
        Isa::Scalar
    }

    #[inline]
    pub fn isa(&self) -> Isa {
        self.isa
    }

    #[inline]
    pub fn name(&self) -> &'static str {
        self.isa.name()
    }

    /// Packing policy: narrow `(k, n)` i32 weight codes to i16/i8 pair
    /// panels when this ISA has a widening-madd kernel for them and the
    /// operand widths keep the arithmetic exact (`a_bits + w_bits <=
    /// 24` bounds every madd pair-sum by `2^23`, far inside i32); the
    /// scalar set always packs plain i32 panels.
    pub fn pack_int(
        &self,
        w: &[i32],
        k: usize,
        n: usize,
        a_bits: u8,
        w_bits: u8,
    ) -> IntPanels {
        let narrow = self.isa != Isa::Scalar
            && a_bits <= 16
            && w_bits <= 16
            && a_bits as u32 + w_bits as u32 <= 24;
        if narrow && w_bits <= 8 {
            IntPanels::I8(PairPanels::pack(w, k, n, a_bits, w_bits))
        } else if narrow {
            IntPanels::I16(PairPanels::pack(w, k, n, a_bits, w_bits))
        } else {
            IntPanels::I32(PackedPanels::pack(w, k, n))
        }
    }

    /// Integer GEMM with the fused bias + requantize (+ ReLU) epilogue
    /// into activation codes; `out` is row-major `(rows, pw.n())`.
    /// Bit-identical to `gemm::gemm_requant_relu` on i32 panels.
    #[allow(clippy::too_many_arguments)]
    pub fn gemm_requant_relu(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &IntPanels,
        bias_acc: &[i64],
        acc_frac: i32,
        fmt: QFormat,
        relu: bool,
        out: &mut [i32],
    ) {
        debug_assert_eq!(out.len(), rows * pw.n());
        if relu {
            self.gemm_int(a, rows, k, pw, bias_acc, |idx, acc| {
                out[idx] = requant_i64(acc, acc_frac, fmt).max(0);
            });
        } else {
            self.gemm_int(a, rows, k, pw, bias_acc, |idx, acc| {
                out[idx] = requant_i64(acc, acc_frac, fmt);
            });
        }
    }

    /// Integer GEMM with the float-head epilogue: bias + decode to f32
    /// logits.  Bit-identical to `gemm::gemm_decode` on i32 panels.
    pub fn gemm_decode(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &IntPanels,
        bias_acc: &[i64],
        acc_frac: i32,
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * pw.n());
        let s = (-(acc_frac as f64)).exp2();
        self.gemm_int(a, rows, k, pw, bias_acc, |idx, acc| {
            out[idx] = (acc as f64 * s) as f32;
        });
    }

    /// Integer GEMM core: dispatch on panel storage and ISA, handing
    /// every finished i64 accumulator (bias folded in) to `emit` exactly
    /// once as `emit(row * n + col, acc)`.
    pub fn gemm_int<E: FnMut(usize, i64)>(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &IntPanels,
        bias_acc: &[i64],
        emit: E,
    ) {
        match pw {
            IntPanels::I32(p) => self.gemm_i32(a, rows, k, p, bias_acc, emit),
            IntPanels::I16(p) => self.gemm_i16(a, rows, k, p, bias_acc, emit),
            IntPanels::I8(p) => self.gemm_i8(a, rows, k, p, bias_acc, emit),
        }
    }

    fn gemm_i32<E: FnMut(usize, i64)>(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &PackedPanels<i32>,
        bias_acc: &[i64],
        emit: E,
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if self.isa == Isa::Avx2 {
                // sound: a facade with isa == Avx2 only exists when
                // detection passed (see `for_isa`)
                unsafe { avx2::gemm_i32(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if self.isa == Isa::Neon {
                unsafe { neon::gemm_i32(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        gemm::gemm_panels(a, rows, k, pw, bias_acc, emit);
    }

    fn gemm_i16<E: FnMut(usize, i64)>(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &PairPanels<i16>,
        bias_acc: &[i64],
        emit: E,
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if self.isa == Isa::Avx2 {
                unsafe { avx2::gemm_pair_i16(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if self.isa == Isa::Neon {
                unsafe { neon::gemm_pair_i16(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        gemm_pair_scalar(a, rows, k, pw, bias_acc, emit);
    }

    fn gemm_i8<E: FnMut(usize, i64)>(
        &self,
        a: &[i32],
        rows: usize,
        k: usize,
        pw: &PairPanels<i8>,
        bias_acc: &[i64],
        emit: E,
    ) {
        #[cfg(target_arch = "x86_64")]
        {
            if self.isa == Isa::Avx2 {
                unsafe { avx2::gemm_pair_i8(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if self.isa == Isa::Neon {
                unsafe { neon::gemm_pair_i8(a, rows, k, pw, bias_acc, emit) };
                return;
            }
        }
        gemm_pair_scalar(a, rows, k, pw, bias_acc, emit);
    }

    /// f32 GEMM with the bias folded into the accumulator start (the
    /// native trainer's forward / input-gradient matmuls); `out` is
    /// row-major `(rows, pw.n)`.  Bit-identical to
    /// `gemm::gemm_bias_f32` -- per-element reduction order is the
    /// scalar order on every ISA.
    pub fn gemm_bias_f32(
        &self,
        a: &[f32],
        rows: usize,
        k: usize,
        pw: &PackedPanels<f32>,
        bias: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), rows * pw.n);
        #[cfg(target_arch = "x86_64")]
        {
            if self.isa == Isa::Avx2 {
                unsafe { avx2::gemm_f32(a, rows, k, pw, bias, out) };
                return;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if self.isa == Isa::Neon {
                unsafe { neon::gemm_f32(a, rows, k, pw, bias, out) };
                return;
            }
        }
        gemm::gemm_bias_f32(a, rows, k, pw, bias, out);
    }

    /// Nearest-half-up quantize pass, in place; returns the saturation
    /// (clip) tally.  Bit-identical to the scalar pipeline in
    /// `fixedpoint::vector` including NaN propagation.  Only this
    /// rounding mode vectorizes -- Floor and Stochastic stay scalar so
    /// the dither RNG stream is untouched.
    pub fn quantize_nearest(&self, xs: &mut [f32], fmt: QFormat) -> u64 {
        #[cfg(target_arch = "x86_64")]
        {
            if self.isa == Isa::Avx2 {
                return unsafe { avx2::quantize_nearest(xs, fmt) };
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if self.isa == Isa::Neon {
                return unsafe { neon::quantize_nearest(xs, fmt) };
            }
        }
        quantize_nearest_scalar(xs, fmt)
    }
}

/// Scalar nearest-half-up quantize: the reference the SIMD lanes must
/// reproduce bit-for-bit, and the tail loop they all share.  Exactly the
/// `RoundMode::NearestHalfUp` arm of
/// `fixedpoint::vector::quantize_slice_counted`.
pub fn quantize_nearest_scalar(xs: &mut [f32], fmt: QFormat) -> u64 {
    let step = fmt.step();
    let inv = 1.0 / step as f64;
    let (lo, hi) = (fmt.qmin() as f64, fmt.qmax() as f64);
    let mut sat = 0u64;
    for x in xs.iter_mut() {
        let raw = ((*x as f64) * inv + 0.5).floor();
        sat += (raw < lo || raw > hi) as u64;
        let code = raw.clamp(lo, hi);
        *x = (code * step as f64) as f32;
    }
    sat
}

/// Scalar reference walk of a narrow pair panel: the same i64 sums as
/// the i32 kernel on the unpacked matrix (exact integer adds, zero pad
/// slots contribute nothing).  Used as the fallback when a narrow panel
/// is driven on a host whose SIMD went away (tests constructing panels
/// explicitly) and as the parity oracle for the SIMD pair kernels.
pub fn gemm_pair_scalar<T: NarrowCode, E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PairPanels<T>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        for i in 0..rows {
            let arow = &a[i * k..(i + 1) * k];
            for j in 0..jw {
                let mut acc = bias_acc[j0 + j];
                for p2 in 0..pw.k2 {
                    let b0 = panel[p2 * 2 * NR + 2 * j].widen();
                    let b1 = panel[p2 * 2 * NR + 2 * j + 1].widen();
                    let a0 = arow[2 * p2] as i64;
                    let a1 =
                        if 2 * p2 + 1 < k { arow[2 * p2 + 1] as i64 } else { 0 };
                    acc += a0 * b0 + a1 * b1;
                }
                emit(i * n + j0 + j, acc);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn q(bits: u8, frac: i8) -> QFormat {
        QFormat::new(bits, frac).unwrap()
    }

    fn random_case(
        seed: u64,
        rows: usize,
        k: usize,
        n: usize,
        a_bits: u8,
        w_bits: u8,
    ) -> (Vec<i32>, Vec<i32>, Vec<i64>) {
        let mut rng = Rng::new(seed);
        let (amax, wmax) = (1i64 << (a_bits - 1), 1i64 << (w_bits - 1));
        let a: Vec<i32> = (0..rows * k)
            .map(|_| (rng.below((2 * amax - 1) as usize) as i64 - (amax - 1)) as i32)
            .collect();
        let w: Vec<i32> = (0..k * n)
            .map(|_| (rng.below((2 * wmax - 1) as usize) as i64 - (wmax - 1)) as i32)
            .collect();
        let bias: Vec<i64> = (0..n).map(|_| rng.below(2001) as i64 - 1000).collect();
        (a, w, bias)
    }

    fn naive(
        a: &[i32],
        rows: usize,
        k: usize,
        w: &[i32],
        n: usize,
        bias: &[i64],
    ) -> Vec<i64> {
        let mut out = vec![0i64; rows * n];
        for r in 0..rows {
            for j in 0..n {
                let mut acc = bias[j];
                for p in 0..k {
                    acc += a[r * k + p] as i64 * w[p * n + j] as i64;
                }
                out[r * n + j] = acc;
            }
        }
        out
    }

    #[test]
    fn scalar_facade_packs_i32_and_matches_gemm_panels() {
        let (rows, k, n) = (7usize, 27usize, 10usize);
        let (a, w, bias) = random_case(3, rows, k, n, 8, 8);
        let ks = Kernels::for_isa(Isa::Scalar);
        let pw = ks.pack_int(&w, k, n, 8, 8);
        assert_eq!(pw.kind(), "i32");
        let mut got = vec![0i64; rows * n];
        ks.gemm_int(&a, rows, k, &pw, &bias, |idx, acc| got[idx] = acc);
        assert_eq!(got, naive(&a, rows, k, &w, n, &bias));
    }

    #[test]
    fn pair_scalar_matches_naive_for_both_widths() {
        for (seed, rows, k, n) in
            [(1u64, 1usize, 3usize, 1usize), (2, 4, 9, 8), (3, 7, 27, 10), (4, 13, 16, 17)]
        {
            let (a, w, bias) = random_case(seed, rows, k, n, 8, 8);
            let want = naive(&a, rows, k, &w, n, &bias);
            let p16: PairPanels<i16> = PairPanels::pack(&w, k, n, 8, 8);
            let mut got = vec![0i64; rows * n];
            gemm_pair_scalar(&a, rows, k, &p16, &bias, |idx, acc| got[idx] = acc);
            assert_eq!(got, want, "i16 rows={rows} k={k} n={n}");
            let p8: PairPanels<i8> = PairPanels::pack(&w, k, n, 8, 8);
            let mut got = vec![0i64; rows * n];
            gemm_pair_scalar(&a, rows, k, &p8, &bias, |idx, acc| got[idx] = acc);
            assert_eq!(got, want, "i8 rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn pack_policy_narrows_only_when_exact_and_simd() {
        let w = vec![0i32; 6];
        let ks = Kernels::for_isa(Isa::Scalar);
        assert_eq!(ks.pack_int(&w, 2, 3, 8, 8).kind(), "i32");
        let kd = Kernels::for_isa(Kernels::detect());
        let expect_narrow = kd.isa() != Isa::Scalar;
        // Q8 weights -> i8 panels under SIMD
        let kind = kd.pack_int(&w, 2, 3, 8, 8).kind();
        assert_eq!(kind, if expect_narrow { "i8" } else { "i32" });
        // 16-bit activations x Q8 weights stay eligible (sum = 24)
        let kind = kd.pack_int(&w, 2, 3, 16, 8).kind();
        assert_eq!(kind, if expect_narrow { "i8" } else { "i32" });
        // wider weights -> i16 panels
        let kind = kd.pack_int(&w, 2, 3, 8, 12).kind();
        assert_eq!(kind, if expect_narrow { "i16" } else { "i32" });
        // too wide for exact madd pair-sums -> plain i32 everywhere
        assert_eq!(kd.pack_int(&w, 2, 3, 16, 12).kind(), "i32");
        assert_eq!(kd.pack_int(&w, 2, 3, 32, 8).kind(), "i32");
    }

    #[test]
    fn detected_isa_matches_scalar_bit_for_bit() {
        let kd = Kernels::for_isa(Kernels::detect());
        let ks = Kernels::for_isa(Isa::Scalar);
        for (seed, rows, k, n, a_bits, w_bits) in [
            (1u64, 1usize, 1usize, 1usize, 8u8, 8u8),
            (2, 5, 9, 9, 8, 8),
            (3, 13, 27, 17, 16, 8),
            (4, 9, 10, 24, 8, 12),
            (5, 32, 33, 7, 12, 12),
        ] {
            let (a, w, bias) = random_case(seed, rows, k, n, a_bits, w_bits);
            let pw_s = ks.pack_int(&w, k, n, a_bits, w_bits);
            let pw_d = kd.pack_int(&w, k, n, a_bits, w_bits);
            let mut want = vec![0i64; rows * n];
            ks.gemm_int(&a, rows, k, &pw_s, &bias, |idx, acc| want[idx] = acc);
            let mut got = vec![0i64; rows * n];
            kd.gemm_int(&a, rows, k, &pw_d, &bias, |idx, acc| got[idx] = acc);
            assert_eq!(
                got,
                want,
                "{} vs scalar, rows={rows} k={k} n={n} ({}b x {}b, {})",
                kd.name(),
                a_bits,
                w_bits,
                pw_d.kind()
            );
        }
    }

    #[test]
    fn detected_isa_f32_gemm_matches_scalar_bit_for_bit() {
        let kd = Kernels::for_isa(Kernels::detect());
        for (seed, rows, k, n) in
            [(11u64, 1usize, 3usize, 1usize), (12, 5, 9, 9), (13, 13, 27, 17)]
        {
            let mut rng = Rng::new(seed);
            let a: Vec<f32> = (0..rows * k).map(|_| rng.normal() as f32).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
            let pw = PackedPanels::pack(&w, k, n);
            let mut want = vec![0f32; rows * n];
            gemm::gemm_bias_f32(&a, rows, k, &pw, &bias, &mut want);
            let mut got = vec![0f32; rows * n];
            kd.gemm_bias_f32(&a, rows, k, &pw, &bias, &mut got);
            let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
            let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
            assert_eq!(got_bits, want_bits, "{} rows={rows} k={k} n={n}", kd.name());
        }
    }

    #[test]
    fn detected_isa_quantize_matches_scalar_bit_for_bit() {
        let kd = Kernels::for_isa(Kernels::detect());
        let mut rng = Rng::new(77);
        for fmt in [q(8, 4), q(4, 1), q(16, 10), q(8, -1)] {
            let mut xs: Vec<f32> =
                (0..1003).map(|_| rng.uniform_in(-40.0, 40.0)).collect();
            // poison with the edge cases the clamp must handle
            xs[0] = f32::NAN;
            xs[1] = f32::INFINITY;
            xs[2] = f32::NEG_INFINITY;
            xs[3] = 0.0;
            xs[4] = -0.0;
            let mut want = xs.clone();
            let sat_want = quantize_nearest_scalar(&mut want, fmt);
            let mut got = xs.clone();
            let sat_got = kd.quantize_nearest(&mut got, fmt);
            assert_eq!(sat_got, sat_want, "{} sat count {fmt}", kd.name());
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                let same =
                    g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan());
                assert!(same, "{} {fmt} elem {i}: {g:?} vs {w:?}", kd.name());
            }
        }
    }

    #[test]
    fn auto_is_a_supported_isa_and_stable() {
        let k1 = Kernels::auto();
        let k2 = Kernels::auto();
        assert!(std::ptr::eq(k1, k2), "auto must pick once");
        // whatever was picked is runnable: a tiny GEMM must not fault
        let pw = k1.pack_int(&[1, 2, 3, 4], 2, 2, 8, 8);
        let mut out = vec![0i64; 2];
        k1.gemm_int(&[1, 1], 1, 2, &pw, &[0, 0], |idx, acc| out[idx] = acc);
        assert_eq!(out, vec![4, 6]);
    }
}
