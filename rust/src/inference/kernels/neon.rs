//! NEON kernels (aarch64).  Every function here is `unsafe` +
//! `#[target_feature(enable = "neon")]`; the only callers are the
//! [`super::Kernels`] facade methods, which hold a NEON facade only
//! when runtime detection passed (see `Kernels::for_isa`).
//!
//! Bit-parity notes (the contract `kernel_parity` pins):
//!
//! * `gemm_i32` uses `vmlal_s32` -- a widening 32x32->64
//!   multiply-accumulate, exactly the scalar `acc + a as i64 * b as
//!   i64` -- so i64 lanes regroup the exact scalar sums.
//! * The pair kernels widen 16x16 products with `vmull_s16` (exact in
//!   i32) and fold each product pair straight into i64 lanes with
//!   `vpadalq_s32` (pairwise add-accumulate long).  Unlike the AVX2
//!   madd path there is no running i32 chunk, so no flush budget is
//!   needed -- every add is exact by construction.
//! * `gemm_f32` keeps the scalar per-element reduction order with
//!   separate `vmulq_f32`/`vaddq_f32` (never `vmlaq`/`vfmaq`, which
//!   fuse on aarch64 and would change rounding).
//! * `quantize_nearest` runs the scalar f64 pipeline two lanes wide per
//!   half; `vmaxq_f64`/`vminq_f64` (FMAX/FMIN) propagate NaN like
//!   `f64::clamp`, and `vrndmq_f64` is floor.

use core::arch::aarch64::*;

use crate::fixedpoint::QFormat;
use crate::inference::gemm::MR;
use crate::inference::packing::{PackedPanels, PairPanels, NR};

use super::quantize_nearest_scalar;

/// i32-panel GEMM: the scalar `gemm_panels::<i32>` walk, eight i64
/// accumulator lanes (four `int64x2_t`) at a time.
#[target_feature(enable = "neon")]
pub unsafe fn gemm_i32<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PackedPanels<i32>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            tile_i32::<MR, E>(a, k, i, n, j0, jw, panel, &init, &mut emit);
            i += MR;
        }
        while i < rows {
            tile_i32::<1, E>(a, k, i, n, j0, jw, panel, &init, &mut emit);
            i += 1;
        }
    }
}

#[inline]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i32<const M: usize, E: FnMut(usize, i64)>(
    a: &[i32],
    k: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: &[i32],
    init: &[i64; NR],
    emit: &mut E,
) {
    // four int64x2_t per row: columns (0,1) (2,3) (4,5) (6,7)
    let mut acc = [[
        vld1q_s64(init.as_ptr()),
        vld1q_s64(init.as_ptr().add(2)),
        vld1q_s64(init.as_ptr().add(4)),
        vld1q_s64(init.as_ptr().add(6)),
    ]; M];
    for p in 0..k {
        let bp = panel.as_ptr().add(p * NR);
        let b0 = vld1q_s32(bp); // cols 0..4
        let b1 = vld1q_s32(bp.add(4)); // cols 4..8
        let (b0l, b0h) = (vget_low_s32(b0), vget_high_s32(b0));
        let (b1l, b1h) = (vget_low_s32(b1), vget_high_s32(b1));
        for ii in 0..M {
            let av = vdup_n_s32(*a.get_unchecked((base + ii) * k + p));
            acc[ii][0] = vmlal_s32(acc[ii][0], b0l, av);
            acc[ii][1] = vmlal_s32(acc[ii][1], b0h, av);
            acc[ii][2] = vmlal_s32(acc[ii][2], b1l, av);
            acc[ii][3] = vmlal_s32(acc[ii][3], b1h, av);
        }
    }
    let mut vals = [0i64; NR];
    for ii in 0..M {
        for (q, &v) in acc[ii].iter().enumerate() {
            vst1q_s64(vals.as_mut_ptr().add(2 * q), v);
        }
        let o = (base + ii) * n + j0;
        for (j, &v) in vals[..jw].iter().enumerate() {
            emit(o + j, v);
        }
    }
}

/// i16 pair-panel GEMM.
#[target_feature(enable = "neon")]
pub unsafe fn gemm_pair_i16<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PairPanels<i16>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            pair_tile::<MR, false, E>(
                a, k, pw.k2, i, n, j0, jw, panel.as_ptr() as *const u8, &init,
                &mut emit,
            );
            i += MR;
        }
        while i < rows {
            pair_tile::<1, false, E>(
                a, k, pw.k2, i, n, j0, jw, panel.as_ptr() as *const u8, &init,
                &mut emit,
            );
            i += 1;
        }
    }
}

/// i8 pair-panel GEMM: the i16 path after an order-preserving
/// `vmovl_s8` widen of each panel row.
#[target_feature(enable = "neon")]
pub unsafe fn gemm_pair_i8<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PairPanels<i8>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            pair_tile::<MR, true, E>(
                a, k, pw.k2, i, n, j0, jw, panel.as_ptr() as *const u8, &init,
                &mut emit,
            );
            i += MR;
        }
        while i < rows {
            pair_tile::<1, true, E>(
                a, k, pw.k2, i, n, j0, jw, panel.as_ptr() as *const u8, &init,
                &mut emit,
            );
            i += 1;
        }
    }
}

/// Shared pair tile.  A pair-row holds 16 narrow values
/// `[e0,o0,e1,o1,...]` (columns x {even,odd} reduction row); the
/// activation pair broadcasts as `[a0,a1,a0,a1]` so `vmull_s16` forms
/// per-column partial products and `vpadalq_s32` folds each (even, odd)
/// product pair into its column's i64 lane.  `BYTE` selects i8 panels
/// (widened on load) vs i16.
#[inline]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn pair_tile<const M: usize, const BYTE: bool, E: FnMut(usize, i64)>(
    a: &[i32],
    k: usize,
    k2: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: *const u8,
    init: &[i64; NR],
    emit: &mut E,
) {
    // four int64x2_t per row: columns (0,1) (2,3) (4,5) (6,7)
    let mut acc = [[vdupq_n_s64(0); 4]; M];
    for p2 in 0..k2 {
        let (b_lo, b_hi) = if BYTE {
            let raw = vld1q_s8(panel.add(p2 * 2 * NR) as *const i8);
            (vmovl_s8(vget_low_s8(raw)), vmovl_s8(vget_high_s8(raw)))
        } else {
            let bp = panel.add(p2 * 2 * NR * 2) as *const i16;
            (vld1q_s16(bp), vld1q_s16(bp.add(8)))
        };
        let quarters = [
            vget_low_s16(b_lo),
            vget_high_s16(b_lo),
            vget_low_s16(b_hi),
            vget_high_s16(b_hi),
        ];
        for ii in 0..M {
            let row = (base + ii) * k;
            let a0 = *a.get_unchecked(row + 2 * p2);
            let a1 = if 2 * p2 + 1 < k {
                *a.get_unchecked(row + 2 * p2 + 1)
            } else {
                0
            };
            let apair = (a0 as u16 as u32) | ((a1 as u16 as u32) << 16);
            let av = vreinterpret_s16_u32(vdup_n_u32(apair)); // [a0,a1,a0,a1]
            for (q, &bq) in quarters.iter().enumerate() {
                acc[ii][q] = vpadalq_s32(acc[ii][q], vmull_s16(bq, av));
            }
        }
    }
    let mut vals = [0i64; NR];
    for ii in 0..M {
        for (q, &v) in acc[ii].iter().enumerate() {
            vst1q_s64(vals.as_mut_ptr().add(2 * q), v);
        }
        let o = (base + ii) * n + j0;
        for (j, &v) in vals[..jw].iter().enumerate() {
            emit(o + j, init[j] + v);
        }
    }
}

/// f32-panel GEMM: one column per lane, scalar reduction order per
/// element, explicit mul-then-add (no fused multiply-add).
#[target_feature(enable = "neon")]
pub unsafe fn gemm_f32(
    a: &[f32],
    rows: usize,
    k: usize,
    pw: &PackedPanels<f32>,
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias.len(), pw.n);
    debug_assert_eq!(out.len(), rows * pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0f32; NR];
        init[..jw].copy_from_slice(&bias[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            tile_f32::<MR>(a, k, i, n, j0, jw, panel, &init, out);
            i += MR;
        }
        while i < rows {
            tile_f32::<1>(a, k, i, n, j0, jw, panel, &init, out);
            i += 1;
        }
    }
}

#[inline]
#[target_feature(enable = "neon")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_f32<const M: usize>(
    a: &[f32],
    k: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: &[f32],
    init: &[f32; NR],
    out: &mut [f32],
) {
    let init_lo = vld1q_f32(init.as_ptr());
    let init_hi = vld1q_f32(init.as_ptr().add(4));
    let mut acc = [[init_lo, init_hi]; M];
    for p in 0..k {
        let bp = panel.as_ptr().add(p * NR);
        let b0 = vld1q_f32(bp);
        let b1 = vld1q_f32(bp.add(4));
        for ii in 0..M {
            let av = vdupq_n_f32(*a.get_unchecked((base + ii) * k + p));
            acc[ii][0] = vaddq_f32(acc[ii][0], vmulq_f32(av, b0));
            acc[ii][1] = vaddq_f32(acc[ii][1], vmulq_f32(av, b1));
        }
    }
    let mut vals = [0f32; NR];
    for ii in 0..M {
        vst1q_f32(vals.as_mut_ptr(), acc[ii][0]);
        vst1q_f32(vals.as_mut_ptr().add(4), acc[ii][1]);
        let o = (base + ii) * n + j0;
        out[o..o + jw].copy_from_slice(&vals[..jw]);
    }
}

/// One f64x2 half of the quantize pipeline: `floor(x*inv + 0.5)`, tally
/// out-of-range lanes into `sat`, clamp (FMAX/FMIN propagate NaN, like
/// `f64::clamp`), `* step`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn quant_half(
    xd: float64x2_t,
    invv: float64x2_t,
    half: float64x2_t,
    lov: float64x2_t,
    hiv: float64x2_t,
    stepv: float64x2_t,
    sat: &mut u64,
) -> float64x2_t {
    let raw = vrndmq_f64(vaddq_f64(vmulq_f64(xd, invv), half));
    let under = vcltq_f64(raw, lov);
    let over = vcgtq_f64(raw, hiv);
    let m = vorrq_u64(under, over);
    *sat += (vgetq_lane_u64::<0>(m) & 1) + (vgetq_lane_u64::<1>(m) & 1);
    let code = vminq_f64(hiv, vmaxq_f64(lov, raw));
    vmulq_f64(code, stepv)
}

/// Nearest-half-up quantize, four f32 at a time through two f64x2
/// halves, with the scalar loop finishing the tail.
#[target_feature(enable = "neon")]
pub unsafe fn quantize_nearest(xs: &mut [f32], fmt: QFormat) -> u64 {
    let step = fmt.step();
    let inv = 1.0 / step as f64;
    let (lo, hi) = (fmt.qmin() as f64, fmt.qmax() as f64);
    let invv = vdupq_n_f64(inv);
    let half = vdupq_n_f64(0.5);
    let lov = vdupq_n_f64(lo);
    let hiv = vdupq_n_f64(hi);
    let stepv = vdupq_n_f64(step as f64);
    let mut sat = 0u64;
    let nfull = xs.len() & !3;
    let mut i = 0usize;
    while i < nfull {
        let x4 = vld1q_f32(xs.as_ptr().add(i));
        let y_lo = quant_half(
            vcvt_f64_f32(vget_low_f32(x4)), invv, half, lov, hiv, stepv, &mut sat,
        );
        let y_hi = quant_half(
            vcvt_f64_f32(vget_high_f32(x4)), invv, half, lov, hiv, stepv, &mut sat,
        );
        let y = vcombine_f32(vcvt_f32_f64(y_lo), vcvt_f32_f64(y_hi));
        vst1q_f32(xs.as_mut_ptr().add(i), y);
        i += 4;
    }
    sat + quantize_nearest_scalar(&mut xs[nfull..], fmt)
}
