//! AVX2 kernels (x86-64).  Every function here is `unsafe` +
//! `#[target_feature(enable = "avx2")]`; the only callers are the
//! [`super::Kernels`] facade methods, which hold an AVX2 facade only
//! when runtime detection passed (see `Kernels::for_isa`).
//!
//! Bit-parity notes (the contract `kernel_parity` pins):
//!
//! * `gemm_i32` multiplies with `_mm256_mul_epi32` -- a sign-extended
//!   32x32->64 multiply, exactly the scalar `a as i64 * b as i64` -- and
//!   adds lanes with exact i64 adds, so any regrouping is bit-identical.
//! * The pair kernels run `_mm256_madd_epi16` (two 16x16 products
//!   summed into an i32 lane).  A single madd is exact because packing
//!   eligibility bounds `|a| < 2^(a_bits-1)`, `|w| < 2^(w_bits-1)` with
//!   `a_bits + w_bits <= 24`: each pair-sum is under `2^23`.  The i32
//!   chunk accumulator is flushed into i64 lanes every
//!   `PairPanels::chunk_pairs` pairs, the bound that keeps the running
//!   i32 sums exact too.
//! * `gemm_f32` keeps the scalar per-element reduction order (one
//!   column per lane, separate `_mm256_mul_ps`/`_mm256_add_ps`, never
//!   FMA) so each output's rounding history is the scalar one.
//! * `quantize_nearest` runs the scalar f64 pipeline four lanes wide;
//!   `max(lo, x)`/`min(hi, t)` with the bound as *first* operand
//!   propagate a NaN `x` exactly like `f64::clamp`.

use core::arch::x86_64::*;

use crate::fixedpoint::QFormat;
use crate::inference::gemm::MR;
use crate::inference::packing::{PackedPanels, PairPanels, NR};

use super::quantize_nearest_scalar;

/// i32-panel GEMM: the scalar `gemm_panels::<i32>` walk, eight i64
/// accumulator lanes at a time.
#[target_feature(enable = "avx2")]
pub unsafe fn gemm_i32<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PackedPanels<i32>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let init_lo = _mm256_loadu_si256(init.as_ptr() as *const __m256i);
        let init_hi = _mm256_loadu_si256(init.as_ptr().add(4) as *const __m256i);
        let mut i = 0usize;
        while i + MR <= rows {
            tile_i32::<MR, E>(a, k, i, n, j0, jw, panel, init_lo, init_hi, &mut emit);
            i += MR;
        }
        while i < rows {
            tile_i32::<1, E>(a, k, i, n, j0, jw, panel, init_lo, init_hi, &mut emit);
            i += 1;
        }
    }
}

#[inline]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i32<const M: usize, E: FnMut(usize, i64)>(
    a: &[i32],
    k: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: &[i32],
    init_lo: __m256i,
    init_hi: __m256i,
    emit: &mut E,
) {
    let mut acc_lo = [init_lo; M];
    let mut acc_hi = [init_hi; M];
    for p in 0..k {
        let bp = panel.as_ptr().add(p * NR);
        let b_lo = _mm256_cvtepi32_epi64(_mm_loadu_si128(bp as *const __m128i));
        let b_hi = _mm256_cvtepi32_epi64(_mm_loadu_si128(bp.add(4) as *const __m128i));
        for ii in 0..M {
            let av = _mm256_set1_epi64x(*a.get_unchecked((base + ii) * k + p) as i64);
            acc_lo[ii] = _mm256_add_epi64(acc_lo[ii], _mm256_mul_epi32(av, b_lo));
            acc_hi[ii] = _mm256_add_epi64(acc_hi[ii], _mm256_mul_epi32(av, b_hi));
        }
    }
    let mut vals = [0i64; NR];
    for ii in 0..M {
        _mm256_storeu_si256(vals.as_mut_ptr() as *mut __m256i, acc_lo[ii]);
        _mm256_storeu_si256(vals.as_mut_ptr().add(4) as *mut __m256i, acc_hi[ii]);
        let o = (base + ii) * n + j0;
        for (j, &v) in vals[..jw].iter().enumerate() {
            emit(o + j, v);
        }
    }
}

/// i16 pair-panel GEMM: one `_mm256_madd_epi16` per packed pair-row per
/// tile row, i32 chunks flushed into i64 lanes under the exactness
/// budget.
#[target_feature(enable = "avx2")]
pub unsafe fn gemm_pair_i16<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PairPanels<i16>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            pair_tile::<MR, false, E>(
                a, k, pw.k2, pw.chunk_pairs, i, n, j0, jw, panel.as_ptr() as *const u8,
                &init, &mut emit,
            );
            i += MR;
        }
        while i < rows {
            pair_tile::<1, false, E>(
                a, k, pw.k2, pw.chunk_pairs, i, n, j0, jw, panel.as_ptr() as *const u8,
                &init, &mut emit,
            );
            i += 1;
        }
    }
}

/// i8 pair-panel GEMM: identical to the i16 path after an
/// order-preserving `_mm256_cvtepi8_epi16` widen of each panel row.
#[target_feature(enable = "avx2")]
pub unsafe fn gemm_pair_i8<E: FnMut(usize, i64)>(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PairPanels<i8>,
    bias_acc: &[i64],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0i64; NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            pair_tile::<MR, true, E>(
                a, k, pw.k2, pw.chunk_pairs, i, n, j0, jw, panel.as_ptr() as *const u8,
                &init, &mut emit,
            );
            i += MR;
        }
        while i < rows {
            pair_tile::<1, true, E>(
                a, k, pw.k2, pw.chunk_pairs, i, n, j0, jw, panel.as_ptr() as *const u8,
                &init, &mut emit,
            );
            i += 1;
        }
    }
}

/// Shared pair-madd tile.  `BYTE` selects the panel element width: a
/// pair-row is 16 i16 (32 bytes) or 16 i8 (16 bytes, widened on load).
/// The panel pointer is byte-typed so both layouts share one body.
#[inline]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn pair_tile<const M: usize, const BYTE: bool, E: FnMut(usize, i64)>(
    a: &[i32],
    k: usize,
    k2: usize,
    chunk_pairs: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: *const u8,
    init: &[i64; NR],
    emit: &mut E,
) {
    let zero = _mm256_setzero_si256();
    let mut acc_lo = [zero; M];
    let mut acc_hi = [zero; M];
    let mut chunks = [zero; M];
    let mut pairs = 0usize;
    for p2 in 0..k2 {
        let b = if BYTE {
            _mm256_cvtepi8_epi16(_mm_loadu_si128(
                panel.add(p2 * 2 * NR) as *const __m128i
            ))
        } else {
            _mm256_loadu_si256(panel.add(p2 * 2 * NR * 2) as *const __m256i)
        };
        for ii in 0..M {
            let row = (base + ii) * k;
            let a0 = *a.get_unchecked(row + 2 * p2);
            let a1 = if 2 * p2 + 1 < k {
                *a.get_unchecked(row + 2 * p2 + 1)
            } else {
                0
            };
            let apair = ((a0 as u16 as u32) | ((a1 as u16 as u32) << 16)) as i32;
            let av = _mm256_set1_epi32(apair);
            chunks[ii] = _mm256_add_epi32(chunks[ii], _mm256_madd_epi16(av, b));
        }
        pairs += 1;
        if pairs == chunk_pairs || p2 == k2 - 1 {
            for ii in 0..M {
                let c = chunks[ii];
                let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(c));
                let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(c));
                acc_lo[ii] = _mm256_add_epi64(acc_lo[ii], lo);
                acc_hi[ii] = _mm256_add_epi64(acc_hi[ii], hi);
                chunks[ii] = zero;
            }
            pairs = 0;
        }
    }
    let mut vals = [0i64; NR];
    for ii in 0..M {
        _mm256_storeu_si256(vals.as_mut_ptr() as *mut __m256i, acc_lo[ii]);
        _mm256_storeu_si256(vals.as_mut_ptr().add(4) as *mut __m256i, acc_hi[ii]);
        let o = (base + ii) * n + j0;
        for (j, &v) in vals[..jw].iter().enumerate() {
            emit(o + j, init[j] + v);
        }
    }
}

/// f32-panel GEMM: one column per lane, scalar reduction order per
/// element, explicit mul-then-add (no FMA contraction).
#[target_feature(enable = "avx2")]
pub unsafe fn gemm_f32(
    a: &[f32],
    rows: usize,
    k: usize,
    pw: &PackedPanels<f32>,
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias.len(), pw.n);
    debug_assert_eq!(out.len(), rows * pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [0f32; NR];
        init[..jw].copy_from_slice(&bias[j0..j0 + jw]);
        let initv = _mm256_loadu_ps(init.as_ptr());
        let mut i = 0usize;
        while i + MR <= rows {
            tile_f32::<MR>(a, k, i, n, j0, jw, panel, initv, out);
            i += MR;
        }
        while i < rows {
            tile_f32::<1>(a, k, i, n, j0, jw, panel, initv, out);
            i += 1;
        }
    }
}

#[inline]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_f32<const M: usize>(
    a: &[f32],
    k: usize,
    base: usize,
    n: usize,
    j0: usize,
    jw: usize,
    panel: &[f32],
    initv: __m256,
    out: &mut [f32],
) {
    let mut acc = [initv; M];
    for p in 0..k {
        let b = _mm256_loadu_ps(panel.as_ptr().add(p * NR));
        for ii in 0..M {
            let av = _mm256_set1_ps(*a.get_unchecked((base + ii) * k + p));
            acc[ii] = _mm256_add_ps(acc[ii], _mm256_mul_ps(av, b));
        }
    }
    let mut vals = [0f32; NR];
    for ii in 0..M {
        _mm256_storeu_ps(vals.as_mut_ptr(), acc[ii]);
        let o = (base + ii) * n + j0;
        out[o..o + jw].copy_from_slice(&vals[..jw]);
    }
}

/// Nearest-half-up quantize, four f64 lanes wide, with the scalar loop
/// finishing the tail.  Pipeline per lane is exactly the scalar one:
/// `floor(x*inv + 0.5)`, saturation tally via ordered compares (NaN
/// counts as in-range, like the scalar `<`/`>`), clamp with
/// NaN-propagating max/min, `* step`, round back to f32.
#[target_feature(enable = "avx2")]
pub unsafe fn quantize_nearest(xs: &mut [f32], fmt: QFormat) -> u64 {
    let step = fmt.step();
    let inv = 1.0 / step as f64;
    let (lo, hi) = (fmt.qmin() as f64, fmt.qmax() as f64);
    let invv = _mm256_set1_pd(inv);
    let half = _mm256_set1_pd(0.5);
    let lov = _mm256_set1_pd(lo);
    let hiv = _mm256_set1_pd(hi);
    let stepv = _mm256_set1_pd(step as f64);
    let mut sat = 0u64;
    let nfull = xs.len() & !3;
    let mut i = 0usize;
    while i < nfull {
        let x4 = _mm_loadu_ps(xs.as_ptr().add(i));
        let xd = _mm256_cvtps_pd(x4);
        let raw = _mm256_floor_pd(_mm256_add_pd(_mm256_mul_pd(xd, invv), half));
        let under = _mm256_cmp_pd::<_CMP_LT_OQ>(raw, lov);
        let over = _mm256_cmp_pd::<_CMP_GT_OQ>(raw, hiv);
        let m = _mm256_movemask_pd(_mm256_or_pd(under, over));
        sat += (m as u32).count_ones() as u64;
        // bound first: max/min return the second operand when either is
        // NaN, so a NaN `raw` rides through like f64::clamp
        let code = _mm256_min_pd(hiv, _mm256_max_pd(lov, raw));
        let y = _mm256_cvtpd_ps(_mm256_mul_pd(code, stepv));
        _mm_storeu_ps(xs.as_mut_ptr().add(i), y);
        i += 4;
    }
    sat + quantize_nearest_scalar(&mut xs[nfull..], fmt)
}
