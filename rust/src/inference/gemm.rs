//! Tiled integer GEMM microkernel with fused requantize epilogues.
//!
//! This is the Figure 1 pipeline expressed as a matrix multiply: i32
//! operand codes widen into i64 accumulators (steps 1-2), and the fused
//! epilogue rounds/saturates back to the activation format (step 3) --
//! or decodes to f32 for a float logit head -- without ever
//! materialising the accumulator plane.  Requantization reuses
//! `ops::requant_i64`, so results are bit-for-bit those of the direct
//! per-image reference path (`FixedPointNet::forward`): integer adds are
//! exact and order-free, and zero-padded taps/columns contribute nothing.
//!
//! Blocking: weights are pre-packed into `NR`-column panels
//! (`packing::PackedPanels`); the microkernel walks `MR`-row strips of
//! the (im2col'd) activation matrix holding an `MR x NR` i64 accumulator
//! tile in registers, so each `a` element loaded from cache feeds `NR`
//! multiplies and each packed `b` row feeds `MR`.

use crate::fixedpoint::QFormat;
use crate::inference::ops::requant_i64;
use crate::inference::packing::{PackedPanels, NR};

/// Rows per microkernel tile.  `MR * NR` i64 accumulators (4x8 = 32)
/// stay comfortably in registers on x86-64 and aarch64.
pub const MR: usize = 4;

/// Element type the microkernel can run over: i32 codes widening into
/// i64 accumulators (the integer inference engine), or f32 operands with
/// f32 accumulators (the native training engine's forward and
/// input-gradient GEMMs).  Accumulation order is a fixed walk over the
/// reduction axis per output element, so both instantiations are
/// deterministic for any row blocking or thread count.
pub trait GemmScalar: Copy + Default {
    type Acc: Copy + Default;
    fn madd(acc: Self::Acc, a: Self, b: Self) -> Self::Acc;
}

impl GemmScalar for i32 {
    type Acc = i64;
    #[inline(always)]
    fn madd(acc: i64, a: i32, b: i32) -> i64 {
        acc + a as i64 * b as i64
    }
}

impl GemmScalar for f32 {
    type Acc = f32;
    #[inline(always)]
    fn madd(acc: f32, a: f32, b: f32) -> f32 {
        acc + a * b
    }
}

/// Accumulate an `M x NR` tile: rows `base..base+M` of the row-major
/// `(rows, k)` matrix `a` against one packed panel, starting every row's
/// accumulators at `init` (the fused bias).
#[inline(always)]
fn micro_tile<T: GemmScalar, const M: usize>(
    a: &[T],
    k: usize,
    base: usize,
    panel: &[T],
    init: &[T::Acc; NR],
) -> [[T::Acc; NR]; M] {
    let mut acc = [[T::Acc::default(); NR]; M];
    for row in acc.iter_mut() {
        *row = *init;
    }
    for p in 0..k {
        let b = &panel[p * NR..(p + 1) * NR];
        for (ii, acc_row) in acc.iter_mut().enumerate() {
            let av = a[(base + ii) * k + p];
            for (accv, &bv) in acc_row.iter_mut().zip(b) {
                *accv = T::madd(*accv, av, bv);
            }
        }
    }
    acc
}

/// Panel-blocked GEMM driver: `emit(row * n + col, acc)` receives every
/// finished accumulator exactly once (bias already folded in).
#[inline]
pub fn gemm_panels<T: GemmScalar, E: FnMut(usize, T::Acc)>(
    a: &[T],
    rows: usize,
    k: usize,
    pw: &PackedPanels<T>,
    bias_acc: &[T::Acc],
    mut emit: E,
) {
    debug_assert_eq!(pw.k, k);
    debug_assert!(a.len() >= rows * k);
    debug_assert_eq!(bias_acc.len(), pw.n);
    let n = pw.n;
    for jp in 0..pw.num_panels() {
        let panel = pw.panel(jp);
        let j0 = jp * NR;
        let jw = NR.min(n - j0);
        let mut init = [T::Acc::default(); NR];
        init[..jw].copy_from_slice(&bias_acc[j0..j0 + jw]);
        let mut i = 0usize;
        while i + MR <= rows {
            let acc = micro_tile::<T, MR>(a, k, i, panel, &init);
            for (ii, acc_row) in acc.iter().enumerate() {
                let o = (i + ii) * n + j0;
                for (j, &v) in acc_row[..jw].iter().enumerate() {
                    emit(o + j, v);
                }
            }
            i += MR;
        }
        while i < rows {
            let acc = micro_tile::<T, 1>(a, k, i, panel, &init);
            let o = i * n + j0;
            for (j, &v) in acc[0][..jw].iter().enumerate() {
                emit(o + j, v);
            }
            i += 1;
        }
    }
}

/// f32 GEMM with the bias folded into the accumulator start: the native
/// training engine's forward (im2col patches x quantized weights) and
/// input-gradient (output grads x transposed weights) matmuls.  `out` is
/// row-major `(rows, pw.n)`.
pub fn gemm_bias_f32(
    a: &[f32],
    rows: usize,
    k: usize,
    pw: &PackedPanels<f32>,
    bias: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * pw.n);
    gemm_panels(a, rows, k, pw, bias, |idx, acc| out[idx] = acc);
}

/// GEMM with the integer epilogue: bias + requantize (+ ReLU) into
/// activation codes.  `out` is row-major `(rows, pw.n)`.
#[allow(clippy::too_many_arguments)]
pub fn gemm_requant_relu(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PackedPanels,
    bias_acc: &[i64],
    acc_frac: i32,
    fmt: QFormat,
    relu: bool,
    out: &mut [i32],
) {
    debug_assert_eq!(out.len(), rows * pw.n);
    if relu {
        gemm_panels(a, rows, k, pw, bias_acc, |idx, acc| {
            out[idx] = requant_i64(acc, acc_frac, fmt).max(0);
        });
    } else {
        gemm_panels(a, rows, k, pw, bias_acc, |idx, acc| {
            out[idx] = requant_i64(acc, acc_frac, fmt);
        });
    }
}

/// GEMM with the float-head epilogue: bias + decode to f32 logits
/// (bit-identical to `ops::decode_acc` on the same accumulators).
pub fn gemm_decode(
    a: &[i32],
    rows: usize,
    k: usize,
    pw: &PackedPanels,
    bias_acc: &[i64],
    acc_frac: i32,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), rows * pw.n);
    let s = (-(acc_frac as f64)).exp2();
    gemm_panels(a, rows, k, pw, bias_acc, |idx, acc| {
        out[idx] = (acc as f64 * s) as f32;
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::ops;
    use crate::util::rng::Rng;

    fn q(bits: u8, frac: i8) -> QFormat {
        QFormat::new(bits, frac).unwrap()
    }

    /// Naive i64 reference: C = A*B + bias.
    fn naive(
        a: &[i32],
        rows: usize,
        k: usize,
        w: &[i32],
        n: usize,
        bias_acc: &[i64],
    ) -> Vec<i64> {
        let mut out = vec![0i64; rows * n];
        for r in 0..rows {
            for j in 0..n {
                let mut acc = bias_acc[j];
                for p in 0..k {
                    acc += a[r * k + p] as i64 * w[p * n + j] as i64;
                }
                out[r * n + j] = acc;
            }
        }
        out
    }

    fn random_case(seed: u64, rows: usize, k: usize, n: usize) -> (Vec<i32>, Vec<i32>, Vec<i64>) {
        let mut rng = Rng::new(seed);
        let a: Vec<i32> = (0..rows * k).map(|_| rng.below(511) as i32 - 255).collect();
        let w: Vec<i32> = (0..k * n).map(|_| rng.below(255) as i32 - 127).collect();
        let bias: Vec<i64> = (0..n).map(|_| rng.below(2001) as i64 - 1000).collect();
        (a, w, bias)
    }

    #[test]
    fn requant_epilogue_matches_naive() {
        // sweep odd shapes around the MR/NR tile edges
        for (seed, rows, k, n) in [
            (1u64, 1usize, 3usize, 1usize),
            (2, 4, 9, 8),
            (3, 7, 27, 10),
            (4, 13, 16, 17),
            (5, 32, 5, 7),
        ] {
            let (a, w, bias) = random_case(seed, rows, k, n);
            let pw = PackedPanels::pack(&w, k, n);
            let fmt = q(8, 2);
            let acc_frac = 7;
            let want: Vec<i32> = naive(&a, rows, k, &w, n, &bias)
                .iter()
                .map(|&acc| requant_i64(acc, acc_frac, fmt).max(0))
                .collect();
            let mut got = vec![0i32; rows * n];
            gemm_requant_relu(&a, rows, k, &pw, &bias, acc_frac, fmt, true, &mut got);
            assert_eq!(got, want, "rows={rows} k={k} n={n}");
            // and without relu
            let want: Vec<i32> = naive(&a, rows, k, &w, n, &bias)
                .iter()
                .map(|&acc| requant_i64(acc, acc_frac, fmt))
                .collect();
            gemm_requant_relu(&a, rows, k, &pw, &bias, acc_frac, fmt, false, &mut got);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn decode_epilogue_matches_decode_acc() {
        let (rows, k, n) = (6usize, 12usize, 10usize);
        let (a, w, bias) = random_case(9, rows, k, n);
        let pw = PackedPanels::pack(&w, k, n);
        let acc_frac = 11;
        let accs = naive(&a, rows, k, &w, n, &bias);
        let want = ops::decode_acc(&accs, acc_frac);
        let mut got = vec![0f32; rows * n];
        gemm_decode(&a, rows, k, &pw, &bias, acc_frac, &mut got);
        assert_eq!(got, want);
    }
}
