//! Shared fixtures for the paper-table benches (benches/*.rs).
//!
//! Benches are sized by environment variables so the same binaries serve
//! quick smoke runs and full-scale reproduction:
//!
//! * `FXP_BENCH_ARCH`     -- architecture (default "shallow": fast; the
//!   full paper reproduction uses "paper12" via `fxpnet grid`)
//! * `FXP_BENCH_STEPS`    -- fine-tune steps per cell (default 30)
//! * `FXP_BENCH_PHASE`    -- steps per Proposal-3 phase (default 15)
//! * `FXP_BENCH_PRETRAIN` -- float pretrain steps (default 250)
//! * `FXP_BENCH_TRAIN_N`  -- training set size (default 3072)
//! * `FXP_BENCH_EVAL_N`   -- eval set size (default 512)
//! * `FXP_BENCH_CKPT`     -- optional float checkpoint to skip pretraining

use crate::coordinator::calibrate;
use crate::coordinator::config::RunCfg;
use crate::coordinator::trainer::{upd_all, Trainer};
use crate::data::loader::LoaderCfg;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::model::checkpoint::Checkpoint;
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::NetQuant;
use crate::runtime::Engine;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// Everything a table bench needs.
pub struct BenchEnv {
    pub engine: Engine,
    pub arch: String,
    pub base: ParamSet,
    pub a_stats: Vec<LayerStats>,
    pub train: Dataset,
    pub eval: Dataset,
    pub cfg: RunCfg,
}

/// Build the bench environment: load or pretrain the float base net,
/// calibrate, size the RunCfg from the environment.
pub fn bench_env() -> Result<BenchEnv> {
    crate::util::logging::init();
    let artifacts = env_str("FXPNET_ARTIFACTS", "artifacts");
    let arch = env_str("FXP_BENCH_ARCH", "shallow");
    let engine = Engine::cpu(&artifacts)?;
    let spec = engine.manifest.arch(&arch)?.clone();
    let train_n = env_usize("FXP_BENCH_TRAIN_N", 3072);
    let eval_n = env_usize("FXP_BENCH_EVAL_N", 512);
    let train = Dataset::generate(train_n, spec.input[0], spec.input[1], 201);
    let eval = Dataset::generate(eval_n, spec.input[0], spec.input[1], 202);

    let ckpt = env_str("FXP_BENCH_CKPT", &format!("{arch}_float.ckpt"));
    let base = if std::path::Path::new(&ckpt).exists() {
        let ck = Checkpoint::load(&ckpt)?;
        ck.check_matches(&arch, &spec.params)?;
        eprintln!("[bench] using checkpoint {ckpt}");
        ck.params
    } else {
        let steps = env_usize("FXP_BENCH_PRETRAIN", 250);
        eprintln!("[bench] no checkpoint {ckpt}; pretraining {steps} steps");
        let p = ParamSet::init(&spec, 42);
        let nq = NetQuant::all_float(spec.num_layers);
        let mut tr = Trainer::new(
            &engine,
            &arch,
            &p,
            &nq,
            &upd_all(spec.num_layers),
            0.05,
            0.9,
            train.clone(),
            LoaderCfg {
                batch: spec.train_batch,
                augment: true,
                max_shift: 2,
                seed: 77,
            },
            30.0,
        )?;
        tr.run(steps, 50)?;
        tr.params()?
    };

    let a_stats =
        calibrate::activation_stats(&engine, &arch, &base, &train, 3)?.a_stats;

    let cfg = RunCfg {
        finetune_steps: env_usize("FXP_BENCH_STEPS", 30),
        phase_steps: env_usize("FXP_BENCH_PHASE", 15),
        ..RunCfg::default()
    };
    Ok(BenchEnv { engine, arch, base, a_stats, train, eval, cfg })
}

impl BenchEnv {
    pub fn runner(&self) -> crate::coordinator::grid::GridRunner<'_> {
        crate::coordinator::grid::GridRunner::new(
            &self.engine,
            &self.arch,
            self.base.clone(),
            self.a_stats.clone(),
            self.train.clone(),
            self.eval.clone(),
            self.cfg.clone(),
        )
    }
}
