//! Shared fixtures for the paper-table benches (benches/*.rs).
//!
//! Benches are sized by environment variables so the same binaries serve
//! quick smoke runs and full-scale reproduction:
//!
//! * `FXP_BENCH_ARCH`     -- architecture (default "shallow": fast; the
//!   full paper reproduction uses "paper12" via `fxpnet grid`)
//! * `FXP_BENCH_STEPS`    -- fine-tune steps per cell (default 30)
//! * `FXP_BENCH_PHASE`    -- steps per Proposal-3 phase (default 15)
//! * `FXP_BENCH_PRETRAIN` -- float pretrain steps (default 250)
//! * `FXP_BENCH_TRAIN_N`  -- training set size (default 3072)
//! * `FXP_BENCH_EVAL_N`   -- eval set size (default 512)
//! * `FXP_BENCH_CKPT`     -- optional float checkpoint to skip pretraining

use std::collections::BTreeMap;

use crate::coordinator::backend::{Backend, BackendSpec, SessionCfg};
use crate::coordinator::config::RunCfg;
use crate::coordinator::trainer::{run_session, upd_all, TrainSession};
use crate::data::loader::LoaderCfg;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::model::checkpoint::Checkpoint;
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::calib::{CalibMethod, LayerStats};
use crate::quant::policy::{NetQuant, WidthSpec};

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// CIFAR-shaped architecture for the integer-engine benches and parity
/// tests: 32x32x3 -> conv32 -> pool -> conv32 -> pool -> fc10.  Built
/// directly (no manifest / artifacts / Engine), so it works in the
/// offline build.
pub fn int_engine_arch() -> ArchSpec {
    ArchSpec {
        name: "cifar-fixture".into(),
        input: [32, 32, 3],
        num_classes: 10,
        num_layers: 3,
        train_batch: 32,
        eval_batch: 32,
        layers: vec![
            ("conv".into(), 32),
            ("pool".into(), 0),
            ("conv".into(), 32),
            ("pool".into(), 0),
            ("fc".into(), 10),
        ],
        params: vec![
            ("l0.w".into(), vec![3, 3, 3, 32]),
            ("l0.b".into(), vec![32]),
            ("l1.w".into(), vec![3, 3, 32, 32]),
            ("l1.b".into(), vec![32]),
            ("l2.w".into(), vec![8 * 8 * 32, 10]),
            ("l2.b".into(), vec![10]),
        ],
        artifacts: BTreeMap::new(),
    }
}

/// Resolve any offline arch into a concrete quantization cell:
/// He-normal params, min-max weight calibration, synthetic activation
/// ranges (only the resulting formats matter for engine benches/tests,
/// not calibration fidelity).
pub fn int_engine_cell(
    spec: &ArchSpec,
    bits: u8,
    seed: u64,
) -> Result<(ParamSet, NetQuant)> {
    let params = ParamSet::init(spec, seed);
    let w_stats = params.weight_stats();
    let a_stats: Vec<LayerStats> = (0..spec.num_layers)
        .map(|i| LayerStats {
            absmax: 3.0 + i as f32,
            meanabs: 0.8,
            meansq: 1.2,
        })
        .collect();
    let nq = NetQuant::for_cell(
        WidthSpec::Bits(bits),
        WidthSpec::Bits(bits),
        &w_stats,
        &a_stats,
        CalibMethod::MinMax,
    )?;
    Ok((params, nq))
}

/// The CIFAR-shaped fixture resolved to a concrete quantization cell.
pub fn int_engine_fixture(bits: u8, seed: u64) -> Result<(ArchSpec, ParamSet, NetQuant)> {
    let spec = int_engine_arch();
    let (params, nq) = int_engine_cell(&spec, bits, seed)?;
    Ok((spec, params, nq))
}

pub fn env_str(key: &str, default: &str) -> String {
    std::env::var(key).unwrap_or_else(|_| default.to_string())
}

/// Perf floor from the committed `BENCH_baseline.json` at the workspace
/// root -- the CI perf-trajectory gate: benches compare their measured
/// speedup *ratios* (machine-independent, unlike absolute rates) against
/// these floors under `FXP_BENCH_ASSERT`.  A missing file or key falls
/// back to `default`, so the benches still run from an uncommitted
/// checkout.
pub fn baseline_floor(bench: &str, key: &str, default: f64) -> f64 {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_baseline.json");
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!(
            "[bench] no {} -- using built-in floor {default}",
            path.display()
        );
        return default;
    };
    match crate::util::json::Json::parse(&text)
        .and_then(|j| j.get(bench)?.get(key)?.as_f64())
    {
        Ok(f) => f,
        Err(e) => {
            eprintln!(
                "[bench] BENCH_baseline.json has no {bench}.{key} ({e}); \
                 using built-in floor {default}"
            );
            default
        }
    }
}

/// Everything a table bench needs.
pub struct BenchEnv {
    pub backend: Box<dyn Backend>,
    pub arch: String,
    pub base: ParamSet,
    pub a_stats: Vec<LayerStats>,
    pub train: Dataset,
    pub eval: Dataset,
    pub cfg: RunCfg,
}

/// Backend for benches: `FXP_BENCH_BACKEND={native|xla}` wins; by
/// default the table benches run the native engine offline and the XLA
/// path when `artifacts/` has been built.
pub fn bench_backend() -> Result<Box<dyn Backend>> {
    let artifacts = env_str("FXPNET_ARTIFACTS", "artifacts");
    let spec = match std::env::var("FXP_BENCH_BACKEND") {
        Ok(s) => BackendSpec::parse(&s, &artifacts)?,
        Err(_) => BackendSpec::auto(&artifacts),
    };
    spec.build()
}

/// Build the bench environment: load or pretrain the float base net,
/// calibrate, size the RunCfg from the environment.
pub fn bench_env() -> Result<BenchEnv> {
    crate::util::logging::init();
    let arch = env_str("FXP_BENCH_ARCH", "shallow");
    let backend = bench_backend()?;
    let spec = backend.arch(&arch)?;
    let train_n = env_usize("FXP_BENCH_TRAIN_N", 3072);
    let eval_n = env_usize("FXP_BENCH_EVAL_N", 512);
    let train = Dataset::generate(train_n, spec.input[0], spec.input[1], 201);
    let eval = Dataset::generate(eval_n, spec.input[0], spec.input[1], 202);

    let ckpt = env_str("FXP_BENCH_CKPT", &format!("{arch}_float.ckpt"));
    let base = if std::path::Path::new(&ckpt).exists() {
        let ck = Checkpoint::load(&ckpt)?;
        ck.check_matches(&arch, &spec.params)?;
        eprintln!("[bench] using checkpoint {ckpt}");
        ck.params
    } else {
        let steps = env_usize("FXP_BENCH_PRETRAIN", 250);
        eprintln!(
            "[bench] no checkpoint {ckpt}; pretraining {steps} steps on the \
             {} backend",
            backend.name()
        );
        let p = ParamSet::init(&spec, 42);
        let nq = NetQuant::all_float(spec.num_layers);
        let mut tr = backend.new_session(SessionCfg {
            arch: &arch,
            params: &p,
            nq: &nq,
            upd: &upd_all(spec.num_layers),
            lr: 0.05,
            momentum: 0.9,
            data: train.clone(),
            loader: LoaderCfg {
                batch: spec.train_batch,
                augment: true,
                max_shift: 2,
                seed: 77,
            },
            max_loss: 30.0,
            seed: 77,
            threads: 1,
        })?;
        run_session(&mut *tr, steps, 50)?;
        tr.params()?
    };

    let a_stats = backend.activation_stats(&arch, &base, &train, 3)?;

    let cfg = RunCfg {
        finetune_steps: env_usize("FXP_BENCH_STEPS", 30),
        phase_steps: env_usize("FXP_BENCH_PHASE", 15),
        ..RunCfg::default()
    };
    Ok(BenchEnv { backend, arch, base, a_stats, train, eval, cfg })
}

impl BenchEnv {
    pub fn runner(&self) -> crate::coordinator::grid::GridRunner<'_> {
        crate::coordinator::grid::GridRunner::new(
            self.backend.as_ref(),
            &self.arch,
            self.base.clone(),
            self.a_stats.clone(),
            self.train.clone(),
            self.eval.clone(),
            self.cfg.clone(),
        )
    }
}
