//! Benchmark harness (criterion is not available offline; this provides
//! the subset the paper-table benches need: warmup, timed iterations,
//! robust stats, throughput, and aligned table printing).

pub mod fixtures;

use std::time::Instant;

use crate::util::{mean, percentile, std_dev};

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub std_ms: f64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl BenchStats {
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        if self.mean_ms <= 0.0 {
            return 0.0;
        }
        items_per_iter / (self.mean_ms / 1e3)
    }
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    BenchStats {
        name: name.to_string(),
        iters,
        mean_ms: mean(&samples),
        std_ms: std_dev(&samples),
        p50_ms: percentile(&samples, 50.0),
        p99_ms: percentile(&samples, 99.0),
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} ms ±{:>8.3}  p50 {:>9.3}  p99 {:>9.3}  (n={})",
            self.name, self.mean_ms, self.std_ms, self.p50_ms, self.p99_ms, self.iters
        )
    }
}

/// Fixed-width table printer for paper-style grids.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                s.push_str(&format!("{:>w$}  ", c, w = widths.get(i).copied().unwrap_or(8)));
            }
            s.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * ncol));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures() {
        let s = bench("noop", 2, 10, || {
            std::hint::black_box(42);
        });
        assert_eq!(s.iters, 10);
        assert!(s.mean_ms >= 0.0);
        assert!(s.p99_ms >= s.p50_ms);
        assert!(s.throughput(100.0) > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Table X", &["Act", "4", "8"]);
        t.row(vec!["4".into(), "98.6".into(), "33.4".into()]);
        t.row(vec!["Float".into(), "96.6".into(), "14.1".into()]);
        let s = t.render();
        assert!(s.contains("Table X"));
        assert!(s.contains("98.6"));
        // all data lines have the same width
        let lines: Vec<&str> = s.lines().skip(1).collect();
        assert_eq!(lines[0].len(), lines[2].len());
    }
}
