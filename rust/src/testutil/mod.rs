//! Mini property-testing harness (proptest is not in the offline cache).
//!
//! `check` runs a property over `n` seeded random cases; on failure it
//! reports the failing seed so the case can be replayed exactly:
//! `check_seeded(seed, prop)`.  Generators are plain functions over
//! `Rng`, composed by hand -- small, but covers the invariants this
//! library cares about (see the property tests in fixedpoint/, quant/,
//! and rust/tests/).

use crate::util::rng::Rng;

/// Run `prop` over `n` random cases; panics with the failing seed.
pub fn check<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    n: usize,
    mut prop: F,
) {
    for case in 0..n {
        let seed = 0xF00D_0000u64 + case as u64;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed at seed {seed:#x}: {msg}");
        }
    }
}

/// Replay a single failing case.
pub fn check_seeded<F: FnMut(&mut Rng) -> std::result::Result<(), String>>(
    name: &str,
    seed: u64,
    mut prop: F,
) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = prop(&mut rng) {
        panic!("property '{name}' failed at seed {seed:#x}: {msg}");
    }
}

/// Generator helpers.
pub mod gen {
    use crate::fixedpoint::QFormat;
    use crate::util::rng::Rng;

    /// Vec of f32 drawn from N(0, scale^2).
    pub fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32 * scale).collect()
    }

    /// Uniform vec in [lo, hi).
    pub fn uniform_vec(rng: &mut Rng, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| rng.uniform_in(lo, hi)).collect()
    }

    /// Random Q-format with bits in [2, 16], frac in [-2, 12].
    pub fn qformat(rng: &mut Rng) -> QFormat {
        let bits = 2 + rng.below(15) as u8;
        let frac = rng.below(15) as i8 - 2;
        QFormat::new(bits, frac).unwrap()
    }

    /// Random length in [1, max].
    pub fn len(rng: &mut Rng, max: usize) -> usize {
        1 + rng.below(max)
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], tol: f32) -> std::result::Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        if (x - y).abs() > tol {
            return Err(format!("index {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_good_property() {
        check("uniform in range", 50, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure() {
        check("always fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn assert_close_works() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0001], 1e-3).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn generators_in_bounds() {
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..100 {
            let q = gen::qformat(&mut rng);
            assert!((2..=16).contains(&q.bits));
            assert!((-2..=12).contains(&q.frac));
            let n = gen::len(&mut rng, 7);
            assert!((1..=7).contains(&n));
        }
    }
}
