//! Grid cells -> runtime quantization configuration.
//!
//! A cell of the paper's experiment grid is a pair (weight width,
//! activation width), each in {4, 8, 16, Float}.  `NetQuant` resolves a
//! cell against per-layer calibration into concrete `QFormat`s (or None
//! for float), applying the paper's special rule that the final layer's
//! output activation is always at least 16-bit ("the subsequent softmax
//! layer is rather sensitive to low precision inputs").  The
//! `QuantVectors` it produces are fed verbatim as the (L,)-shaped inputs
//! of every AOT executable.

use crate::error::Result;
use crate::fixedpoint::QFormat;

use super::calib::{CalibMethod, LayerStats};

/// One axis value of the experiment grid.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WidthSpec {
    Bits(u8),
    Float,
}

impl WidthSpec {
    pub fn parse(s: &str) -> Option<WidthSpec> {
        match s {
            "float" | "f" | "fp" => Some(WidthSpec::Float),
            _ => s.parse::<u8>().ok().map(WidthSpec::Bits),
        }
    }

    pub fn label(&self) -> String {
        match self {
            WidthSpec::Bits(b) => b.to_string(),
            WidthSpec::Float => "Float".to_string(),
        }
    }

    /// Stable tag for seed derivation (`util::rng::derive_seed`): the bit
    /// width itself, or a constant far outside the u8 range for Float.
    pub fn seed_tag(&self) -> u64 {
        match self {
            WidthSpec::Bits(b) => *b as u64,
            WidthSpec::Float => 0xF10A7,
        }
    }

    /// The paper's grid axes: 4, 8, 16, Float.
    pub fn paper_axis() -> [WidthSpec; 4] {
        [
            WidthSpec::Bits(4),
            WidthSpec::Bits(8),
            WidthSpec::Bits(16),
            WidthSpec::Float,
        ]
    }
}

/// Resolved per-layer quantization of one network: `None` = float.
#[derive(Clone, Debug)]
pub struct NetQuant {
    pub weights: Vec<Option<QFormat>>,
    pub acts: Vec<Option<QFormat>>,
}

/// The (L,)-shaped runtime vectors consumed by the AOT executables.
#[derive(Clone, Debug)]
pub struct QuantVectors {
    pub w_step: Vec<f32>,
    pub w_lo: Vec<f32>,
    pub w_hi: Vec<f32>,
    pub w_en: Vec<f32>,
    pub a_step: Vec<f32>,
    pub a_lo: Vec<f32>,
    pub a_hi: Vec<f32>,
    pub a_en: Vec<f32>,
}

fn push_cfg(
    fmt: &Option<QFormat>,
    step: &mut Vec<f32>,
    lo: &mut Vec<f32>,
    hi: &mut Vec<f32>,
    en: &mut Vec<f32>,
) {
    match fmt {
        Some(f) => {
            let (s, l, h) = f.runtime_cfg();
            step.push(s);
            lo.push(l);
            hi.push(h);
            en.push(1.0);
        }
        None => {
            // disabled: enable=0 bypasses; benign placeholder params
            step.push(1.0);
            lo.push(-1.0);
            hi.push(1.0);
            en.push(0.0);
        }
    }
}

impl NetQuant {
    /// Everything float (the pretraining configuration).
    pub fn all_float(num_layers: usize) -> NetQuant {
        NetQuant {
            weights: vec![None; num_layers],
            acts: vec![None; num_layers],
        }
    }

    /// Resolve a grid cell.
    ///
    /// * `w_width` / `a_width`: the cell's axes.
    /// * `w_stats` / `a_stats`: per-layer calibration statistics
    ///   (weights from the checkpoint, activations from `stats_batch`).
    /// * `method`: min-max or SQNR-optimal.
    ///
    /// The final layer's activation (the logits) is kept at >= 16 bits
    /// whenever activations are quantized, per the paper's protocol.
    pub fn for_cell(
        w_width: WidthSpec,
        a_width: WidthSpec,
        w_stats: &[LayerStats],
        a_stats: &[LayerStats],
        method: CalibMethod,
    ) -> Result<NetQuant> {
        assert_eq!(w_stats.len(), a_stats.len());
        let n = w_stats.len();
        let mut weights = Vec::with_capacity(n);
        let mut acts = Vec::with_capacity(n);
        for (i, (ws, as_)) in w_stats.iter().zip(a_stats).enumerate() {
            weights.push(match w_width {
                WidthSpec::Float => None,
                WidthSpec::Bits(b) => Some(method.choose(b, ws)?),
            });
            let is_last = i == n - 1;
            acts.push(match a_width {
                WidthSpec::Float => None,
                WidthSpec::Bits(b) => {
                    // paper: final FC output always 16-bit
                    let eff = if is_last { b.max(16) } else { b };
                    Some(method.choose(eff, as_)?)
                }
            });
        }
        Ok(NetQuant { weights, acts })
    }

    pub fn num_layers(&self) -> usize {
        self.weights.len()
    }

    /// True when this cell can run on the pure-integer inference engine
    /// (`FixedPointNet`): every weight quantized and every *hidden*
    /// activation quantized.  The head activation may stay float --
    /// logits decode to f32 either way.  Cells failing this (the Float
    /// rows/columns of the paper grids) only exist as simulated
    /// quantization in a float forward.
    pub fn integer_deployable(&self) -> bool {
        let l = self.weights.len();
        self.weights.iter().all(|w| w.is_some())
            && self.acts[..l.saturating_sub(1)].iter().all(|a| a.is_some())
    }

    /// Activation formats fixed-point only for layers `< k` (the Table 1
    /// phase schedule of Proposal 3: during phase p, activations of
    /// layers 0..=p are fixed point, everything above stays float).
    pub fn with_act_prefix(&self, k: usize) -> NetQuant {
        let mut out = self.clone();
        for (i, a) in out.acts.iter_mut().enumerate() {
            if i >= k {
                *a = None;
            }
        }
        out
    }

    /// All activations float, weights unchanged (Proposal 1 training
    /// configuration).
    pub fn with_float_acts(&self) -> NetQuant {
        let mut out = self.clone();
        for a in out.acts.iter_mut() {
            *a = None;
        }
        out
    }

    /// Per-layer weight quantization step sizes (`None` = float layer).
    /// The training-stability telemetry normalizes each layer's mean
    /// absolute weight update by this step: a healthy fixed-point run
    /// keeps the ratio well above ~1e-3, while a collapsed ratio means
    /// every update rounds back to the same code (the Q4 pathology of
    /// section 2.2) and the cell is doomed.
    pub fn weight_steps(&self) -> Vec<Option<f32>> {
        self.weights.iter().map(|w| w.map(|f| f.step())).collect()
    }

    /// The runtime vectors for the executables.
    pub fn vectors(&self) -> QuantVectors {
        let mut v = QuantVectors {
            w_step: vec![],
            w_lo: vec![],
            w_hi: vec![],
            w_en: vec![],
            a_step: vec![],
            a_lo: vec![],
            a_hi: vec![],
            a_en: vec![],
        };
        for f in &self.weights {
            push_cfg(f, &mut v.w_step, &mut v.w_lo, &mut v.w_hi, &mut v.w_en);
        }
        for f in &self.acts {
            push_cfg(f, &mut v.a_step, &mut v.a_lo, &mut v.a_hi, &mut v.a_en);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(n: usize) -> Vec<LayerStats> {
        (0..n)
            .map(|i| LayerStats {
                absmax: 2.0 + i as f32,
                meanabs: 0.5,
                meansq: 1.0,
            })
            .collect()
    }

    #[test]
    fn cell_resolution_basic() {
        let s = stats(4);
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(8),
            WidthSpec::Bits(4),
            &s,
            &s,
            CalibMethod::MinMax,
        )
        .unwrap();
        assert_eq!(nq.num_layers(), 4);
        assert!(nq.weights.iter().all(|w| w.unwrap().bits == 8));
        // hidden acts 4-bit, last >= 16-bit (paper's softmax rule)
        assert!(nq.acts[..3].iter().all(|a| a.unwrap().bits == 4));
        assert_eq!(nq.acts[3].unwrap().bits, 16);
    }

    #[test]
    fn float_axes() {
        let s = stats(3);
        let nq = NetQuant::for_cell(
            WidthSpec::Float,
            WidthSpec::Float,
            &s,
            &s,
            CalibMethod::MinMax,
        )
        .unwrap();
        assert!(nq.weights.iter().all(|w| w.is_none()));
        assert!(nq.acts.iter().all(|a| a.is_none()));
    }

    #[test]
    fn act_prefix_schedule() {
        let s = stats(4);
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(8),
            WidthSpec::Bits(8),
            &s,
            &s,
            CalibMethod::MinMax,
        )
        .unwrap();
        // phase 1 of Table 1: only layer 0 activations fixed point
        let p1 = nq.with_act_prefix(1);
        assert!(p1.acts[0].is_some());
        assert!(p1.acts[1..].iter().all(|a| a.is_none()));
        // weights untouched
        assert!(p1.weights.iter().all(|w| w.is_some()));
        // prefix 0: nothing quantized
        assert!(nq.with_act_prefix(0).acts.iter().all(|a| a.is_none()));
        // full prefix: everything as resolved
        assert_eq!(
            nq.with_act_prefix(4).acts.iter().filter(|a| a.is_some()).count(),
            4
        );
    }

    #[test]
    fn integer_deployable_cases() {
        let s = stats(3);
        let cell = |w, a| {
            NetQuant::for_cell(w, a, &s, &s, CalibMethod::MinMax).unwrap()
        };
        // fully quantized: deployable
        assert!(cell(WidthSpec::Bits(8), WidthSpec::Bits(8)).integer_deployable());
        // float weights or float activations: not deployable
        assert!(!cell(WidthSpec::Float, WidthSpec::Bits(8)).integer_deployable());
        assert!(!cell(WidthSpec::Bits(8), WidthSpec::Float).integer_deployable());
        assert!(!NetQuant::all_float(3).integer_deployable());
        // a float *head* activation alone is fine (logits decode anyway)
        let mut nq = cell(WidthSpec::Bits(8), WidthSpec::Bits(8));
        nq.acts[2] = None;
        assert!(nq.integer_deployable());
        // a float hidden activation is not
        let mut nq = cell(WidthSpec::Bits(8), WidthSpec::Bits(8));
        nq.acts[0] = None;
        assert!(!nq.integer_deployable());
    }

    #[test]
    fn vectors_layout() {
        let s = stats(2);
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(4),
            WidthSpec::Float,
            &s,
            &s,
            CalibMethod::MinMax,
        )
        .unwrap();
        let v = nq.vectors();
        assert_eq!(v.w_en, vec![1.0, 1.0]);
        assert_eq!(v.a_en, vec![0.0, 0.0]);
        assert_eq!(v.w_lo, vec![-8.0, -8.0]);
        assert_eq!(v.w_hi, vec![7.0, 7.0]);
        assert!(v.w_step.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn weight_steps_per_layer() {
        let s = stats(3);
        let nq = NetQuant::for_cell(
            WidthSpec::Bits(4),
            WidthSpec::Bits(8),
            &s,
            &s,
            CalibMethod::MinMax,
        )
        .unwrap();
        let steps = nq.weight_steps();
        assert_eq!(steps.len(), 3);
        for (st, w) in steps.iter().zip(&nq.weights) {
            assert_eq!(*st, w.map(|f| f.step()));
            assert!(st.unwrap() > 0.0);
        }
        assert!(NetQuant::all_float(3)
            .weight_steps()
            .iter()
            .all(|s| s.is_none()));
    }

    #[test]
    fn width_spec_parse() {
        assert_eq!(WidthSpec::parse("8"), Some(WidthSpec::Bits(8)));
        assert_eq!(WidthSpec::parse("float"), Some(WidthSpec::Float));
        assert_eq!(WidthSpec::parse("x"), None);
        assert_eq!(WidthSpec::paper_axis().len(), 4);
    }
}
