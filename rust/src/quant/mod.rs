//! Quantization format selection and experiment-grid configuration.
//!
//! The paper fine-tunes networks that were quantized with the scheme of
//! its companion paper (Lin, Talathi & Annapureddy, ICML 2016: "Fixed
//! point quantization of deep convolutional networks") -- per-layer
//! fractional lengths chosen to maximise SQNR.  `calib` implements that
//! baseline (plus plain min-max) from activation statistics collected by
//! the `stats_batch` AOT executable; `policy` turns grid cells like
//! (w=4 bits, a=8 bits) into the runtime config vectors the executables
//! consume.

pub mod calib;
pub mod policy;

pub use calib::{CalibMethod, LayerStats};
pub use policy::{NetQuant, QuantVectors, WidthSpec};
