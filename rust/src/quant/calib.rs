//! Fractional-length calibration: min-max and SQNR-optimal (the Lin et
//! al. ICML 2016 baseline quantizer the paper builds on).
//!
//! Min-max guarantees no overload distortion; SQNR-optimal trades a
//! little clipping of the distribution tail for a finer step, maximising
//! the signal-to-quantization-noise ratio.  For bell-shaped activation /
//! weight distributions the optimum is typically 1-2 fractional bits
//! finer than min-max at 8 bits and below.

use crate::error::Result;
use crate::fixedpoint::QFormat;

/// Per-layer statistics collected by the `stats_batch` executable (over
/// pre-activations) or computed directly from weight tensors.
#[derive(Clone, Copy, Debug)]
pub struct LayerStats {
    pub absmax: f32,
    pub meanabs: f32,
    pub meansq: f32,
}

/// Which calibration rule to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibMethod {
    /// Cover the observed absmax exactly (no clipping).
    MinMax,
    /// Maximise analytic SQNR under a Gaussian fit of the stats.
    SqnrGaussian,
}

impl CalibMethod {
    pub fn parse(s: &str) -> Option<CalibMethod> {
        match s {
            "minmax" => Some(CalibMethod::MinMax),
            "sqnr" => Some(CalibMethod::SqnrGaussian),
            _ => None,
        }
    }

    /// Choose a format for one layer.
    pub fn choose(&self, bits: u8, stats: &LayerStats) -> Result<QFormat> {
        match self {
            CalibMethod::MinMax => QFormat::fit_absmax(bits, stats.absmax),
            CalibMethod::SqnrGaussian => sqnr_optimal_gaussian(bits, stats),
        }
    }
}

/// erf via the Abramowitz & Stegun 7.1.26 rational approximation
/// (|error| <= 1.5e-7, plenty for picking an integer fractional length).
fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
            - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

fn phi(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Expected quantization distortion of a zero-mean Gaussian with std
/// `sigma` under a symmetric uniform quantizer with step `delta` and
/// clip level `c` (granular + overload noise, standard high-rate model).
fn gaussian_distortion(sigma: f64, delta: f64, c: f64) -> f64 {
    if sigma <= 0.0 {
        return 0.0;
    }
    let a = c / sigma;
    // P(|x| < c)
    let p_in = erf(a / std::f64::consts::SQRT_2);
    let granular = delta * delta / 12.0 * p_in;
    // E[(|x|-c)^2 ; |x|>c] for x ~ N(0, sigma^2):
    //   = 2 * [ (sigma^2 + c^2) * Q(a) - sigma * c * phi(a) ]   with
    //   Q(a) = 0.5 * erfc(a / sqrt2)
    let q_a = 0.5 * (1.0 - erf(a / std::f64::consts::SQRT_2));
    let overload = 2.0 * ((sigma * sigma + c * c) * q_a - sigma * c * phi(a));
    granular + overload.max(0.0)
}

/// SQNR-optimal fractional length under a Gaussian fit: search formats
/// from min-max (no clipping) down to several bits finer, minimising the
/// analytic distortion.
pub fn sqnr_optimal_gaussian(bits: u8, stats: &LayerStats) -> Result<QFormat> {
    let base = QFormat::fit_absmax(bits, stats.absmax)?;
    let sigma = (stats.meansq.max(0.0) as f64).sqrt();
    if sigma == 0.0 {
        return Ok(base);
    }
    let mut best = base;
    let mut best_d = f64::INFINITY;
    for extra in 0..=6i8 {
        let frac = base.frac.saturating_add(extra);
        let fmt = QFormat::new(bits, frac)?;
        let delta = fmt.step() as f64;
        let c = fmt.max_value() as f64;
        let d = gaussian_distortion(sigma, delta, c);
        if d < best_d {
            best_d = d;
            best = fmt;
        }
    }
    Ok(best)
}

/// Empirical SQNR-optimal format from raw samples (used for weights,
/// which the coordinator holds in full): sweep candidate fractional
/// lengths, measure true SQNR, keep the best.
pub fn sqnr_optimal_empirical(bits: u8, samples: &[f32]) -> Result<QFormat> {
    let absmax = samples.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
    let base = QFormat::fit_absmax(bits, absmax)?;
    let mut best = base;
    let mut best_sqnr = f64::NEG_INFINITY;
    for extra in 0..=6i8 {
        let fmt = QFormat::new(bits, base.frac.saturating_add(extra))?;
        let s = crate::fixedpoint::vector::sqnr_db(samples, fmt);
        if s > best_sqnr {
            best_sqnr = s;
            best = fmt;
        }
    }
    Ok(best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn gauss_samples(n: usize, sigma: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32 * sigma).collect()
    }

    #[test]
    fn erf_accuracy() {
        // reference values
        for (x, want) in [(0.0, 0.0), (0.5, 0.5204999), (1.0, 0.8427008), (2.0, 0.9953223)] {
            assert!((erf(x) - want).abs() < 1e-5, "erf({x})");
        }
        assert!((erf(-1.0) + 0.8427008).abs() < 1e-5);
    }

    #[test]
    fn minmax_never_clips() {
        let xs = gauss_samples(5000, 2.0, 1);
        let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let stats = LayerStats { absmax, meanabs: 0.0, meansq: 4.0 };
        let fmt = CalibMethod::MinMax.choose(8, &stats).unwrap();
        assert!(fmt.max_value() >= absmax * 0.999);
    }

    #[test]
    fn sqnr_gaussian_beats_minmax_in_sqnr() {
        // the whole point of the companion-paper quantizer
        let xs = gauss_samples(20000, 1.0, 2);
        let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let meansq = xs.iter().map(|&x| x * x).sum::<f32>() / xs.len() as f32;
        let stats = LayerStats { absmax, meanabs: 0.8, meansq };
        for bits in [4u8, 8] {
            let mm = CalibMethod::MinMax.choose(bits, &stats).unwrap();
            let sq = CalibMethod::SqnrGaussian.choose(bits, &stats).unwrap();
            let s_mm = crate::fixedpoint::vector::sqnr_db(&xs, mm);
            let s_sq = crate::fixedpoint::vector::sqnr_db(&xs, sq);
            assert!(
                s_sq >= s_mm - 0.3,
                "bits={bits}: sqnr {s_sq:.2} dB vs minmax {s_mm:.2} dB ({sq} vs {mm})"
            );
            // at low bit-width the optimum clips: finer frac than minmax
            if bits <= 8 {
                assert!(sq.frac >= mm.frac, "{sq} vs {mm}");
            }
        }
    }

    #[test]
    fn empirical_matches_or_beats_gaussian() {
        let xs = gauss_samples(20000, 0.7, 3);
        let absmax = xs.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
        let meansq = xs.iter().map(|&x| x * x).sum::<f32>() / xs.len() as f32;
        let stats = LayerStats { absmax, meanabs: 0.0, meansq };
        let g = sqnr_optimal_gaussian(4, &stats).unwrap();
        let e = sqnr_optimal_empirical(4, &xs).unwrap();
        let s_g = crate::fixedpoint::vector::sqnr_db(&xs, g);
        let s_e = crate::fixedpoint::vector::sqnr_db(&xs, e);
        assert!(s_e >= s_g - 1e-9, "{s_e} vs {s_g}");
        // gaussian analytic pick should be within 1.5 dB of empirical best
        assert!(s_g > s_e - 1.5, "{s_g} vs {s_e}");
    }

    #[test]
    fn degenerate_stats() {
        let stats = LayerStats { absmax: 0.0, meanabs: 0.0, meansq: 0.0 };
        assert!(CalibMethod::MinMax.choose(8, &stats).is_ok());
        assert!(CalibMethod::SqnrGaussian.choose(8, &stats).is_ok());
    }

    #[test]
    fn parse() {
        assert_eq!(CalibMethod::parse("minmax"), Some(CalibMethod::MinMax));
        assert_eq!(CalibMethod::parse("sqnr"), Some(CalibMethod::SqnrGaussian));
        assert_eq!(CalibMethod::parse("x"), None);
    }
}
