//! Shared length-prefixed frame codec: the wire substrate under both
//! `cluster::proto` (sweep protocol) and `serve::proto` (inference
//! protocol).
//!
//! One frame = `u32` little-endian payload length, then exactly that
//! many bytes of UTF-8 JSON.  [`MAX_FRAME`] bounds the payload so a
//! corrupt or hostile length prefix can never make a peer allocate
//! unbounded memory.  Any framing violation is an `Err` -- endpoints
//! respond by dropping the peer with a logged error, never by panicking
//! (pinned by tests/cluster_proto.rs and tests/serve.rs, which run the
//! same malformed-frame corpus against this codec).
//!
//! ## Timeout semantics
//!
//! With a socket read timeout set, a quiet frame *boundary* surfaces as
//! [`RawFrame::TimedOut`] -- a scheduling tick for the caller's deadline
//! bookkeeping, not an error.  A frame that *started* keeps reading
//! through timeout ticks until `deadline` (if `Some`); hitting the
//! deadline mid-frame is an error, because a half-frame can never be
//! resynchronized.  A clean EOF is only "clean" at a boundary.

use std::io::{Read, Write};
use std::time::Instant;

use crate::error::{FxpError, Result};
use crate::util::json::Json;

/// Maximum frame payload in bytes.  Messages are small (a cell result is
/// a few hundred bytes; an inference request is a few tens of KB); the
/// cap exists to bound allocation on a corrupt length prefix.
pub const MAX_FRAME: usize = 1 << 20;

/// What one raw read attempt produced.
#[derive(Debug)]
pub enum RawFrame {
    /// A complete payload (length-checked, not yet parsed).
    Payload(Vec<u8>),
    /// Clean EOF at a frame boundary (the peer closed).
    Eof,
    /// The socket's read timeout fired before any byte of a new frame
    /// arrived -- a scheduling tick, not an error.
    TimedOut,
}

/// A raw frame with the payload parsed as one JSON value.
#[derive(Debug)]
pub enum JsonFrame {
    Msg(Json),
    Eof,
    TimedOut,
}

/// Encode `bytes` as one frame.  Errors (rather than truncating) if the
/// payload would exceed [`MAX_FRAME`]; nothing hits the wire on error.
pub fn write_frame_bytes(w: &mut impl Write, bytes: &[u8]) -> Result<()> {
    if bytes.len() > MAX_FRAME {
        return Err(FxpError::config(format!(
            "frame payload {} bytes exceeds MAX_FRAME {MAX_FRAME}",
            bytes.len()
        )));
    }
    w.write_all(&(bytes.len() as u32).to_le_bytes())?;
    w.write_all(bytes)?;
    w.flush()?;
    Ok(())
}

/// Serialize one JSON value as a frame.
pub fn write_json_frame(w: &mut impl Write, j: &Json) -> Result<()> {
    write_frame_bytes(w, j.to_string().as_bytes())
}

pub(crate) fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// Read exactly `buf.len()` bytes, tolerating short reads and (until
/// `deadline`) read-timeout ticks.  `started` says whether earlier bytes
/// of this frame were already consumed: a clean EOF is only "clean"
/// before the first byte.
fn read_exact_deadline(
    r: &mut impl Read,
    buf: &mut [u8],
    started: bool,
    deadline: Option<Instant>,
) -> Result<Option<()>> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => {
                if got == 0 && !started {
                    return Ok(None); // peer closed at a frame boundary
                }
                return Err(FxpError::Json("truncated frame (peer closed)".into()));
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) if is_timeout(&e) => {
                if got == 0 && !started {
                    return Err(e.into()); // boundary timeout: caller's tick
                }
                // mid-frame: the sender paused (or a fault layer delayed
                // it); keep waiting until the caller's deadline
                if let Some(d) = deadline {
                    if Instant::now() >= d {
                        return Err(FxpError::Json("timed out mid-frame".into()));
                    }
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(()))
}

/// Read one raw frame.  See the module docs for the boundary-vs-mid-frame
/// timeout contract.  Everything malformed (oversized length, truncation)
/// is `Err`.
pub fn read_frame_bytes(r: &mut impl Read, deadline: Option<Instant>) -> Result<RawFrame> {
    let mut len_bytes = [0u8; 4];
    match read_exact_deadline(r, &mut len_bytes, false, deadline) {
        Ok(None) => return Ok(RawFrame::Eof),
        Ok(Some(())) => {}
        Err(FxpError::Io(e)) if is_timeout(&e) => return Ok(RawFrame::TimedOut),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len_bytes) as usize;
    if len > MAX_FRAME {
        return Err(FxpError::Json(format!(
            "oversized frame: {len} bytes (cap {MAX_FRAME})"
        )));
    }
    let mut payload = vec![0u8; len];
    read_exact_deadline(r, &mut payload, true, deadline)?;
    Ok(RawFrame::Payload(payload))
}

/// Read one frame and parse its payload as JSON (UTF-8 and JSON
/// violations are `Err`, like any other malformed frame).
pub fn read_json_frame(r: &mut impl Read, deadline: Option<Instant>) -> Result<JsonFrame> {
    Ok(match read_frame_bytes(r, deadline)? {
        RawFrame::Payload(p) => {
            let text = std::str::from_utf8(&p)
                .map_err(|_| FxpError::Json("frame payload is not UTF-8".into()))?;
            JsonFrame::Msg(Json::parse(text)?)
        }
        RawFrame::Eof => JsonFrame::Eof,
        RawFrame::TimedOut => JsonFrame::TimedOut,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn raw_round_trip_and_eof() {
        let mut wire = Vec::new();
        write_frame_bytes(&mut wire, b"{\"x\":1}").unwrap();
        write_frame_bytes(&mut wire, b"").unwrap();
        let mut r = wire.as_slice();
        match read_frame_bytes(&mut r, None).unwrap() {
            RawFrame::Payload(p) => assert_eq!(p, b"{\"x\":1}"),
            other => panic!("{other:?}"),
        }
        match read_frame_bytes(&mut r, None).unwrap() {
            RawFrame::Payload(p) => assert!(p.is_empty()),
            other => panic!("{other:?}"),
        }
        assert!(matches!(read_frame_bytes(&mut r, None).unwrap(), RawFrame::Eof));
    }

    #[test]
    fn json_layer_round_trips_and_rejects() {
        let j = Json::obj(vec![("type", Json::from("ping")), ("n", Json::from(3usize))]);
        let mut wire = Vec::new();
        write_json_frame(&mut wire, &j).unwrap();
        match read_json_frame(&mut wire.as_slice(), None).unwrap() {
            JsonFrame::Msg(back) => assert_eq!(back, j),
            other => panic!("{other:?}"),
        }
        // valid frame, invalid JSON payload
        let mut bad = Vec::new();
        write_frame_bytes(&mut bad, b"{oops").unwrap();
        assert!(read_json_frame(&mut bad.as_slice(), None).is_err());
        // valid frame, non-UTF-8 payload
        let mut bad = Vec::new();
        write_frame_bytes(&mut bad, &[0xFF, 0xFE, 0xFD]).unwrap();
        assert!(read_json_frame(&mut bad.as_slice(), None).is_err());
    }

    #[test]
    fn oversize_rejected_both_directions() {
        let mut buf = Vec::new();
        assert!(write_frame_bytes(&mut buf, &vec![0u8; MAX_FRAME + 1]).is_err());
        assert!(buf.is_empty(), "nothing must hit the wire");
        let wire = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_frame_bytes(&mut (&wire[..] as &[u8]), None).is_err());
    }

    /// A reader stuck mid-frame: yields a partial frame, then times out
    /// forever -- the shape of a hung peer behind a socket read timeout.
    struct HungReader {
        data: Vec<u8>,
        pos: usize,
    }

    impl Read for HungReader {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            if self.pos >= self.data.len() {
                return Err(std::io::Error::from(std::io::ErrorKind::WouldBlock));
            }
            let n = (self.data.len() - self.pos).min(buf.len());
            buf[..n].copy_from_slice(&self.data[self.pos..self.pos + n]);
            self.pos += n;
            Ok(n)
        }
    }

    #[test]
    fn mid_frame_stall_errors_at_the_deadline() {
        // 100-byte length prefix but only 3 payload bytes ever arrive
        let mut data = 100u32.to_le_bytes().to_vec();
        data.extend_from_slice(b"abc");
        let mut r = HungReader { data, pos: 0 };
        let deadline = Instant::now() + Duration::from_millis(30);
        let t0 = Instant::now();
        let err = read_frame_bytes(&mut r, Some(deadline)).unwrap_err();
        assert!(err.to_string().contains("mid-frame"), "{err}");
        assert!(t0.elapsed() < Duration::from_secs(5), "deadline not honored");
    }

    #[test]
    fn boundary_stall_is_a_tick_not_an_error() {
        let mut r = HungReader { data: Vec::new(), pos: 0 };
        assert!(matches!(
            read_frame_bytes(&mut r, None).unwrap(),
            RawFrame::TimedOut
        ));
    }
}
