//! Training-stability telemetry: per-step, per-layer statistics of the
//! native fixed-point trainer.
//!
//! The source paper attributes fixed-point training failure to gradient
//! noise interacting with limited-precision updates; Li et al. (PAPERS.md)
//! make that quantitative through the ratio of the typical weight update
//! to the weight grid's quantization step.  This module records exactly
//! those quantities each step:
//!
//! * `loss` -- the step's batch loss;
//! * per layer: gradient L2 norm, update L2 norm (`lr * mask * velocity`,
//!   i.e. what is actually subtracted from the weights), the mean
//!   |update| / weight-quantization-step ratio (the Li et al. collapse
//!   indicator), and saturation counts from the simulated-quantization
//!   clamps -- weight clips from the stochastic-rounding snap in the SGD
//!   update, activation clips from the forward pass's activation
//!   quantizers (both harvested via
//!   [`fixedpoint::vector::quantize_slice_counted`], whose numerics and
//!   RNG stream are definitionally identical to the non-counting path).
//!
//! ## Determinism contract
//!
//! Every number here is bit-identical for any `--threads` count, just
//! like the loss history:
//!
//! * L2 norms and update sums are accumulated serially, in index order,
//!   inside the single worker that owns the layer (layers are never
//!   split across update workers), so the float reduction order is
//!   fixed;
//! * saturation counters are u64 element tallies; the forward pass sums
//!   one partial count per activation shard, and integer addition is
//!   associative, so any chunking yields the same total;
//! * telemetry consumes zero RNG draws and never writes to tensors, so
//!   enabling it cannot change what a session trains.
//!
//! [`TelemetryLog::to_json`] serialises f32 stats through exact f64
//! widening and the repo's shortest-round-trip JSON formatting, so two
//! runs agree byte-for-byte iff they agree bit-for-bit.

use crate::util::json::Json;

/// One layer's statistics for one training step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerStepStats {
    /// false for frozen layers (update mask 0): no gradient was applied,
    /// every other field is zero
    pub active: bool,
    /// true when the layer's weights are quantized (a weight QFormat is
    /// in effect); `upd_to_step` and `sat_w` are only meaningful then
    pub quantized: bool,
    /// L2 norm of the layer's (weight + bias) gradient
    pub grad_l2: f32,
    /// L2 norm of the applied update `lr * mask * velocity`
    pub update_l2: f32,
    /// mean |weight update| / weight quantization step (Li et al.);
    /// 0 when the layer's weights are float or frozen
    pub upd_to_step: f32,
    /// weight elements clipped by the post-update quantization snap
    pub sat_w: u64,
    /// activation elements clipped by this layer's activation quantizer
    /// during the step's forward pass
    pub sat_a: u64,
    /// weight elements quantized (denominator for `sat_w`)
    pub n_w: u64,
    /// activation elements quantized (denominator for `sat_a`)
    pub n_a: u64,
}

/// One training step's record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepStats {
    /// global step index (1-based: the value of `global_step()` after
    /// the step ran)
    pub step: usize,
    pub loss: f32,
    pub layers: Vec<LayerStepStats>,
}

impl StepStats {
    /// Fraction of quantized elements (weights + activations) clipped
    /// this step, over all layers.  0 when nothing was quantized.
    pub fn sat_rate(&self) -> f64 {
        let (mut sat, mut n) = (0u64, 0u64);
        for l in &self.layers {
            sat += l.sat_w + l.sat_a;
            n += l.n_w + l.n_a;
        }
        if n == 0 {
            0.0
        } else {
            sat as f64 / n as f64
        }
    }

    /// Smallest update-to-quantization-step ratio over active layers
    /// with quantized weights -- the Li et al. "updates vanish beneath
    /// the grid" indicator.  `None` when no such layer exists.
    pub fn min_upd_to_step(&self) -> Option<f32> {
        self.layers
            .iter()
            .filter(|l| l.active && l.quantized)
            .map(|l| l.upd_to_step)
            .fold(None, |m, x| Some(m.map_or(x, |m: f32| m.min(x))))
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("active", Json::from(l.active as usize)),
                    ("quantized", Json::from(l.quantized as usize)),
                    ("grad_l2", Json::Num(l.grad_l2 as f64)),
                    ("update_l2", Json::Num(l.update_l2 as f64)),
                    ("upd_to_step", Json::Num(l.upd_to_step as f64)),
                    ("sat_w", Json::from(l.sat_w as usize)),
                    ("sat_a", Json::from(l.sat_a as usize)),
                    ("n_w", Json::from(l.n_w as usize)),
                    ("n_a", Json::from(l.n_a as usize)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("step", Json::from(self.step)),
            ("loss", Json::Num(self.loss as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

/// An accumulated stream of [`StepStats`] -- one entry per training
/// step, in step order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryLog {
    pub steps: Vec<StepStats>,
}

impl TelemetryLog {
    pub fn push(&mut self, s: StepStats) {
        self.steps.push(s);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.steps.iter().map(StepStats::to_json).collect())
    }
}

/// Steps per trajectory window in [`TelemetrySummary`].  Pinned: the
/// window width shapes every stability report's bytes, so changing it
/// means bumping `report::REPORT_VERSION`.
pub const SUMMARY_WINDOW_STEPS: usize = 25;

/// Fixed quantile probabilities summarizing each window's
/// update-to-step ratios (min / quartiles / max).
pub const SUMMARY_QUANTILES: [f64; 5] = [0.0, 0.25, 0.5, 0.75, 1.0];

/// Serialize a float that may legitimately be non-finite (a NaN-loss
/// abort records the NaN step): finite values stay JSON numbers,
/// non-finite become the strings `"nan"` / `"inf"` / `"-inf"` so the
/// output is always valid JSON and still deterministic.
pub(crate) fn num_json(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else if v.is_nan() {
        Json::from("nan")
    } else if v > 0.0 {
        Json::from("inf")
    } else {
        Json::from("-inf")
    }
}

pub(crate) fn num_from_json(j: &Json) -> crate::error::Result<f64> {
    match j {
        Json::Num(n) => Ok(*n),
        Json::Str(s) => match s.as_str() {
            "nan" => Ok(f64::NAN),
            "inf" => Ok(f64::INFINITY),
            "-inf" => Ok(f64::NEG_INFINITY),
            _ => Err(crate::error::FxpError::Json(format!(
                "not a number: \"{s}\""
            ))),
        },
        other => {
            Err(crate::error::FxpError::Json(format!("not a number: {other}")))
        }
    }
}

fn opt_f32_json(v: Option<f32>) -> Json {
    match v {
        Some(x) => num_json(x as f64),
        None => Json::Null,
    }
}

fn opt_f32_from_json(j: &Json) -> crate::error::Result<Option<f32>> {
    match j {
        Json::Null => Ok(None),
        other => Ok(Some(num_from_json(other)? as f32)),
    }
}

/// Linear-interpolation quantiles of an already-sorted slice at the
/// [`SUMMARY_QUANTILES`] probabilities: index `p * (n-1)` between
/// neighbours.  With `n == 1` every quantile is the single value; with
/// all-equal inputs every quantile equals that value exactly (the
/// interpolation `lo + (hi-lo)*frac` is `lo` when `hi == lo`).
pub(crate) fn quantiles(sorted: &[f64]) -> Vec<f64> {
    SUMMARY_QUANTILES
        .iter()
        .map(|&q| {
            let pos = q * (sorted.len() - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let frac = pos - lo as f64;
            sorted[lo] + (sorted[hi] - sorted[lo]) * frac
        })
        .collect()
}

/// Quantile summary of the update-to-step ratios over one pinned window
/// of [`SUMMARY_WINDOW_STEPS`] consecutive steps.
#[derive(Clone, Debug, PartialEq)]
pub struct WindowSummary {
    /// global step of the window's first record
    pub start_step: usize,
    /// global step of the window's last record (inclusive)
    pub end_step: usize,
    /// steps in the window that produced a ratio (active quantized
    /// layers existed); `ratio_q` is empty when this is 0
    pub count: usize,
    /// [`SUMMARY_QUANTILES`] of the per-step `min_upd_to_step` ratios
    pub ratio_q: Vec<f64>,
}

impl WindowSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("start_step", Json::from(self.start_step)),
            ("end_step", Json::from(self.end_step)),
            ("count", Json::from(self.count)),
            ("ratio_q", Json::Arr(self.ratio_q.iter().map(|&r| num_json(r)).collect())),
        ])
    }

    pub fn from_json(j: &Json) -> crate::error::Result<WindowSummary> {
        Ok(WindowSummary {
            start_step: j.get("start_step")?.as_usize()?,
            end_step: j.get("end_step")?.as_usize()?,
            count: j.get("count")?.as_usize()?,
            ratio_q: j
                .get("ratio_q")?
                .as_arr()?
                .iter()
                .map(num_from_json)
                .collect::<crate::error::Result<_>>()?,
        })
    }
}

/// Compact per-run digest of a [`TelemetryLog`]: what the stability
/// report persists per cell instead of the raw per-step stream.
///
/// Everything here is a deterministic pure function of the log, which is
/// itself bit-identical for any `--threads` count -- so two summaries
/// agree byte-for-byte iff the runs agreed bit-for-bit.  `loss_start`
/// uses the same "mean of the first <= 5 losses" baseline as
/// [`AbortPolicy`](crate::coordinator::trainer::AbortPolicy)'s blow-up
/// predicate, so thresholds learned from summaries compare
/// apples-to-apples with what the live watcher will see.
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetrySummary {
    /// steps recorded (== steps executed: the sink sees every step)
    pub steps: usize,
    /// mean of the first <= 5 losses (the abort watcher's baseline)
    pub loss_start: f32,
    /// highest finite loss observed
    pub loss_peak: f32,
    /// last recorded loss (NaN when the run died on a NaN step)
    pub loss_final: f32,
    /// saturation rate of the final step
    pub sat_final: f64,
    /// highest per-step saturation rate over the run
    pub sat_peak: f64,
    /// smallest per-step `min_upd_to_step` over the run; `None` when no
    /// step had an active quantized layer
    pub ratio_min: Option<f32>,
    /// final step's `min_upd_to_step`
    pub ratio_final: Option<f32>,
    /// ratio-trajectory quantiles over pinned step windows
    pub windows: Vec<WindowSummary>,
}

impl TelemetrySummary {
    /// Digest a telemetry log; `None` for an empty log (a regime that
    /// never trained, e.g. no-finetune / Proposal 1 cells).
    pub fn summarize(log: &TelemetryLog) -> Option<TelemetrySummary> {
        if log.is_empty() {
            return None;
        }
        let head: Vec<f32> =
            log.steps.iter().take(5).map(|s| s.loss).collect();
        let loss_start = head.iter().sum::<f32>() / head.len() as f32;
        // f32::max ignores a NaN operand, so NaN-loss steps (recorded,
        // then the run dies) cannot poison the peak
        let loss_peak = log
            .steps
            .iter()
            .map(|s| s.loss)
            .fold(f32::NEG_INFINITY, f32::max);
        let last = log.steps.last().expect("non-empty");
        let sat_final = last.sat_rate();
        let sat_peak = log
            .steps
            .iter()
            .map(StepStats::sat_rate)
            .fold(0.0f64, f64::max);
        let ratio_min = log
            .steps
            .iter()
            .filter_map(StepStats::min_upd_to_step)
            .fold(None, |m: Option<f32>, x| Some(m.map_or(x, |m| m.min(x))));
        let mut windows = Vec::new();
        for chunk in log.steps.chunks(SUMMARY_WINDOW_STEPS) {
            let mut rs: Vec<f64> = chunk
                .iter()
                .filter_map(StepStats::min_upd_to_step)
                .map(|r| r as f64)
                .collect();
            rs.sort_by(f64::total_cmp);
            windows.push(WindowSummary {
                start_step: chunk[0].step,
                end_step: chunk[chunk.len() - 1].step,
                count: rs.len(),
                ratio_q: if rs.is_empty() { Vec::new() } else { quantiles(&rs) },
            });
        }
        Some(TelemetrySummary {
            steps: log.len(),
            loss_start,
            loss_peak,
            loss_final: last.loss,
            sat_final,
            sat_peak,
            ratio_min,
            ratio_final: last.min_upd_to_step(),
            windows,
        })
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("steps", Json::from(self.steps)),
            ("loss_start", num_json(self.loss_start as f64)),
            ("loss_peak", num_json(self.loss_peak as f64)),
            ("loss_final", num_json(self.loss_final as f64)),
            ("sat_final", num_json(self.sat_final)),
            ("sat_peak", num_json(self.sat_peak)),
            ("ratio_min", opt_f32_json(self.ratio_min)),
            ("ratio_final", opt_f32_json(self.ratio_final)),
            (
                "windows",
                Json::Arr(self.windows.iter().map(WindowSummary::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> crate::error::Result<TelemetrySummary> {
        Ok(TelemetrySummary {
            steps: j.get("steps")?.as_usize()?,
            loss_start: num_from_json(j.get("loss_start")?)? as f32,
            loss_peak: num_from_json(j.get("loss_peak")?)? as f32,
            loss_final: num_from_json(j.get("loss_final")?)? as f32,
            sat_final: num_from_json(j.get("sat_final")?)?,
            sat_peak: num_from_json(j.get("sat_peak")?)?,
            ratio_min: opt_f32_from_json(j.get("ratio_min")?)?,
            ratio_final: opt_f32_from_json(j.get("ratio_final")?)?,
            windows: j
                .get("windows")?
                .as_arr()?
                .iter()
                .map(WindowSummary::from_json)
                .collect::<crate::error::Result<_>>()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(active: bool, quantized: bool, sat: u64, n: u64, r: f32) -> LayerStepStats {
        LayerStepStats {
            active,
            quantized,
            grad_l2: 1.0,
            update_l2: 0.5,
            upd_to_step: r,
            sat_w: sat,
            sat_a: 0,
            n_w: n,
            n_a: 0,
        }
    }

    #[test]
    fn sat_rate_and_min_ratio() {
        let s = StepStats {
            step: 3,
            loss: 2.0,
            layers: vec![
                layer(true, true, 5, 10, 0.2),
                layer(true, true, 0, 10, 0.05),
                layer(false, true, 0, 0, 0.0),  // frozen: ignored by min
                layer(true, false, 0, 0, 0.0),  // float: ignored by min
            ],
        };
        assert_eq!(s.sat_rate(), 0.25);
        assert_eq!(s.min_upd_to_step(), Some(0.05));
        let empty = StepStats { step: 1, loss: 0.0, layers: vec![] };
        assert_eq!(empty.sat_rate(), 0.0);
        assert_eq!(empty.min_upd_to_step(), None);
    }

    #[test]
    fn json_round_trips_and_is_deterministic() {
        let mut log = TelemetryLog::default();
        log.push(StepStats {
            step: 1,
            loss: 0.1 + 0.2,
            layers: vec![layer(true, true, 1, 4, 0.125)],
        });
        let a = log.to_json().to_string();
        let b = log.clone().to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        let steps = parsed.as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("step").unwrap().as_usize().unwrap(), 1);
        // f32 -> f64 widening is exact, so the loss round-trips bit-exactly
        let loss = steps[0].get("loss").unwrap().as_f64().unwrap();
        assert_eq!(loss as f32, 0.1f32 + 0.2f32);
    }

    fn log_of(ratios: &[f32]) -> TelemetryLog {
        let mut log = TelemetryLog::default();
        for (i, &r) in ratios.iter().enumerate() {
            log.push(StepStats {
                step: i + 1,
                loss: 2.0 - 0.01 * i as f32,
                layers: vec![layer(true, true, i as u64, 10, r)],
            });
        }
        log
    }

    #[test]
    fn summary_of_empty_log_is_none() {
        assert_eq!(TelemetrySummary::summarize(&TelemetryLog::default()), None);
    }

    #[test]
    fn quantiles_single_sample_all_equal() {
        // n = 1: every quantile is the single value
        let s = TelemetrySummary::summarize(&log_of(&[0.25])).unwrap();
        assert_eq!(s.steps, 1);
        assert_eq!(s.windows.len(), 1);
        assert_eq!(s.windows[0].count, 1);
        assert_eq!(s.windows[0].ratio_q, vec![0.25; 5]);
        assert_eq!(s.ratio_min, Some(0.25));
        assert_eq!(s.ratio_final, Some(0.25));
        // all-equal: interpolation collapses to the common value exactly
        let s = TelemetrySummary::summarize(&log_of(&[0.5; 7])).unwrap();
        assert_eq!(s.windows[0].ratio_q, vec![0.5; 5]);
    }

    #[test]
    fn quantiles_interpolate_and_window_split() {
        // 30 steps: windows [1..25] and [26..30]
        let ratios: Vec<f32> = (0..30).map(|i| i as f32).collect();
        let s = TelemetrySummary::summarize(&log_of(&ratios)).unwrap();
        assert_eq!(s.windows.len(), 2);
        assert_eq!((s.windows[0].start_step, s.windows[0].end_step), (1, 25));
        assert_eq!((s.windows[1].start_step, s.windows[1].end_step), (26, 30));
        assert_eq!(s.windows[0].count, 25);
        assert_eq!(s.windows[1].count, 5);
        // window 1 holds 0..=24 sorted: min 0, median 12, max 24
        assert_eq!(s.windows[0].ratio_q[0], 0.0);
        assert_eq!(s.windows[0].ratio_q[2], 12.0);
        assert_eq!(s.windows[0].ratio_q[4], 24.0);
        // quartile of 25 values: index 0.25 * 24 = 6 exactly
        assert_eq!(s.windows[0].ratio_q[1], 6.0);
        // window 2 holds 25..=29: quartile interpolates at index 1.0
        assert_eq!(s.windows[1].ratio_q[1], 26.0);
        assert_eq!(s.ratio_min, Some(0.0));
        assert_eq!(s.ratio_final, Some(29.0));
    }

    #[test]
    fn summary_loss_baseline_matches_abort_watch() {
        // loss_start = mean of the first <= 5 losses, in f32, exactly as
        // AbortWatch computes its blow-up baseline
        let s = TelemetrySummary::summarize(&log_of(&[0.1; 8])).unwrap();
        let head: Vec<f32> = (0..5).map(|i| 2.0 - 0.01 * i as f32).collect();
        let expect = head.iter().sum::<f32>() / 5.0;
        assert_eq!(s.loss_start, expect);
        assert_eq!(s.loss_peak, 2.0);
        assert_eq!(s.loss_final, 2.0 - 0.01 * 7.0);
    }

    #[test]
    fn summary_json_round_trips_including_nan() {
        let mut log = log_of(&[0.2, 0.3]);
        log.push(StepStats { step: 3, loss: f32::NAN, layers: vec![] });
        let s = TelemetrySummary::summarize(&log).unwrap();
        assert!(s.loss_final.is_nan());
        assert_eq!(s.loss_peak, 2.0); // NaN ignored by the peak
        assert_eq!(s.ratio_final, None); // layer-less final step
        let text = s.to_json().to_string();
        let back =
            TelemetrySummary::from_json(&Json::parse(&text).unwrap()).unwrap();
        // NaN != NaN, so compare through the serialized form
        assert_eq!(back.to_json().to_string(), text);
        assert!(back.loss_final.is_nan());
    }
}
