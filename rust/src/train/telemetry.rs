//! Training-stability telemetry: per-step, per-layer statistics of the
//! native fixed-point trainer.
//!
//! The source paper attributes fixed-point training failure to gradient
//! noise interacting with limited-precision updates; Li et al. (PAPERS.md)
//! make that quantitative through the ratio of the typical weight update
//! to the weight grid's quantization step.  This module records exactly
//! those quantities each step:
//!
//! * `loss` -- the step's batch loss;
//! * per layer: gradient L2 norm, update L2 norm (`lr * mask * velocity`,
//!   i.e. what is actually subtracted from the weights), the mean
//!   |update| / weight-quantization-step ratio (the Li et al. collapse
//!   indicator), and saturation counts from the simulated-quantization
//!   clamps -- weight clips from the stochastic-rounding snap in the SGD
//!   update, activation clips from the forward pass's activation
//!   quantizers (both harvested via
//!   [`fixedpoint::vector::quantize_slice_counted`], whose numerics and
//!   RNG stream are definitionally identical to the non-counting path).
//!
//! ## Determinism contract
//!
//! Every number here is bit-identical for any `--threads` count, just
//! like the loss history:
//!
//! * L2 norms and update sums are accumulated serially, in index order,
//!   inside the single worker that owns the layer (layers are never
//!   split across update workers), so the float reduction order is
//!   fixed;
//! * saturation counters are u64 element tallies; the forward pass sums
//!   one partial count per activation shard, and integer addition is
//!   associative, so any chunking yields the same total;
//! * telemetry consumes zero RNG draws and never writes to tensors, so
//!   enabling it cannot change what a session trains.
//!
//! [`TelemetryLog::to_json`] serialises f32 stats through exact f64
//! widening and the repo's shortest-round-trip JSON formatting, so two
//! runs agree byte-for-byte iff they agree bit-for-bit.

use crate::util::json::Json;

/// One layer's statistics for one training step.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LayerStepStats {
    /// false for frozen layers (update mask 0): no gradient was applied,
    /// every other field is zero
    pub active: bool,
    /// true when the layer's weights are quantized (a weight QFormat is
    /// in effect); `upd_to_step` and `sat_w` are only meaningful then
    pub quantized: bool,
    /// L2 norm of the layer's (weight + bias) gradient
    pub grad_l2: f32,
    /// L2 norm of the applied update `lr * mask * velocity`
    pub update_l2: f32,
    /// mean |weight update| / weight quantization step (Li et al.);
    /// 0 when the layer's weights are float or frozen
    pub upd_to_step: f32,
    /// weight elements clipped by the post-update quantization snap
    pub sat_w: u64,
    /// activation elements clipped by this layer's activation quantizer
    /// during the step's forward pass
    pub sat_a: u64,
    /// weight elements quantized (denominator for `sat_w`)
    pub n_w: u64,
    /// activation elements quantized (denominator for `sat_a`)
    pub n_a: u64,
}

/// One training step's record.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepStats {
    /// global step index (1-based: the value of `global_step()` after
    /// the step ran)
    pub step: usize,
    pub loss: f32,
    pub layers: Vec<LayerStepStats>,
}

impl StepStats {
    /// Fraction of quantized elements (weights + activations) clipped
    /// this step, over all layers.  0 when nothing was quantized.
    pub fn sat_rate(&self) -> f64 {
        let (mut sat, mut n) = (0u64, 0u64);
        for l in &self.layers {
            sat += l.sat_w + l.sat_a;
            n += l.n_w + l.n_a;
        }
        if n == 0 {
            0.0
        } else {
            sat as f64 / n as f64
        }
    }

    /// Smallest update-to-quantization-step ratio over active layers
    /// with quantized weights -- the Li et al. "updates vanish beneath
    /// the grid" indicator.  `None` when no such layer exists.
    pub fn min_upd_to_step(&self) -> Option<f32> {
        self.layers
            .iter()
            .filter(|l| l.active && l.quantized)
            .map(|l| l.upd_to_step)
            .fold(None, |m, x| Some(m.map_or(x, |m: f32| m.min(x))))
    }

    pub fn to_json(&self) -> Json {
        let layers = self
            .layers
            .iter()
            .map(|l| {
                Json::obj(vec![
                    ("active", Json::from(l.active as usize)),
                    ("quantized", Json::from(l.quantized as usize)),
                    ("grad_l2", Json::Num(l.grad_l2 as f64)),
                    ("update_l2", Json::Num(l.update_l2 as f64)),
                    ("upd_to_step", Json::Num(l.upd_to_step as f64)),
                    ("sat_w", Json::from(l.sat_w as usize)),
                    ("sat_a", Json::from(l.sat_a as usize)),
                    ("n_w", Json::from(l.n_w as usize)),
                    ("n_a", Json::from(l.n_a as usize)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("step", Json::from(self.step)),
            ("loss", Json::Num(self.loss as f64)),
            ("layers", Json::Arr(layers)),
        ])
    }
}

/// An accumulated stream of [`StepStats`] -- one entry per training
/// step, in step order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TelemetryLog {
    pub steps: Vec<StepStats>,
}

impl TelemetryLog {
    pub fn push(&mut self, s: StepStats) {
        self.steps.push(s);
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    pub fn to_json(&self) -> Json {
        Json::Arr(self.steps.iter().map(StepStats::to_json).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(active: bool, quantized: bool, sat: u64, n: u64, r: f32) -> LayerStepStats {
        LayerStepStats {
            active,
            quantized,
            grad_l2: 1.0,
            update_l2: 0.5,
            upd_to_step: r,
            sat_w: sat,
            sat_a: 0,
            n_w: n,
            n_a: 0,
        }
    }

    #[test]
    fn sat_rate_and_min_ratio() {
        let s = StepStats {
            step: 3,
            loss: 2.0,
            layers: vec![
                layer(true, true, 5, 10, 0.2),
                layer(true, true, 0, 10, 0.05),
                layer(false, true, 0, 0, 0.0),  // frozen: ignored by min
                layer(true, false, 0, 0, 0.0),  // float: ignored by min
            ],
        };
        assert_eq!(s.sat_rate(), 0.25);
        assert_eq!(s.min_upd_to_step(), Some(0.05));
        let empty = StepStats { step: 1, loss: 0.0, layers: vec![] };
        assert_eq!(empty.sat_rate(), 0.0);
        assert_eq!(empty.min_upd_to_step(), None);
    }

    #[test]
    fn json_round_trips_and_is_deterministic() {
        let mut log = TelemetryLog::default();
        log.push(StepStats {
            step: 1,
            loss: 0.1 + 0.2,
            layers: vec![layer(true, true, 1, 4, 0.125)],
        });
        let a = log.to_json().to_string();
        let b = log.clone().to_json().to_string();
        assert_eq!(a, b);
        let parsed = Json::parse(&a).unwrap();
        let steps = parsed.as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("step").unwrap().as_usize().unwrap(), 1);
        // f32 -> f64 widening is exact, so the loss round-trips bit-exactly
        let loss = steps[0].get("loss").unwrap().as_f64().unwrap();
        assert_eq!(loss as f32, 0.1f32 + 0.2f32);
    }
}
