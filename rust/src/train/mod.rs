//! The native fixed-point training backend: pure-Rust backprop + SGD
//! with stochastic-rounding weight updates, zero external dependencies.
//!
//! This is the offline twin of the XLA `train_step` path.  The forward/
//! backward math lives in [`net`] (simulated quantization, STE
//! gradients, reusing the PR 2 GEMM microkernel at f32); this module
//! adds the paper's *training* semantics on top:
//!
//! * **Stochastic-rounding SGD** (Gupta et al. 2015, "Deep Learning with
//!   Limited Numerical Precision"): after the momentum update, each
//!   quantized layer's weights are rounded back onto their Q-format grid
//!   with `floor(x/step + u)` dither -- the unbiased rounding that makes
//!   sub-step gradients accumulate in expectation instead of vanishing,
//!   which is what lets fixed-point training converge at all (the
//!   convergence behaviour matches the theory in Li et al., "Training
//!   Quantized Nets: A Deeper Understanding").  The dither streams are
//!   *pre-split*: layer `li` of step `s` draws from its own [`Rng`]
//!   seeded by `(session seed, s, li)`, so the per-layer updates can run
//!   on `--threads` scoped workers in any schedule without changing the
//!   draws any layer sees.
//! * **Per-layer update masks** -- Proposal 2 (top layers only) and
//!   Proposal 3 (one layer per phase) freeze weights through the same
//!   `upd` vector the XLA graphs consume.
//! * **Float-activation mode** -- Proposal 1 trains with quantized
//!   weights but float activations; here that is just `NetQuant` with
//!   `acts = None`, no special case.
//!
//! Determinism contract: a session's whole loss history is a pure
//! function of `(arch, params, NetQuant, data seed, session seed)` --
//! never of `--threads` (the GEMM/gradient sharding has a fixed
//! accumulation order, see [`net`], and the rounding streams are
//! pre-split per step and layer) nor of `--workers`/shard layout (the
//! rounding RNG is seeded per cell through the grid's seed tree).
//! Pinned by rust/tests/train_native.rs.
//!
//! Evaluation: fully quantized cells report the *deployment-grade*
//! number -- the trained f32 net is quantized with the cell's
//! calibration and run through the batched zero-alloc integer GEMM
//! engine ([`crate::inference::FixedPointNet`] via
//! [`crate::coordinator::evaluator::evaluate_int_batched`]).  Cells with
//! float weights or float hidden activations cannot run on the integer
//! engine and fall back to the simulated-quantization float forward
//! ([`NativeBackend::evaluate_simulated`]).

pub mod net;
pub mod telemetry;

use std::collections::BTreeMap;

use crate::coordinator::backend::{Backend, SessionCfg};
use crate::coordinator::evaluator::{self, metrics_from_logits, EvalResult};
use crate::coordinator::trainer::TrainSession;
use crate::data::loader::Loader;
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::fixedpoint::vector::quantize_slice_counted;
use crate::fixedpoint::{QFormat, RoundMode};
use crate::inference::FixedPointNet;
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::model::zoo;
use crate::quant::calib::LayerStats;
use crate::quant::policy::NetQuant;
use crate::tensor::{Tensor, TensorF};
use crate::train::telemetry::{LayerStepStats, StepStats};
use crate::util::rng::{derive_seed, Rng};

pub use net::NativeNet;

/// The native backend: a stateless arch registry; every session owns its
/// complete training state, so one backend instance can serve any number
/// of sequential sessions (sweep workers build one each).
pub struct NativeBackend {
    archs: BTreeMap<String, ArchSpec>,
    /// GEMM row-block workers for evaluation/calibration forwards (and
    /// the default for sessions opened through this backend).  Purely a
    /// performance knob: results are bit-identical for every value.
    threads: usize,
}

impl NativeBackend {
    /// Registry over the built-in paper architectures ([`zoo`]).
    pub fn new() -> NativeBackend {
        NativeBackend { archs: zoo::builtin_archs(), threads: 1 }
    }

    /// Add (or override) an architecture -- tests and benches inject
    /// custom shapes this way.
    pub fn with_arch(mut self, spec: ArchSpec) -> NativeBackend {
        self.archs.insert(spec.name.clone(), spec);
        self
    }

    /// Set the GEMM row-block worker count used by evaluation and
    /// calibration (0 and 1 both mean serial).
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = threads.max(1);
        self
    }

    /// Evaluate through the *simulated-quantization float forward*
    /// ([`NativeNet`]) -- the training-time semantics.  Cells with float
    /// weights or float hidden activations can only run here; fully
    /// quantized cells normally take the integer engine instead (see
    /// [`Backend::evaluate`]), and the pinned agreement between the two
    /// paths is tested in rust/tests/eval_int_native.rs.
    pub fn evaluate_simulated(
        &self,
        arch: &str,
        params: &ParamSet,
        nq: &NetQuant,
        data: &Dataset,
    ) -> Result<EvalResult> {
        let spec = self.arch(arch)?;
        let chunk = spec.eval_batch.max(1);
        let mut net = NativeNet::build_threaded(&spec, chunk, self.threads)?;
        net.set_weights(params, nq)?;
        let total = data.len();
        let nc = spec.num_classes;
        let img_len = spec.input[0] * spec.input[1] * spec.input[2];
        let mut logits = vec![0f32; total * nc];
        let mut i = 0usize;
        while i < total {
            let n = chunk.min(total - i);
            // contiguous row range of the row-major dataset tensor
            let images = &data.images.data()[i * img_len..(i + n) * img_len];
            let lg = net.forward(images, n)?;
            logits[i * nc..(i + n) * nc].copy_from_slice(lg);
            i += n;
        }
        let logits = Tensor::from_vec(&[total, nc], logits)?;
        metrics_from_logits(&logits, data.labels.data())
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn supports_fresh_init(&self) -> bool {
        true
    }

    fn arch(&self, name: &str) -> Result<ArchSpec> {
        self.archs.get(name).cloned().ok_or_else(|| {
            FxpError::config(format!(
                "native backend has no arch '{name}' (have: {})",
                self.archs.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    fn new_session(&self, cfg: SessionCfg<'_>) -> Result<Box<dyn TrainSession>> {
        let spec = self.arch(cfg.arch)?;
        Ok(Box::new(NativeTrainer::new(&spec, cfg)?))
    }

    /// Fully quantized cells report the *deployment-grade* number: the
    /// trained f32 parameters are quantized with the cell's calibration
    /// and evaluated on the batched zero-alloc integer GEMM engine.
    /// Cells the integer engine cannot express (float weights or float
    /// hidden activations) fall back to the simulated-quantization float
    /// forward ([`NativeBackend::evaluate_simulated`]).
    fn evaluate(
        &self,
        arch: &str,
        params: &ParamSet,
        nq: &NetQuant,
        data: &Dataset,
    ) -> Result<EvalResult> {
        if nq.integer_deployable() {
            let spec = self.arch(arch)?;
            // Q16.14 input codes: negligible input error next to the
            // 4-16 bit layer formats (same choice as `fxpnet infer`)
            let net =
                FixedPointNet::build(&spec, params, nq, QFormat::new(16, 14)?)?;
            return evaluator::evaluate_int_batched(
                &net,
                data,
                spec.eval_batch.max(1),
                self.threads,
            );
        }
        self.evaluate_simulated(arch, params, nq, data)
    }

    fn activation_stats(
        &self,
        arch: &str,
        params: &ParamSet,
        data: &Dataset,
        batches: usize,
    ) -> Result<Vec<LayerStats>> {
        let spec = self.arch(arch)?;
        let l = spec.num_layers;
        let chunk = spec.eval_batch.max(1);
        let mut net = NativeNet::build_threaded(&spec, chunk, self.threads)?;
        // calibration always measures the *float* network
        net.set_weights(params, &NetQuant::all_float(l))?;
        let mut absmax = vec![0f32; l];
        let mut meanabs = vec![0f64; l];
        let mut meansq = vec![0f64; l];
        let img_len = spec.input[0] * spec.input[1] * spec.input[2];
        let mut used = 0usize;
        let mut i = 0usize;
        while i < data.len() && used < batches.max(1) {
            let n = chunk.min(data.len() - i);
            let images = &data.images.data()[i * img_len..(i + n) * img_len];
            net.forward(images, n)?;
            for li in 0..l {
                let a = net.layer_activation(li, n);
                let count = a.len().max(1) as f64;
                let mut am = 0f32;
                let mut ma = 0f64;
                let mut ms = 0f64;
                for &v in a {
                    am = am.max(v.abs());
                    ma += v.abs() as f64;
                    ms += (v as f64) * (v as f64);
                }
                absmax[li] = absmax[li].max(am);
                meanabs[li] += ma / count;
                meansq[li] += ms / count;
            }
            used += 1;
            i += n;
        }
        let used = used.max(1) as f64;
        Ok((0..l)
            .map(|li| LayerStats {
                absmax: absmax[li],
                meanabs: (meanabs[li] / used) as f32,
                meansq: (meansq[li] / used) as f32,
            })
            .collect())
    }
}

/// One native fine-tuning session (the [`TrainSession`] the regimes
/// drive).  Owns the float-master/grid-resident parameters, momentum
/// buffers, gradient buffers, the prefetching data loader, and the seed
/// of the pre-split stochastic-rounding streams.
pub struct NativeTrainer {
    net: NativeNet,
    params: ParamSet,
    vel: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    nq: NetQuant,
    upd: Vec<f32>,
    lr: f32,
    momentum: f32,
    loader: Loader,
    /// root of the per-(step, layer) stochastic-rounding streams
    seed: u64,
    /// scoped workers for the step's GEMMs/gradients and the per-layer
    /// optimizer updates; bit-identical results for every value
    threads: usize,
    max_loss: f32,
    batch: usize,
    step: usize,
    /// collect per-layer [`StepStats`] each step (off by default: the
    /// L2-norm passes cost a little; the saturation tallies are free)
    telemetry: bool,
    last_stats: Option<StepStats>,
}

impl NativeTrainer {
    /// Build a session for `spec` starting from `cfg.params` (momenta
    /// zero).  Mirrors `Trainer::new`'s batch-size contract.
    pub fn new(spec: &ArchSpec, cfg: SessionCfg<'_>) -> Result<NativeTrainer> {
        if cfg.loader.batch != spec.train_batch {
            return Err(FxpError::config(format!(
                "loader batch {} != arch train batch {}",
                cfg.loader.batch, spec.train_batch
            )));
        }
        if cfg.upd.len() != spec.num_layers {
            return Err(FxpError::config(format!(
                "update mask has {} entries, arch {} layers",
                cfg.upd.len(),
                spec.num_layers
            )));
        }
        if cfg.params.len() != 2 * spec.num_layers {
            return Err(FxpError::config(format!(
                "{} param tensors, arch needs {}",
                cfg.params.len(),
                2 * spec.num_layers
            )));
        }
        let threads = cfg.threads.max(1);
        let net = NativeNet::build_threaded(spec, cfg.loader.batch, threads)?;
        let vel: Vec<Vec<f32>> = cfg
            .params
            .tensors
            .iter()
            .map(|t| vec![0f32; t.len()])
            .collect();
        let grads = vel.clone();
        let batch = cfg.loader.batch;
        let loader = Loader::spawn(cfg.data, cfg.loader);
        Ok(NativeTrainer {
            net,
            params: cfg.params.clone(),
            vel,
            grads,
            nq: cfg.nq.clone(),
            upd: cfg.upd.to_vec(),
            lr: cfg.lr,
            momentum: cfg.momentum,
            loader,
            seed: cfg.seed,
            threads,
            max_loss: cfg.max_loss,
            batch,
            step: 0,
            telemetry: false,
            last_stats: None,
        })
    }

    /// Override the kernel facade of the session's net (see
    /// [`NativeNet::set_kernels`]): a bench/test seam for scalar-vs-SIMD
    /// comparisons; results are bit-identical for every ISA.
    pub fn set_kernels(&mut self, kernels: &'static crate::inference::Kernels) {
        self.net.set_kernels(kernels);
    }
}

/// One layer's momentum + SGD update over its `[w, b]` tensor/velocity
/// pairs, with the Gupta-style stochastic snap of the weights back onto
/// their fixed-point grid.  `rng_seed` keys this layer's own pre-split
/// dither stream, so layers can update on any worker in any schedule
/// without changing the draws any one of them sees.
///
/// When `stats` is given, the layer's telemetry is filled in: gradient
/// and update L2 norms (f64 accumulation in index order over the layer's
/// own slices -- the reduction order never depends on thread count), the
/// mean |weight update| / quantization-step ratio of Li et al., and the
/// clip tally of the stochastic snap.  Collection reads values the
/// update computes anyway and consumes no RNG, so a session trains
/// identically with or without it.
#[allow(clippy::too_many_arguments)]
fn update_layer(
    tensors: &mut [TensorF],
    vel: &mut [Vec<f32>],
    gw: &[f32],
    gb: &[f32],
    mask: f32,
    lr: f32,
    mu: f32,
    w_fmt: Option<QFormat>,
    rng_seed: u64,
    stats: Option<&mut LayerStepStats>,
) {
    let collect = stats.is_some();
    let mut grad_sq = 0f64;
    let mut upd_sq = 0f64;
    let mut w_abs_sum = 0f64;
    let mut sat_w = 0u64;
    let mut n_w = 0u64;
    for (ti, g) in [gw, gb].into_iter().enumerate() {
        let v = &mut vel[ti];
        for (vv, &gv) in v.iter_mut().zip(g) {
            *vv = mu * *vv + gv;
        }
        let p = tensors[ti].data_mut();
        for (pv, &vv) in p.iter_mut().zip(v.iter()) {
            *pv -= lr * mask * vv;
        }
        if collect {
            for &gv in g {
                grad_sq += gv as f64 * gv as f64;
            }
            for &vv in v.iter() {
                let u = (lr * mask * vv) as f64;
                upd_sq += u * u;
                if ti == 0 {
                    w_abs_sum += u.abs();
                }
            }
        }
        if ti == 0 {
            if let Some(fmt) = w_fmt {
                // Gupta et al.: the stored weight lives on the
                // fixed-point grid; the update rounds stochastically so
                // sub-step gradients survive in expectation
                let mut rng = Rng::new(rng_seed);
                let sat =
                    quantize_slice_counted(p, fmt, RoundMode::Stochastic, Some(&mut rng));
                if collect {
                    sat_w = sat;
                    n_w = p.len() as u64;
                }
            }
        }
    }
    if let Some(st) = stats {
        st.active = true;
        st.quantized = w_fmt.is_some();
        st.grad_l2 = grad_sq.sqrt() as f32;
        st.update_l2 = upd_sq.sqrt() as f32;
        st.upd_to_step = match w_fmt {
            Some(fmt) if n_w > 0 => {
                ((w_abs_sum / n_w as f64) / fmt.step() as f64) as f32
            }
            _ => 0.0,
        };
        st.sat_w = sat_w;
        st.n_w = n_w;
        // sat_a / n_a come from the net's forward tally (see step())
    }
}

impl TrainSession for NativeTrainer {
    /// One SGD step: quantize weights -> forward -> backward -> per-layer
    /// momentum update + stochastic-rounding snap back onto the weight
    /// grid, the layer updates sharded over scoped workers (each layer
    /// draws from its own pre-split `(seed, step, layer)` stream, so the
    /// history is bit-identical for every thread count).
    fn step(&mut self) -> Result<f32> {
        self.net.set_weights(&self.params, &self.nq)?;
        let b = self.loader.next_batch();
        let n = self.batch;
        self.net.forward(b.images.data(), n)?;
        let loss = self.net.loss(b.labels.data(), n)?;
        self.net.backward(b.labels.data(), n, &self.upd, &mut self.grads)?;
        let (lr, mu) = (self.lr, self.momentum);
        let step_idx = self.step as u64;
        let seed = self.seed;
        let num_layers = self.upd.len();
        // contiguous layer chunks over exactly `threads` workers (not one
        // spawn per layer); each layer's stream is pre-split, so the
        // grouping -- like the thread count -- cannot change the draws
        let workers = self.threads.min(num_layers.max(1));
        let collect = self.telemetry;
        // each worker owns its layers' stats slots (same contiguous
        // chunking as the tensors), and every norm is reduced serially
        // inside update_layer -- so the stats, like the weights, are
        // bit-identical for every thread count
        let mut layer_stats: Vec<LayerStepStats> = if collect {
            vec![LayerStepStats::default(); num_layers]
        } else {
            Vec::new()
        };
        std::thread::scope(|s| {
            let mut tens_rem: &mut [TensorF] = &mut self.params.tensors;
            let mut vel_rem: &mut [Vec<f32>] = &mut self.vel;
            let mut stats_rem: &mut [LayerStepStats] = &mut layer_stats;
            let grads = &self.grads;
            let nq = &self.nq;
            let upd = &self.upd;
            let mut l0 = 0usize;
            for wid in 0..workers {
                let l1 = (wid + 1) * num_layers / workers;
                let count = l1 - l0;
                let (tchunk, tr) = tens_rem.split_at_mut(2 * count);
                tens_rem = tr;
                let (vchunk, vr) = vel_rem.split_at_mut(2 * count);
                vel_rem = vr;
                let schunk: &mut [LayerStepStats] = if collect {
                    let (sc, sr) = stats_rem.split_at_mut(count);
                    stats_rem = sr;
                    sc
                } else {
                    &mut []
                };
                let base = l0;
                l0 = l1;
                let run = move || {
                    for i in 0..count {
                        let li = base + i;
                        let mask = upd[li];
                        if mask == 0.0 {
                            // frozen layer: backward skipped its
                            // gradients, so there is nothing to
                            // integrate -- its velocity stays as-is
                            // (Proposal 3 resets momenta at every phase
                            // change anyway); its stats slot keeps
                            // active == false
                            continue;
                        }
                        let rng_seed = derive_seed(
                            seed,
                            "sgd-round-step",
                            &[step_idx, li as u64],
                        );
                        update_layer(
                            &mut tchunk[2 * i..2 * i + 2],
                            &mut vchunk[2 * i..2 * i + 2],
                            &grads[2 * li][..],
                            &grads[2 * li + 1][..],
                            mask,
                            lr,
                            mu,
                            nq.weights[li],
                            rng_seed,
                            if collect { Some(&mut schunk[i]) } else { None },
                        );
                    }
                };
                if wid + 1 < workers {
                    s.spawn(run);
                } else {
                    run();
                }
            }
        });
        self.step += 1;
        if collect {
            for (li, st) in layer_stats.iter_mut().enumerate() {
                let (sa, na) = self.net.act_saturation(li);
                st.sat_a = sa;
                st.n_a = na;
                st.quantized = self.nq.weights[li].is_some();
            }
            self.last_stats = Some(StepStats {
                step: self.step,
                loss,
                layers: layer_stats,
            });
        }
        Ok(loss)
    }

    fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()> {
        if upd.len() != self.upd.len() {
            return Err(FxpError::config(format!(
                "update mask has {} entries, arch {} layers",
                upd.len(),
                self.upd.len()
            )));
        }
        if nq.num_layers() != self.nq.num_layers() {
            return Err(FxpError::config(format!(
                "NetQuant has {} layers, arch {}",
                nq.num_layers(),
                self.nq.num_layers()
            )));
        }
        self.nq = nq.clone();
        self.upd = upd.to_vec();
        self.lr = lr;
        self.momentum = momentum;
        Ok(())
    }

    fn reset_momenta(&mut self) -> Result<()> {
        for v in self.vel.iter_mut() {
            v.fill(0.0);
        }
        Ok(())
    }

    fn params(&self) -> Result<ParamSet> {
        Ok(self.params.clone())
    }

    fn global_step(&self) -> usize {
        self.step
    }

    fn max_loss(&self) -> f32 {
        self.max_loss
    }

    fn set_telemetry(&mut self, on: bool) {
        self.telemetry = on;
        if !on {
            self.last_stats = None;
        }
    }

    fn last_step_stats(&self) -> Option<&StepStats> {
        self.last_stats.as_ref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::trainer::run_session;
    use crate::data::loader::LoaderCfg;

    fn session_cfg<'a>(
        params: &'a ParamSet,
        nq: &'a NetQuant,
        upd: &'a [f32],
        data: Dataset,
        seed: u64,
    ) -> SessionCfg<'a> {
        SessionCfg {
            arch: "tiny",
            params,
            nq,
            upd,
            lr: 0.05,
            momentum: 0.9,
            data,
            loader: LoaderCfg { batch: 16, augment: false, max_shift: 0, seed },
            max_loss: 30.0,
            seed,
            threads: 1,
        }
    }

    #[test]
    fn native_history_replays_bit_for_bit() {
        let backend = NativeBackend::new();
        let spec = backend.arch("tiny").unwrap();
        let params = ParamSet::init(&spec, 1);
        let w_stats = params.weight_stats();
        let a_stats: Vec<LayerStats> = (0..spec.num_layers)
            .map(|i| LayerStats {
                absmax: 2.0 + i as f32,
                meanabs: 0.5,
                meansq: 0.8,
            })
            .collect();
        // fixed-point weights: the stochastic rounding stream is active
        let nq = NetQuant::for_cell(
            crate::quant::policy::WidthSpec::Bits(8),
            crate::quant::policy::WidthSpec::Bits(8),
            &w_stats,
            &a_stats,
            crate::quant::calib::CalibMethod::MinMax,
        )
        .unwrap();
        let upd = vec![1.0; spec.num_layers];
        let data = Dataset::generate(64, 16, 16, 2);
        let run = |seed: u64| {
            let mut s = backend
                .new_session(session_cfg(&params, &nq, &upd, data.clone(), seed))
                .unwrap();
            run_session(&mut *s, 6, 1).unwrap()
        };
        let a = run(9);
        let b = run(9);
        assert_eq!(a.history, b.history);
        assert!(!a.diverged);
        // a different session seed changes the rounding stream
        let c = run(10);
        assert_ne!(a.history, c.history);
    }

    #[test]
    fn update_mask_freezes_layers() {
        let backend = NativeBackend::new();
        let spec = backend.arch("tiny").unwrap();
        let params = ParamSet::init(&spec, 3);
        let nq = NetQuant::all_float(spec.num_layers);
        let mut upd = vec![0.0; spec.num_layers];
        upd[spec.num_layers - 1] = 1.0;
        let data = Dataset::generate(64, 16, 16, 4);
        let mut s = backend
            .new_session(session_cfg(&params, &nq, &upd, data, 5))
            .unwrap();
        for _ in 0..3 {
            s.step().unwrap();
        }
        let tuned = s.params().unwrap();
        for li in 0..spec.num_layers {
            let changed = tuned.weight(li).data() != params.weight(li).data();
            assert_eq!(changed, li == spec.num_layers - 1, "layer {li}");
        }
        assert_eq!(s.global_step(), 3);
    }

    #[test]
    fn native_evaluate_counts_every_row() {
        let backend = NativeBackend::new();
        let spec = backend.arch("tiny").unwrap();
        let params = ParamSet::init(&spec, 6);
        let nq = NetQuant::all_float(spec.num_layers);
        // 40 rows with eval_batch 32: exercises the tail chunk
        let data = Dataset::generate(40, 16, 16, 8);
        let ev = backend.evaluate("tiny", &params, &nq, &data).unwrap();
        assert_eq!(ev.n, 40);
        assert!(ev.top1_err >= 0.0 && ev.top1_err <= 1.0);
        assert!(ev.mean_loss.is_finite());
        // deterministic
        let ev2 = backend.evaluate("tiny", &params, &nq, &data).unwrap();
        assert_eq!(ev, ev2);
    }

    #[test]
    fn activation_stats_are_sane() {
        let backend = NativeBackend::new();
        let spec = backend.arch("tiny").unwrap();
        let params = ParamSet::init(&spec, 2);
        let data = Dataset::generate(64, 16, 16, 3);
        let stats = backend.activation_stats("tiny", &params, &data, 2).unwrap();
        assert_eq!(stats.len(), spec.num_layers);
        for (li, st) in stats.iter().enumerate() {
            assert!(st.absmax > 0.0, "layer {li}");
            assert!(st.meansq > 0.0 && st.meansq.is_finite(), "layer {li}");
            assert!(st.meanabs <= st.absmax, "layer {li}");
        }
    }
}
