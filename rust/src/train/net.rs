//! Native forward/backward over the paper's layer set: 3x3 SAME conv,
//! 2x2 max-pool, fully-connected, ReLU, softmax cross-entropy.
//!
//! Semantics mirror the AOT-compiled XLA graphs: *simulated*
//! quantization in f32 -- weights snap to their Q-format grid
//! (nearest-half-up) before every forward, hidden activations snap after
//! ReLU, and gradients flow through the quantizers as straight-through
//! estimators (the paper's "presumed" smooth gradient, so the section
//! 2.2 gradient mismatch is physically present here exactly as it is in
//! the compiled graphs).
//!
//! The heavy math reuses the PR 2 GEMM machinery, instantiated at f32
//! and dispatched through the runtime-selected [`Kernels`] facade: the
//! forward conv/fc matmuls run blocked im2col + panel-packed microkernel
//! (scalar reference or its bit-identical AVX2/NEON twin), and the
//! input-gradient matmuls run the same microkernel against
//! per-step-packed transposed weights.
//! Weight gradients use an A-stationary rank-1 accumulation (patch rows
//! are already materialised, so no second im2col pass is needed).
//!
//! Determinism: every accumulation walks a fixed order that depends only
//! on the architecture and batch size -- never on threads, blocking, or
//! scheduling -- so a loss history is a pure function of
//! `(arch, params, quantization, data seed)`.  Max-pool ties route the
//! gradient to the *first* maximal element.
//!
//! Threading ([`NativeNet::set_threads`]): the training step shards over
//! `std::thread::scope` workers exactly like the inference engine, with
//! the accumulation order pinned so results stay bit-identical for
//! *every* thread count:
//!
//! * forward conv GEMMs shard contiguous row ranges of the im2col'd
//!   patch matrix -- each output element is an independent fixed-order
//!   reduction over `k`, so blocking/sharding cannot change it;
//! * weight/bias gradients accumulate into [`GRAD_STRIPES`] fixed
//!   per-stripe partial buffers (stripe = a contiguous range of
//!   `ROW_BLOCK` blocks, a pure function of the layer shape) which are
//!   reduced serially in stripe order -- the same tree for 1 thread as
//!   for N, so the f32 sums are bit-identical;
//! * conv input gradients shard whole *images*: each worker scatter-adds
//!   (`col2im_add`) only into its own images' planes, walking its rows
//!   in increasing order -- per-element accumulation order is identical
//!   to the serial walk.
//!
//! All buffers are allocated once at [`NativeNet::build`] /
//! [`NativeNet::set_threads`] and reused; steady-state training steps do
//! no heap allocation.

#![allow(clippy::needless_range_loop)]

use crate::error::{FxpError, Result};
use crate::fixedpoint::vector::{quantize_slice, quantize_slice_counted};
use crate::fixedpoint::{QFormat, RoundMode};
use crate::inference::kernels::Kernels;
use crate::inference::packing::{self, PackedPanels};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;

/// Patch rows extracted per im2col + GEMM block (same rationale as the
/// inference engine's block size: keep a block resident in L2).
const ROW_BLOCK: usize = 64;

/// Fixed number of partial-accumulation stripes for conv weight/bias
/// gradients.  A stripe owns a contiguous range of `ROW_BLOCK` blocks --
/// a pure function of the layer shape, never of the thread count -- and
/// the stripe partials are reduced serially in stripe order.  This is
/// what makes the f32 gradient sums bit-identical for any number of
/// workers (the stripes are merely *computed* in parallel); it also caps
/// the useful parallelism of the weight-gradient stage.
const GRAD_STRIPES: usize = 8;

/// One structural stage of the network (weighted layers carry their
/// flat layer index `li`).
#[derive(Clone, Copy, Debug)]
enum Stage {
    Conv { li: usize, cin: usize, cout: usize },
    Pool,
    Fc { li: usize, k: usize, nout: usize },
}

/// A network instance with training caches: quantized forward weights,
/// per-stage activation planes, pre-activation planes (for the ReLU
/// mask), pool argmax maps, and gradient planes.
pub struct NativeNet {
    stages: Vec<Stage>,
    /// the kernel set every f32 GEMM of this net dispatches through
    /// (bit-identical to scalar by the kernel-layer parity contract, so
    /// training numerics do not depend on the host ISA)
    kernels: &'static Kernels,
    /// (h, w, c) per stage boundary; `shapes[0]` is the input plane.
    shapes: Vec<(usize, usize, usize)>,
    /// stage index of each weighted layer
    layer_stage: Vec<usize>,
    /// (k, n) GEMM dims of each weighted layer
    layer_dims: Vec<(usize, usize)>,
    num_layers: usize,
    num_classes: usize,
    batch: usize,
    /// GEMM row-block workers for forward/backward (results are
    /// bit-identical for any value; see the module docs)
    threads: usize,
    /// length of one worker's im2col scratch slice
    /// (`ROW_BLOCK * max conv k`)
    patch_stride: usize,
    // per weighted layer, refreshed by `set_weights`:
    wq: Vec<Vec<f32>>,
    bias: Vec<Vec<f32>>,
    packed_w: Vec<PackedPanels<f32>>,
    packed_wt: Vec<PackedPanels<f32>>,
    a_fmt: Vec<Option<QFormat>>,
    /// per weighted layer: activation elements clipped by the layer's
    /// quantizer during the last forward (0 when the activations are
    /// float).  The tally rides along the quantizer itself
    /// (`quantize_slice_counted`), so keeping it never changes numerics.
    act_sat: Vec<u64>,
    /// per weighted layer: activation elements quantized during the last
    /// forward (denominator for `act_sat`)
    act_n: Vec<u64>,
    /// per-worker saturation partials for `activate_sharded` (u64
    /// addition is associative, so the chunked sum equals the serial one)
    sat_scratch: Vec<u64>,
    // caches sized for `batch` images:
    acts: Vec<Vec<f32>>,
    zs: Vec<Vec<f32>>,
    argmax: Vec<Vec<u32>>,
    dacts: Vec<Vec<f32>>,
    probs: Vec<f32>,
    /// per-worker im2col scratch (`threads` slices of `patch_stride`)
    patches: Vec<f32>,
    /// per-worker input-gradient patch scratch (same layout)
    dpatches: Vec<f32>,
    /// per-stripe conv weight-gradient partials (`GRAD_STRIPES` buffers
    /// of the largest conv `k * cout`)
    gw_parts: Vec<Vec<f32>>,
    /// per-stripe conv bias-gradient partials
    gb_parts: Vec<Vec<f32>>,
    zero_bias: Vec<f32>,
}

impl NativeNet {
    /// Build the structure and allocate every buffer for `batch`-image
    /// steps.  Weights are loaded separately ([`NativeNet::set_weights`])
    /// because they change every training step.
    pub fn build(spec: &ArchSpec, batch: usize) -> Result<NativeNet> {
        if batch == 0 {
            return Err(FxpError::config("native net: batch must be > 0"));
        }
        let mut shapes = vec![(
            spec.input[0],
            spec.input[1],
            spec.input[2],
        )];
        let mut stages = Vec::new();
        let mut layer_stage = Vec::new();
        let mut layer_dims = Vec::new();
        let mut li = 0usize;
        for (kind, out) in &spec.layers {
            let (h, w, c) = *shapes.last().unwrap();
            match kind.as_str() {
                "conv" => {
                    layer_stage.push(stages.len());
                    stages.push(Stage::Conv { li, cin: c, cout: *out });
                    layer_dims.push((9 * c, *out));
                    shapes.push((h, w, *out));
                    li += 1;
                }
                "pool" => {
                    if h < 2 || w < 2 {
                        return Err(FxpError::config(format!(
                            "native net: pool over a {h}x{w} plane"
                        )));
                    }
                    stages.push(Stage::Pool);
                    shapes.push((h / 2, w / 2, c));
                }
                "fc" => {
                    layer_stage.push(stages.len());
                    stages.push(Stage::Fc { li, k: h * w * c, nout: *out });
                    layer_dims.push((h * w * c, *out));
                    shapes.push((1, 1, *out));
                    li += 1;
                }
                other => {
                    return Err(FxpError::config(format!(
                        "native net: unknown layer kind '{other}'"
                    )))
                }
            }
        }
        if li != spec.num_layers {
            return Err(FxpError::config(format!(
                "native net: arch '{}' declares {} layers, walk found {li}",
                spec.name, spec.num_layers
            )));
        }
        let (lh, lw, lc) = *shapes.last().unwrap();
        if lh * lw * lc != spec.num_classes {
            return Err(FxpError::config(format!(
                "native net: head leaves {} values/image, expected {} logits",
                lh * lw * lc,
                spec.num_classes
            )));
        }
        let acts: Vec<Vec<f32>> = shapes
            .iter()
            .map(|&(h, w, c)| vec![0f32; batch * h * w * c])
            .collect();
        let dacts = acts.clone();
        let zs: Vec<Vec<f32>> = stages
            .iter()
            .enumerate()
            .map(|(s, st)| match st {
                Stage::Pool => Vec::new(),
                _ => {
                    let (h, w, c) = shapes[s + 1];
                    vec![0f32; batch * h * w * c]
                }
            })
            .collect();
        let argmax: Vec<Vec<u32>> = stages
            .iter()
            .enumerate()
            .map(|(s, st)| match st {
                Stage::Pool => {
                    let (h, w, c) = shapes[s + 1];
                    vec![0u32; batch * h * w * c]
                }
                _ => Vec::new(),
            })
            .collect();
        let conv_k_max = stages
            .iter()
            .map(|st| match st {
                Stage::Conv { cin, .. } => 9 * cin,
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        // largest conv (k * cout) / cout: sizes the gradient stripe
        // partials (fc layers are not striped -- their row count is the
        // batch, at most one block)
        let conv_kn_max = stages
            .iter()
            .map(|st| match st {
                Stage::Conv { cin, cout, .. } => 9 * cin * cout,
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        let conv_cout_max = stages
            .iter()
            .map(|st| match st {
                Stage::Conv { cout, .. } => *cout,
                _ => 0,
            })
            .max()
            .unwrap_or(0);
        let k_max = layer_dims.iter().map(|&(k, _)| k).max().unwrap_or(0);
        let num_layers = spec.num_layers;
        let patch_stride = ROW_BLOCK * conv_k_max;
        Ok(NativeNet {
            stages,
            kernels: Kernels::auto(),
            shapes,
            layer_stage,
            layer_dims,
            num_layers,
            num_classes: spec.num_classes,
            batch,
            threads: 1,
            patch_stride,
            wq: vec![Vec::new(); num_layers],
            bias: vec![Vec::new(); num_layers],
            packed_w: (0..num_layers)
                .map(|_| PackedPanels::<f32>::pack(&[], 0, 0))
                .collect(),
            packed_wt: (0..num_layers)
                .map(|_| PackedPanels::<f32>::pack(&[], 0, 0))
                .collect(),
            a_fmt: vec![None; num_layers],
            act_sat: vec![0; num_layers],
            act_n: vec![0; num_layers],
            sat_scratch: vec![0; 1],
            acts,
            zs,
            argmax,
            dacts,
            probs: vec![0f32; batch * spec.num_classes],
            patches: vec![0f32; patch_stride],
            dpatches: vec![0f32; patch_stride],
            gw_parts: vec![vec![0f32; conv_kn_max]; GRAD_STRIPES],
            gb_parts: vec![vec![0f32; conv_cout_max]; GRAD_STRIPES],
            zero_bias: vec![0f32; k_max],
        })
    }

    /// [`NativeNet::build`] with the worker count set in one go.
    pub fn build_threaded(
        spec: &ArchSpec,
        batch: usize,
        threads: usize,
    ) -> Result<NativeNet> {
        let mut net = NativeNet::build(spec, batch)?;
        net.set_threads(threads);
        Ok(net)
    }

    /// Set the GEMM row-block worker count for forward/backward (0 and 1
    /// both mean serial).  Resizes the per-worker scratch; results are
    /// bit-identical for every value (see the module docs), so this is
    /// purely a performance knob.
    pub fn set_threads(&mut self, threads: usize) {
        let threads = threads.max(1);
        self.threads = threads;
        self.patches.resize(threads * self.patch_stride, 0.0);
        self.dpatches.resize(threads * self.patch_stride, 0.0);
        self.sat_scratch.resize(threads, 0);
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Override the kernel facade this net's GEMMs dispatch through
    /// (default: [`Kernels::auto`]).  A performance knob only -- the
    /// kernel layer's bit-parity contract makes every ISA compute
    /// identical results -- exposed so benches and parity tests can
    /// compare scalar and SIMD training in one process.
    pub fn set_kernels(&mut self, kernels: &'static Kernels) {
        self.kernels = kernels;
    }

    pub fn kernels(&self) -> &'static Kernels {
        self.kernels
    }

    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Load `params` under the cell's quantization: weights snap to
    /// their grid (nearest-half-up, the Pallas kernel semantics) and are
    /// packed for the forward and input-gradient GEMMs; biases stay in
    /// full precision (they live on the accumulator grid in hardware).
    /// Called once per training step -- buffers are reused, so a warm
    /// net repacks without allocating.
    pub fn set_weights(&mut self, params: &ParamSet, nq: &NetQuant) -> Result<()> {
        if nq.num_layers() != self.num_layers {
            return Err(FxpError::config(format!(
                "native net: NetQuant has {} layers, net {}",
                nq.num_layers(),
                self.num_layers
            )));
        }
        if params.num_layers() != self.num_layers {
            return Err(FxpError::config(format!(
                "native net: ParamSet has {} layers, net {}",
                params.num_layers(),
                self.num_layers
            )));
        }
        for li in 0..self.num_layers {
            let (k, n) = self.layer_dims[li];
            let w = params.weight(li);
            if w.len() != k * n {
                return Err(FxpError::shape(format!(
                    "native net: layer {li} weights have {} values, \
                     expected {k}x{n}",
                    w.len()
                )));
            }
            let wq = &mut self.wq[li];
            wq.clear();
            wq.extend_from_slice(w.data());
            if let Some(fmt) = nq.weights[li] {
                quantize_slice(wq, fmt, RoundMode::NearestHalfUp, None);
            }
            self.packed_w[li].pack_into(wq, k, n);
            self.packed_wt[li].pack_transposed_into(wq, k, n);
            let b = params.bias(li);
            if b.len() != n {
                return Err(FxpError::shape(format!(
                    "native net: layer {li} bias has {} values, expected {n}",
                    b.len()
                )));
            }
            let bias = &mut self.bias[li];
            bias.clear();
            bias.extend_from_slice(b.data());
            self.a_fmt[li] = nq.acts[li];
        }
        Ok(())
    }

    /// Forward `n` images (NHWC floats in [0,1]) through the quantized
    /// net; returns the `(n, classes)` logits.  Caches every stage's
    /// activations and pre-activations for [`NativeNet::backward`].
    pub fn forward(&mut self, images: &[f32], n: usize) -> Result<&[f32]> {
        let (h0, w0, c0) = self.shapes[0];
        if n == 0 || n > self.batch {
            return Err(FxpError::shape(format!(
                "native net: batch {n} not in 1..={}",
                self.batch
            )));
        }
        if images.len() != n * h0 * w0 * c0 {
            return Err(FxpError::shape(format!(
                "native net: batch len {} != {n}x{h0}x{w0}x{c0}",
                images.len()
            )));
        }
        let last = self.num_layers - 1;
        let threads = self.threads;
        let patch_stride = self.patch_stride;
        let kernels = self.kernels;
        {
            let NativeNet {
                stages,
                shapes,
                acts,
                zs,
                argmax,
                packed_w,
                bias,
                a_fmt,
                act_sat,
                act_n,
                sat_scratch,
                patches,
                ..
            } = &mut *self;
            let packed_w = &*packed_w;
            acts[0][..images.len()].copy_from_slice(images);
            for (s, stage) in stages.iter().enumerate() {
                let (ih, iw, ic) = shapes[s];
                let (oh, ow, _oc) = shapes[s + 1];
                let (lo, hi) = acts.split_at_mut(s + 1);
                let src = &lo[s][..n * ih * iw * ic];
                let dst = &mut hi[0];
                match *stage {
                    Stage::Pool => {
                        maxpool2_argmax(
                            src,
                            n,
                            ih,
                            iw,
                            ic,
                            &mut dst[..n * oh * ow * ic],
                            &mut argmax[s][..n * oh * ow * ic],
                        );
                    }
                    Stage::Conv { li, cin, cout } => {
                        let rows = n * oh * ow;
                        let z = &mut zs[s][..rows * cout];
                        let pw = &packed_w[li];
                        let lb = &bias[li][..];
                        shard_gemm_rows(
                            rows,
                            cout,
                            threads,
                            patch_stride,
                            z,
                            patches,
                            |row0, out_chunk, patch| {
                                conv_rows_gemm(
                                    kernels, src, n, ih, iw, cin, pw, lb, row0,
                                    out_chunk, patch,
                                );
                            },
                        );
                        act_sat[li] = activate_sharded(
                            z,
                            &mut dst[..rows * cout],
                            li < last,
                            a_fmt[li],
                            threads,
                            sat_scratch,
                        );
                        act_n[li] = if a_fmt[li].is_some() {
                            (rows * cout) as u64
                        } else {
                            0
                        };
                    }
                    Stage::Fc { li, k, nout } => {
                        let z = &mut zs[s][..n * nout];
                        kernels.gemm_bias_f32(
                            &src[..n * k],
                            n,
                            k,
                            &packed_w[li],
                            &bias[li],
                            z,
                        );
                        act_sat[li] =
                            activate(z, &mut dst[..n * nout], li < last, a_fmt[li]);
                        act_n[li] = if a_fmt[li].is_some() {
                            (n * nout) as u64
                        } else {
                            0
                        };
                    }
                }
            }
        }
        Ok(&self.acts[self.stages.len()][..n * self.num_classes])
    }

    /// Mean softmax cross-entropy of the cached logits against `labels`
    /// (f64 accumulation); caches the softmax for the backward pass.
    pub fn loss(&mut self, labels: &[i32], n: usize) -> Result<f32> {
        let nc = self.num_classes;
        if labels.len() < n {
            return Err(FxpError::shape(format!(
                "native net: {} labels for batch {n}",
                labels.len()
            )));
        }
        let logits = &self.acts[self.stages.len()];
        let probs = &mut self.probs;
        let mut total = 0f64;
        for i in 0..n {
            let y = labels[i] as usize;
            if y >= nc {
                return Err(FxpError::shape(format!(
                    "native net: label {y} out of range {nc}"
                )));
            }
            let row = &logits[i * nc..(i + 1) * nc];
            let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
            let mut zsum = 0f64;
            for &v in row {
                zsum += ((v - m) as f64).exp();
            }
            let prow = &mut probs[i * nc..(i + 1) * nc];
            for (p, &v) in prow.iter_mut().zip(row) {
                *p = (((v - m) as f64).exp() / zsum) as f32;
            }
            total -= (row[y] - m) as f64 - zsum.ln();
        }
        Ok((total / n as f64) as f32)
    }

    /// Backprop from the cached softmax to parameter gradients.
    ///
    /// `grads` follows the [`ParamSet`] layout (`[w0, b0, w1, b1, ...]`)
    /// and is zeroed here before accumulation.  Gradients pass straight
    /// through the quantizers (STE) and through the ReLU mask taken from
    /// the *pre-quantization* pre-activation.
    ///
    /// `upd` is the per-layer update mask: layers with `upd[li] == 0.0`
    /// skip their (dominant-cost) weight/bias gradient accumulation and
    /// leave zeros in `grads` -- the error signal still propagates
    /// *through* them, which is all Proposals 2/3 need.  The first
    /// stage's input gradient is never consumed and is skipped too.
    pub fn backward(
        &mut self,
        labels: &[i32],
        n: usize,
        upd: &[f32],
        grads: &mut [Vec<f32>],
    ) -> Result<()> {
        if upd.len() != self.num_layers {
            return Err(FxpError::shape(format!(
                "native net: update mask has {} entries, net {}",
                upd.len(),
                self.num_layers
            )));
        }
        if grads.len() != 2 * self.num_layers {
            return Err(FxpError::shape(format!(
                "native net: {} grad tensors, expected {}",
                grads.len(),
                2 * self.num_layers
            )));
        }
        for (t, &(k, c)) in self.layer_dims.iter().enumerate() {
            if grads[2 * t].len() != k * c || grads[2 * t + 1].len() != c {
                return Err(FxpError::shape(format!(
                    "native net: grad tensor shapes for layer {t} do not \
                     match ({k}x{c})"
                )));
            }
            grads[2 * t].fill(0.0);
            grads[2 * t + 1].fill(0.0);
        }
        let nc = self.num_classes;
        let last = self.num_layers - 1;
        let threads = self.threads;
        let patch_stride = self.patch_stride;
        let kernels = self.kernels;
        let NativeNet {
            stages,
            shapes,
            acts,
            zs,
            argmax,
            packed_wt,
            dacts,
            probs,
            patches,
            dpatches,
            gw_parts,
            gb_parts,
            zero_bias,
            ..
        } = &mut *self;
        let packed_wt = &*packed_wt;
        let top = stages.len();
        // dL/dlogits = (softmax - onehot) / n
        let dl = &mut dacts[top][..n * nc];
        for i in 0..n {
            let y = labels[i] as usize;
            let prow = &probs[i * nc..(i + 1) * nc];
            let drow = &mut dl[i * nc..(i + 1) * nc];
            for (j, (d, &p)) in drow.iter_mut().zip(prow).enumerate() {
                let onehot = if j == y { 1.0 } else { 0.0 };
                *d = (p - onehot) / n as f32;
            }
        }
        for s in (0..top).rev() {
            let (ih, iw, ic) = shapes[s];
            let (oh, ow, _oc) = shapes[s + 1];
            let (dlo, dhi) = dacts.split_at_mut(s + 1);
            let da_in = &mut dlo[s];
            let dz = &mut dhi[0];
            match stages[s] {
                Stage::Pool => {
                    if s == 0 {
                        continue;
                    }
                    let in_len = n * ih * iw * ic;
                    let out_len = n * oh * ow * ic;
                    da_in[..in_len].fill(0.0);
                    let am = &argmax[s][..out_len];
                    for (i, &src_idx) in am.iter().enumerate() {
                        da_in[src_idx as usize] += dz[i];
                    }
                }
                Stage::Fc { li, k, nout } => {
                    let dzb = &mut dz[..n * nout];
                    if li < last {
                        relu_mask(dzb, &zs[s][..n * nout]);
                    }
                    if upd[li] != 0.0 {
                        let (gw, gb) = grad_pair(grads, li);
                        accumulate_bias_grad(dzb, n, nout, gb);
                        accumulate_weight_grad(
                            &acts[s][..n * k],
                            dzb,
                            n,
                            k,
                            nout,
                            gw,
                        );
                    }
                    if s > 0 {
                        kernels.gemm_bias_f32(
                            dzb,
                            n,
                            nout,
                            &packed_wt[li],
                            &zero_bias[..k],
                            &mut da_in[..n * k],
                        );
                    }
                }
                Stage::Conv { li, cin, cout } => {
                    let rows = n * oh * ow;
                    let k = 9 * cin;
                    {
                        let dzm = &mut dz[..rows * cout];
                        if li < last {
                            relu_mask(dzm, &zs[s][..rows * cout]);
                        }
                    }
                    // shared from here on: both gradient stages read it
                    let dzb = &dz[..rows * cout];
                    if upd[li] != 0.0 {
                        let (gw, gb) = grad_pair(grads, li);
                        let src_act = &acts[s][..n * ih * iw * ic];
                        conv_grads_striped(
                            src_act,
                            n,
                            ih,
                            iw,
                            cin,
                            cout,
                            dzb,
                            threads,
                            patch_stride,
                            patches,
                            gw_parts,
                            gb_parts,
                            gw,
                            gb,
                        );
                    }
                    if s > 0 {
                        let in_len = n * ih * iw * ic;
                        conv_input_grads_sharded(
                            kernels,
                            dzb,
                            n,
                            ih,
                            iw,
                            cin,
                            cout,
                            &packed_wt[li],
                            &zero_bias[..k],
                            threads,
                            patch_stride,
                            dpatches,
                            &mut da_in[..in_len],
                        );
                    }
                }
            }
        }
        Ok(())
    }

    /// Cached activations of weighted layer `li` after the last forward
    /// (post-ReLU / post-quantization for hidden layers, logits for the
    /// head) -- the values calibration measures.
    pub fn layer_activation(&self, li: usize, n: usize) -> &[f32] {
        let s = self.layer_stage[li];
        let (h, w, c) = self.shapes[s + 1];
        &self.acts[s + 1][..n * h * w * c]
    }

    /// Activation-saturation tally of weighted layer `li` from the last
    /// forward: `(elements clipped, elements quantized)`.  `(0, 0)` when
    /// the layer's activations are float.  Bit-identical for any thread
    /// count: counting happens inside the quantizer, and the per-shard
    /// u64 partials sum to the same total under any chunking.
    pub fn act_saturation(&self, li: usize) -> (u64, u64) {
        (self.act_sat[li], self.act_n[li])
    }
}

/// ReLU (optional) + simulated activation quantization from the
/// pre-activation plane into the stage output.  Returns how many
/// elements the quantizer clipped (0 when `fmt` is `None`) -- the count
/// falls out of `quantize_slice_counted` for free, so the telemetry
/// layer never pays a second pass.
fn activate(z: &[f32], out: &mut [f32], relu: bool, fmt: Option<QFormat>) -> u64 {
    if relu {
        for (o, &v) in out.iter_mut().zip(z) {
            *o = v.max(0.0);
        }
    } else {
        out.copy_from_slice(z);
    }
    if let Some(f) = fmt {
        quantize_slice_counted(out, f, RoundMode::NearestHalfUp, None)
    } else {
        0
    }
}

/// [`activate`] sharded into equal element chunks over scoped workers --
/// purely elementwise (nearest-half-up needs no RNG), so chunking cannot
/// change a single bit, but the quantize pass over a big conv plane is
/// a meaningful slice of the step that would otherwise stay serial.
/// Each worker writes its clip tally into its own `counts` slot
/// (caller-provided scratch, at least `threads` long); the u64 partials
/// are summed at the end, and integer addition is associative, so the
/// total is bit-identical for every thread count.
fn activate_sharded(
    z: &[f32],
    out: &mut [f32],
    relu: bool,
    fmt: Option<QFormat>,
    threads: usize,
    counts: &mut [u64],
) -> u64 {
    let total = out.len();
    let threads = threads.max(1).min(total.max(1));
    if threads == 1 {
        return activate(z, out, relu, fmt);
    }
    let per = total.div_ceil(threads);
    let nchunks = total.div_ceil(per);
    debug_assert!(counts.len() >= nchunks);
    std::thread::scope(|s| {
        let mut z_rem = &z[..total];
        let mut out_rem: &mut [f32] = out;
        let mut cnt_rem: &mut [u64] = counts;
        while !out_rem.is_empty() {
            let len = per.min(out_rem.len());
            let (zc, zr) = z_rem.split_at(len);
            z_rem = zr;
            let (oc, orest) = out_rem.split_at_mut(len);
            out_rem = orest;
            let (cs, crest) = cnt_rem.split_at_mut(1);
            cnt_rem = crest;
            if out_rem.is_empty() {
                cs[0] = activate(zc, oc, relu, fmt);
            } else {
                s.spawn(move || cs[0] = activate(zc, oc, relu, fmt));
            }
        }
    });
    counts[..nchunks].iter().sum()
}

/// STE through ReLU: kill the gradient where the pre-activation was
/// non-positive.
fn relu_mask(dz: &mut [f32], z: &[f32]) {
    for (g, &zv) in dz.iter_mut().zip(z) {
        if zv <= 0.0 {
            *g = 0.0;
        }
    }
}

/// The (dW, db) gradient buffers of weighted layer `li`.
fn grad_pair(grads: &mut [Vec<f32>], li: usize) -> (&mut [f32], &mut [f32]) {
    let (a, b) = grads.split_at_mut(2 * li + 1);
    (&mut a[2 * li][..], &mut b[0][..])
}

/// Split `total` GEMM rows into per-worker contiguous ranges, give each
/// worker its own `patch_stride` slice of im2col scratch, and run
/// `work(first_row, out_chunk, patch_chunk)` on each (inline when one
/// worker suffices; the last chunk runs on the calling thread).  Every
/// output element is an independent fixed-order reduction, so the result
/// is bit-identical for any thread count.
fn shard_gemm_rows<W>(
    total: usize,
    n_out: usize,
    threads: usize,
    patch_stride: usize,
    out: &mut [f32],
    patches: &mut [f32],
    work: W,
) where
    W: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    let threads = threads.max(1).min(total.max(1));
    if threads == 1 {
        work(0, &mut out[..total * n_out], &mut patches[..patch_stride]);
        return;
    }
    let rows_per = total.div_ceil(threads);
    std::thread::scope(|s| {
        let mut out_rem: &mut [f32] = &mut out[..total * n_out];
        let mut patch_rem: &mut [f32] = patches;
        let mut row0 = 0usize;
        while row0 < total {
            let rows = rows_per.min(total - row0);
            let (out_chunk, orest) = out_rem.split_at_mut(rows * n_out);
            out_rem = orest;
            let (patch_chunk, prest) = patch_rem.split_at_mut(patch_stride);
            patch_rem = prest;
            let r0 = row0;
            row0 += rows;
            if row0 < total {
                let work = &work;
                s.spawn(move || work(r0, out_chunk, patch_chunk));
            } else {
                work(r0, out_chunk, patch_chunk);
            }
        }
    });
}

/// One worker's rows of a forward conv: walk `ROW_BLOCK` blocks, im2col
/// each into the worker's scratch, GEMM with the fused bias.
#[allow(clippy::too_many_arguments)]
fn conv_rows_gemm(
    kernels: &Kernels,
    src: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    pw: &PackedPanels<f32>,
    bias: &[f32],
    row0: usize,
    out: &mut [f32],
    patch: &mut [f32],
) {
    let k = 9 * cin;
    let cout = pw.n;
    let rows = out.len() / cout;
    let mut r = 0usize;
    while r < rows {
        let block = ROW_BLOCK.min(rows - r);
        let pb = &mut patch[..block * k];
        packing::im2col_rows(src, n, h, w, cin, row0 + r, block, pb);
        kernels.gemm_bias_f32(
            pb,
            block,
            k,
            pw,
            bias,
            &mut out[r * cout..(r + block) * cout],
        );
        r += block;
    }
}

/// Conv weight/bias gradients through fixed accumulation stripes: stripe
/// `si` owns a contiguous range of `ROW_BLOCK` blocks (a pure function
/// of the layer shape, never of the thread count), accumulates its own
/// partial, and the partials are reduced serially in stripe order.  The
/// sums are therefore bit-identical for every thread count -- only the
/// stripe *computation* runs in parallel.
#[allow(clippy::too_many_arguments)]
fn conv_grads_striped(
    src_act: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    dz: &[f32],
    threads: usize,
    patch_stride: usize,
    patches: &mut [f32],
    gw_parts: &mut [Vec<f32>],
    gb_parts: &mut [Vec<f32>],
    gw: &mut [f32],
    gb: &mut [f32],
) {
    let k = 9 * cin;
    let rows = dz.len() / cout;
    let blocks = rows.div_ceil(ROW_BLOCK);
    let stripes = GRAD_STRIPES.min(blocks).max(1);
    let stripe_work =
        |si: usize, gw_p: &mut [f32], gb_p: &mut [f32], patch: &mut [f32]| {
            gw_p.fill(0.0);
            gb_p.fill(0.0);
            let b0 = si * blocks / stripes;
            let b1 = (si + 1) * blocks / stripes;
            for b in b0..b1 {
                let r0 = b * ROW_BLOCK;
                let block = ROW_BLOCK.min(rows - r0);
                let pb = &mut patch[..block * k];
                packing::im2col_rows(src_act, n, h, w, cin, r0, block, pb);
                let dzb = &dz[r0 * cout..(r0 + block) * cout];
                accumulate_bias_grad(dzb, block, cout, gb_p);
                accumulate_weight_grad(pb, dzb, block, k, cout, gw_p);
            }
        };
    let workers = threads.max(1).min(stripes);
    if workers == 1 {
        // the serial path still goes through the stripe partials, so the
        // accumulation tree is the same one every thread count reduces
        for (si, (gw_p, gb_p)) in
            gw_parts.iter_mut().zip(gb_parts.iter_mut()).take(stripes).enumerate()
        {
            stripe_work(
                si,
                &mut gw_p[..k * cout],
                &mut gb_p[..cout],
                &mut patches[..patch_stride],
            );
        }
    } else {
        std::thread::scope(|s| {
            let mut gw_rem: &mut [Vec<f32>] = &mut gw_parts[..stripes];
            let mut gb_rem: &mut [Vec<f32>] = &mut gb_parts[..stripes];
            let mut patch_rem: &mut [f32] = patches;
            let mut s0 = 0usize;
            for wid in 0..workers {
                let s1 = (wid + 1) * stripes / workers;
                let count = s1 - s0;
                let (gw_chunk, gwr) = gw_rem.split_at_mut(count);
                gw_rem = gwr;
                let (gb_chunk, gbr) = gb_rem.split_at_mut(count);
                gb_rem = gbr;
                let (patch_chunk, prest) = patch_rem.split_at_mut(patch_stride);
                patch_rem = prest;
                let base = s0;
                s0 = s1;
                let stripe_work = &stripe_work;
                let run = move || {
                    for (i, (gw_p, gb_p)) in
                        gw_chunk.iter_mut().zip(gb_chunk.iter_mut()).enumerate()
                    {
                        stripe_work(
                            base + i,
                            &mut gw_p[..k * cout],
                            &mut gb_p[..cout],
                            &mut *patch_chunk,
                        );
                    }
                };
                if wid + 1 < workers {
                    s.spawn(run);
                } else {
                    run();
                }
            }
        });
    }
    // fixed-order reduction, identical for every thread count
    for si in 0..stripes {
        for (g, &p) in gw.iter_mut().zip(&gw_parts[si][..k * cout]) {
            *g += p;
        }
        for (g, &p) in gb.iter_mut().zip(&gb_parts[si][..cout]) {
            *g += p;
        }
    }
}

/// Conv input gradients sharded by *image*: each worker owns a
/// contiguous image range, runs the input-gradient GEMM block by block
/// into its own patch scratch, and scatter-adds (`col2im_add`) only into
/// its own images' planes in increasing row order -- exactly the
/// per-element accumulation order of the serial walk, so results are
/// bit-identical for every thread count.
#[allow(clippy::too_many_arguments)]
fn conv_input_grads_sharded(
    kernels: &Kernels,
    dz: &[f32],
    n: usize,
    h: usize,
    w: usize,
    cin: usize,
    cout: usize,
    wt: &PackedPanels<f32>,
    zero_bias: &[f32],
    threads: usize,
    patch_stride: usize,
    dpatches: &mut [f32],
    da_in: &mut [f32],
) {
    let k = 9 * cin;
    let plane = h * w * cin;
    let img_rows = h * w;
    debug_assert_eq!(da_in.len(), n * plane);
    debug_assert_eq!(dz.len(), n * img_rows * cout);
    let worker = |img0: usize, da_chunk: &mut [f32], dp: &mut [f32]| {
        da_chunk.fill(0.0);
        let rows_w = da_chunk.len() / plane * img_rows;
        let row_base = img0 * img_rows;
        let mut r = 0usize;
        while r < rows_w {
            let block = ROW_BLOCK.min(rows_w - r);
            let r0 = row_base + r;
            let dpb = &mut dp[..block * k];
            kernels.gemm_bias_f32(
                &dz[r0 * cout..(r0 + block) * cout],
                block,
                cout,
                wt,
                zero_bias,
                dpb,
            );
            col2im_add(dpb, h, w, cin, r0, block, img0, da_chunk);
            r += block;
        }
    };
    let workers = threads.max(1).min(n);
    if workers == 1 {
        worker(0, da_in, &mut dpatches[..patch_stride]);
        return;
    }
    std::thread::scope(|s| {
        let mut da_rem: &mut [f32] = da_in;
        let mut dp_rem: &mut [f32] = dpatches;
        let mut i0 = 0usize;
        for wid in 0..workers {
            let i1 = (wid + 1) * n / workers;
            let imgs = i1 - i0;
            let (da_chunk, drest) = da_rem.split_at_mut(imgs * plane);
            da_rem = drest;
            let (dp_chunk, prest) = dp_rem.split_at_mut(patch_stride);
            dp_rem = prest;
            let img0 = i0;
            i0 = i1;
            if wid + 1 < workers {
                let worker = &worker;
                s.spawn(move || worker(img0, da_chunk, dp_chunk));
            } else {
                worker(img0, da_chunk, dp_chunk);
            }
        }
    });
}

/// db[j] += sum over rows of dz[r, j].
fn accumulate_bias_grad(dz: &[f32], rows: usize, n: usize, gb: &mut [f32]) {
    for r in 0..rows {
        let grow = &dz[r * n..(r + 1) * n];
        for (b, &g) in gb.iter_mut().zip(grow) {
            *b += g;
        }
    }
}

/// dW[p, j] += sum over rows of a[r, p] * dz[r, j] (A-stationary rank-1
/// updates; `a` rows with zero entries -- ReLU sparsity -- are skipped).
fn accumulate_weight_grad(
    a: &[f32],
    dz: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    gw: &mut [f32],
) {
    for r in 0..rows {
        let arow = &a[r * k..(r + 1) * k];
        let grow = &dz[r * n..(r + 1) * n];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let wrow = &mut gw[p * n..(p + 1) * n];
            for (wv, &gv) in wrow.iter_mut().zip(grow) {
                *wv += av * gv;
            }
        }
    }
}

/// 2x2 max-pool (VALID, stride 2) recording the absolute source index of
/// each maximum (first maximal element on ties) for the backward pass.
fn maxpool2_argmax(
    src: &[f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    dst: &mut [f32],
    arg: &mut [u32],
) {
    let oh = h / 2;
    let ow = w / 2;
    debug_assert_eq!(src.len(), n * h * w * c);
    debug_assert_eq!(dst.len(), n * oh * ow * c);
    for img in 0..n {
        let base_in = img * h * w * c;
        let base_out = img * oh * ow * c;
        for y in 0..oh {
            for x in 0..ow {
                for ch in 0..c {
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = base_in + (2 * y * w + 2 * x) * c + ch;
                    for dy in 0..2usize {
                        for dx in 0..2usize {
                            let idx =
                                base_in + ((2 * y + dy) * w + 2 * x + dx) * c + ch;
                            if src[idx] > best {
                                best = src[idx];
                                bi = idx;
                            }
                        }
                    }
                    let o = base_out + (y * ow + x) * c + ch;
                    dst[o] = best;
                    arg[o] = bi as u32;
                }
            }
        }
    }
}

/// Scatter-add im2col patch gradients back onto the input plane
/// (inverse of `packing::im2col_rows` over the same row range).  `dst`
/// starts at image `img0`'s plane, so image-sharded workers can scatter
/// into just their own slice of the batch.
#[allow(clippy::too_many_arguments)]
fn col2im_add(
    dpatch: &[f32],
    h: usize,
    w: usize,
    cin: usize,
    row0: usize,
    rows: usize,
    img0: usize,
    dst: &mut [f32],
) {
    let k = 9 * cin;
    debug_assert!(dpatch.len() >= rows * k);
    debug_assert_eq!(dst.len() % (h * w * cin), 0);
    for ri in 0..rows {
        let r = row0 + ri;
        let img = r / (h * w);
        let y = (r / w) % h;
        let x = r % w;
        debug_assert!(img >= img0);
        let img_base = (img - img0) * h * w * cin;
        let src_row = &dpatch[ri * k..(ri + 1) * k];
        for ky in 0..3usize {
            let sy = y as isize + ky as isize - 1;
            if sy < 0 || sy >= h as isize {
                continue;
            }
            for kx in 0..3usize {
                let sx = x as isize + kx as isize - 1;
                if sx < 0 || sx >= w as isize {
                    continue;
                }
                let d = img_base + (sy as usize * w + sx as usize) * cin;
                let s = (ky * 3 + kx) * cin;
                for ci in 0..cin {
                    dst[d + ci] += src_row[s + ci];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::zoo;
    use crate::quant::policy::NetQuant;
    use crate::util::rng::Rng;

    fn tiny() -> ArchSpec {
        zoo::builtin_archs().remove("tiny").unwrap()
    }

    #[test]
    fn forward_is_deterministic_and_batch_independent() {
        let spec = tiny();
        let params = ParamSet::init(&spec, 3);
        let nq = NetQuant::all_float(spec.num_layers);
        let n = 4;
        let mut rng = Rng::new(9);
        let img_len = 16 * 16 * 3;
        let images: Vec<f32> =
            (0..n * img_len).map(|_| rng.uniform() as f32).collect();
        let mut net = NativeNet::build(&spec, n).unwrap();
        net.set_weights(&params, &nq).unwrap();
        let a = net.forward(&images, n).unwrap().to_vec();
        // same inputs replay exactly
        let b = net.forward(&images, n).unwrap().to_vec();
        assert_eq!(a, b);
        // each image's logits do not depend on its batch neighbours
        let mut net1 = NativeNet::build(&spec, 1).unwrap();
        net1.set_weights(&params, &nq).unwrap();
        for i in 0..n {
            let solo = net1
                .forward(&images[i * img_len..(i + 1) * img_len], 1)
                .unwrap()
                .to_vec();
            assert_eq!(&a[i * 10..(i + 1) * 10], &solo[..], "image {i}");
        }
    }

    #[test]
    fn forward_backward_bit_identical_across_threads() {
        // the tentpole property at the net level: logits, loss, and every
        // gradient tensor replay bit-for-bit under any worker count
        let spec = tiny();
        let params = ParamSet::init(&spec, 4);
        let nq = NetQuant::all_float(spec.num_layers);
        let n = 8;
        let mut rng = Rng::new(3);
        let img_len = 16 * 16 * 3;
        let images: Vec<f32> =
            (0..n * img_len).map(|_| rng.uniform() as f32).collect();
        let labels: Vec<i32> = (0..n).map(|_| rng.below(10) as i32).collect();
        let upd = vec![1.0f32; spec.num_layers];
        let run = |threads: usize| {
            let mut net = NativeNet::build_threaded(&spec, n, threads).unwrap();
            net.set_weights(&params, &nq).unwrap();
            let logits = net.forward(&images, n).unwrap().to_vec();
            let loss = net.loss(&labels, n).unwrap();
            let mut grads: Vec<Vec<f32>> =
                params.tensors.iter().map(|t| vec![0f32; t.len()]).collect();
            net.backward(&labels, n, &upd, &mut grads).unwrap();
            (logits, loss, grads)
        };
        let a = run(1);
        for t in [2usize, 3, 8] {
            let b = run(t);
            assert_eq!(a.0, b.0, "{t} threads: logits differ");
            assert_eq!(a.1.to_bits(), b.1.to_bits(), "{t} threads: loss differs");
            assert_eq!(a.2, b.2, "{t} threads: gradients differ");
        }
    }

    #[test]
    fn pool_argmax_routes_first_max() {
        let src = vec![1.0f32, 3.0, 3.0, 2.0]; // 2x2, c=1: ties at value 3
        let mut dst = vec![0f32; 1];
        let mut arg = vec![0u32; 1];
        maxpool2_argmax(&src, 1, 2, 2, 1, &mut dst, &mut arg);
        assert_eq!(dst[0], 3.0);
        assert_eq!(arg[0], 1); // first maximal element wins
    }

    #[test]
    fn col2im_inverts_im2col_adjointly() {
        // <im2col(x), p> == <x, col2im(p)> for random x, p: the two ops
        // must be exact adjoints or conv gradients are silently wrong
        let (n, h, w, cin) = (2usize, 4usize, 3usize, 2usize);
        let mut rng = Rng::new(5);
        let x: Vec<f32> =
            (0..n * h * w * cin).map(|_| rng.uniform() as f32 - 0.5).collect();
        let rows = n * h * w;
        let k = 9 * cin;
        let p: Vec<f32> = (0..rows * k).map(|_| rng.uniform() as f32 - 0.5).collect();
        let mut im2 = vec![0f32; rows * k];
        packing::im2col_rows(&x, n, h, w, cin, 0, rows, &mut im2);
        let lhs: f64 = im2
            .iter()
            .zip(&p)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        let mut back = vec![0f32; n * h * w * cin];
        col2im_add(&p, h, w, cin, 0, rows, 0, &mut back);
        let rhs: f64 = x
            .iter()
            .zip(&back)
            .map(|(&a, &b)| a as f64 * b as f64)
            .sum();
        assert!((lhs - rhs).abs() < 1e-3, "{lhs} vs {rhs}");
    }

    #[test]
    fn loss_decreases_under_plain_sgd() {
        // three hand-rolled float SGD steps on one batch must reduce the
        // loss -- a coarse end-to-end sanity check of the gradients
        let spec = tiny();
        let mut params = ParamSet::init(&spec, 7);
        let nq = NetQuant::all_float(spec.num_layers);
        let data = crate::data::synth::Dataset::generate(8, 16, 16, 11);
        let n = 8;
        let mut net = NativeNet::build(&spec, n).unwrap();
        let mut grads: Vec<Vec<f32>> =
            params.tensors.iter().map(|t| vec![0f32; t.len()]).collect();
        let upd = vec![1.0f32; spec.num_layers];
        let mut losses = Vec::new();
        for _ in 0..3 {
            net.set_weights(&params, &nq).unwrap();
            net.forward(&data.images.data()[..n * 16 * 16 * 3], n).unwrap();
            losses.push(net.loss(data.labels.data(), n).unwrap());
            net.backward(data.labels.data(), n, &upd, &mut grads).unwrap();
            for (t, g) in params.tensors.iter_mut().zip(&grads) {
                for (p, &gv) in t.data_mut().iter_mut().zip(g) {
                    *p -= 0.5 * gv;
                }
            }
        }
        net.set_weights(&params, &nq).unwrap();
        net.forward(&data.images.data()[..n * 16 * 16 * 3], n).unwrap();
        let final_loss = net.loss(data.labels.data(), n).unwrap();
        assert!(
            final_loss < losses[0],
            "loss did not decrease: {losses:?} -> {final_loss}"
        );
    }
}
