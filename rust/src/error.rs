//! Library-wide error type.
//!
//! Hand-implemented `Display`/`Error`/`From` (thiserror is not in the
//! offline crate cache -- see `util/mod.rs` on the substitution policy).

use std::fmt;

/// All errors surfaced by fxpnet.
#[derive(Debug)]
pub enum FxpError {
    /// Errors from the XLA/PJRT runtime (compilation, execution, literals).
    Xla(xla::Error),

    /// Filesystem / IO errors.
    Io(std::io::Error),

    /// Manifest / metrics JSON problems.
    Json(String),

    /// Artifact manifest is missing something the coordinator needs.
    Manifest(String),

    /// Checkpoint file corrupt or mismatched.
    Checkpoint(String),

    /// Shape mismatch in tensor plumbing.
    Shape(String),

    /// Bad configuration (CLI, quantization format, schedule...).
    Config(String),

    /// Training diverged (NaN/Inf loss or runaway loss) -- the paper's
    /// "fails to converge" outcome; the grid runner records it as `n/a`.
    Diverged { step: usize, loss: f32 },
}

impl fmt::Display for FxpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FxpError::Xla(e) => write!(f, "xla: {e}"),
            FxpError::Io(e) => write!(f, "io: {e}"),
            FxpError::Json(m) => write!(f, "json: {m}"),
            FxpError::Manifest(m) => write!(f, "manifest: {m}"),
            FxpError::Checkpoint(m) => write!(f, "checkpoint: {m}"),
            FxpError::Shape(m) => write!(f, "shape: {m}"),
            FxpError::Config(m) => write!(f, "config: {m}"),
            FxpError::Diverged { step, loss } => {
                write!(f, "diverged at step {step}: loss={loss}")
            }
        }
    }
}

impl std::error::Error for FxpError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            FxpError::Xla(e) => Some(e),
            FxpError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for FxpError {
    fn from(e: xla::Error) -> Self {
        FxpError::Xla(e)
    }
}

impl From<std::io::Error> for FxpError {
    fn from(e: std::io::Error) -> Self {
        FxpError::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, FxpError>;

impl FxpError {
    pub fn config(msg: impl Into<String>) -> Self {
        FxpError::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        FxpError::Shape(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_source() {
        let e = FxpError::config("bad flag");
        assert_eq!(e.to_string(), "config: bad flag");
        let e = FxpError::Diverged { step: 7, loss: f32::NAN };
        assert!(e.to_string().contains("step 7"));
        // via From, without assuming the xla Error's concrete shape
        // (the stub and the real crate differ there)
        let e: FxpError =
            std::io::Error::new(std::io::ErrorKind::NotFound, "boom").into();
        assert!(e.to_string().starts_with("io:"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
