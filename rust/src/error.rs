//! Library-wide error type.

use thiserror::Error;

/// All errors surfaced by fxpnet.
#[derive(Error, Debug)]
pub enum FxpError {
    /// Errors from the XLA/PJRT runtime (compilation, execution, literals).
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),

    /// Filesystem / IO errors.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Manifest / metrics JSON problems.
    #[error("json: {0}")]
    Json(String),

    /// Artifact manifest is missing something the coordinator needs.
    #[error("manifest: {0}")]
    Manifest(String),

    /// Checkpoint file corrupt or mismatched.
    #[error("checkpoint: {0}")]
    Checkpoint(String),

    /// Shape mismatch in tensor plumbing.
    #[error("shape: {0}")]
    Shape(String),

    /// Bad configuration (CLI, quantization format, schedule...).
    #[error("config: {0}")]
    Config(String),

    /// Training diverged (NaN/Inf loss or runaway loss) -- the paper's
    /// "fails to converge" outcome; the grid runner records it as `n/a`.
    #[error("diverged at step {step}: loss={loss}")]
    Diverged { step: usize, loss: f32 },
}

pub type Result<T> = std::result::Result<T, FxpError>;

impl FxpError {
    pub fn config(msg: impl Into<String>) -> Self {
        FxpError::Config(msg.into())
    }
    pub fn shape(msg: impl Into<String>) -> Self {
        FxpError::Shape(msg.into())
    }
}
