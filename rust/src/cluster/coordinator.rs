//! The cluster coordinator: owns the sweep, serves cells to workers.
//!
//! One coordinator process binds a TCP listener, opens the sweep's
//! [`ShardedCache`] (taking its advisory lock for the whole run), and
//! hands out cells pull-style: a worker asks, the coordinator assigns.
//! There is no push and no scheduler state on workers, so work-stealing
//! falls out for free -- a fast worker simply asks more often.
//!
//! ## Failure model
//!
//! * **Worker death** is detected per connection: silence past the
//!   heartbeat deadline, an EOF while a cell is in flight, or a protocol
//!   violation all requeue the in-flight cell.  Requeued cells back off
//!   exponentially (`backoff_base * 2^(attempt-2)`) and count against
//!   [`ClusterOpts::retry_cap`] total attempts; exhausting the cap is a
//!   hard error, not a silent n/a -- per-cell determinism means a cell
//!   that keeps killing workers will keep doing so.
//! * **Duplicate results** (a presumed-dead worker's result arriving
//!   after a re-dispatch completed) are idempotent: cells are a pure
//!   function of the seed tree, so the copies must agree bit-for-bit
//!   ([`shard::cells_bit_equal`]); any mismatch is a hard error because
//!   it means determinism itself is broken.
//! * **Coordinator crash** is covered by the cache: every finished cell
//!   is flushed through the same strict v4 [`CellCache`] the
//!   single-process sweep writes (fsync + atomic rename), and a
//!   restarted coordinator pre-fills from it -- resume is not optional
//!   in cluster mode.
//! * **Graceful drain**: on SIGTERM/ctrl-C the coordinator stops
//!   assigning, answers `Drain` to requests, waits a bounded grace for
//!   in-flight results, then exits reporting an incomplete sweep
//!   (exit code 2 at the CLI, like `grid --check`).
//!
//! [`CellCache`]: crate::coordinator::report::CellCache

use std::collections::{BTreeMap, HashMap};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::cluster::heartbeat::{DeadlineClock, HeartbeatCfg};
use crate::cluster::proto::{read_frame, write_frame, Frame, Msg, PROTO_VERSION};
use crate::coordinator::grid::{grid_jobs, in_shard, CellJob, CellOutcome, GridResult};
use crate::coordinator::regimes::{CellEval, CellResult, Regime};
use crate::coordinator::report::{CellCache, CACHE_VERSION};
use crate::coordinator::shard::{self, LockOpts, ShardedCache};
use crate::error::{FxpError, Result};
use crate::quant::policy::WidthSpec;
use crate::train::telemetry::TelemetrySummary;
use crate::util::json::Json;

/// How often handler threads tick their sockets (read timeout) and the
/// accept loop polls.
const TICK: Duration = Duration::from_millis(20);

/// `Wait` backoff suggested to workers when nothing is assignable.
const WAIT_MS: u64 = 25;

/// Coordinator knobs (`fxpnet cluster coordinator` flags).
#[derive(Clone, Debug)]
pub struct ClusterOpts {
    /// Bind address; port 0 picks a free port (see `port_file`).
    pub listen: String,
    /// File to write the bound `host:port` to once listening -- the
    /// rendezvous mechanism for `--listen 127.0.0.1:0`.
    pub port_file: Option<PathBuf>,
    pub hb: HeartbeatCfg,
    /// Maximum total attempts per cell (first dispatch included).
    pub retry_cap: usize,
    /// Base of the exponential re-dispatch backoff.
    pub backoff_base: Duration,
    /// Where to write the run summary JSON.
    pub summary_path: Option<PathBuf>,
    /// The sweep's cell cache (same file/schema as `fxpnet grid`).
    pub cache_path: PathBuf,
    pub lock: LockOpts,
}

impl Default for ClusterOpts {
    fn default() -> Self {
        ClusterOpts {
            listen: "127.0.0.1:0".into(),
            port_file: None,
            hb: HeartbeatCfg::default(),
            retry_cap: 5,
            backoff_base: Duration::from_millis(100),
            summary_path: None,
            cache_path: PathBuf::from("cache.json"),
            lock: LockOpts::default(),
        }
    }
}

/// Run accounting, written as `--summary` JSON.
#[derive(Clone, Debug, Default)]
pub struct ClusterSummary {
    /// grid size
    pub cells: usize,
    /// cells computed by workers this run
    pub computed: usize,
    /// cells pre-filled from the cache (crash-resume)
    pub cached: usize,
    /// re-dispatches after a presumed worker death
    pub redispatched: usize,
    /// duplicate results that bit-matched an already-recorded cell
    pub duplicates: usize,
    /// connections declared dead (deadline, EOF mid-cell, violation)
    pub worker_deaths: usize,
    /// handshakes refused (fingerprint/version/shard mismatch)
    pub rejected: usize,
    /// successful worker handshakes (reconnects count again)
    pub workers: usize,
    /// every cell of the grid accounted for
    pub complete: bool,
    /// the run ended by drain (signal) rather than completion
    pub drained: bool,
}

impl ClusterSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cells", Json::from(self.cells)),
            ("computed", Json::from(self.computed)),
            ("cached", Json::from(self.cached)),
            ("redispatched", Json::from(self.redispatched)),
            ("duplicates", Json::from(self.duplicates)),
            ("worker_deaths", Json::from(self.worker_deaths)),
            ("rejected", Json::from(self.rejected)),
            ("workers", Json::from(self.workers)),
            ("complete", Json::from(self.complete)),
            ("drained", Json::from(self.drained)),
        ])
    }

    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let tmp = path.with_extension("json.tmp");
        crate::util::durable::write_atomic(
            path,
            &tmp,
            self.to_json().to_string().as_bytes(),
        )
    }
}

/// What a coordinator run produced.
#[derive(Debug)]
pub struct ClusterOutcome {
    pub grid: GridResult,
    pub summary: ClusterSummary,
    /// every recorded cell keyed by cache cell key (drained-away cells
    /// absent) -- the stability report's input, same shape as
    /// `SweepOutcome::cells`
    pub cells: BTreeMap<String, CellEval>,
    /// telemetry digests of cells computed this run (cached pre-fills
    /// carry none), keyed like `cells`
    pub telemetry: BTreeMap<String, TelemetrySummary>,
}

/// A cell awaiting (re-)dispatch.
#[derive(Clone, Copy, Debug)]
struct Pending {
    flat: usize,
    /// attempt number the *next* dispatch will carry (1 = first)
    attempt: usize,
    /// backoff gate; `None` = immediately assignable
    not_before: Option<Instant>,
}

#[derive(Default)]
struct Stats {
    computed: usize,
    redispatched: usize,
    duplicates: usize,
    worker_deaths: usize,
    rejected: usize,
    workers: usize,
}

struct Shared {
    jobs: Vec<CellJob>,
    pending: Vec<Pending>,
    /// flat -> attempt currently in flight
    inflight: HashMap<usize, usize>,
    done: HashMap<usize, CellResult>,
    /// flat -> stability digest of cells computed this run
    telemetry: HashMap<usize, TelemetrySummary>,
    cache: ShardedCache,
    draining: bool,
    fatal: Option<String>,
    stats: Stats,
}

impl Shared {
    fn complete(&self) -> bool {
        self.done.len() == self.jobs.len()
    }

    fn set_fatal(&mut self, reason: String) {
        if self.fatal.is_none() {
            log::error!("cluster fatal: {reason}");
            self.fatal = Some(reason);
        }
    }

    /// A connection holding `flat` died (deadline, EOF, violation).
    fn requeue(&mut self, flat: usize, backoff_base: Duration, retry_cap: usize) {
        self.stats.worker_deaths += 1;
        let Some(attempt) = self.inflight.remove(&flat) else {
            return; // its result already landed via another path
        };
        if self.done.contains_key(&flat) {
            return;
        }
        let next = attempt + 1;
        if next > retry_cap {
            self.set_fatal(format!(
                "cell flat={flat} ({}) exceeded retry cap: {retry_cap} \
                 attempts, every worker holding it died",
                CellCache::key(&self.jobs[flat])
            ));
            return;
        }
        self.stats.redispatched += 1;
        // exponential backoff: 1x, 2x, 4x... of the base
        let wait = backoff_base * (1u32 << (next - 2).min(16) as u32);
        log::warn!(
            "requeueing cell flat={flat} as attempt {next} (backoff {wait:?})"
        );
        self.pending.push(Pending {
            flat,
            attempt: next,
            not_before: Some(Instant::now() + wait),
        });
    }

    /// Record one result.  Duplicates must bit-match (and their
    /// telemetry digests byte-match); first copies are cached
    /// immediately so a coordinator crash never loses them.
    fn record(
        &mut self,
        flat: usize,
        attempt: usize,
        eval: CellEval,
        telemetry: Option<TelemetrySummary>,
    ) {
        self.inflight.remove(&flat);
        if let Some(prev) = self.done.get(&flat) {
            if shard::cells_bit_equal(prev, &eval) {
                self.stats.duplicates += 1;
                log::info!(
                    "duplicate result for cell flat={flat} (attempt {attempt}) \
                     bit-matches the recorded copy"
                );
                match (self.telemetry.get(&flat), telemetry) {
                    (Some(p), Some(t))
                        if p.to_json().to_string()
                            != t.to_json().to_string() =>
                    {
                        self.set_fatal(format!(
                            "duplicate result for cell flat={flat} ({}) \
                             bit-matches but its telemetry digest differs; \
                             per-cell determinism is broken",
                            CellCache::key(&self.jobs[flat])
                        ));
                    }
                    // a cache-prefilled cell has no digest; a late
                    // duplicate's is as good as a first copy's
                    (None, Some(t)) => {
                        self.telemetry.insert(flat, t);
                    }
                    _ => {}
                }
            } else {
                self.set_fatal(format!(
                    "duplicate result for cell flat={flat} ({}) does NOT \
                     bit-match the recorded copy: {prev:?} vs {eval:?}; \
                     per-cell determinism is broken",
                    CellCache::key(&self.jobs[flat])
                ));
            }
            return;
        }
        self.done.insert(flat, eval);
        if let Some(t) = telemetry {
            self.telemetry.insert(flat, t);
        }
        self.stats.computed += 1;
        self.cache.put(&self.jobs[flat], &eval);
        if let Err(e) = self.cache.save() {
            log::warn!("cell cache save failed: {e}");
        }
    }

    /// Pick the next assignable cell for a worker pinned to `wshard`.
    fn assign(&mut self, wshard: Option<(usize, usize)>) -> Option<Pending> {
        let now = Instant::now();
        let idx = self.pending.iter().position(|p| {
            in_shard(p.flat, wshard)
                && p.not_before.map(|t| t <= now).unwrap_or(true)
        })?;
        let p = self.pending.swap_remove(idx);
        self.inflight.insert(p.flat, p.attempt);
        Some(p)
    }
}

/// Serve one sweep to TCP workers until complete, drained, or fatal.
///
/// `fp` is the sweep fingerprint ([`crate::cluster::sweep_fingerprint`])
/// this coordinator's flags derive; workers whose own fingerprint
/// differs are rejected at handshake.  `shutdown` is polled each tick --
/// hook it to SIGTERM/SIGINT via
/// [`crate::cluster::install_drain_handler`].
pub fn run_coordinator(
    regime: Regime,
    arch: &str,
    base_seed: u64,
    fp: u64,
    opts: &ClusterOpts,
    shutdown: &AtomicBool,
) -> Result<ClusterOutcome> {
    let jobs = grid_jobs(regime, base_seed);
    debug_assert!(jobs.iter().enumerate().all(|(i, j)| i == j.flat));

    // crash-resume: the cache (opened under its advisory lock) pre-fills
    // `done`; only the remainder is served
    let cache = ShardedCache::open(
        &opts.cache_path,
        arch,
        regime,
        base_seed,
        None,
        &opts.lock,
    )?;
    let mut done = HashMap::new();
    for job in &jobs {
        if let Some(r) = cache.get(job) {
            done.insert(job.flat, r);
        }
    }
    let cached = done.len();
    let pending: Vec<Pending> = jobs
        .iter()
        .filter(|j| !done.contains_key(&j.flat))
        .map(|j| Pending { flat: j.flat, attempt: 1, not_before: None })
        .collect();
    log::info!(
        "cluster coordinator: {} cells ({} cached, {} to serve), cache {}",
        jobs.len(),
        cached,
        pending.len(),
        cache.path().display()
    );

    let listener = TcpListener::bind(&opts.listen)?;
    listener.set_nonblocking(true)?;
    let addr = listener.local_addr()?;
    log::info!("cluster coordinator listening on {addr}");
    if let Some(pf) = &opts.port_file {
        // atomic write: a polling worker/launcher never sees a partial
        // address
        let tmp = pf.with_extension("tmp");
        crate::util::durable::write_atomic(pf, &tmp, format!("{addr}\n").as_bytes())?;
    }

    let shared = Mutex::new(Shared {
        jobs,
        pending,
        inflight: HashMap::new(),
        done,
        telemetry: HashMap::new(),
        cache,
        draining: false,
        fatal: None,
        stats: Stats::default(),
    });
    let mut drained = false;

    std::thread::scope(|s| -> Result<()> {
        let mut drain_since: Option<Instant> = None;
        loop {
            if shutdown.load(Ordering::SeqCst) {
                let mut sh = shared.lock().unwrap();
                if !sh.draining {
                    log::warn!("shutdown requested: draining (no new assignments)");
                    sh.draining = true;
                    drained = true;
                    drain_since = Some(Instant::now());
                }
            }
            // drain the whole accept backlog each tick: a burst of
            // workers must not trickle in at one connection per tick
            loop {
                match listener.accept() {
                    Ok((stream, peer)) => {
                        log::info!("connection from {peer}");
                        s.spawn(|| handle_conn(stream, &shared, fp, opts));
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                    Err(e) => {
                        shared.lock().unwrap().set_fatal(format!("accept: {e}"));
                        break;
                    }
                }
            }
            {
                let sh = shared.lock().unwrap();
                if sh.fatal.is_some() || sh.complete() {
                    break;
                }
                if sh.draining {
                    // bounded grace for in-flight results, then give up
                    let grace_up = drain_since
                        .map(|t| t.elapsed() > 2 * opts.hb.deadline)
                        .unwrap_or(true);
                    if sh.inflight.is_empty() || grace_up {
                        break;
                    }
                }
            }
            std::thread::sleep(TICK);
        }
        // handler threads observe complete/draining/fatal on their next
        // tick and exit; the scope join is bounded by the heartbeat
        // deadline even for hung peers
        shared.lock().unwrap().draining = true;
        Ok(())
    })?;

    let mut sh = shared.into_inner().unwrap();
    if let Err(e) = sh.cache.save() {
        log::warn!("final cell cache save failed: {e}");
    }
    let complete = sh.complete();
    let summary = ClusterSummary {
        cells: sh.jobs.len(),
        computed: sh.stats.computed,
        cached,
        redispatched: sh.stats.redispatched,
        duplicates: sh.stats.duplicates,
        worker_deaths: sh.stats.worker_deaths,
        rejected: sh.stats.rejected,
        workers: sh.stats.workers,
        complete,
        drained,
    };
    if let Some(p) = &opts.summary_path {
        summary.save(p)?;
        log::info!("summary written to {}", p.display());
    }
    if let Some(reason) = sh.fatal.take() {
        return Err(FxpError::config(format!("cluster: {reason}")));
    }

    // assemble the table exactly like the single-process sweep: missing
    // cells (drained early) render n/a
    let w_axis = WidthSpec::paper_axis().to_vec();
    let a_axis = WidthSpec::paper_axis().to_vec();
    let mut outcomes = Vec::with_capacity(a_axis.len());
    let mut cells = BTreeMap::new();
    let mut telemetry = BTreeMap::new();
    for (ai, &a) in a_axis.iter().enumerate() {
        let mut row = Vec::with_capacity(w_axis.len());
        for (wi, &w) in w_axis.iter().enumerate() {
            let flat = ai * w_axis.len() + wi;
            let known = sh.done.get(&flat).copied();
            if let Some(eval) = known {
                let key = CellCache::key(&sh.jobs[flat]);
                cells.insert(key.clone(), eval);
                if let Some(t) = sh.telemetry.get(&flat) {
                    telemetry.insert(key, t.clone());
                }
            }
            row.push(CellOutcome { w, a, eval: known.unwrap_or(CellEval::Na) });
        }
        outcomes.push(row);
    }
    Ok(ClusterOutcome {
        grid: GridResult {
            regime,
            arch: arch.to_string(),
            w_axis,
            a_axis,
            outcomes,
        },
        summary,
        cells,
        telemetry,
    })
}

fn reply(stream: &mut TcpStream, msg: &Msg) -> Result<()> {
    write_frame(stream, msg)
}

/// One connection's lifecycle, run on its own scoped thread.
fn handle_conn(
    mut stream: TcpStream,
    shared: &Mutex<Shared>,
    fp: u64,
    opts: &ClusterOpts,
) {
    if let Err(e) = stream.set_read_timeout(Some(TICK)) {
        log::warn!("set_read_timeout: {e}");
        return;
    }
    let _ = stream.set_nodelay(true);

    // handshake, bounded by the heartbeat deadline
    let hello_deadline = Instant::now() + opts.hb.deadline;
    let (name, wshard) = loop {
        match read_frame(&mut stream, Some(hello_deadline)) {
            Ok(Frame::TimedOut) => {
                if Instant::now() >= hello_deadline {
                    log::warn!("peer never said hello; dropping");
                    return;
                }
            }
            Ok(Frame::Eof) => return,
            Ok(Frame::Msg(Msg::Hello {
                proto,
                cache_version,
                name,
                pid,
                host,
                fp: worker_fp,
                shard: wshard,
            })) => {
                let mut why = None;
                if proto != PROTO_VERSION {
                    why = Some(format!(
                        "protocol {proto} != coordinator {PROTO_VERSION}"
                    ));
                } else if cache_version != CACHE_VERSION {
                    why = Some(format!(
                        "cache version {cache_version} != coordinator \
                         {CACHE_VERSION}"
                    ));
                } else if worker_fp != fp {
                    why = Some(format!(
                        "sweep fingerprint {worker_fp:016x} != coordinator \
                         {fp:016x}: flags describe different sweeps"
                    ));
                } else if let Some((i, n)) = wshard {
                    if let Err(e) = shard::validate_shard(i, n) {
                        why = Some(e.to_string());
                    }
                }
                if let Some(reason) = why {
                    log::warn!("rejecting {name} ({host}, pid {pid}): {reason}");
                    shared.lock().unwrap().stats.rejected += 1;
                    let _ = reply(&mut stream, &Msg::Reject { reason });
                    return;
                }
                log::info!("worker {name} ({host}, pid {pid}) joined");
                shared.lock().unwrap().stats.workers += 1;
                if reply(
                    &mut stream,
                    &Msg::Welcome {
                        heartbeat_ms: opts.hb.interval.as_millis() as u64,
                        deadline_ms: opts.hb.deadline.as_millis() as u64,
                    },
                )
                .is_err()
                {
                    return;
                }
                break (name, wshard);
            }
            Ok(Frame::Msg(other)) => {
                log::warn!("peer spoke before hello ({other:?}); dropping");
                return;
            }
            Err(e) => {
                log::warn!("bad handshake frame: {e}; dropping peer");
                return;
            }
        }
    };

    let mut clock = DeadlineClock::new(opts.hb.deadline);
    // the cell this connection is computing right now
    let mut holding: Option<usize> = None;

    // on every exit path, a held cell must be requeued
    macro_rules! die {
        () => {{
            if let Some(flat) = holding {
                log::warn!("worker {name} presumed dead holding cell {flat}");
                shared.lock().unwrap().requeue(
                    flat,
                    opts.backoff_base,
                    opts.retry_cap,
                );
            }
            return;
        }};
    }

    loop {
        match read_frame(&mut stream, Some(clock.expires_at())) {
            Ok(Frame::TimedOut) => {
                if clock.expired() {
                    log::warn!(
                        "worker {name}: no contact for {:?}",
                        opts.hb.deadline
                    );
                    die!();
                }
                let sh = shared.lock().unwrap();
                if sh.fatal.is_some() && holding.is_none() {
                    let reason = sh.fatal.clone().unwrap();
                    drop(sh);
                    let _ = reply(&mut stream, &Msg::Fatal { reason });
                    return;
                }
            }
            Ok(Frame::Eof) => {
                if holding.is_some() {
                    die!();
                }
                log::info!("worker {name} disconnected");
                return;
            }
            Ok(Frame::Msg(Msg::Heartbeat)) => clock.touch(),
            Ok(Frame::Msg(Msg::Request)) => {
                clock.touch();
                let out = {
                    let mut sh = shared.lock().unwrap();
                    if let Some(reason) = sh.fatal.clone() {
                        Msg::Fatal { reason }
                    } else if sh.complete() {
                        Msg::Drain { complete: true }
                    } else if sh.draining {
                        Msg::Drain { complete: false }
                    } else if let Some(p) = sh.assign(wshard) {
                        holding = Some(p.flat);
                        Msg::Assign {
                            flat: p.flat,
                            key: CellCache::key(&sh.jobs[p.flat]),
                            attempt: p.attempt,
                        }
                    } else {
                        Msg::Wait { ms: WAIT_MS }
                    }
                };
                let assigned = matches!(out, Msg::Assign { .. });
                let terminal = matches!(out, Msg::Drain { .. } | Msg::Fatal { .. });
                if reply(&mut stream, &out).is_err() {
                    die!();
                }
                if terminal {
                    return;
                }
                if !assigned {
                    holding = None;
                }
            }
            Ok(Frame::Msg(Msg::Result { flat, key, attempt, eval, telemetry })) => {
                clock.touch();
                let mut sh = shared.lock().unwrap();
                let expect = sh
                    .jobs
                    .get(flat)
                    .map(CellCache::key)
                    .unwrap_or_default();
                if key != expect {
                    sh.set_fatal(format!(
                        "worker {name} returned cell key '{key}' for flat \
                         {flat}, expected '{expect}'"
                    ));
                    return;
                }
                sh.record(flat, attempt, eval, telemetry);
                holding = None;
            }
            Ok(Frame::Msg(Msg::Fatal { reason })) => {
                log::warn!("worker {name} aborted: {reason}");
                die!();
            }
            Ok(Frame::Msg(other)) => {
                log::warn!(
                    "worker {name}: protocol violation ({other:?}); dropping"
                );
                die!();
            }
            Err(e) => {
                log::warn!("worker {name}: bad frame: {e}; dropping");
                die!();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requeue_backs_off_and_caps() {
        let dir = std::env::temp_dir().join(format!(
            "fxp_cluster_requeue_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let cache = ShardedCache::open(
            &dir.join("cache.json"),
            "tiny",
            Regime::Vanilla,
            42,
            None,
            &LockOpts::default(),
        )
        .unwrap();
        let jobs = grid_jobs(Regime::Vanilla, 42);
        let n = jobs.len();
        let mut sh = Shared {
            jobs,
            pending: Vec::new(),
            inflight: HashMap::new(),
            done: HashMap::new(),
            telemetry: HashMap::new(),
            cache,
            draining: false,
            fatal: None,
            stats: Stats::default(),
        };
        let base = Duration::from_millis(10);

        // attempt 1 dies -> requeued as attempt 2 with a backoff gate
        sh.inflight.insert(3, 1);
        sh.requeue(3, base, 3);
        assert_eq!(sh.pending.len(), 1);
        assert_eq!(sh.pending[0].attempt, 2);
        assert!(sh.pending[0].not_before.is_some());
        assert_eq!(sh.stats.redispatched, 1);
        assert!(sh.fatal.is_none());

        // immediately assignable only once the gate passes
        assert!(sh.assign(None).is_none());
        std::thread::sleep(Duration::from_millis(25));
        let p = sh.assign(None).expect("gate passed");
        assert_eq!((p.flat, p.attempt), (3, 2));

        // cap exhaustion is fatal, not a silent n/a
        sh.requeue(3, base, 3); // attempt 3 queued
        sh.pending.clear();
        sh.inflight.insert(3, 3);
        sh.requeue(3, base, 3);
        assert!(sh.fatal.as_deref().unwrap().contains("retry cap"));

        // a death with no in-flight cell requeues nothing
        let deaths = sh.stats.worker_deaths;
        sh.requeue(n - 1, base, 3);
        assert_eq!(sh.stats.worker_deaths, deaths + 1);
        assert!(sh.pending.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn duplicate_results_must_bit_match() {
        let dir = std::env::temp_dir().join(format!(
            "fxp_cluster_dup_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let cache = ShardedCache::open(
            &dir.join("cache.json"),
            "tiny",
            Regime::Vanilla,
            42,
            None,
            &LockOpts::default(),
        )
        .unwrap();
        let mut sh = Shared {
            jobs: grid_jobs(Regime::Vanilla, 42),
            pending: Vec::new(),
            inflight: HashMap::new(),
            done: HashMap::new(),
            telemetry: HashMap::new(),
            cache,
            draining: false,
            fatal: None,
            stats: Stats::default(),
        };
        let ok = CellEval::Ok(crate::coordinator::evaluator::EvalResult {
            n: 100,
            top1_err: 0.25,
            top5_err: 0.1,
            mean_loss: 1.5,
        });
        sh.record(0, 1, ok, None);
        assert_eq!(sh.stats.computed, 1);

        // bit-identical duplicate: counted, harmless
        sh.record(0, 2, ok, None);
        assert_eq!(sh.stats.duplicates, 1);
        assert!(sh.fatal.is_none());

        // bit-mismatched duplicate: hard error
        let skewed = CellEval::Ok(crate::coordinator::evaluator::EvalResult {
            n: 100,
            top1_err: 0.25 + f64::EPSILON,
            top5_err: 0.1,
            mean_loss: 1.5,
        });
        sh.record(0, 3, skewed, None);
        assert!(sh.fatal.as_deref().unwrap().contains("bit-match"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
