//! Worker liveness: heartbeat contract and deadline bookkeeping.
//!
//! The coordinator tells each worker (in `Welcome`) how often to beat
//! and how long silence may last.  Any frame from a worker -- heartbeat,
//! request, result -- counts as liveness; a [`DeadlineClock`] that
//! expires means the worker is presumed dead and its in-flight cell is
//! requeued.  The deadline should be several heartbeat intervals so one
//! lost or delayed beat never kills a healthy worker.

use std::time::{Duration, Instant};

/// Heartbeat contract handed to workers at handshake.
#[derive(Clone, Copy, Debug)]
pub struct HeartbeatCfg {
    /// How often workers send `Heartbeat`.
    pub interval: Duration,
    /// Silence longer than this marks the worker dead.
    pub deadline: Duration,
}

impl Default for HeartbeatCfg {
    fn default() -> Self {
        HeartbeatCfg {
            interval: Duration::from_secs(1),
            deadline: Duration::from_secs(5),
        }
    }
}

/// Last-contact tracker for one connection.
#[derive(Clone, Copy, Debug)]
pub struct DeadlineClock {
    last: Instant,
    deadline: Duration,
}

impl DeadlineClock {
    pub fn new(deadline: Duration) -> Self {
        DeadlineClock { last: Instant::now(), deadline }
    }

    /// Record contact (any frame, not just heartbeats).
    pub fn touch(&mut self) {
        self.last = Instant::now();
    }

    /// Has the silence exceeded the deadline?
    pub fn expired(&self) -> bool {
        self.last.elapsed() > self.deadline
    }

    /// Absolute instant after which [`expired`](Self::expired) holds;
    /// useful as a read-until bound for mid-frame reads.
    pub fn expires_at(&self) -> Instant {
        self.last + self.deadline
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn touch_resets_the_clock() {
        let mut c = DeadlineClock::new(Duration::from_millis(30));
        assert!(!c.expired());
        std::thread::sleep(Duration::from_millis(45));
        assert!(c.expired());
        c.touch();
        assert!(!c.expired());
        assert!(c.expires_at() > Instant::now());
    }

    #[test]
    fn default_deadline_spans_several_intervals() {
        let cfg = HeartbeatCfg::default();
        assert!(cfg.deadline >= cfg.interval * 3);
    }
}
