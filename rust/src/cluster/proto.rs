//! Wire protocol for `fxpnet cluster`: length-prefixed JSON frames over
//! a `std::net` TCP stream.
//!
//! ## Framing
//!
//! One frame = `u32` little-endian payload length, then exactly that
//! many bytes of UTF-8 JSON (one message object carrying a `"type"`
//! tag), via the shared codec in [`crate::netio`] (the same substrate
//! `serve::proto` frames ride on).  [`MAX_FRAME`] bounds the payload so
//! a corrupt or hostile length prefix can never make a peer allocate
//! unbounded memory.  Any framing or schema violation is an `Err` --
//! both endpoints respond by dropping the peer with a logged error,
//! never by panicking (pinned by tests/cluster_proto.rs and the
//! malformed-frame integration test).
//!
//! ## Message flow
//!
//! Workers pull; the coordinator never initiates:
//!
//! ```text
//! worker                         coordinator
//!   Hello{fp, shard?}        ->
//!                            <-  Welcome{heartbeat_ms, deadline_ms}
//!                                | Reject{reason}
//!   Request                  ->
//!                            <-  Assign{flat, key, attempt}
//!                                | Wait{ms} | Drain{complete}
//!                                | Fatal{reason}
//!   Result{flat, .., eval}   ->
//!   Heartbeat                ->      (any time, incl. mid-cell)
//! ```
//!
//! Cell results ride in the cell cache's own JSON shape
//! ([`report::cell_eval_to_json`]), so a result that crossed the wire is
//! byte-for-byte what the cache file records -- the bit-identity
//! contract has a single serialization to audit.

use std::io::{Read, Write};
use std::time::Instant;

use crate::coordinator::regimes::CellEval;
use crate::coordinator::report::{cell_eval_from_json, cell_eval_to_json};
use crate::error::{FxpError, Result};
use crate::netio::{self, JsonFrame};
use crate::train::telemetry::TelemetrySummary;
use crate::util::json::Json;

pub use crate::netio::MAX_FRAME;

/// Protocol revision; bumped on any incompatible message change.  A
/// mismatch is rejected at handshake.  v2: `Result` carries the cell's
/// optional telemetry digest (stability analytics) -- a v1 peer would
/// silently drop it, losing the telemetry union's determinism, so the
/// handshake refuses the pairing instead.
pub const PROTO_VERSION: usize = 2;

/// One protocol message.
#[derive(Clone, Debug, PartialEq)]
pub enum Msg {
    /// Worker registration, once per connection.  `fp` is the sweep
    /// fingerprint ([`crate::cluster::sweep_fingerprint`]) both sides
    /// derive from their own flags; `shard` optionally pins the worker
    /// to a static `I/N` slice of the grid.
    Hello {
        proto: usize,
        cache_version: usize,
        name: String,
        pid: u64,
        host: String,
        fp: u64,
        shard: Option<(usize, usize)>,
    },
    /// Handshake accepted; the coordinator's heartbeat contract.
    Welcome { heartbeat_ms: u64, deadline_ms: u64 },
    /// Handshake refused (version/fingerprint mismatch, bad shard...).
    Reject { reason: String },
    /// Worker asks for a cell.
    Request,
    /// One unit of work.  `attempt` counts dispatches of this cell (1 =
    /// first); it rides back in `Result` so re-dispatch accounting never
    /// guesses.
    Assign { flat: usize, key: String, attempt: usize },
    /// Nothing assignable right now (cells in flight elsewhere or
    /// backing off); ask again in `ms`.
    Wait { ms: u64 },
    /// No more work ever: sweep complete, or the coordinator is
    /// draining.  The worker disconnects.
    Drain { complete: bool },
    /// A computed cell.  `telemetry` is the run's stability digest
    /// (`None` for evaluation-only regimes and synthetic executors); it
    /// rides the wire in [`TelemetrySummary::to_json`]'s byte-stable
    /// shape so a cluster sweep's stability report stays byte-identical
    /// to a single-process reference.
    Result {
        flat: usize,
        key: String,
        attempt: usize,
        eval: CellEval,
        telemetry: Option<TelemetrySummary>,
    },
    /// Liveness signal (sent from a side thread even mid-cell).
    Heartbeat,
    /// Unrecoverable sweep error (e.g. a bit-mismatched duplicate); the
    /// worker aborts.
    Fatal { reason: String },
}

fn u64_str(v: u64) -> Json {
    // u64 round-trips as a string; Json numbers are f64 (2^53 cap)
    Json::Str(v.to_string())
}

fn parse_u64(j: &Json, key: &str) -> Result<u64> {
    let s = j.get(key)?.as_str()?;
    s.parse::<u64>()
        .map_err(|_| FxpError::Json(format!("bad u64 '{s}' for '{key}'")))
}

impl Msg {
    pub fn to_json(&self) -> Json {
        match self {
            Msg::Hello { proto, cache_version, name, pid, host, fp, shard } => {
                let mut pairs = vec![
                    ("type", Json::from("hello")),
                    ("proto", Json::from(*proto)),
                    ("cache_version", Json::from(*cache_version)),
                    ("name", Json::Str(name.clone())),
                    ("pid", u64_str(*pid)),
                    ("host", Json::Str(host.clone())),
                    ("fp", u64_str(*fp)),
                ];
                if let Some((i, n)) = shard {
                    pairs.push(("shard_index", Json::from(*i)));
                    pairs.push(("shard_count", Json::from(*n)));
                }
                Json::obj(pairs)
            }
            Msg::Welcome { heartbeat_ms, deadline_ms } => Json::obj(vec![
                ("type", Json::from("welcome")),
                ("heartbeat_ms", Json::from(*heartbeat_ms as usize)),
                ("deadline_ms", Json::from(*deadline_ms as usize)),
            ]),
            Msg::Reject { reason } => Json::obj(vec![
                ("type", Json::from("reject")),
                ("reason", Json::Str(reason.clone())),
            ]),
            Msg::Request => Json::obj(vec![("type", Json::from("request"))]),
            Msg::Assign { flat, key, attempt } => Json::obj(vec![
                ("type", Json::from("assign")),
                ("flat", Json::from(*flat)),
                ("key", Json::Str(key.clone())),
                ("attempt", Json::from(*attempt)),
            ]),
            Msg::Wait { ms } => Json::obj(vec![
                ("type", Json::from("wait")),
                ("ms", Json::from(*ms as usize)),
            ]),
            Msg::Drain { complete } => Json::obj(vec![
                ("type", Json::from("drain")),
                ("complete", Json::from(*complete)),
            ]),
            Msg::Result { flat, key, attempt, eval, telemetry } => {
                let mut pairs = vec![
                    ("type", Json::from("result")),
                    ("flat", Json::from(*flat)),
                    ("key", Json::Str(key.clone())),
                    ("attempt", Json::from(*attempt)),
                    // the cache's own cell encoding: non-finite evals
                    // flatten to "na" exactly like CellCache::put would
                    ("cell", cell_eval_to_json(eval)),
                ];
                if let Some(t) = telemetry {
                    pairs.push(("telemetry", t.to_json()));
                }
                Json::obj(pairs)
            }
            Msg::Heartbeat => Json::obj(vec![("type", Json::from("heartbeat"))]),
            Msg::Fatal { reason } => Json::obj(vec![
                ("type", Json::from("fatal")),
                ("reason", Json::Str(reason.clone())),
            ]),
        }
    }

    pub fn from_json(j: &Json) -> Result<Msg> {
        let ty = j.get("type")?.as_str()?;
        Ok(match ty {
            "hello" => {
                let shard = match (j.opt("shard_index"), j.opt("shard_count")) {
                    (Some(i), Some(n)) => Some((i.as_usize()?, n.as_usize()?)),
                    (None, None) => None,
                    _ => {
                        return Err(FxpError::Json(
                            "hello: half-specified shard".into(),
                        ))
                    }
                };
                Msg::Hello {
                    proto: j.get("proto")?.as_usize()?,
                    cache_version: j.get("cache_version")?.as_usize()?,
                    name: j.get("name")?.as_str()?.to_string(),
                    pid: parse_u64(j, "pid")?,
                    host: j.get("host")?.as_str()?.to_string(),
                    fp: parse_u64(j, "fp")?,
                    shard,
                }
            }
            "welcome" => Msg::Welcome {
                heartbeat_ms: j.get("heartbeat_ms")?.as_usize()? as u64,
                deadline_ms: j.get("deadline_ms")?.as_usize()? as u64,
            },
            "reject" => Msg::Reject {
                reason: j.get("reason")?.as_str()?.to_string(),
            },
            "request" => Msg::Request,
            "assign" => Msg::Assign {
                flat: j.get("flat")?.as_usize()?,
                key: j.get("key")?.as_str()?.to_string(),
                attempt: j.get("attempt")?.as_usize()?,
            },
            "wait" => Msg::Wait { ms: j.get("ms")?.as_usize()? as u64 },
            "drain" => Msg::Drain {
                complete: match j.get("complete")? {
                    Json::Bool(b) => *b,
                    other => {
                        return Err(FxpError::Json(format!(
                            "drain: bad 'complete' {other}"
                        )))
                    }
                },
            },
            "result" => Msg::Result {
                flat: j.get("flat")?.as_usize()?,
                key: j.get("key")?.as_str()?.to_string(),
                attempt: j.get("attempt")?.as_usize()?,
                eval: cell_eval_from_json("result", j.get("cell")?)?,
                telemetry: match j.opt("telemetry") {
                    Some(t) => Some(TelemetrySummary::from_json(t)?),
                    None => None,
                },
            },
            "heartbeat" => Msg::Heartbeat,
            "fatal" => Msg::Fatal {
                reason: j.get("reason")?.as_str()?.to_string(),
            },
            other => {
                return Err(FxpError::Json(format!("unknown message type '{other}'")))
            }
        })
    }
}

/// What one read attempt produced.
#[derive(Debug)]
pub enum Frame {
    /// A complete, well-formed message.
    Msg(Msg),
    /// Clean EOF at a frame boundary (the peer closed).
    Eof,
    /// The socket's read timeout fired before any byte of a new frame
    /// arrived -- a scheduling tick, not an error (the caller checks its
    /// heartbeat deadline and retries).
    TimedOut,
}

/// Encode `msg` as one frame.  Errors (rather than truncating) if the
/// payload would exceed [`MAX_FRAME`].
pub fn write_frame(w: &mut impl Write, msg: &Msg) -> Result<()> {
    netio::write_json_frame(w, &msg.to_json())
}

/// Read one frame.  With a socket read timeout set, a quiet boundary
/// returns [`Frame::TimedOut`] so the caller can run its deadline
/// bookkeeping; a frame that *started* keeps reading until `deadline`.
/// A clean close at a boundary is [`Frame::Eof`]; everything malformed
/// (oversized length, truncation, bad JSON, unknown type) is `Err`.
pub fn read_frame(r: &mut impl Read, deadline: Option<Instant>) -> Result<Frame> {
    Ok(match netio::read_json_frame(r, deadline)? {
        JsonFrame::Msg(j) => Frame::Msg(Msg::from_json(&j)?),
        JsonFrame::Eof => Frame::Eof,
        JsonFrame::TimedOut => Frame::TimedOut,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::EvalResult;
    use crate::coordinator::trainer::AbortReason;

    fn round_trip(m: &Msg) -> Msg {
        let mut buf = Vec::new();
        write_frame(&mut buf, m).unwrap();
        match read_frame(&mut buf.as_slice(), None).unwrap() {
            Frame::Msg(back) => back,
            other => panic!("expected a message, got {other:?}"),
        }
    }

    #[test]
    fn basic_round_trips() {
        let msgs = vec![
            Msg::Request,
            Msg::Heartbeat,
            Msg::Wait { ms: 123 },
            Msg::Drain { complete: true },
            Msg::Assign { flat: 7, key: "w=8,a=4".into(), attempt: 2 },
            Msg::Result {
                flat: 7,
                key: "w=8,a=4".into(),
                attempt: 2,
                eval: CellEval::Ok(EvalResult {
                    n: 1000,
                    top1_err: 0.1 + 0.2,
                    top5_err: 1.0 / 3.0,
                    mean_loss: 1e-17,
                }),
                telemetry: None,
            },
            Msg::Result {
                flat: 3,
                key: "w=4,a=8".into(),
                attempt: 1,
                eval: CellEval::Na,
                telemetry: Some(TelemetrySummary {
                    steps: 12,
                    loss_start: 2.25,
                    loss_peak: 3.5,
                    loss_final: 3.5,
                    sat_final: 0.125,
                    sat_peak: 0.25,
                    ratio_min: Some(1.5e-4),
                    ratio_final: None,
                    windows: vec![crate::train::telemetry::WindowSummary {
                        start_step: 1,
                        end_step: 12,
                        count: 12,
                        ratio_q: vec![1.5e-4, 2e-4, 3e-4, 4e-4, 5e-4],
                    }],
                }),
            },
            Msg::Hello {
                proto: PROTO_VERSION,
                cache_version: 4,
                name: "w0".into(),
                pid: u64::MAX,
                host: "h".into(),
                fp: 0xDEAD_BEEF_DEAD_BEEF,
                shard: Some((1, 3)),
            },
        ];
        for m in &msgs {
            assert_eq!(&round_trip(m), m);
        }
        // bit-exactness of floats through the wire
        if let Msg::Result { eval: CellEval::Ok(e), .. } = round_trip(&msgs[5]) {
            assert_eq!(e.top1_err.to_bits(), (0.1f64 + 0.2).to_bits());
            assert_eq!(e.mean_loss.to_bits(), 1e-17f64.to_bits());
        } else {
            panic!("result did not round trip");
        }
    }

    #[test]
    fn aborted_and_na_results_round_trip() {
        for eval in [
            CellEval::Na,
            CellEval::Aborted { reason: AbortReason::NanLoss, step: 37 },
        ] {
            let m = Msg::Result {
                flat: 0,
                key: "w=4,a=4".into(),
                attempt: 1,
                eval,
                telemetry: None,
            };
            assert_eq!(round_trip(&m), m);
        }
    }

    #[test]
    fn non_finite_eval_flattens_to_na_like_the_cache() {
        let m = Msg::Result {
            flat: 0,
            key: "w=4,a=4".into(),
            attempt: 1,
            eval: CellEval::Ok(EvalResult {
                n: 10,
                top1_err: f64::NAN,
                top5_err: 0.1,
                mean_loss: 1.0,
            }),
            telemetry: None,
        };
        match round_trip(&m) {
            Msg::Result { eval: CellEval::Na, .. } => {}
            other => panic!("expected na, got {other:?}"),
        }
    }

    #[test]
    fn eof_and_oversize_and_garbage() {
        // clean EOF at a boundary
        assert!(matches!(
            read_frame(&mut (&[] as &[u8]), None).unwrap(),
            Frame::Eof
        ));
        // EOF mid-length-prefix is truncation, not clean
        assert!(read_frame(&mut (&[1u8, 0] as &[u8]), None).is_err());
        // oversized length prefix
        let big = ((MAX_FRAME + 1) as u32).to_le_bytes();
        assert!(read_frame(&mut (&big[..] as &[u8]), None).is_err());
        // valid length, garbage payload
        let mut buf = 3u32.to_le_bytes().to_vec();
        buf.extend_from_slice(b"{x}");
        assert!(read_frame(&mut buf.as_slice(), None).is_err());
        // unknown message type
        let payload = br#"{"type":"warp-core-breach"}"#;
        let mut buf = (payload.len() as u32).to_le_bytes().to_vec();
        buf.extend_from_slice(payload);
        assert!(read_frame(&mut buf.as_slice(), None).is_err());
    }

    #[test]
    fn oversize_is_rejected_on_the_write_side_too() {
        let m = Msg::Fatal { reason: "x".repeat(MAX_FRAME) };
        let mut buf = Vec::new();
        assert!(write_frame(&mut buf, &m).is_err());
        assert!(buf.is_empty(), "nothing must hit the wire");
    }
}
