//! Multi-machine sweep execution: `fxpnet cluster`.
//!
//! A coordinator process owns one regime's sweep and serves cells over
//! plain TCP ([`proto`]) to any number of worker processes, which pull
//! work, compute cells with the same per-cell seed tree as
//! `fxpnet grid`, and stream results back.  The coordinator writes the
//! same strict v4 cell cache and table JSON as a single-process sweep --
//! cluster execution is a *scheduling* change only, and the chaos test
//! pins the final artifacts byte-identical to a `--workers 1` reference
//! run even while workers are killed mid-cell.
//!
//! `fxpnet grid --shard I/N` remains as the static-scheduler escape
//! hatch (no coordinator process, shards merged offline); `cluster` is
//! for elastic pools where workers come, go, and die.
//!
//! Module map:
//! * [`proto`] -- length-prefixed JSON wire protocol;
//! * [`heartbeat`] -- liveness contract and deadline clocks;
//! * [`coordinator`] -- work-stealing scheduler, retry/backoff,
//!   duplicate bit-verification, crash-resume, graceful drain;
//! * [`worker`] -- pull loop, heartbeat thread, reconnects;
//! * [`fault`] -- deterministic fault injection for chaos tests.

pub mod coordinator;
pub mod fault;
pub mod heartbeat;
pub mod proto;
pub mod worker;

pub use coordinator::{run_coordinator, ClusterOpts, ClusterOutcome, ClusterSummary};
pub use fault::FaultSpec;
pub use heartbeat::HeartbeatCfg;
pub use worker::{run_worker, CellExec, SyntheticExec, WorkerOpts, WorkerReport};

use std::sync::atomic::{AtomicBool, Ordering};

use crate::coordinator::config::RunCfg;
use crate::coordinator::regimes::Regime;
use crate::coordinator::report::CACHE_VERSION;
use crate::util::rng::derive_seed;

/// Fingerprint of everything that must agree between a coordinator and
/// a worker for their cells to be interchangeable: the sweep identity
/// (arch, regime, base seed), the cache schema, the executor kind
/// (synthetic vs real), and every `RunCfg` field that shapes cell
/// numerics.  Both sides derive it from their *own* flags; the
/// handshake rejects a mismatch, so a mis-flagged worker can never
/// poison a sweep with bit-different results.
///
/// Deliberately excluded: `workers`/`threads` (bit-identical by the
/// engine's contract) and `topk` (rendering only).
pub fn sweep_fingerprint(
    arch: &str,
    regime: Regime,
    base_seed: u64,
    synthetic: bool,
    cfg: &RunCfg,
) -> u64 {
    fn fold_str(h: u64, domain: &str, s: &str) -> u64 {
        let mut parts = vec![s.len() as u64];
        parts.extend(s.as_bytes().iter().map(|&b| b as u64));
        derive_seed(h, domain, &parts)
    }
    let mut h = derive_seed(0x5EED_C105, "cluster-fp", &[]);
    h = fold_str(h, "arch", arch);
    h = derive_seed(
        h,
        "sweep",
        &[
            regime.seed_tag(),
            base_seed,
            CACHE_VERSION as u64,
            synthetic as u64,
        ],
    );
    h = derive_seed(
        h,
        "cfg",
        &[
            cfg.lr.to_bits() as u64,
            cfg.momentum.to_bits() as u64,
            cfg.finetune_steps as u64,
            cfg.phase_steps as u64,
            cfg.pretrain_steps as u64,
            cfg.pretrain_lr.to_bits() as u64,
            cfg.calib_batches as u64,
            cfg.method as u64,
            cfg.max_loss.to_bits() as u64,
            cfg.augment as u64,
            cfg.early_abort as u64,
        ],
    );
    // the resolved abort thresholds shape which cells end "aborted" vs
    // burn their full budget, so two processes disagreeing on an
    // `--abort-policy` overlay must not share a sweep.  One word per
    // regime entry keeps the fold order deterministic (BTreeMap).
    if cfg.early_abort {
        if let Some(overlay) = &cfg.abort_overlay {
            if let Some(p) = &overlay.default {
                h = derive_seed(h, "abort-default", &p.fingerprint_words());
            }
            for (tag, p) in &overlay.regimes {
                h = fold_str(h, "abort-regime", tag);
                h = derive_seed(h, "abort-policy", &p.fingerprint_words());
            }
        }
    }
    h
}

static DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn drain_signal_handler(_sig: i32) {
    // async-signal-safe: a single atomic store
    DRAIN.store(true, Ordering::SeqCst);
}

/// Install SIGINT/SIGTERM handlers that flip a drain flag instead of
/// killing the process, and return that flag for
/// [`run_coordinator`]'s `shutdown` argument.  The coordinator then
/// stops assigning, waits a bounded grace for in-flight cells, and
/// exits cleanly (exit code 2 if the sweep is incomplete).
///
/// Std-only: uses raw `signal(2)` via FFI (no signal-handling crate is
/// available offline).  On non-unix targets this is a no-op flag that
/// never fires.
pub fn install_drain_handler() -> &'static AtomicBool {
    #[cfg(unix)]
    {
        extern "C" {
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        const SIGTERM: i32 = 15;
        unsafe {
            signal(SIGINT, drain_signal_handler as usize);
            signal(SIGTERM, drain_signal_handler as usize);
        }
    }
    &DRAIN
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_separates_sweeps() {
        let cfg = RunCfg::smoke();
        let base = sweep_fingerprint("tiny", Regime::Vanilla, 42, true, &cfg);
        // stable across calls
        assert_eq!(
            base,
            sweep_fingerprint("tiny", Regime::Vanilla, 42, true, &cfg)
        );
        // every dimension separates
        let variants = [
            sweep_fingerprint("small", Regime::Vanilla, 42, true, &cfg),
            sweep_fingerprint("tiny", Regime::NoFinetune, 42, true, &cfg),
            sweep_fingerprint("tiny", Regime::Vanilla, 43, true, &cfg),
            sweep_fingerprint("tiny", Regime::Vanilla, 42, false, &cfg),
            sweep_fingerprint(
                "tiny",
                Regime::Vanilla,
                42,
                true,
                &RunCfg { lr: 0.5, ..RunCfg::smoke() },
            ),
            sweep_fingerprint(
                "tiny",
                Regime::Vanilla,
                42,
                true,
                &RunCfg { early_abort: false, ..RunCfg::smoke() },
            ),
            sweep_fingerprint(
                "tiny",
                Regime::Vanilla,
                42,
                true,
                &RunCfg {
                    abort_overlay: Some({
                        use crate::coordinator::trainer::{
                            AbortOverlay, AbortPolicy,
                        };
                        let mut o = AbortOverlay::default();
                        o.regimes.insert(
                            "vanilla".into(),
                            AbortPolicy { window: 9, ..Default::default() },
                        );
                        o
                    }),
                    ..RunCfg::smoke()
                },
            ),
        ];
        for v in variants {
            assert_ne!(base, v);
        }
    }

    #[test]
    fn drain_handler_returns_shared_flag() {
        let flag = install_drain_handler();
        assert!(!flag.load(Ordering::SeqCst) || cfg!(not(unix)));
    }
}
