//! Deterministic fault injection for cluster chaos tests.
//!
//! `--inject drop=P,delay=MS,kill-after=N` arms a [`FaultLayer`] inside
//! a worker.  Every decision is drawn from an RNG seeded off the cell
//! RNG tree (`derive_seed(base_seed, "fault-inject", [fnv64(name)])`),
//! so a chaos run replays exactly: the same worker name and base seed
//! drop the same frames and die after the same cell, which is what lets
//! the chaos test assert a byte-identical final table.
//!
//! Faults model the *network and process*, never the math:
//! - `drop=P` -- each send decision independently fails with
//!   probability P; the worker treats it as a broken connection and
//!   reconnects (heartbeat drops are just skipped beats).
//! - `delay=MS` -- sleep before each send, exercising mid-frame reads
//!   and deadline slack on the coordinator.
//! - `kill-after=N` -- after *computing* N cells, die without sending
//!   the Nth result: the canonical "worker killed mid-cell", guaranteed
//!   to force a re-dispatch.  `kill-after=0` dies at the first
//!   assignment before computing anything.

use std::time::Duration;

use crate::error::{FxpError, Result};
use crate::util::rng::{derive_seed, Rng};

/// FNV-1a over a name, to fold worker identity into the fault seed.
fn fnv64(s: &str) -> u64 {
    let mut h = 0xCBF2_9CE4_8422_2325u64;
    for &b in s.as_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

/// Parsed `--inject` spec.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultSpec {
    /// Probability in [0,1] that any one send is dropped.
    pub drop: f64,
    /// Fixed latency added before each send.
    pub delay: Duration,
    /// Die after computing this many cells (0 = before the first).
    pub kill_after: Option<usize>,
}

impl FaultSpec {
    /// Parse `"drop=0.2,delay=50,kill-after=3"`.  Keys may appear in
    /// any order; unknown keys are an error.
    pub fn parse(s: &str) -> Result<FaultSpec> {
        let mut spec = FaultSpec::default();
        for part in s.split(',').filter(|p| !p.is_empty()) {
            let (key, val) = part.split_once('=').ok_or_else(|| {
                FxpError::config(format!("--inject '{part}': expected key=value"))
            })?;
            match key {
                "drop" => {
                    let p: f64 = val.parse().map_err(|_| {
                        FxpError::config(format!("--inject drop: bad number '{val}'"))
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(FxpError::config(format!(
                            "--inject drop={p}: probability must be in [0,1]"
                        )));
                    }
                    spec.drop = p;
                }
                "delay" => {
                    let ms: u64 = val.parse().map_err(|_| {
                        FxpError::config(format!("--inject delay: bad ms '{val}'"))
                    })?;
                    spec.delay = Duration::from_millis(ms);
                }
                "kill-after" => {
                    let n: usize = val.parse().map_err(|_| {
                        FxpError::config(format!(
                            "--inject kill-after: bad count '{val}'"
                        ))
                    })?;
                    spec.kill_after = Some(n);
                }
                other => {
                    return Err(FxpError::config(format!(
                        "--inject: unknown key '{other}' \
                         (known: drop, delay, kill-after)"
                    )))
                }
            }
        }
        Ok(spec)
    }

    pub fn is_noop(&self) -> bool {
        *self == FaultSpec::default()
    }
}

/// Frame categories that take independent drop decisions.  Keeping a
/// counter per kind makes a decision a pure function of (seed, kind,
/// how many frames of that kind came before) -- reconnects and retries
/// don't shift the sequence of another kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SendKind {
    Heartbeat,
    Request,
    Result,
}

/// Live fault state for one worker process.
#[derive(Debug)]
pub struct FaultLayer {
    spec: FaultSpec,
    seed: u64,
    counts: [u64; 3],
    computed: usize,
}

impl FaultLayer {
    pub fn new(spec: FaultSpec, base_seed: u64, worker_name: &str) -> FaultLayer {
        FaultLayer {
            spec,
            seed: derive_seed(base_seed, "fault-inject", &[fnv64(worker_name)]),
            counts: [0; 3],
            computed: 0,
        }
    }

    fn kind_idx(kind: SendKind) -> usize {
        match kind {
            SendKind::Heartbeat => 0,
            SendKind::Request => 1,
            SendKind::Result => 2,
        }
    }

    /// Should the next send of this kind be dropped?  Deterministic per
    /// (seed, kind, per-kind counter); advances the counter.
    pub fn should_drop(&mut self, kind: SendKind) -> bool {
        if self.spec.drop <= 0.0 {
            return false;
        }
        let idx = Self::kind_idx(kind);
        let n = self.counts[idx];
        self.counts[idx] += 1;
        let mut rng =
            Rng::new(derive_seed(self.seed, "drop", &[idx as u64, n]));
        rng.uniform() < self.spec.drop
    }

    /// Latency to apply before each send (zero when not injecting).
    pub fn delay(&self) -> Duration {
        self.spec.delay
    }

    /// Record one computed cell; true means "die now, without sending
    /// this result".
    pub fn should_kill_after_compute(&mut self) -> bool {
        self.computed += 1;
        matches!(self.spec.kill_after, Some(n) if n > 0 && self.computed >= n)
    }

    /// True when `kill-after=0`: die on first assignment, pre-compute.
    pub fn kill_on_assign(&self) -> bool {
        self.spec.kill_after == Some(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_full_and_partial_specs() {
        let s = FaultSpec::parse("drop=0.2,delay=50,kill-after=3").unwrap();
        assert_eq!(s.drop, 0.2);
        assert_eq!(s.delay, Duration::from_millis(50));
        assert_eq!(s.kill_after, Some(3));

        let s = FaultSpec::parse("kill-after=0").unwrap();
        assert_eq!(s.kill_after, Some(0));
        assert_eq!(s.drop, 0.0);

        assert!(FaultSpec::parse("").unwrap().is_noop());
    }

    #[test]
    fn rejects_bad_specs() {
        for bad in ["drop", "drop=1.5", "drop=x", "delay=-3", "warp=9"] {
            assert!(FaultSpec::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn drop_decisions_replay_exactly() {
        let spec = FaultSpec::parse("drop=0.5").unwrap();
        let run = |name: &str| {
            let mut layer = FaultLayer::new(spec, 42, name);
            (0..64)
                .map(|i| {
                    let kind = match i % 3 {
                        0 => SendKind::Heartbeat,
                        1 => SendKind::Request,
                        _ => SendKind::Result,
                    };
                    layer.should_drop(kind)
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run("w0"), run("w0"), "same worker must replay");
        assert_ne!(run("w0"), run("w1"), "workers draw independent faults");
        let flips = run("w0").iter().filter(|&&d| d).count();
        assert!((10..=54).contains(&flips), "drop=0.5 wildly off: {flips}/64");
    }

    #[test]
    fn per_kind_counters_are_independent() {
        let spec = FaultSpec::parse("drop=0.5").unwrap();
        // results-only sequence must match the result-subsequence of a
        // mixed run: other kinds can't perturb it
        let mut mixed = FaultLayer::new(spec, 7, "w");
        let mut solo = FaultLayer::new(spec, 7, "w");
        let mut mixed_results = Vec::new();
        for i in 0..30 {
            if i % 2 == 0 {
                mixed.should_drop(SendKind::Heartbeat);
            } else {
                mixed_results.push(mixed.should_drop(SendKind::Result));
            }
        }
        let solo_results: Vec<bool> =
            (0..15).map(|_| solo.should_drop(SendKind::Result)).collect();
        assert_eq!(mixed_results, solo_results);
    }

    #[test]
    fn kill_after_counts_computed_cells() {
        let spec = FaultSpec::parse("kill-after=2").unwrap();
        let mut layer = FaultLayer::new(spec, 1, "w");
        assert!(!layer.kill_on_assign());
        assert!(!layer.should_kill_after_compute());
        assert!(layer.should_kill_after_compute());

        let mut eager = FaultLayer::new(FaultSpec::parse("kill-after=0").unwrap(), 1, "w");
        assert!(eager.kill_on_assign());

        let mut never = FaultLayer::new(FaultSpec::default(), 1, "w");
        assert!(!never.kill_on_assign());
        assert!(!(0..100).any(|_| never.should_kill_after_compute()));
    }
}
