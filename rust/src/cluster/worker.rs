//! The cluster worker: connect, pull cells, compute, report.
//!
//! A worker is stateless between cells -- everything it needs to run a
//! cell travels in the `Assign` message plus its own flags (which must
//! describe the same sweep as the coordinator's, enforced by the
//! fingerprint handshake).  Determinism therefore holds regardless of
//! which worker computes which cell, which is what makes the
//! coordinator's duplicate bit-check meaningful.
//!
//! A heartbeat thread beats at the coordinator-assigned interval even
//! while a cell is computing, so a long cell is not mistaken for a dead
//! worker.  Connection loss (including injected drops) triggers a
//! bounded reconnect loop with linear backoff; cells whose result could
//! not be delivered are simply recomputed by whoever is assigned them
//! next -- bit-identically.

use std::net::TcpStream;
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::cluster::fault::{FaultLayer, FaultSpec, SendKind};
use crate::cluster::proto::{read_frame, write_frame, Frame, Msg, PROTO_VERSION};
use crate::coordinator::grid::{grid_jobs, CellJob};
use crate::coordinator::regimes::{CellEval, CellResult, Regime};
use crate::coordinator::report::{CellCache, CACHE_VERSION};
use crate::coordinator::shard;
use crate::error::{FxpError, Result};
use crate::train::telemetry::TelemetrySummary;

/// One cell executor.  Implementations: synthetic (tests/CI) and the
/// real backend runner in the CLI.  Alongside the result, a run returns
/// the cell's stability-telemetry digest (`None` for evaluation-only
/// regimes and synthetic cells), which rides back to the coordinator in
/// `Msg::Result`.
pub trait CellExec {
    fn run(
        &mut self,
        job: &CellJob,
    ) -> Result<(CellResult, Option<TelemetrySummary>)>;
}

/// The engine-free executor (`--synthetic`), same cells as
/// `fxpnet grid --synthetic`.
pub struct SyntheticExec;

impl CellExec for SyntheticExec {
    fn run(
        &mut self,
        job: &CellJob,
    ) -> Result<(CellResult, Option<TelemetrySummary>)> {
        Ok((crate::coordinator::grid::synthetic_cell(job)?, None))
    }
}

/// Worker knobs (`fxpnet cluster worker` flags).
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// Coordinator `host:port`.
    pub connect: String,
    /// Identity reported at handshake (also seeds fault injection).
    pub name: String,
    /// Optional static `I/N` pin; the coordinator then only assigns
    /// this worker cells of that shard.
    pub shard: Option<(usize, usize)>,
    /// Deterministic fault injection (`--inject`).
    pub fault: FaultSpec,
    /// Reconnect attempts after a lost connection before giving up.
    pub reconnect_cap: usize,
    /// Pause between reconnect attempts (multiplied by the attempt
    /// number).
    pub reconnect_backoff: Duration,
    /// TCP connect budget per attempt (a blackholed coordinator address
    /// must not hang the worker in `connect(2)` past its backoff math).
    pub connect_timeout: Duration,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            connect: String::new(),
            name: format!("{}-{}", shard::hostname(), std::process::id()),
            shard: None,
            fault: FaultSpec::default(),
            reconnect_cap: 8,
            reconnect_backoff: Duration::from_millis(200),
            connect_timeout: Duration::from_secs(10),
        }
    }
}

/// What one worker process did (its exit report).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerReport {
    /// cells computed (whether or not the result was delivered)
    pub computed: usize,
    /// results delivered to the coordinator
    pub delivered: usize,
    /// reconnects after a lost/dropped connection
    pub reconnects: usize,
    /// the sweep was complete when the coordinator said drain
    pub sweep_complete: bool,
}

/// Why the inner connection loop ended.
enum ConnEnd {
    /// coordinator said `Drain`
    Drained { complete: bool },
    /// connection lost (EOF, IO error, injected drop)
    Lost(String),
    /// unrecoverable (Reject, Fatal, protocol violation, injected kill)
    Fatal(FxpError),
}

/// Send a frame through the shared (heartbeat-contended) stream,
/// applying fault injection.  `Ok(false)` = injected drop (the caller
/// treats the connection as lost).
fn faulty_send(
    stream: &Mutex<TcpStream>,
    fault: &Mutex<FaultLayer>,
    kind: SendKind,
    msg: &Msg,
) -> Result<bool> {
    let (dropped, delay) = {
        let mut f = fault.lock().unwrap();
        (f.should_drop(kind), f.delay())
    };
    if !delay.is_zero() {
        std::thread::sleep(delay);
    }
    if dropped {
        log::warn!("fault injection: dropping {kind:?} send");
        return Ok(false);
    }
    write_frame(&mut *stream.lock().unwrap(), msg)?;
    Ok(true)
}

/// `TcpStream::connect` with a per-address timeout (std's plain
/// `connect` has none, so a blackholed address could hang a worker for
/// the OS default of minutes).
fn connect_with_timeout(addr: &str, timeout: Duration) -> std::io::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let mut last = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, timeout) {
            Ok(s) => return Ok(s),
            Err(e) => last = Some(e),
        }
    }
    Err(last.unwrap_or_else(|| {
        std::io::Error::new(
            std::io::ErrorKind::InvalidInput,
            format!("'{addr}' resolved to no addresses"),
        )
    }))
}

/// Run one connection to completion (drain/loss/fatal).
#[allow(clippy::too_many_arguments)]
fn run_conn(
    opts: &WorkerOpts,
    fp: u64,
    jobs: &[CellJob],
    exec: &mut dyn CellExec,
    fault: &Mutex<FaultLayer>,
    report: &mut WorkerReport,
) -> ConnEnd {
    let stream = match connect_with_timeout(&opts.connect, opts.connect_timeout) {
        Ok(s) => s,
        Err(e) => return ConnEnd::Lost(format!("connect {}: {e}", opts.connect)),
    };
    let _ = stream.set_nodelay(true);
    if let Err(e) = stream.set_read_timeout(Some(Duration::from_millis(50))) {
        return ConnEnd::Lost(format!("set_read_timeout: {e}"));
    }

    // handshake (direct writes: the heartbeat thread doesn't exist yet)
    let mut s = stream;
    let hello = Msg::Hello {
        proto: PROTO_VERSION,
        cache_version: CACHE_VERSION,
        name: opts.name.clone(),
        pid: std::process::id() as u64,
        host: shard::hostname(),
        fp,
        shard: opts.shard,
    };
    if let Err(e) = write_frame(&mut s, &hello) {
        return ConnEnd::Lost(format!("hello: {e}"));
    }
    // the deadline bounds mid-frame stalls too (a coordinator that
    // hangs after sending half a Welcome must not wedge the worker)
    let welcome_by = std::time::Instant::now() + Duration::from_secs(10);
    let (hb_interval, reply_deadline) = loop {
        match read_frame(&mut s, Some(welcome_by)) {
            Ok(Frame::TimedOut) => {
                if std::time::Instant::now() >= welcome_by {
                    return ConnEnd::Lost("no welcome within 10s".into());
                }
            }
            Ok(Frame::Eof) => return ConnEnd::Lost("EOF at handshake".into()),
            Ok(Frame::Msg(Msg::Welcome { heartbeat_ms, deadline_ms })) => {
                // the coordinator's own liveness deadline, reused
                // symmetrically: if IT goes silent that long while we
                // await a reply, treat the connection as lost
                break (
                    Duration::from_millis(heartbeat_ms.max(10)),
                    Duration::from_millis(deadline_ms.max(100)),
                );
            }
            Ok(Frame::Msg(Msg::Reject { reason })) => {
                return ConnEnd::Fatal(FxpError::config(format!(
                    "coordinator rejected this worker: {reason}"
                )));
            }
            Ok(Frame::Msg(other)) => {
                return ConnEnd::Fatal(FxpError::config(format!(
                    "bad handshake reply: {other:?}"
                )));
            }
            Err(e) => return ConnEnd::Lost(format!("handshake: {e}")),
        }
    };

    // split the socket: the main loop reads on one handle while the
    // heartbeat thread and the main loop share the write side under a
    // mutex -- a blocked read can then never starve a heartbeat
    let write_half = match s.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(e) => return ConnEnd::Lost(format!("try_clone: {e}")),
    };
    let mut read_half = s;
    let stop_hb = Arc::new(AtomicBool::new(false));
    std::thread::scope(|scope| {
        {
            let hb_stream = Arc::clone(&write_half);
            let stop = Arc::clone(&stop_hb);
            scope.spawn(move || {
                while !stop.load(Ordering::SeqCst) {
                    std::thread::sleep(hb_interval);
                    if stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let (dropped, delay) = {
                        let mut f = fault.lock().unwrap();
                        (f.should_drop(SendKind::Heartbeat), f.delay())
                    };
                    if !delay.is_zero() {
                        std::thread::sleep(delay);
                    }
                    if dropped {
                        continue; // a missed beat, not an outage
                    }
                    if write_frame(&mut *hb_stream.lock().unwrap(), &Msg::Heartbeat)
                        .is_err()
                    {
                        break; // main loop will notice the loss too
                    }
                }
            });
        }
        let end = conn_loop(
            opts,
            jobs,
            exec,
            fault,
            &write_half,
            &mut read_half,
            reply_deadline,
            report,
        );
        stop_hb.store(true, Ordering::SeqCst);
        end
    })
}

/// The request/assign/result loop on an established connection.
#[allow(clippy::too_many_arguments)]
fn conn_loop(
    opts: &WorkerOpts,
    jobs: &[CellJob],
    exec: &mut dyn CellExec,
    fault: &Mutex<FaultLayer>,
    write: &Mutex<TcpStream>,
    read: &mut TcpStream,
    reply_deadline: Duration,
    report: &mut WorkerReport,
) -> ConnEnd {
    loop {
        match faulty_send(write, fault, SendKind::Request, &Msg::Request) {
            Ok(true) => {}
            Ok(false) => return ConnEnd::Lost("injected drop (request)".into()),
            Err(e) => return ConnEnd::Lost(format!("request: {e}")),
        }
        // a healthy coordinator answers Request promptly (Assign / Wait /
        // Drain); silence for its own declared liveness deadline means it
        // is hung, and reconnecting beats waiting forever.  The deadline
        // also bounds mid-frame stalls inside read_frame.
        let reply_by = std::time::Instant::now() + reply_deadline;
        let assigned = loop {
            match read_frame(read, Some(reply_by)) {
                Ok(Frame::TimedOut) => {
                    if std::time::Instant::now() >= reply_by {
                        return ConnEnd::Lost(format!(
                            "coordinator silent for {reply_deadline:?} \
                             awaiting assignment"
                        ));
                    }
                    continue;
                }
                Ok(Frame::Eof) => return ConnEnd::Lost("EOF".into()),
                Ok(Frame::Msg(Msg::Wait { ms })) => {
                    std::thread::sleep(Duration::from_millis(ms.min(1000)));
                    break None;
                }
                Ok(Frame::Msg(Msg::Drain { complete })) => {
                    return ConnEnd::Drained { complete };
                }
                Ok(Frame::Msg(Msg::Fatal { reason })) => {
                    return ConnEnd::Fatal(FxpError::config(format!(
                        "coordinator reported fatal: {reason}"
                    )));
                }
                Ok(Frame::Msg(Msg::Assign { flat, key, attempt })) => {
                    break Some((flat, key, attempt));
                }
                Ok(Frame::Msg(other)) => {
                    return ConnEnd::Fatal(FxpError::config(format!(
                        "unexpected message awaiting assignment: {other:?}"
                    )));
                }
                Err(e) => return ConnEnd::Lost(format!("read: {e}")),
            }
        };
        let Some((flat, key, attempt)) = assigned else {
            continue; // waited; re-request
        };

        let job = match jobs.get(flat) {
            Some(j) if CellCache::key(j) == key => *j,
            _ => {
                return ConnEnd::Fatal(FxpError::config(format!(
                    "coordinator assigned unknown cell flat={flat} key='{key}'"
                )));
            }
        };
        if fault.lock().unwrap().kill_on_assign() {
            return ConnEnd::Fatal(FxpError::config(
                "fault injection: kill-after=0 (dying on first assignment)"
                    .into(),
            ));
        }

        log::info!("computing cell {key} (flat {flat}, attempt {attempt})");
        // a panicking or erroring cell becomes n/a -- identical to the
        // single-process sweep's semantics, so tables stay bit-identical.
        // Telemetry survives a non-finite flatten (the run happened and
        // its digest is exactly what the grid path would record) but not
        // an error/panic (no trustworthy digest exists).
        let (eval, telemetry) = match std::panic::catch_unwind(
            AssertUnwindSafe(|| exec.run(&job)),
        ) {
            Ok(Ok((CellEval::Ok(e), t)))
                if !(e.top1_err.is_finite()
                    && e.top5_err.is_finite()
                    && e.mean_loss.is_finite()) =>
            {
                (CellEval::Na, t)
            }
            Ok(Ok((eval, t))) => (eval, t),
            Ok(Err(e)) => {
                log::warn!("cell {key} failed: {e}; recording n/a");
                (CellEval::Na, None)
            }
            Err(_) => {
                log::warn!("cell {key} panicked; recording n/a");
                (CellEval::Na, None)
            }
        };
        report.computed += 1;

        if fault.lock().unwrap().should_kill_after_compute() {
            // die *between* computing and sending: the canonical
            // mid-cell kill, guaranteeing the coordinator re-dispatches
            return ConnEnd::Fatal(FxpError::config(format!(
                "fault injection: kill-after reached after computing {key}"
            )));
        }

        let msg = Msg::Result { flat, key, attempt, eval, telemetry };
        match faulty_send(write, fault, SendKind::Result, &msg) {
            Ok(true) => report.delivered += 1,
            Ok(false) => return ConnEnd::Lost("injected drop (result)".into()),
            Err(e) => return ConnEnd::Lost(format!("result: {e}")),
        }
    }
}

/// Run a worker until the coordinator drains it, the connection is
/// unrecoverable, or a fatal condition (including injected kills).
///
/// `fp` must be derived from the worker's own flags with
/// [`crate::cluster::sweep_fingerprint`]; the coordinator compares it to
/// its own at handshake.
pub fn run_worker(
    regime: Regime,
    base_seed: u64,
    fp: u64,
    exec: &mut dyn CellExec,
    opts: &WorkerOpts,
) -> Result<WorkerReport> {
    if let Some((i, n)) = opts.shard {
        shard::validate_shard(i, n)?;
    }
    let jobs = grid_jobs(regime, base_seed);
    let fault = Mutex::new(FaultLayer::new(opts.fault, base_seed, &opts.name));
    let mut report = WorkerReport::default();
    let mut lost = 0usize;
    loop {
        match run_conn(opts, fp, &jobs, exec, &fault, &mut report) {
            ConnEnd::Drained { complete } => {
                report.sweep_complete = complete;
                log::info!(
                    "drained by coordinator (sweep complete: {complete}); \
                     computed {} cells, delivered {}",
                    report.computed,
                    report.delivered
                );
                return Ok(report);
            }
            ConnEnd::Fatal(e) => return Err(e),
            ConnEnd::Lost(why) => {
                lost += 1;
                if lost > opts.reconnect_cap {
                    return Err(FxpError::config(format!(
                        "connection lost ({why}) and reconnect cap \
                         {} exhausted",
                        opts.reconnect_cap
                    )));
                }
                report.reconnects += 1;
                let wait = opts.reconnect_backoff * lost as u32;
                log::warn!(
                    "connection lost ({why}); reconnect {lost}/{} in {wait:?}",
                    opts.reconnect_cap
                );
                std::thread::sleep(wait);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read as _, Write as _};
    use std::net::TcpListener;
    use std::time::Instant;

    /// A worker pointed at `addr` with no reconnect budget: the first
    /// `Lost` surfaces as `Err`, which is what the timeout tests await.
    fn one_shot_worker(addr: String) -> WorkerOpts {
        WorkerOpts {
            connect: addr,
            reconnect_cap: 0,
            reconnect_backoff: Duration::from_millis(1),
            connect_timeout: Duration::from_secs(2),
            ..WorkerOpts::default()
        }
    }

    fn run_one_shot(opts: &WorkerOpts) -> Result<WorkerReport> {
        run_worker(Regime::Vanilla, 42, 0xfeed, &mut SyntheticExec, opts)
    }

    /// Fake coordinator: accept one worker, consume its Hello, send a
    /// Welcome with the given liveness deadline, then run `after` with
    /// the raw stream.
    fn fake_coordinator(
        deadline_ms: u64,
        after: impl FnOnce(TcpStream) + Send + 'static,
    ) -> (String, std::thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            match read_frame(&mut s, None) {
                Ok(Frame::Msg(Msg::Hello { .. })) => {}
                other => panic!("expected Hello, got {other:?}"),
            }
            write_frame(&mut s, &Msg::Welcome { heartbeat_ms: 50, deadline_ms })
                .unwrap();
            after(s);
        });
        (addr, h)
    }

    #[test]
    fn mid_frame_stall_cannot_wedge_the_worker() {
        // Welcome, then 3 bytes of a length prefix, then silence with
        // the socket held open: before the fix the worker's
        // `read_frame(..., None)` waited forever mid-frame.
        let (addr, coord) = fake_coordinator(300, |mut s| {
            s.write_all(&[0x40, 0x00, 0x00]).unwrap();
            let mut sink = [0u8; 256];
            // keep the socket open (and drained) well past the
            // worker's deadline
            let _ = s.set_read_timeout(Some(Duration::from_millis(50)));
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_secs(4) {
                match s.read(&mut sink) {
                    Ok(0) => break, // worker hung up: done
                    Ok(_) => {}
                    Err(_) => {}
                }
            }
        });
        let t0 = Instant::now();
        let err = run_one_shot(&one_shot_worker(addr)).unwrap_err();
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(3),
            "worker wedged for {waited:?} on a mid-frame stall"
        );
        assert!(
            err.to_string().contains("connection lost"),
            "unexpected error: {err}"
        );
        coord.join().unwrap();
    }

    #[test]
    fn silent_coordinator_trips_the_reply_deadline() {
        // Welcome with a 300ms liveness deadline, then total silence:
        // before the fix the worker span on boundary TimedOut ticks
        // forever awaiting its assignment.
        let (addr, coord) = fake_coordinator(300, |s| {
            let mut s = s;
            let mut sink = [0u8; 256];
            let _ = s.set_read_timeout(Some(Duration::from_millis(50)));
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_secs(4) {
                match s.read(&mut sink) {
                    Ok(0) => break,
                    Ok(_) => {} // drain Request/Heartbeat, reply never
                    Err(_) => {}
                }
            }
        });
        let t0 = Instant::now();
        let err = run_one_shot(&one_shot_worker(addr)).unwrap_err();
        let waited = t0.elapsed();
        assert!(
            waited < Duration::from_secs(3),
            "worker waited {waited:?} on a silent coordinator"
        );
        assert!(
            err.to_string().contains("silent"),
            "error should name the silence: {err}"
        );
        coord.join().unwrap();
    }

    #[test]
    fn connect_failure_is_bounded_and_reported() {
        // a port nothing listens on: connect must fail fast, not hang
        let free = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = free.local_addr().unwrap().to_string();
        drop(free);
        let t0 = Instant::now();
        let err = run_one_shot(&one_shot_worker(addr)).unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(err.to_string().contains("connect"), "{err}");
    }

    #[test]
    fn welcome_deadline_floors_at_100ms() {
        // a coordinator advertising deadline_ms=0 must not make the
        // worker declare it hung instantly
        let (addr, coord) = fake_coordinator(0, |mut s| {
            // answer the first Request properly, then drain
            loop {
                match read_frame(&mut s, None) {
                    Ok(Frame::Msg(Msg::Request)) => break,
                    Ok(Frame::Msg(Msg::Heartbeat)) => continue,
                    other => panic!("expected Request, got {other:?}"),
                }
            }
            write_frame(&mut s, &Msg::Drain { complete: true }).unwrap();
        });
        let report = run_one_shot(&one_shot_worker(addr)).unwrap();
        assert!(report.sweep_complete);
        coord.join().unwrap();
    }
}
