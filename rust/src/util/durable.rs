//! Crash-durable file replacement: fsync the data *and* the directory
//! entry around an atomic rename.
//!
//! `write(tmp) + rename(tmp, target)` alone is atomic against concurrent
//! readers but not against power loss / kill-9: the rename can reach
//! disk before the temp file's data blocks do, leaving a
//! truncated-but-renamed target that a later `--resume` or `grid merge`
//! would read.  The durable sequence is write -> fsync(file) ->
//! rename -> fsync(parent dir); after a crash either the old or the new
//! contents exist, never a hybrid.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::error::Result;

/// fsync the directory containing `path`, making a just-renamed (or
/// just-created) entry durable.  On non-unix platforms directories
/// cannot be opened for syncing; the rename is still atomic there, just
/// not power-loss durable.
pub fn sync_parent_dir(path: &Path) -> Result<()> {
    let dir: PathBuf = match path.parent() {
        Some(d) if !d.as_os_str().is_empty() => d.to_path_buf(),
        _ => PathBuf::from("."),
    };
    #[cfg(unix)]
    File::open(&dir)?.sync_all()?;
    #[cfg(not(unix))]
    let _ = dir;
    Ok(())
}

/// Durably replace `path`'s contents: write `bytes` to `tmp` (same
/// directory), fsync it, rename over `path`, fsync the directory.
pub fn write_atomic(path: &Path, tmp: &Path, bytes: &[u8]) -> Result<()> {
    let mut f = File::create(tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    drop(f);
    std::fs::rename(tmp, path)?;
    sync_parent_dir(path)?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = std::env::temp_dir().join("fxp_durable_test");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let target = dir.join("data.json");
        let tmp = dir.join(".data.json.tmp");
        std::fs::write(&target, b"old").unwrap();
        write_atomic(&target, &tmp, b"new contents").unwrap();
        assert_eq!(std::fs::read(&target).unwrap(), b"new contents");
        assert!(!tmp.exists());
        // the parent-dir sync helper works on a bare filename too
        sync_parent_dir(Path::new("lonely.json")).unwrap();
    }
}
