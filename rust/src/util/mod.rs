//! Small self-contained utilities.
//!
//! The offline crate cache in this image only carries the `xla` crate's
//! dependency closure, so the usual ecosystem picks (rand, serde_json,
//! env_logger, ...) are re-implemented here at the size this project
//! needs (DESIGN.md section 2, substitution table).

pub mod durable;
pub mod json;
pub mod logging;
pub mod rng;
pub mod timer;

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (1.25f64).sqrt()).abs() < 1e-12);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }
}
