//! Deterministic PRNG: xoshiro256** plus the distributions the library
//! needs (uniform, normal, integer ranges, shuffling).
//!
//! Every stochastic component in fxpnet (dataset generation, parameter
//! init, batch shuffling, stochastic rounding on the Rust side) is seeded
//! through this type, so whole experiments replay bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// One splitmix64 step as a pure mixing function.
#[inline]
fn mix64(seed: u64) -> u64 {
    let mut s = seed;
    splitmix64(&mut s)
}

/// Derive a child seed from `(base, domain, parts)`.
///
/// This is the seed tree behind the parallel grid runner: every
/// stochastic stream of a grid cell is keyed by *what the cell is*
/// (regime, weight width, activation width, stream tag), never by which
/// worker thread or in which order it runs -- so sweeps are bit-identical
/// under any worker count, scheduling, sharding, or resume pattern.
///
/// Properties the tests pin down:
/// * deterministic (pure function of the inputs);
/// * domain-separated (`derive_seed(b, "x", p) != derive_seed(b, "y", p)`);
/// * position-sensitive (`[1, 2]` and `[2, 1]` differ, as do `[1]` and
///   `[1, 0]`).
pub fn derive_seed(base: u64, domain: &str, parts: &[u64]) -> u64 {
    // FNV-1a over the domain string, folded into the base
    let mut h = base ^ 0xCBF2_9CE4_8422_2325;
    for &b in domain.as_bytes() {
        h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
    }
    h = mix64(h);
    for (i, &p) in parts.iter().enumerate() {
        // the (i+1) tag makes the fold position-sensitive and
        // distinguishes [1] from [1, 0]
        h = mix64(h ^ p ^ ((i as u64 + 1) << 56));
    }
    h
}

impl Rng {
    /// Seed via splitmix64 (as the xoshiro authors recommend).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Independent child stream (used to give each worker/epoch its own
    /// reproducible stream without sharing state).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a slice with uniforms in [0, 1) -- the exact stream
    /// [`uniform`](Self::uniform) would produce, but drawn in one tight
    /// loop so vectorised consumers (e.g. stochastic `quantize_slice`)
    /// amortise the call overhead over a block.
    #[inline]
    pub fn fill_uniform(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.uniform();
        }
    }

    /// Uniform f32 in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n): Lemire's widening-multiply method
    /// (next_u64 * n) >> 64, with the standard rejection step that
    /// removes the multiply's modulo bias exactly.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        // hard assert: the old float-modulo implementation panicked on
        // n == 0 in every profile; a silent 0 would surface as an
        // out-of-bounds read far from the caller's bug
        assert!(n > 0, "Rng::below(0)");
        let n64 = n as u64;
        let mut m = self.next_u64() as u128 * n64 as u128;
        let mut lo = m as u64;
        if lo < n64 {
            // threshold = 2^64 mod n; draws with low half below it are the
            // over-represented remainder and get rejected
            let t = n64.wrapping_neg() % n64;
            while lo < t {
                m = self.next_u64() as u128 * n64 as u128;
                lo = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer with exactly `bits` random bits (1..=128), i.e. in
    /// [0, 2^bits).  Draws one `next_u64` for <= 64 bits, two above --
    /// full-resolution integer randomness for wide stochastic
    /// requantization shifts where a f64 mantissa (53 bits) cannot reach
    /// the low bits.
    #[inline]
    pub fn bits128(&mut self, bits: u32) -> u128 {
        debug_assert!((1..=128).contains(&bits));
        if bits <= 64 {
            (self.next_u64() >> (64 - bits)) as u128
        } else {
            let hi = (self.next_u64() >> (128 - bits)) as u128;
            (hi << 64) | self.next_u64() as u128
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Fill with N(0, std^2) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], std: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * std;
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..20000).map(|_| r.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 0.5).abs() < 0.01, "{m}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        assert!(m.abs() < 0.03, "{m}");
        assert!((v - 1.0).abs() < 0.05, "{v}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let k = r.below(10);
            assert!(k < 10);
            seen[k] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_is_uniform() {
        // Lemire widening-multiply: each residue within 3 sigma of n/k
        let mut r = Rng::new(17);
        let n = 30000usize;
        for k in [3usize, 7, 10, 16] {
            let mut counts = vec![0usize; k];
            for _ in 0..n {
                counts[r.below(k)] += 1;
            }
            let expect = n as f64 / k as f64;
            let sigma = (expect * (1.0 - 1.0 / k as f64)).sqrt();
            for (i, &c) in counts.iter().enumerate() {
                assert!(
                    (c as f64 - expect).abs() < 5.0 * sigma + 1.0,
                    "k={k} residue {i}: {c} vs {expect}"
                );
            }
        }
        // degenerate range
        assert_eq!(r.below(1), 0);
    }

    #[test]
    fn fill_uniform_matches_scalar_stream() {
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        let mut buf = [0f64; 97];
        a.fill_uniform(&mut buf);
        for (i, &u) in buf.iter().enumerate() {
            assert_eq!(u, b.uniform(), "index {i}");
        }
    }

    #[test]
    fn bits128_range_and_low_bit_coverage() {
        let mut r = Rng::new(31);
        for bits in [1u32, 7, 53, 60, 64, 65, 100, 127, 128] {
            let mut low_ones = 0usize;
            for _ in 0..200 {
                let v = r.bits128(bits);
                if bits < 128 {
                    assert!(v < 1u128 << bits, "bits={bits}: {v}");
                }
                low_ones += (v & 1) as usize;
            }
            // the low bit must actually vary -- this is exactly what the
            // old f64-based draw lost for shifts > 53
            assert!(
                (40..=160).contains(&low_ones),
                "bits={bits}: low bit set {low_ones}/200"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn derive_seed_deterministic_and_separated() {
        let a = derive_seed(42, "grid-cell", &[3, 8, 8]);
        assert_eq!(a, derive_seed(42, "grid-cell", &[3, 8, 8]));
        // base, domain, part value, part order, part count all matter
        assert_ne!(a, derive_seed(43, "grid-cell", &[3, 8, 8]));
        assert_ne!(a, derive_seed(42, "p1-net", &[3, 8, 8]));
        assert_ne!(a, derive_seed(42, "grid-cell", &[3, 8, 4]));
        assert_ne!(a, derive_seed(42, "grid-cell", &[8, 3, 8]));
        assert_ne!(a, derive_seed(42, "grid-cell", &[3, 8]));
        assert_ne!(
            derive_seed(42, "grid-cell", &[1]),
            derive_seed(42, "grid-cell", &[1, 0])
        );
    }

    #[test]
    fn derive_seed_spreads_over_small_grids() {
        // the 4x4 paper grid x 5 regimes must not collide
        let mut seen = std::collections::HashSet::new();
        for regime in 2..7u64 {
            for w in [4u64, 8, 16, 0xF10A7] {
                for a in [4u64, 8, 16, 0xF10A7] {
                    assert!(seen.insert(derive_seed(42, "grid-cell", &[regime, w, a])));
                }
            }
        }
        assert_eq!(seen.len(), 80);
    }

    #[test]
    fn fork_independent() {
        let mut r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
