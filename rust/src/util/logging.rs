//! Logging configuration: level from `FXPNET_LOG` (error|warn|info|debug
//! |trace; default info).
//!
//! The sink itself (timestamped stderr lines) lives in the offline `log`
//! shim crate (rust/log-shim); this module only translates the
//! environment variable into a level filter.

use log::LevelFilter;

/// Install the log level from the environment (idempotent).
pub fn init() {
    let level = match std::env::var("FXPNET_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    log::set_max_level(level);
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_twice_is_fine() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
