//! Tiny `log`-facade backend: timestamped stderr logging, level from
//! `FXPNET_LOG` (error|warn|info|debug|trace; default info).

use std::io::Write;
use std::time::Instant;

use log::{Level, LevelFilter, Metadata, Record};
use once_cell::sync::OnceCell;

static START: OnceCell<Instant> = OnceCell::new();

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        let lvl = match record.level() {
            Level::Error => "E",
            Level::Warn => "W",
            Level::Info => "I",
            Level::Debug => "D",
            Level::Trace => "T",
        };
        let mut err = std::io::stderr().lock();
        let _ = writeln!(err, "[{t:9.3}s {lvl} {}] {}", record.target(), record.args());
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Install the logger (idempotent).
pub fn init() {
    START.get_or_init(Instant::now);
    let level = match std::env::var("FXPNET_LOG").as_deref() {
        Ok("error") => LevelFilter::Error,
        Ok("warn") => LevelFilter::Warn,
        Ok("debug") => LevelFilter::Debug,
        Ok("trace") => LevelFilter::Trace,
        _ => LevelFilter::Info,
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn init_twice_is_fine() {
        super::init();
        super::init();
        log::info!("logging smoke test");
    }
}
