//! Wall-clock helpers used by the trainer and the bench harness.

use std::time::{Duration, Instant};

/// A resettable stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// "1.234s" / "56.7ms" / "890us" style human formatting.
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{:.0}us", s * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn formats() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.000s");
        assert_eq!(fmt_duration(Duration::from_millis(25)), "25.0ms");
        assert_eq!(fmt_duration(Duration::from_micros(80)), "80us");
    }

    #[test]
    fn stopwatch_restart() {
        let mut sw = Stopwatch::start();
        let e = sw.restart();
        assert!(e.as_secs_f64() >= 0.0);
        assert!(sw.elapsed_ms() >= 0.0);
    }
}
