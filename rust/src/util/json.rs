//! Minimal JSON: enough to parse the AOT manifest and emit metrics /
//! reports.  (serde is not in the offline crate cache -- DESIGN.md sec. 2.)

use std::collections::BTreeMap;
use std::fmt;

use crate::error::{FxpError, Result};

/// A JSON value.  Numbers are kept as f64 (the manifest only contains
/// shapes/counts well within 2^53).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(FxpError::Json(format!(
                "trailing data at byte {}",
                p.i
            )));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| FxpError::Json(format!("missing key '{key}'"))),
            _ => Err(FxpError::Json(format!("'{key}': not an object"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(FxpError::Json(format!("not a string: {self}"))),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => Err(FxpError::Json(format!("not a number: {self}"))),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            return Err(FxpError::Json(format!("not a usize: {n}")));
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(FxpError::Json(format!("not an array: {self}"))),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(FxpError::Json(format!("not an object: {self}"))),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|j| j.as_usize()).collect()
    }

    // -- builders ------------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| FxpError::Json("unexpected end of input".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(FxpError::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(FxpError::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => {
                    return Err(FxpError::Json(format!(
                        "expected ',' or '}}' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => {
                    return Err(FxpError::Json(format!(
                        "expected ',' or ']' at byte {}, found '{}'",
                        self.i, c as char
                    )))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(FxpError::Json("bad \\u".into()));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i..self.i + 4])
                                    .map_err(|_| FxpError::Json("bad \\u".into()))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| FxpError::Json("bad \\u".into()))?;
                            self.i += 4;
                            // BMP only -- fine for our own files
                            s.push(
                                char::from_u32(cp)
                                    .ok_or_else(|| FxpError::Json("bad codepoint".into()))?,
                            );
                        }
                        _ => {
                            return Err(FxpError::Json(format!(
                                "bad escape at byte {}",
                                self.i
                            )))
                        }
                    }
                }
                _ => s.push(c as char),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| FxpError::Json("bad number".into()))?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| FxpError::Json(format!("bad number '{s}'")))
    }
}

// -- writer -------------------------------------------------------------------

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{v}", Json::Str(k.clone()))?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" 42 ").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x\n"}], "c": null}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_usize().unwrap(), 1);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "x\n");
        assert_eq!(*j.get("c").unwrap(), Json::Null);
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("[1] trailing").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn round_trip() {
        let src = r#"{"shape":[3,3,3,32],"name":"l0.w","f":1.25,"t":true}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn escapes_round_trip() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn usize_vec() {
        let j = Json::parse("[3,3,3,32]").unwrap();
        assert_eq!(j.usize_vec().unwrap(), vec![3, 3, 3, 32]);
        assert!(Json::parse("[1.5]").unwrap().usize_vec().is_err());
    }

    #[test]
    fn real_manifest_snippet() {
        let src = r#"{"version":1,"archs":{"tiny":{"num_layers":3,
          "params":[{"name":"l0.w","shape":[3,3,3,8]}]}}}"#;
        let j = Json::parse(src).unwrap();
        let t = j.get("archs").unwrap().get("tiny").unwrap();
        assert_eq!(t.get("num_layers").unwrap().as_usize().unwrap(), 3);
    }
}
