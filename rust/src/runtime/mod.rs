//! PJRT runtime: load AOT-compiled HLO text, compile once, execute many.
//!
//! This is the only place the `xla` crate is touched.  The `Engine` owns
//! the (process-wide) CPU PJRT client and an executable cache keyed by
//! (arch, kind); `exec::Executable` wraps one compiled program with its
//! manifest signature so callers feed/receive named host tensors instead
//! of raw literals.
//!
//! Everything here is single-threaded by design (the PJRT wrapper types
//! hold raw pointers); the data loader runs on its own thread and talks
//! to the engine's thread through channels.

pub mod exec;
pub mod literal;

use std::collections::HashMap;
use std::rc::Rc;

use crate::error::{FxpError, Result};
use crate::model::manifest::Manifest;

pub use exec::Executable;
pub use literal::HostValue;

/// The runtime engine: PJRT client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::cell::RefCell<HashMap<(String, String), Rc<Executable>>>,
}

impl Engine {
    /// Create a CPU engine over an artifact directory (must contain
    /// `manifest.json`; see `make artifacts`).
    pub fn cpu(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::info!(
            "PJRT client: platform={} devices={}",
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { client, manifest, cache: Default::default() })
    }

    /// Compile (or fetch from cache) the executable for (arch, kind).
    pub fn executable(&self, arch: &str, kind: &str) -> Result<Rc<Executable>> {
        let key = (arch.to_string(), kind.to_string());
        if let Some(e) = self.cache.borrow().get(&key) {
            return Ok(e.clone());
        }
        let path = self.manifest.artifact_path(arch, kind)?;
        let spec = self.manifest.arch(arch)?.artifact(kind)?.clone();
        let t = std::time::Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            FxpError::Manifest(format!(
                "cannot parse HLO text {}: {e}",
                path.display()
            ))
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        log::info!(
            "compiled {arch}/{kind} in {:.2}s ({} inputs, {} outputs)",
            t.elapsed().as_secs_f64(),
            spec.inputs.len(),
            spec.outputs.len()
        );
        let wrapped = Rc::new(Executable::new(exe, spec));
        self.cache.borrow_mut().insert(key, wrapped.clone());
        Ok(wrapped)
    }

    /// Drop all cached executables (frees memory; mostly for tests).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }
}
