//! A compiled executable plus its manifest signature.
//!
//! Two call levels:
//! * `run` -- named host tensors in/out with full validation (used by
//!   evaluation, calibration, one-shot paths);
//! * `run_literals` -- raw literal in/out (the training hot path: the
//!   updated parameter/momentum literals returned by one step are fed
//!   straight back into the next step without a host round-trip).

use crate::error::{FxpError, Result};
use crate::model::manifest::ArtifactSpec;
use crate::runtime::literal::{check_input, from_literal, to_literal, HostValue};

pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
}

impl Executable {
    pub fn new(exe: xla::PjRtLoadedExecutable, spec: ArtifactSpec) -> Executable {
        Executable { exe, spec }
    }

    pub fn num_inputs(&self) -> usize {
        self.spec.inputs.len()
    }

    pub fn num_outputs(&self) -> usize {
        self.spec.outputs.len()
    }

    /// Execute with raw literals (no validation beyond arity); returns the
    /// untupled output literals in manifest order.
    pub fn run_literals(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(FxpError::shape(format!(
                "executable {}: {} inputs, expected {}",
                self.spec.file,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        let result = self.exe.execute::<&xla::Literal>(inputs)?;
        // AOT lowering uses return_tuple=True: single tuple result.
        let lit = result[0][0].to_literal_sync()?;
        let outs = lit.to_tuple()?;
        if outs.len() != self.spec.outputs.len() {
            return Err(FxpError::shape(format!(
                "executable {}: {} outputs, manifest says {}",
                self.spec.file,
                outs.len(),
                self.spec.outputs.len()
            )));
        }
        Ok(outs)
    }

    /// Execute with validated host tensors; returns host tensors.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<HostValue>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(FxpError::shape(format!(
                "executable {}: {} inputs, expected {}",
                self.spec.file,
                inputs.len(),
                self.spec.inputs.len()
            )));
        }
        for (v, spec) in inputs.iter().zip(&self.spec.inputs) {
            check_input(v, spec)?;
        }
        let lits: Vec<xla::Literal> =
            inputs.iter().map(to_literal).collect::<Result<_>>()?;
        let refs: Vec<&xla::Literal> = lits.iter().collect();
        let outs = self.run_literals(&refs)?;
        outs.iter()
            .zip(&self.spec.outputs)
            .map(|(l, s)| from_literal(l, s))
            .collect()
    }

    /// Convert host inputs to literals without running (callers that reuse
    /// constant inputs across many steps convert once).
    pub fn literals_of(&self, inputs: &[HostValue]) -> Result<Vec<xla::Literal>> {
        inputs.iter().map(to_literal).collect()
    }

    /// Read one named output from a literal row returned by `run_literals`.
    pub fn output_host(&self, outs: &[xla::Literal], name: &str) -> Result<HostValue> {
        let idx = self.spec.output_index(name)?;
        from_literal(&outs[idx], &self.spec.outputs[idx])
    }
}
