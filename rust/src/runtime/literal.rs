//! Host tensor <-> XLA literal conversion.

use crate::error::{FxpError, Result};
use crate::model::manifest::{Dtype, IoSpec};
use crate::tensor::{Tensor, TensorF, TensorI};

/// A host-side value crossing the executable boundary.
#[derive(Clone, Debug)]
pub enum HostValue {
    F32(TensorF),
    I32(TensorI),
}

impl HostValue {
    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => t.shape(),
            HostValue::I32(t) => t.shape(),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32(_) => Dtype::F32,
            HostValue::I32(_) => Dtype::I32,
        }
    }

    pub fn as_f32(&self) -> Result<&TensorF> {
        match self {
            HostValue::F32(t) => Ok(t),
            _ => Err(FxpError::shape("expected f32 tensor")),
        }
    }

    pub fn into_f32(self) -> Result<TensorF> {
        match self {
            HostValue::F32(t) => Ok(t),
            _ => Err(FxpError::shape("expected f32 tensor")),
        }
    }

    pub fn into_i32(self) -> Result<TensorI> {
        match self {
            HostValue::I32(t) => Ok(t),
            _ => Err(FxpError::shape("expected i32 tensor")),
        }
    }

    /// Scalar f32 view (for loss outputs).
    pub fn scalar_f32(&self) -> Result<f32> {
        let t = self.as_f32()?;
        if t.len() != 1 {
            return Err(FxpError::shape(format!(
                "expected scalar, got shape {:?}",
                t.shape()
            )));
        }
        Ok(t.data()[0])
    }
}

impl From<TensorF> for HostValue {
    fn from(t: TensorF) -> Self {
        HostValue::F32(t)
    }
}

impl From<TensorI> for HostValue {
    fn from(t: TensorI) -> Self {
        HostValue::I32(t)
    }
}

/// Build an XLA literal from a host value (bulk byte copy).
pub fn to_literal(v: &HostValue) -> Result<xla::Literal> {
    match v {
        HostValue::F32(t) => {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    t.data().as_ptr() as *const u8,
                    t.data().len() * 4,
                )
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::F32,
                t.shape(),
                bytes,
            )?)
        }
        HostValue::I32(t) => {
            let bytes: &[u8] = unsafe {
                std::slice::from_raw_parts(
                    t.data().as_ptr() as *const u8,
                    t.data().len() * 4,
                )
            };
            Ok(xla::Literal::create_from_shape_and_untyped_data(
                xla::ElementType::S32,
                t.shape(),
                bytes,
            )?)
        }
    }
}

/// Read a literal back into a host value, validated against the spec.
pub fn from_literal(lit: &xla::Literal, spec: &IoSpec) -> Result<HostValue> {
    match spec.dtype {
        Dtype::F32 => {
            let data = lit.to_vec::<f32>()?;
            Ok(HostValue::F32(Tensor::from_vec(&spec.shape, data)?))
        }
        Dtype::I32 => {
            let data = lit.to_vec::<i32>()?;
            Ok(HostValue::I32(Tensor::from_vec(&spec.shape, data)?))
        }
    }
}

/// Validate a host value against an input spec (shape + dtype).
pub fn check_input(v: &HostValue, spec: &IoSpec) -> Result<()> {
    if v.dtype() != spec.dtype {
        return Err(FxpError::shape(format!(
            "input '{}': dtype {:?}, expected {:?}",
            spec.name,
            v.dtype(),
            spec.dtype
        )));
    }
    if v.shape() != spec.shape.as_slice() {
        return Err(FxpError::shape(format!(
            "input '{}': shape {:?}, expected {:?}",
            spec.name,
            v.shape(),
            spec.shape
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_round_trip() {
        let t = TensorF::from_vec(&[2, 3], vec![1.0, -2.0, 3.5, 0.0, 1e-9, -7.25])
            .unwrap();
        let v = HostValue::F32(t.clone());
        let lit = to_literal(&v).unwrap();
        let spec = IoSpec { name: "x".into(), shape: vec![2, 3], dtype: Dtype::F32 };
        let back = from_literal(&lit, &spec).unwrap().into_f32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_round_trip() {
        let t = TensorI::from_vec(&[4], vec![0, -5, 123456, i32::MAX]).unwrap();
        let lit = to_literal(&HostValue::I32(t.clone())).unwrap();
        let spec = IoSpec { name: "y".into(), shape: vec![4], dtype: Dtype::I32 };
        let back = from_literal(&lit, &spec).unwrap().into_i32().unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn check_input_catches_mismatch() {
        let spec = IoSpec { name: "x".into(), shape: vec![2], dtype: Dtype::F32 };
        let ok = HostValue::F32(TensorF::zeros(&[2]));
        check_input(&ok, &spec).unwrap();
        let bad_shape = HostValue::F32(TensorF::zeros(&[3]));
        assert!(check_input(&bad_shape, &spec).is_err());
        let bad_ty = HostValue::I32(TensorI::zeros(&[2]));
        assert!(check_input(&bad_ty, &spec).is_err());
    }

    #[test]
    fn scalar_accessor() {
        let v = HostValue::F32(TensorF::from_vec(&[], vec![2.5]).unwrap());
        assert_eq!(v.scalar_f32().unwrap(), 2.5);
        let not_scalar = HostValue::F32(TensorF::zeros(&[2]));
        assert!(not_scalar.scalar_f32().is_err());
    }
}
