//! Rounding modes for float -> code conversion.

use crate::util::rng::Rng;

/// How a real value is mapped to the nearest integer code.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RoundMode {
    /// floor(x + 0.5): round-to-nearest, ties toward +inf.  Matches the
    /// Pallas kernel and ref.py bit-for-bit.
    NearestHalfUp,
    /// Truncation toward -inf (the cheapest HW option; shown in ablations).
    Floor,
    /// floor(x + u), u ~ U[0,1): unbiased stochastic rounding
    /// (Gupta et al. 2015), the paper's named complementary technique.
    Stochastic,
}

impl RoundMode {
    /// Round a scaled value (already divided by the step) to an integer.
    #[inline]
    pub fn round(&self, scaled: f64, rng: Option<&mut Rng>) -> i64 {
        match self {
            RoundMode::NearestHalfUp => (scaled + 0.5).floor() as i64,
            RoundMode::Floor => scaled.floor() as i64,
            RoundMode::Stochastic => {
                let u = rng.expect("stochastic rounding needs an Rng").uniform();
                (scaled + u).floor() as i64
            }
        }
    }

    pub fn parse(s: &str) -> Option<RoundMode> {
        match s {
            "nearest" => Some(RoundMode::NearestHalfUp),
            "floor" => Some(RoundMode::Floor),
            "stochastic" => Some(RoundMode::Stochastic),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_half_up() {
        let m = RoundMode::NearestHalfUp;
        assert_eq!(m.round(0.5, None), 1);
        assert_eq!(m.round(-0.5, None), 0);
        assert_eq!(m.round(1.49, None), 1);
        assert_eq!(m.round(-1.51, None), -2);
    }

    #[test]
    fn floor() {
        let m = RoundMode::Floor;
        assert_eq!(m.round(1.99, None), 1);
        assert_eq!(m.round(-0.01, None), -1);
    }

    #[test]
    fn stochastic_unbiased() {
        let mut rng = Rng::new(3);
        let m = RoundMode::Stochastic;
        let n = 40000;
        let sum: i64 = (0..n).map(|_| m.round(0.3, Some(&mut rng))).sum();
        let mean = sum as f64 / n as f64;
        assert!((mean - 0.3).abs() < 0.01, "{mean}");
    }

    #[test]
    fn stochastic_exact_integers_stay() {
        let mut rng = Rng::new(4);
        let m = RoundMode::Stochastic;
        for _ in 0..100 {
            assert_eq!(m.round(7.0, Some(&mut rng)), 7);
        }
    }

    #[test]
    fn parse() {
        assert_eq!(RoundMode::parse("nearest"), Some(RoundMode::NearestHalfUp));
        assert_eq!(RoundMode::parse("bogus"), None);
    }
}
