//! Software fixed-point arithmetic (Q-format), the bit-exact model of the
//! paper's Figure 1 hardware pipeline.
//!
//! Three views of the same semantics live in this repo and are
//! cross-checked by tests:
//!
//! 1. this module -- integer arithmetic on raw codes (used by the pure
//!    fixed-point inference engine and by calibration);
//! 2. the L1 Pallas kernels -- float simulation `clip(round(x/step))*step`
//!    (what the AOT executables run);
//! 3. `python/compile/kernels/ref.py` -- the pure-jnp oracle.
//!
//! Conventions: signed two's-complement codes, saturating, rounding mode
//! "nearest, half up" (floor(x + 0.5)) unless stated otherwise.

pub mod format;
pub mod rounding;
pub mod value;
pub mod vector;

pub use format::QFormat;
pub use rounding::RoundMode;
pub use value::Fx;
