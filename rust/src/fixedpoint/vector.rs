//! Vectorised quantization over f32 slices -- the host-side twin of the
//! L1 Pallas quantize kernel, plus the SQNR measurement used by
//! calibration and the Figure 2 staircase sampler.

use super::format::QFormat;
use super::rounding::RoundMode;
use crate::inference::kernels::Kernels;
use crate::util::rng::Rng;

/// Where a quantize/requantize pass reports its clip (saturation)
/// tally.  The pass is written once, generic over the sink, so the
/// plain and telemetry-counted entry points are definitionally the same
/// numerics/RNG stream -- a [`NoCount`] sink compiles to nothing.
pub trait SatSink {
    fn clipped(&mut self, n: u64);
}

/// Discard the tally (the plain entry points).
#[derive(Default)]
pub struct NoCount;

impl SatSink for NoCount {
    #[inline(always)]
    fn clipped(&mut self, _n: u64) {}
}

/// Accumulate the tally (the PR 6 telemetry entry points).
#[derive(Default)]
pub struct SatCount(pub u64);

impl SatSink for SatCount {
    #[inline(always)]
    fn clipped(&mut self, n: u64) {
        self.0 += n;
    }
}

/// Quantize a slice in place: `x <- clip(round(x/step), qmin, qmax)*step`.
/// Bit-identical to the Pallas kernel for `NearestHalfUp`.
pub fn quantize_slice(
    xs: &mut [f32],
    fmt: QFormat,
    mode: RoundMode,
    rng: Option<&mut Rng>,
) {
    quantize_pass(xs, fmt, mode, rng, &mut NoCount);
}

/// [`quantize_slice`] plus a saturation counter: returns how many
/// elements' raw codes fell outside `[qmin, qmax]` and were clipped to
/// the format bounds.  Both entry points delegate to the same
/// sink-generic [`quantize_pass`], so values written and RNG draws
/// consumed are definitionally identical whether or not the count is
/// used -- the
/// telemetry layer can harvest clip counts without perturbing training
/// numerics (pinned by tests/properties.rs).  The count is a plain
/// element tally, so any partition of `xs` into sub-slices sums to the
/// same total (u64 addition is associative), which is what makes the
/// per-layer saturation statistics thread-invariant.
pub fn quantize_slice_counted(
    xs: &mut [f32],
    fmt: QFormat,
    mode: RoundMode,
    rng: Option<&mut Rng>,
) -> u64 {
    let mut sink = SatCount(0);
    quantize_pass(xs, fmt, mode, rng, &mut sink);
    sink.0
}

/// The one quantize pass implementation, generic over the clip-tally
/// sink.  `NearestHalfUp` (the hot mode: every activation pass, weight
/// rounding outside stochastic SGD) routes through the process-wide
/// [`Kernels`] facade and so vectorizes on AVX2/NEON hosts -- the SIMD
/// pipeline is bit-identical to the scalar one by the kernel-layer
/// parity contract.  `Floor` stays scalar, and `Stochastic` keeps the
/// block-buffered dither loop untouched so the RNG draw stream is
/// bit-identical to every prior release.
pub fn quantize_pass<S: SatSink>(
    xs: &mut [f32],
    fmt: QFormat,
    mode: RoundMode,
    mut rng: Option<&mut Rng>,
    sink: &mut S,
) {
    let step = fmt.step();
    let inv = 1.0 / step as f64;
    let (lo, hi) = (fmt.qmin() as f64, fmt.qmax() as f64);
    let mut sat = 0u64;
    match mode {
        RoundMode::NearestHalfUp => {
            sat += Kernels::auto().quantize_nearest(xs, fmt);
        }
        RoundMode::Floor => {
            for x in xs.iter_mut() {
                let raw = ((*x as f64) * inv).floor();
                sat += (raw < lo || raw > hi) as u64;
                let code = raw.clamp(lo, hi);
                *x = (code * step as f64) as f32;
            }
        }
        RoundMode::Stochastic => {
            // block-buffered dither: one fill_uniform call per 256
            // elements instead of one uniform() call each -- the same
            // draw stream, so results are bit-identical to the scalar
            // loop, but the rounding loop below stays branch-free
            let rng = rng.as_mut().expect("stochastic needs rng");
            let mut us = [0f64; 256];
            for chunk in xs.chunks_mut(256) {
                let dither = &mut us[..chunk.len()];
                rng.fill_uniform(dither);
                for (x, &u) in chunk.iter_mut().zip(dither.iter()) {
                    let raw = ((*x as f64) * inv + u).floor();
                    sat += (raw < lo || raw > hi) as u64;
                    let code = raw.clamp(lo, hi);
                    *x = (code * step as f64) as f32;
                }
            }
        }
    }
    sink.clipped(sat);
}

/// Non-destructive quantization.
pub fn quantized(xs: &[f32], fmt: QFormat, mode: RoundMode, rng: Option<&mut Rng>) -> Vec<f32> {
    let mut out = xs.to_vec();
    quantize_slice(&mut out, fmt, mode, rng);
    out
}

/// Encode a slice to integer codes (the deployment path of the inference
/// engine).
pub fn encode(xs: &[f32], fmt: QFormat) -> Vec<i64> {
    let step = fmt.step() as f64;
    xs.iter()
        .map(|&x| {
            ((x as f64 / step + 0.5).floor() as i64).clamp(fmt.qmin(), fmt.qmax())
        })
        .collect()
}

/// Decode integer codes back to floats.
pub fn decode(codes: &[i64], fmt: QFormat) -> Vec<f32> {
    let step = fmt.step();
    codes.iter().map(|&c| c as f32 * step).collect()
}

/// Signal-to-quantization-noise ratio in dB of representing `xs` in `fmt`.
/// This is the objective the SQNR-optimal calibration (quant/calib.rs)
/// maximises, after Lin et al., ICML 2016.
///
/// Single pass, no intermediate buffer: each element is quantized on the
/// fly with the same nearest-half-up arithmetic as [`quantize_slice`]
/// (identical numerics), and only the two running sums are kept.  The
/// SQNR-optimal calibration calls this once per candidate format per
/// layer, so the allocation it used to make was a hot one.
pub fn sqnr_db(xs: &[f32], fmt: QFormat) -> f64 {
    let step = fmt.step();
    let inv = 1.0 / step as f64;
    let (lo, hi) = (fmt.qmin() as f64, fmt.qmax() as f64);
    let mut sig = 0.0f64;
    let mut noise = 0.0f64;
    for &x in xs {
        sig += (x as f64) * (x as f64);
        let code = ((x as f64) * inv + 0.5).floor().clamp(lo, hi);
        let xq = (code * step as f64) as f32;
        let d = (x - xq) as f64;
        noise += d * d;
    }
    if sig == 0.0 {
        return 0.0;
    }
    if noise == 0.0 {
        return f64::INFINITY;
    }
    10.0 * (sig / noise).log10()
}

/// Sample the *effective* activation function of Figure 2(b):
/// `relu` then quantization, over `n` points of [lo, hi].
/// Returns (x, effective, presumed) triples for the figure bench.
pub fn effective_relu_curve(
    fmt: QFormat,
    lo: f32,
    hi: f32,
    n: usize,
) -> Vec<(f32, f32, f32)> {
    (0..n)
        .map(|i| {
            let x = lo + (hi - lo) * i as f32 / (n - 1).max(1) as f32;
            let presumed = x.max(0.0);
            let mut v = [presumed];
            quantize_slice(&mut v, fmt, RoundMode::NearestHalfUp, None);
            (x, v[0], presumed)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(bits: u8, frac: i8) -> QFormat {
        QFormat::new(bits, frac).unwrap()
    }

    #[test]
    fn quantize_matches_scalar_path() {
        let mut rng = Rng::new(1);
        let fmt = q(6, 2);
        let xs: Vec<f32> = (0..500).map(|_| rng.uniform_in(-20.0, 20.0)).collect();
        let v = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        for (&x, &got) in xs.iter().zip(&v) {
            let fx = super::super::value::Fx::from_f32(
                x,
                fmt,
                RoundMode::NearestHalfUp,
                None,
            );
            assert_eq!(got, fx.to_f32(), "x={x}");
        }
    }

    #[test]
    fn idempotent() {
        let mut rng = Rng::new(2);
        let fmt = q(8, 3);
        let xs: Vec<f32> = (0..300).map(|_| rng.uniform_in(-40.0, 40.0)).collect();
        let q1 = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
        let q2 = quantized(&q1, fmt, RoundMode::NearestHalfUp, None);
        assert_eq!(q1, q2);
    }

    #[test]
    fn encode_decode_round_trip() {
        let fmt = q(8, 4);
        let xs = vec![0.0f32, 1.5, -3.25, 7.9375, -8.0, 100.0];
        let codes = encode(&xs, fmt);
        assert_eq!(codes, vec![0, 24, -52, 127, -128, 127]);
        let back = decode(&codes, fmt);
        assert_eq!(back[1], 1.5);
        let again = encode(&back, fmt);
        assert_eq!(codes, again);
    }

    #[test]
    fn sqnr_improves_with_bits() {
        let mut rng = Rng::new(3);
        let xs: Vec<f32> = (0..4000).map(|_| rng.normal() as f32).collect();
        let s4 = sqnr_db(&xs, QFormat::fit_absmax(4, 4.0).unwrap());
        let s8 = sqnr_db(&xs, QFormat::fit_absmax(8, 4.0).unwrap());
        let s16 = sqnr_db(&xs, QFormat::fit_absmax(16, 4.0).unwrap());
        assert!(s4 < s8 && s8 < s16, "{s4} {s8} {s16}");
        // each extra bit is worth ~6 dB
        assert!((s8 - s4) > 15.0 && (s16 - s8) > 15.0, "{s4} {s8} {s16}");
    }

    #[test]
    fn sqnr_edge_cases() {
        assert_eq!(sqnr_db(&[0.0; 8], q(8, 4)), 0.0);
        // exactly representable values -> infinite SQNR
        assert_eq!(sqnr_db(&[1.0, 0.5, -0.25], q(8, 4)), f64::INFINITY);
    }

    #[test]
    fn staircase_has_flat_steps() {
        let curve = effective_relu_curve(q(4, 1), -1.0, 3.0, 801);
        let distinct: std::collections::BTreeSet<i64> =
            curve.iter().map(|&(_, e, _)| (e * 16.0) as i64).collect();
        // 4-bit signed frac 1: positive codes 0..7 -> at most 8 levels
        assert!(distinct.len() <= 8, "{}", distinct.len());
        // effective differs from presumed somewhere
        assert!(curve.iter().any(|&(_, e, p)| (e - p).abs() > 0.2));
        // negative x collapses to zero
        assert!(curve
            .iter()
            .filter(|&&(x, _, _)| x < -0.3)
            .all(|&(_, e, _)| e == 0.0));
    }

    #[test]
    fn stochastic_block_buffering_keeps_the_stream() {
        // the 256-block fill_uniform path must consume the rng exactly as
        // the old per-element loop did (lengths straddle block edges)
        for n in [1usize, 255, 256, 257, 1000] {
            let mut rng = Rng::new(41);
            let fmt = q(8, 3);
            let xs: Vec<f32> = (0..n).map(|i| (i as f32 * 0.37).sin() * 5.0).collect();
            let got = quantized(&xs, fmt, RoundMode::Stochastic, Some(&mut rng));
            // reference: scalar draws from an identical rng
            let mut rref = Rng::new(41);
            let step = fmt.step() as f64;
            let inv = 1.0 / step;
            let want: Vec<f32> = xs
                .iter()
                .map(|&x| {
                    let u = rref.uniform();
                    let code = (x as f64 * inv + u)
                        .floor()
                        .clamp(fmt.qmin() as f64, fmt.qmax() as f64);
                    (code * step) as f32
                })
                .collect();
            assert_eq!(got, want, "n={n}");
        }
    }

    #[test]
    fn sqnr_single_pass_matches_quantized_reference() {
        let mut rng = Rng::new(6);
        let xs: Vec<f32> = (0..3000).map(|_| rng.normal() as f32 * 2.0).collect();
        for fmt in [q(4, 2), q(8, 4), q(16, 10), q(8, -1)] {
            let q = quantized(&xs, fmt, RoundMode::NearestHalfUp, None);
            let (mut sig, mut noise) = (0f64, 0f64);
            for (&x, &xq) in xs.iter().zip(&q) {
                sig += (x as f64) * (x as f64);
                let d = (x - xq) as f64;
                noise += d * d;
            }
            let want = 10.0 * (sig / noise).log10();
            assert_eq!(sqnr_db(&xs, fmt), want, "{fmt}");
        }
    }

    #[test]
    fn stochastic_slice_unbiased() {
        let mut rng = Rng::new(9);
        let xs = vec![0.3f32; 20000];
        let v = quantized(&xs, q(8, 2), RoundMode::Stochastic, Some(&mut rng));
        let mean: f64 = v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64;
        assert!((mean - 0.3).abs() < 0.005, "{mean}");
    }
}
