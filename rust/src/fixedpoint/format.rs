//! Q-format descriptors.

use std::fmt;

use crate::error::{FxpError, Result};

/// A signed fixed-point format: `bits` total bits (including sign),
/// `frac` fractional bits.  `frac` may be negative (coarser-than-integer
/// steps) or exceed `bits` (sub-unit ranges); both occur when calibrating
/// layers with very small/large dynamic ranges.
///
/// Representable grid: `{qmin, ..., qmax} * 2^-frac` with
/// `qmin = -2^(bits-1)` and `qmax = 2^(bits-1) - 1`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct QFormat {
    pub bits: u8,
    pub frac: i8,
}

impl QFormat {
    pub fn new(bits: u8, frac: i8) -> Result<Self> {
        if !(2..=32).contains(&bits) {
            return Err(FxpError::config(format!(
                "QFormat bits must be in 2..=32, got {bits}"
            )));
        }
        Ok(QFormat { bits, frac })
    }

    /// Quantization step 2^-frac.
    #[inline]
    pub fn step(&self) -> f32 {
        (self.frac as f32).exp2().recip()
    }

    /// Smallest representable code.
    #[inline]
    pub fn qmin(&self) -> i64 {
        -(1i64 << (self.bits - 1))
    }

    /// Largest representable code.
    #[inline]
    pub fn qmax(&self) -> i64 {
        (1i64 << (self.bits - 1)) - 1
    }

    /// Most negative representable value.
    #[inline]
    pub fn min_value(&self) -> f32 {
        self.qmin() as f32 * self.step()
    }

    /// Most positive representable value.
    #[inline]
    pub fn max_value(&self) -> f32 {
        self.qmax() as f32 * self.step()
    }

    /// The (step, qmin, qmax) triple fed to the AOT executables as runtime
    /// config (matches python ref.qparams).
    pub fn runtime_cfg(&self) -> (f32, f32, f32) {
        (self.step(), self.qmin() as f32, self.qmax() as f32)
    }

    /// Smallest `frac` such that `absmax` still fits without overflow --
    /// the min-max calibration rule: use all `bits-1` magnitude bits for
    /// the integer part of the largest observed value.
    pub fn fit_absmax(bits: u8, absmax: f32) -> Result<QFormat> {
        if absmax <= 0.0 || !absmax.is_finite() {
            // degenerate layer (all zeros): any format works; pick mid.
            return QFormat::new(bits, (bits as i8) - 1);
        }
        // need absmax <= (2^(bits-1) - 1) * 2^-frac  (approx 2^(bits-1-frac))
        // -> frac = bits - 1 - ceil(log2(absmax / (1 - 2^-(bits-1))))
        let il = (absmax as f64 / (1.0 - 0.5f64.powi(bits as i32 - 1))).log2().ceil()
            as i32;
        let frac = bits as i32 - 1 - il;
        let frac = frac.clamp(i8::MIN as i32, i8::MAX as i32) as i8;
        QFormat::new(bits, frac)
    }

    /// Parse "B.F" / "B:F" (e.g. "8.4") into a format.
    pub fn parse(s: &str) -> Result<QFormat> {
        let s = s.trim();
        let (b, f) = s
            .split_once(['.', ':'])
            .ok_or_else(|| FxpError::config(format!("bad QFormat '{s}'")))?;
        let bits: u8 = b
            .parse()
            .map_err(|_| FxpError::config(format!("bad bits in '{s}'")))?;
        let frac: i8 = f
            .parse()
            .map_err(|_| FxpError::config(format!("bad frac in '{s}'")))?;
        QFormat::new(bits, frac)
    }
}

impl fmt::Display for QFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Q{}.{}", self.bits, self.frac)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let q = QFormat::new(8, 4).unwrap();
        assert_eq!(q.step(), 0.0625);
        assert_eq!(q.qmin(), -128);
        assert_eq!(q.qmax(), 127);
        assert_eq!(q.min_value(), -8.0);
        assert!((q.max_value() - 7.9375).abs() < 1e-6);
    }

    #[test]
    fn negative_frac() {
        let q = QFormat::new(4, -1).unwrap();
        assert_eq!(q.step(), 2.0);
        assert_eq!(q.max_value(), 14.0);
    }

    #[test]
    fn bits_bounds() {
        assert!(QFormat::new(1, 0).is_err());
        assert!(QFormat::new(33, 0).is_err());
        assert!(QFormat::new(2, 0).is_ok());
        assert!(QFormat::new(32, 16).is_ok());
    }

    #[test]
    fn fit_absmax_covers() {
        for &bits in &[4u8, 8, 16] {
            for &am in &[0.3f32, 1.0, 1.5, 7.9, 100.0, 0.01] {
                let q = QFormat::fit_absmax(bits, am).unwrap();
                assert!(
                    q.max_value() >= am * 0.999,
                    "bits={bits} absmax={am} got {q} max={}",
                    q.max_value()
                );
                // and not wastefully large: one fewer integer bit overflows
                let tighter = QFormat::new(bits, q.frac + 1).unwrap();
                assert!(
                    tighter.max_value() < am,
                    "bits={bits} absmax={am}: {q} not tight"
                );
            }
        }
    }

    #[test]
    fn fit_absmax_degenerate() {
        let q = QFormat::fit_absmax(8, 0.0).unwrap();
        assert_eq!(q.bits, 8);
    }

    #[test]
    fn parse_display() {
        let q = QFormat::parse("8.4").unwrap();
        assert_eq!(q, QFormat::new(8, 4).unwrap());
        assert_eq!(QFormat::parse("16:-2").unwrap().frac, -2);
        assert!(QFormat::parse("x").is_err());
        assert_eq!(q.to_string(), "Q8.4");
    }

    #[test]
    fn runtime_cfg_matches_python_qparams() {
        // mirrors ref.qparams(8, 4)
        let (s, lo, hi) = QFormat::new(8, 4).unwrap().runtime_cfg();
        assert_eq!((s, lo, hi), (0.0625, -128.0, 127.0));
    }
}
