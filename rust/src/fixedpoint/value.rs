//! Scalar fixed-point values: raw integer codes + format, with the
//! saturating arithmetic of the paper's Figure 1 pipeline.
//!
//! `Fx` is the unit of the pure-integer inference engine: multiplication
//! widens (step 1), sums accumulate in i64 "wide accumulators" (step 2),
//! and `requantize` rounds/saturates back to a narrow format (step 3).

use super::format::QFormat;
use super::rounding::RoundMode;
use crate::util::rng::Rng;

/// A fixed-point number: integer `code` in format `fmt`
/// (value = code * 2^-fmt.frac).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Fx {
    pub code: i64,
    pub fmt: QFormat,
}

impl Fx {
    /// Encode a float (saturating, given rounding mode).
    pub fn from_f32(x: f32, fmt: QFormat, mode: RoundMode, rng: Option<&mut Rng>) -> Fx {
        let scaled = x as f64 / fmt.step() as f64;
        let code = mode.round(scaled, rng).clamp(fmt.qmin(), fmt.qmax());
        Fx { code, fmt }
    }

    #[inline]
    pub fn to_f32(&self) -> f32 {
        self.code as f32 * self.fmt.step()
    }

    /// Saturating add within the same format.
    pub fn sat_add(&self, other: &Fx) -> Fx {
        assert_eq!(self.fmt, other.fmt, "sat_add: format mismatch");
        let code = (self.code + other.code).clamp(self.fmt.qmin(), self.fmt.qmax());
        Fx { code, fmt: self.fmt }
    }

    /// Widening multiply (Figure 1 step 1): an (a.bits x b.bits) multiply
    /// produces a code in a (a.bits + b.bits)-bit format with summed
    /// fractional lengths.  No rounding, no saturation -- exact.
    pub fn wide_mul(&self, other: &Fx) -> WideAcc {
        WideAcc {
            acc: self.code as i128 * other.code as i128,
            frac: self.fmt.frac as i32 + other.fmt.frac as i32,
        }
    }
}

/// The wide accumulator of Figure 1 step 2: i128 to make overflow
/// impossible for any realistic layer size (codes are <= 2^31; a dot
/// product of 2^40 terms still fits).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WideAcc {
    pub acc: i128,
    /// fractional length of the accumulator grid
    pub frac: i32,
}

impl WideAcc {
    pub fn zero(frac: i32) -> WideAcc {
        WideAcc { acc: 0, frac }
    }

    /// Accumulate another product (must share the fractional length --
    /// guaranteed when all operands share formats, as within one layer).
    #[inline]
    pub fn add(&mut self, p: WideAcc) {
        debug_assert_eq!(self.frac, p.frac, "accumulator frac mismatch");
        self.acc += p.acc;
    }

    /// Add a bias value expressed in float (converted exactly onto the
    /// accumulator grid with nearest rounding -- biases are kept in
    /// accumulator precision, cf. model.py).
    pub fn add_f32(&mut self, b: f32) {
        let scaled = b as f64 * (self.frac as f64).exp2();
        self.acc += (scaled + 0.5).floor() as i128;
    }

    pub fn to_f64(&self) -> f64 {
        self.acc as f64 * (-(self.frac as f64)).exp2()
    }

    /// Figure 1 step 3: round/truncate the accumulator into `fmt`.
    pub fn requantize(&self, fmt: QFormat, mode: RoundMode, rng: Option<&mut Rng>) -> Fx {
        self.requantize_counted(fmt, mode, rng).0
    }

    /// [`WideAcc::requantize`] plus a saturation flag: true iff the
    /// rounded code fell outside `fmt`'s representable range and was
    /// clipped.  `requantize` delegates here, so the returned `Fx` (and
    /// any stochastic-rounding draw) is definitionally identical with or
    /// without the flag -- the overflow telemetry rides along for free
    /// (pinned by tests/properties.rs).
    pub fn requantize_counted(
        &self,
        fmt: QFormat,
        mode: RoundMode,
        rng: Option<&mut Rng>,
    ) -> (Fx, bool) {
        // shift = number of fractional bits to drop (may be negative)
        let shift = self.frac - fmt.frac as i32;
        let code = if shift == 0 {
            self.acc
        } else if shift > 0 {
            // dropping bits: round at the new LSB
            match mode {
                RoundMode::NearestHalfUp => {
                    let half = 1i128 << (shift - 1);
                    (self.acc + half) >> shift
                }
                RoundMode::Floor => self.acc >> shift,
                RoundMode::Stochastic => {
                    // draw the additive dither as an *integer* uniform in
                    // [0, 2^shift): a f64 draw scaled by 2^shift only has
                    // 53 mantissa bits, so for shift > 53 it could never
                    // set the low bits and the rounding went subtly
                    // deterministic in them
                    debug_assert!(shift < 128, "requantize shift {shift} too wide");
                    let frac_units =
                        rng.expect("stochastic needs rng").bits128(shift as u32) as i128;
                    (self.acc + frac_units) >> shift
                }
            }
        } else {
            // gaining bits: exact
            self.acc << (-shift)
        };
        let saturated = code < fmt.qmin() as i128 || code > fmt.qmax() as i128;
        let code =
            code.clamp(fmt.qmin() as i128, fmt.qmax() as i128) as i64;
        (Fx { code, fmt }, saturated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(bits: u8, frac: i8) -> QFormat {
        QFormat::new(bits, frac).unwrap()
    }

    #[test]
    fn encode_decode() {
        let f = q(8, 4);
        let x = Fx::from_f32(1.5, f, RoundMode::NearestHalfUp, None);
        assert_eq!(x.code, 24);
        assert_eq!(x.to_f32(), 1.5);
        // saturation
        let s = Fx::from_f32(100.0, f, RoundMode::NearestHalfUp, None);
        assert_eq!(s.code, 127);
        let s = Fx::from_f32(-100.0, f, RoundMode::NearestHalfUp, None);
        assert_eq!(s.code, -128);
    }

    #[test]
    fn encode_matches_kernel_semantics() {
        // same numbers as python test_round_half_up
        let f = q(8, 0);
        for (x, want) in [(0.5f32, 1), (-0.5, 0), (1.5, 2), (-1.5, -1), (2.5, 3)] {
            assert_eq!(
                Fx::from_f32(x, f, RoundMode::NearestHalfUp, None).code,
                want,
                "{x}"
            );
        }
    }

    #[test]
    fn sat_add() {
        let f = q(4, 0); // range -8..7
        let a = Fx { code: 5, fmt: f };
        let b = Fx { code: 6, fmt: f };
        assert_eq!(a.sat_add(&b).code, 7);
        let c = Fx { code: -8, fmt: f };
        assert_eq!(c.sat_add(&c).code, -8);
    }

    #[test]
    fn wide_mul_exact() {
        // 1.5 * 2.25 in Q8.4: codes 24 and 36, product 864 at frac 8
        let f = q(8, 4);
        let a = Fx::from_f32(1.5, f, RoundMode::NearestHalfUp, None);
        let b = Fx::from_f32(2.25, f, RoundMode::NearestHalfUp, None);
        let p = a.wide_mul(&b);
        assert_eq!(p.acc, 864);
        assert_eq!(p.frac, 8);
        assert_eq!(p.to_f64(), 3.375);
    }

    #[test]
    fn accumulate_and_requantize() {
        let f = q(8, 4);
        let mut acc = WideAcc::zero(8);
        for _ in 0..10 {
            let a = Fx::from_f32(0.5, f, RoundMode::NearestHalfUp, None);
            let b = Fx::from_f32(0.5, f, RoundMode::NearestHalfUp, None);
            acc.add(a.wide_mul(&b));
        }
        assert_eq!(acc.to_f64(), 2.5);
        let out = acc.requantize(q(8, 4), RoundMode::NearestHalfUp, None);
        assert_eq!(out.to_f32(), 2.5);
        // to a coarser grid
        let out = acc.requantize(q(8, 0), RoundMode::NearestHalfUp, None);
        assert_eq!(out.to_f32(), 3.0); // 2.5 rounds half-up to 3
    }

    #[test]
    fn requantize_saturates() {
        let mut acc = WideAcc::zero(8);
        acc.add_f32(1000.0);
        let out = acc.requantize(q(8, 4), RoundMode::NearestHalfUp, None);
        assert_eq!(out.code, 127);
    }

    #[test]
    fn requantize_gaining_bits_is_exact() {
        let mut acc = WideAcc::zero(2);
        acc.add_f32(1.25);
        let out = acc.requantize(q(16, 8), RoundMode::NearestHalfUp, None);
        assert_eq!(out.to_f32(), 1.25);
    }

    #[test]
    fn bias_on_accumulator_grid() {
        let mut acc = WideAcc::zero(8);
        acc.add_f32(0.125);
        assert_eq!(acc.acc, 32);
    }

    #[test]
    fn stochastic_requantize_unbiased_at_wide_shift() {
        // shift = 60 (> 53): the old f64-scaled draw lost the low bits of
        // the dither; the integer draw must stay unbiased and in-range.
        let mut rng = Rng::new(77);
        let out_fmt = q(8, 0);
        // value 2.5 placed exactly on a frac-60 accumulator grid
        let acc = WideAcc { acc: 5i128 << 59, frac: 60 };
        let mut sum = 0i64;
        let n = 20000;
        for _ in 0..n {
            let c = acc.requantize(out_fmt, RoundMode::Stochastic, Some(&mut rng)).code;
            assert!(c == 2 || c == 3, "{c}");
            sum += c;
        }
        let mean = sum as f64 / n as f64;
        assert!((mean - 2.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn stochastic_requantize_deterministic_per_seed() {
        let acc = WideAcc { acc: (3i128 << 70) + 12345, frac: 72 };
        let fmt = q(16, 4);
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..100 {
            assert_eq!(
                acc.requantize(fmt, RoundMode::Stochastic, Some(&mut a)).code,
                acc.requantize(fmt, RoundMode::Stochastic, Some(&mut b)).code
            );
        }
    }

    #[test]
    fn requant_nearest_matches_float_path() {
        // cross-check integer rounding against the float formula on many
        // random accumulators (the parity the inference engine relies on)
        let mut rng = Rng::new(11);
        let out_fmt = q(8, 3);
        for _ in 0..2000 {
            let v = (rng.uniform() - 0.5) * 60.0;
            let mut acc = WideAcc::zero(12);
            // place v on the accumulator grid exactly
            acc.acc = ((v * (1u64 << 12) as f64) + 0.5).floor() as i128;
            let got = acc.requantize(out_fmt, RoundMode::NearestHalfUp, None);
            let vv = acc.to_f64();
            let want = ((vv / out_fmt.step() as f64 + 0.5).floor())
                .clamp(out_fmt.qmin() as f64, out_fmt.qmax() as f64)
                as i64;
            assert_eq!(got.code, want, "v={vv}");
        }
    }
}
