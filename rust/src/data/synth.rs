//! SynthShapes: procedural 10-class RGB image dataset.
//!
//! Class = shape family (disk, ring, box, cross, stripes) x texture
//! (smooth, modulated).  Every image is generated independently from
//! `hash(seed, index)`, so the dataset is fully deterministic, lazily
//! generatable, and identical regardless of generation order or count.
//!
//! Per-image nuisance variation: centre/scale/rotation jitter, foreground
//! /background colour jitter, background gradient and pixel noise -- the
//! point is that a linear model cannot solve it while a small CNN can fit
//! it well, giving fine-tuning experiments a meaningful accuracy range.

use crate::tensor::{Tensor, TensorF, TensorI};
use crate::util::rng::Rng;

pub const NUM_CLASSES: usize = 10;

/// An in-memory dataset (images NHWC in [0,1], labels i32).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub images: TensorF,
    pub labels: TensorI,
    pub h: usize,
    pub w: usize,
}

#[derive(Clone, Copy)]
enum Shape {
    Disk,
    Ring,
    Box_,
    Cross,
    Stripes,
}

impl Shape {
    fn of_class(c: usize) -> Shape {
        match c % 5 {
            0 => Shape::Disk,
            1 => Shape::Ring,
            2 => Shape::Box_,
            3 => Shape::Cross,
            _ => Shape::Stripes,
        }
    }
}

/// Signed distance-ish membership of a pixel in the (rotated, scaled)
/// shape, in [0,1].
fn shape_mask(shape: Shape, u: f32, v: f32) -> f32 {
    // u, v in shape-local coordinates, roughly [-1, 1]
    let r = (u * u + v * v).sqrt();
    let soft = |d: f32| (1.0 - d * 8.0).clamp(0.0, 1.0);
    match shape {
        Shape::Disk => soft(r - 0.75),
        Shape::Ring => soft((r - 0.62).abs() - 0.22),
        Shape::Box_ => {
            let d = u.abs().max(v.abs());
            soft(d - 0.7)
        }
        Shape::Cross => {
            let d = (u.abs().min(v.abs()) - 0.28).max(u.abs().max(v.abs()) - 0.85);
            soft(d)
        }
        Shape::Stripes => {
            let s = (u * 6.0).sin();
            let inside = soft(r - 0.85);
            inside * (0.5 + 0.5 * s).round()
        }
    }
}

/// Generate image `index` of the stream identified by `seed`.
/// Returns (pixels HWC, label).
pub fn gen_image(seed: u64, index: u64, h: usize, w: usize) -> (Vec<f32>, i32) {
    let mut rng = Rng::new(seed ^ index.wrapping_mul(0x9E3779B97F4A7C15).rotate_left(17));
    let class = (rng.next_u64() % NUM_CLASSES as u64) as usize;
    let shape = Shape::of_class(class);
    // class = shape family (5) x texture-frequency band (2).  The bands
    // are adjacent, and scale jitter varies the *apparent* frequency by
    // 2x, so the discrimination is genuinely fine-grained -- exactly the
    // kind of feature low-precision activations destroy first.
    let high_band = class >= 5;

    // nuisance parameters (aggressive: the task must be hard enough that
    // a deep net fits real structure and quantization visibly hurts)
    let cx = 0.5 + rng.uniform_in(-0.18, 0.18);
    let cy = 0.5 + rng.uniform_in(-0.18, 0.18);
    let scale = rng.uniform_in(0.6, 0.95);
    let theta = rng.uniform_in(-0.9, 0.9);
    let (sin_t, cos_t) = (theta.sin(), theta.cos());

    // colours: hue is NOT class-correlated (fully random), so colour
    // carries no label information -- only shape and texture do
    let fg = hue_rgb(rng.uniform_in(0.0, 1.0));
    let fg_gain = rng.uniform_in(0.45, 0.95);
    let bg = [
        rng.uniform_in(0.05, 0.5),
        rng.uniform_in(0.05, 0.5),
        rng.uniform_in(0.05, 0.5),
    ];
    let grad = [
        rng.uniform_in(-0.25, 0.25),
        rng.uniform_in(-0.25, 0.25),
        rng.uniform_in(-0.25, 0.25),
    ];
    // the bands OVERLAP in [7.9, 8.3]: samples there are genuinely
    // ambiguous, giving the task an irreducible error floor (like real
    // datasets) and a fine decision boundary that low-precision
    // activations erode first.
    let tex_freq = if high_band {
        rng.uniform_in(7.9, 11.5)
    } else {
        rng.uniform_in(5.2, 8.3)
    };
    let noise = rng.uniform_in(0.03, 0.10);

    // a distractor shape of a random *other* family, drawn fainter behind
    // the labelled shape
    let d_shape = Shape::of_class(rng.below(5));
    let dcx = 0.5 + rng.uniform_in(-0.3, 0.3);
    let dcy = 0.5 + rng.uniform_in(-0.3, 0.3);
    let d_scale = rng.uniform_in(0.3, 0.55);
    let d_fg = hue_rgb(rng.uniform_in(0.0, 1.0));
    let d_gain = rng.uniform_in(0.2, 0.45);

    let mut px = vec![0f32; h * w * 3];
    for y in 0..h {
        for x in 0..w {
            let nx = x as f32 / w as f32;
            let ny = y as f32 / h as f32;
            // shape-local rotated coords
            let du = (nx - cx) / (scale * 0.5);
            let dv = (ny - cy) / (scale * 0.5);
            let u = cos_t * du - sin_t * dv;
            let v = sin_t * du + cos_t * dv;
            let m = shape_mask(shape, u, v);
            let dm = shape_mask(
                d_shape,
                (nx - dcx) / (d_scale * 0.5),
                (ny - dcy) / (d_scale * 0.5),
            ) * (1.0 - m); // distractor sits behind the labelled shape
            // every shape carries a grating; its frequency band is half
            // of the label (classes 0-4 low band, 5-9 high band)
            let tex = 0.55 + 0.45 * ((u * tex_freq).sin() * (v * tex_freq).cos());
            let base = y * w * 3 + x * 3;
            for c in 0..3 {
                let bgc = (bg[c] + grad[c] * (nx + ny - 1.0)).clamp(0.0, 1.0);
                let dgc = (d_fg[c] * d_gain).clamp(0.0, 1.0);
                let fgc = (fg[c] * fg_gain * tex).clamp(0.0, 1.0);
                let under = bgc * (1.0 - dm) + dgc * dm;
                let val = under * (1.0 - m) + fgc * m
                    + (rng.uniform() as f32 - 0.5) * 2.0 * noise;
                px[base + c] = val.clamp(0.0, 1.0);
            }
        }
    }
    (px, class as i32)
}

/// Cheap hue -> RGB (saturated palette).
fn hue_rgb(h: f32) -> [f32; 3] {
    let h = (h.rem_euclid(1.0)) * 6.0;
    let x = 1.0 - (h % 2.0 - 1.0).abs();
    match h as usize {
        0 => [1.0, x, 0.0],
        1 => [x, 1.0, 0.0],
        2 => [0.0, 1.0, x],
        3 => [0.0, x, 1.0],
        4 => [x, 0.0, 1.0],
        _ => [1.0, 0.0, x],
    }
}

impl Dataset {
    /// Generate `n` images of size (h, w) for stream `seed`.
    pub fn generate(n: usize, h: usize, w: usize, seed: u64) -> Dataset {
        let mut images = vec![0f32; n * h * w * 3];
        let mut labels = vec![0i32; n];
        for i in 0..n {
            let (px, y) = gen_image(seed, i as u64, h, w);
            images[i * h * w * 3..(i + 1) * h * w * 3].copy_from_slice(&px);
            labels[i] = y;
        }
        Dataset {
            images: Tensor::from_vec(&[n, h, w, 3], images).unwrap(),
            labels: Tensor::from_vec(&[n], labels).unwrap(),
            h,
            w,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Class histogram (sanity/debug).
    pub fn class_counts(&self) -> [usize; NUM_CLASSES] {
        let mut c = [0usize; NUM_CLASSES];
        for &y in self.labels.data() {
            c[y as usize] += 1;
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_order_independent() {
        let (a, ya) = gen_image(7, 123, 16, 16);
        let (b, yb) = gen_image(7, 123, 16, 16);
        assert_eq!(a, b);
        assert_eq!(ya, yb);
        let (c, _) = gen_image(8, 123, 16, 16);
        assert_ne!(a, c);
        // generating a larger set reproduces the same leading images
        let d1 = Dataset::generate(4, 16, 16, 7);
        let d2 = Dataset::generate(8, 16, 16, 7);
        assert_eq!(
            &d1.images.data()[..],
            &d2.images.data()[..4 * 16 * 16 * 3]
        );
    }

    #[test]
    fn pixel_range_and_shapes() {
        let d = Dataset::generate(32, 32, 32, 1);
        assert_eq!(d.images.shape(), &[32, 32, 32, 3]);
        assert!(d.images.data().iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!(d.labels.data().iter().all(|&y| (0..10).contains(&y)));
    }

    #[test]
    fn classes_roughly_balanced() {
        let d = Dataset::generate(2000, 8, 8, 3);
        let c = d.class_counts();
        for (i, &n) in c.iter().enumerate() {
            assert!(n > 120, "class {i}: {n}");
        }
    }

    #[test]
    fn classes_are_visually_distinct() {
        // mean image per class should differ measurably between classes --
        // a necessary condition for learnability
        let d = Dataset::generate(600, 16, 16, 5);
        let hw3 = 16 * 16 * 3;
        let mut means = vec![vec![0f64; hw3]; NUM_CLASSES];
        let mut counts = [0usize; NUM_CLASSES];
        for i in 0..d.len() {
            let y = d.labels.data()[i] as usize;
            counts[y] += 1;
            for j in 0..hw3 {
                means[y][j] += d.images.data()[i * hw3 + j] as f64;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c.max(1) as f64;
            }
        }
        let mut min_dist = f64::INFINITY;
        for a in 0..NUM_CLASSES {
            for b in (a + 1)..NUM_CLASSES {
                let d2: f64 = means[a]
                    .iter()
                    .zip(&means[b])
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                min_dist = min_dist.min(d2.sqrt());
            }
        }
        assert!(min_dist > 0.5, "classes too similar: {min_dist}");
    }
}
