//! Training-time augmentation: horizontal flip + pad-and-crop shifts,
//! applied by the loader's worker thread on the host (never on the
//! request path of the XLA executables).

use crate::util::rng::Rng;

/// Flip one HWC image horizontally in place.
pub fn hflip(px: &mut [f32], h: usize, w: usize, c: usize) {
    for y in 0..h {
        for x in 0..w / 2 {
            for ch in 0..c {
                let a = y * w * c + x * c + ch;
                let b = y * w * c + (w - 1 - x) * c + ch;
                px.swap(a, b);
            }
        }
    }
}

/// Shift one HWC image by (dy, dx) pixels (zero padding) into `out`.
pub fn shift(px: &[f32], out: &mut [f32], h: usize, w: usize, c: usize, dy: i32, dx: i32) {
    out.fill(0.0);
    for y in 0..h as i32 {
        let sy = y - dy;
        if sy < 0 || sy >= h as i32 {
            continue;
        }
        for x in 0..w as i32 {
            let sx = x - dx;
            if sx < 0 || sx >= w as i32 {
                continue;
            }
            let src = (sy as usize * w + sx as usize) * c;
            let dst = (y as usize * w + x as usize) * c;
            out[dst..dst + c].copy_from_slice(&px[src..src + c]);
        }
    }
}

/// Augment a batch in place: each image flips with p=0.5 and shifts
/// uniformly in [-max_shift, max_shift]^2.
pub fn augment_batch(
    batch: &mut [f32],
    n: usize,
    h: usize,
    w: usize,
    c: usize,
    max_shift: i32,
    rng: &mut Rng,
) {
    let img_len = h * w * c;
    let mut tmp = vec![0f32; img_len];
    for i in 0..n {
        let img = &mut batch[i * img_len..(i + 1) * img_len];
        if rng.uniform() < 0.5 {
            hflip(img, h, w, c);
        }
        if max_shift > 0 {
            let dy = rng.below((2 * max_shift + 1) as usize) as i32 - max_shift;
            let dx = rng.below((2 * max_shift + 1) as usize) as i32 - max_shift;
            if dy != 0 || dx != 0 {
                shift(img, &mut tmp, h, w, c, dy, dx);
                img.copy_from_slice(&tmp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hflip_involution() {
        let mut px: Vec<f32> = (0..2 * 4 * 3).map(|i| i as f32).collect();
        let orig = px.clone();
        hflip(&mut px, 2, 4, 3);
        assert_ne!(px, orig);
        hflip(&mut px, 2, 4, 3);
        assert_eq!(px, orig);
    }

    #[test]
    fn hflip_moves_columns() {
        // 1x3x1 image [1,2,3] -> [3,2,1]
        let mut px = vec![1.0, 2.0, 3.0];
        hflip(&mut px, 1, 3, 1);
        assert_eq!(px, vec![3.0, 2.0, 1.0]);
    }

    #[test]
    fn shift_zero_is_identity() {
        let px: Vec<f32> = (0..3 * 3 * 2).map(|i| i as f32).collect();
        let mut out = vec![0f32; px.len()];
        shift(&px, &mut out, 3, 3, 2, 0, 0);
        assert_eq!(px, out);
    }

    #[test]
    fn shift_pads_with_zero() {
        let px = vec![1.0f32; 2 * 2];
        let mut out = vec![9f32; 4];
        shift(&px, &mut out, 2, 2, 1, 1, 0);
        // first row zero, second row copied from first
        assert_eq!(out, vec![0.0, 0.0, 1.0, 1.0]);
    }

    #[test]
    fn augment_preserves_range() {
        let mut rng = Rng::new(1);
        let mut batch: Vec<f32> = (0..4 * 8 * 8 * 3)
            .map(|i| (i % 7) as f32 / 7.0)
            .collect();
        augment_batch(&mut batch, 4, 8, 8, 3, 2, &mut rng);
        assert!(batch.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }
}
