//! Shuffling batch loader with a background prefetch worker.
//!
//! The worker thread assembles (and optionally augments) the next batches
//! while the main thread drives the XLA executable, connected by a
//! bounded channel (natural backpressure: the worker blocks once
//! `PREFETCH_DEPTH` batches are waiting).  Epoch order is derived from a
//! forked RNG stream, so runs replay exactly for a given seed.

use std::sync::mpsc;
use std::thread;

use crate::data::augment::augment_batch;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::tensor::{TensorF, TensorI};
use crate::util::rng::Rng;

/// Number of batches the worker may run ahead.
pub const PREFETCH_DEPTH: usize = 4;

/// One training/eval batch.
#[derive(Debug)]
pub struct Batch {
    pub images: TensorF,
    pub labels: TensorI,
    /// 0-based step index of this batch within the loader's lifetime.
    pub step: usize,
}

/// Loader configuration.
#[derive(Clone, Debug)]
pub struct LoaderCfg {
    pub batch: usize,
    pub augment: bool,
    pub max_shift: i32,
    pub seed: u64,
}

/// A prefetching loader producing an endless stream of shuffled batches
/// (reshuffles at every epoch boundary).
pub struct Loader {
    rx: mpsc::Receiver<Batch>,
    _worker: thread::JoinHandle<()>,
}

impl Loader {
    pub fn spawn(data: Dataset, cfg: LoaderCfg) -> Loader {
        let (tx, rx) = mpsc::sync_channel::<Batch>(PREFETCH_DEPTH);
        let worker = thread::Builder::new()
            .name("fxpnet-loader".into())
            .spawn(move || worker_loop(data, cfg, tx))
            .expect("spawn loader");
        Loader { rx, _worker: worker }
    }

    /// Next batch (blocks on the worker if the queue is empty).
    pub fn next_batch(&self) -> Batch {
        self.rx.recv().expect("loader worker died")
    }
}

fn worker_loop(data: Dataset, cfg: LoaderCfg, tx: mpsc::SyncSender<Batch>) {
    let n = data.len();
    let (h, w) = (data.h, data.w);
    let mut rng = Rng::new(cfg.seed);
    let mut order: Vec<usize> = (0..n).collect();
    let mut pos = n; // force initial shuffle
    let mut step = 0usize;
    loop {
        if pos + cfg.batch > n {
            rng.shuffle(&mut order);
            pos = 0;
        }
        let rows = &order[pos..pos + cfg.batch];
        pos += cfg.batch;
        let mut images = data.images.gather_rows(rows).expect("gather");
        let labels = data.labels.gather_rows(rows).expect("gather");
        if cfg.augment {
            let mut arng = rng.fork(step as u64);
            augment_batch(
                images.data_mut(),
                cfg.batch,
                h,
                w,
                3,
                cfg.max_shift,
                &mut arng,
            );
        }
        if tx.send(Batch { images, labels, step }).is_err() {
            return; // receiver dropped: shut down
        }
        step += 1;
    }
}

/// Sequential (non-shuffled, non-augmented) batches covering the dataset
/// once; the evaluator uses this.  The tail partial batch is dropped if
/// `drop_tail`, else padded by wrapping around (count returned).
pub fn sequential_batches(
    data: &Dataset,
    batch: usize,
) -> Result<Vec<(TensorF, TensorI, usize)>> {
    let n = data.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        let valid = batch.min(n - i);
        let rows: Vec<usize> = (0..batch).map(|k| (i + k) % n).collect();
        out.push((
            data.images.gather_rows(&rows)?,
            data.labels.gather_rows(&rows)?,
            valid,
        ));
        i += batch;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_data(n: usize) -> Dataset {
        Dataset::generate(n, 8, 8, 42)
    }

    #[test]
    fn loader_streams_batches() {
        let data = tiny_data(20);
        let loader = Loader::spawn(
            data,
            LoaderCfg { batch: 8, augment: false, max_shift: 0, seed: 1 },
        );
        for want in 0..5 {
            let b = loader.next_batch();
            assert_eq!(b.step, want);
            assert_eq!(b.images.shape(), &[8, 8, 8, 3]);
            assert_eq!(b.labels.shape(), &[8]);
        }
    }

    #[test]
    fn loader_deterministic_for_seed() {
        let mk = || {
            Loader::spawn(
                tiny_data(32),
                LoaderCfg { batch: 8, augment: true, max_shift: 2, seed: 9 },
            )
        };
        let a = mk();
        let b = mk();
        for _ in 0..6 {
            let ba = a.next_batch();
            let bb = b.next_batch();
            assert_eq!(ba.images.data(), bb.images.data());
            assert_eq!(ba.labels.data(), bb.labels.data());
        }
    }

    #[test]
    fn epoch_covers_all_rows() {
        let data = tiny_data(24);
        let labels: Vec<i32> = data.labels.data().to_vec();
        let loader = Loader::spawn(
            data,
            LoaderCfg { batch: 8, augment: false, max_shift: 0, seed: 3 },
        );
        // one epoch = 3 batches; the multiset of labels must match
        let mut seen = Vec::new();
        for _ in 0..3 {
            seen.extend_from_slice(loader.next_batch().labels.data());
        }
        let mut a = labels;
        let mut b = seen;
        a.sort();
        b.sort();
        assert_eq!(a, b);
    }

    #[test]
    fn sequential_covers_once() {
        let data = tiny_data(20);
        let batches = sequential_batches(&data, 8).unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[2].2, 4); // tail has 4 valid rows
        let total: usize = batches.iter().map(|b| b.2).sum();
        assert_eq!(total, 20);
    }
}
