//! Data pipeline: the SynthShapes dataset and a prefetching batch loader.
//!
//! ImageNet is not available in this environment (DESIGN.md section 2);
//! SynthShapes is the substitution: a deterministic, procedurally
//! generated 10-class image classification task hard enough that a deep
//! CNN has to fit real structure -- which is all the paper's optimization
//! -stability phenomenon needs.

pub mod augment;
pub mod loader;
pub mod synth;

pub use loader::{Batch, Loader};
pub use synth::Dataset;
