//! Built-in architecture definitions for the native training backend.
//!
//! The XLA backend learns its architectures from `artifacts/manifest.json`
//! (written at AOT-compile time); the native backend needs no artifacts,
//! so the same three paper architectures are defined here directly.  The
//! names mirror the Python side (`python/compile/model.py`): `tiny` for
//! tests/CI, `shallow` for quick experiments, `paper12` for the full
//! reproduction grid.
//!
//! A zoo arch carries an empty `artifacts` map -- asking the XLA runtime
//! to execute one is a manifest error, exactly as asking the native
//! backend for an arch outside the zoo is.

use std::collections::BTreeMap;

use crate::model::manifest::ArchSpec;

/// Build an [`ArchSpec`] from a layer walk, deriving parameter shapes the
/// same way the Python model does: conv kernels are HWIO `(3, 3, cin,
/// cout)`, pools halve the spatial dims, the FC matrix flattens whatever
/// plane reaches it.
pub fn make_arch(
    name: &str,
    input: [usize; 3],
    layers: &[(&str, usize)],
    train_batch: usize,
    eval_batch: usize,
) -> ArchSpec {
    let (mut h, mut w, mut c) = (input[0], input[1], input[2]);
    let mut params = Vec::new();
    let mut spec_layers = Vec::new();
    let mut li = 0usize;
    let mut num_classes = 0usize;
    for &(kind, out) in layers {
        spec_layers.push((kind.to_string(), out));
        match kind {
            "conv" => {
                params.push((format!("l{li}.w"), vec![3, 3, c, out]));
                params.push((format!("l{li}.b"), vec![out]));
                c = out;
                num_classes = out;
                li += 1;
            }
            "pool" => {
                h /= 2;
                w /= 2;
            }
            "fc" => {
                params.push((format!("l{li}.w"), vec![h * w * c, out]));
                params.push((format!("l{li}.b"), vec![out]));
                h = 1;
                w = 1;
                c = out;
                num_classes = out;
                li += 1;
            }
            other => panic!("zoo: unknown layer kind '{other}'"),
        }
    }
    ArchSpec {
        name: name.to_string(),
        input,
        num_classes,
        num_layers: li,
        train_batch,
        eval_batch,
        layers: spec_layers,
        params,
        artifacts: BTreeMap::new(),
    }
}

/// The native backend's architecture registry.
pub fn builtin_archs() -> BTreeMap<String, ArchSpec> {
    let mut m = BTreeMap::new();
    // 3 weighted layers on 16x16 inputs: the test/CI workhorse.
    m.insert(
        "tiny".to_string(),
        make_arch(
            "tiny",
            [16, 16, 3],
            &[("conv", 8), ("pool", 0), ("conv", 16), ("pool", 0), ("fc", 10)],
            16,
            32,
        ),
    );
    // CIFAR-shaped quick-experiment net.
    m.insert(
        "shallow".to_string(),
        make_arch(
            "shallow",
            [32, 32, 3],
            &[
                ("conv", 32),
                ("pool", 0),
                ("conv", 32),
                ("pool", 0),
                ("fc", 10),
            ],
            32,
            64,
        ),
    );
    // The deep network behind the paper's main tables.
    m.insert(
        "paper12".to_string(),
        make_arch(
            "paper12",
            [32, 32, 3],
            &[
                ("conv", 64),
                ("conv", 64),
                ("pool", 0),
                ("conv", 128),
                ("conv", 128),
                ("pool", 0),
                ("conv", 256),
                ("conv", 256),
                ("pool", 0),
                ("fc", 10),
            ],
            32,
            64,
        ),
    );
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_shapes_are_consistent() {
        for (name, spec) in builtin_archs() {
            assert_eq!(spec.name, name);
            assert_eq!(spec.params.len(), 2 * spec.num_layers, "{name}");
            assert_eq!(spec.num_classes, 10, "{name}");
            assert!(spec.train_batch > 0 && spec.eval_batch > 0);
            // parameters are initialisable (shape conventions hold)
            let p = crate::model::params::ParamSet::init(&spec, 1);
            assert_eq!(p.num_layers(), spec.num_layers);
        }
    }

    #[test]
    fn tiny_fc_input_is_flattened_plane() {
        let archs = builtin_archs();
        let tiny = &archs["tiny"];
        // 16x16 -> conv8 -> pool(8x8) -> conv16 -> pool(4x4) -> fc
        let (fc_name, fc_shape) = &tiny.params[4];
        assert_eq!(fc_name, "l2.w");
        assert_eq!(fc_shape, &vec![4 * 4 * 16, 10]);
        assert_eq!(tiny.num_layers, 3);
    }

    #[test]
    fn paper12_is_deep() {
        let archs = builtin_archs();
        assert_eq!(archs["paper12"].num_layers, 7);
        let (_, fc_shape) = archs["paper12"].params.last().map(|p| (&p.0, &p.1)).unwrap();
        assert_eq!(fc_shape, &vec![10]);
    }
}
