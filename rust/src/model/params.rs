//! Parameter (and momentum) state: the float "master copy" the paper's
//! fine-tuning updates, with per-layer access helpers and weight
//! statistics for calibration.

use crate::error::{FxpError, Result};
use crate::model::manifest::ArchSpec;
use crate::quant::calib::LayerStats;
use crate::tensor::{init, TensorF};
use crate::util::rng::Rng;

/// Named, ordered parameter tensors ([w0, b0, w1, b1, ...]).
#[derive(Clone, Debug)]
pub struct ParamSet {
    pub names: Vec<String>,
    pub tensors: Vec<TensorF>,
}

impl ParamSet {
    /// He-normal weights / zero biases matching the manifest's shapes.
    pub fn init(arch: &ArchSpec, seed: u64) -> ParamSet {
        let mut rng = Rng::new(seed);
        let mut names = Vec::with_capacity(arch.params.len());
        let mut tensors = Vec::with_capacity(arch.params.len());
        for (name, shape) in &arch.params {
            names.push(name.clone());
            tensors.push(init::for_param(name, shape, &mut rng));
        }
        ParamSet { names, tensors }
    }

    /// Zero tensors of the same shapes (momentum buffers).
    pub fn zeros_like(&self) -> ParamSet {
        ParamSet {
            names: self.names.clone(),
            tensors: self
                .tensors
                .iter()
                .map(|t| TensorF::zeros(t.shape()))
                .collect(),
        }
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    /// Number of weighted layers (= len / 2).
    pub fn num_layers(&self) -> usize {
        self.len() / 2
    }

    /// Total scalar parameter count.
    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Weight tensor of layer l (index 2l).
    pub fn weight(&self, l: usize) -> &TensorF {
        &self.tensors[2 * l]
    }

    /// Bias tensor of layer l (index 2l+1).
    pub fn bias(&self, l: usize) -> &TensorF {
        &self.tensors[2 * l + 1]
    }

    /// Replace all tensors (used after a train step returns new params).
    pub fn replace(&mut self, tensors: Vec<TensorF>) -> Result<()> {
        if tensors.len() != self.tensors.len() {
            return Err(FxpError::shape(format!(
                "replace: {} tensors, expected {}",
                tensors.len(),
                self.tensors.len()
            )));
        }
        for (old, new) in self.tensors.iter().zip(&tensors) {
            if old.shape() != new.shape() {
                return Err(FxpError::shape(format!(
                    "replace: shape {:?} -> {:?}",
                    old.shape(),
                    new.shape()
                )));
            }
        }
        self.tensors = tensors;
        Ok(())
    }

    /// Per-layer *weight* statistics for calibration (biases excluded --
    /// they stay in accumulator precision).
    pub fn weight_stats(&self) -> Vec<LayerStats> {
        (0..self.num_layers())
            .map(|l| {
                let w = self.weight(l);
                let absmax = w.abs_max();
                let n = w.len().max(1) as f64;
                let meanabs =
                    (w.data().iter().map(|&x| x.abs() as f64).sum::<f64>() / n) as f32;
                let meansq = (w.data().iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>()
                    / n) as f32;
                LayerStats { absmax, meanabs, meansq }
            })
            .collect()
    }

    /// Raw weight samples of layer l (for empirical SQNR calibration).
    pub fn weight_samples(&self, l: usize) -> &[f32] {
        self.weight(l).data()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::Manifest;
    use std::path::PathBuf;

    fn arch() -> ArchSpec {
        let text = r#"{"version":1,"archs":{"t":{
            "input":[8,8,3],"num_classes":10,"num_layers":2,
            "train_batch":4,"eval_batch":8,
            "layers":[{"kind":"conv","out":4},{"kind":"fc","out":10}],
            "params":[
              {"name":"l0.w","shape":[3,3,3,4]},{"name":"l0.b","shape":[4]},
              {"name":"l1.w","shape":[256,10]},{"name":"l1.b","shape":[10]}],
            "artifacts":{}}}}"#;
        Manifest::parse(text, PathBuf::new())
            .unwrap()
            .arch("t")
            .unwrap()
            .clone()
    }

    #[test]
    fn init_shapes_and_determinism() {
        let a = arch();
        let p1 = ParamSet::init(&a, 5);
        let p2 = ParamSet::init(&a, 5);
        assert_eq!(p1.len(), 4);
        assert_eq!(p1.num_layers(), 2);
        assert_eq!(p1.weight(1).shape(), &[256, 10]);
        assert_eq!(p1.bias(0).shape(), &[4]);
        assert_eq!(p1.tensors[0].data(), p2.tensors[0].data());
        assert_ne!(
            p1.tensors[0].data(),
            ParamSet::init(&a, 6).tensors[0].data()
        );
        assert_eq!(p1.num_scalars(), 3 * 3 * 3 * 4 + 4 + 256 * 10 + 10);
    }

    #[test]
    fn zeros_like_and_replace() {
        let a = arch();
        let mut p = ParamSet::init(&a, 1);
        let m = p.zeros_like();
        assert!(m.tensors.iter().all(|t| t.data().iter().all(|&x| x == 0.0)));
        let new = m.tensors.clone();
        p.replace(new).unwrap();
        assert!(p.weight(0).data().iter().all(|&x| x == 0.0));
        // wrong arity
        assert!(p.replace(vec![]).is_err());
    }

    #[test]
    fn weight_stats_sane() {
        let a = arch();
        let p = ParamSet::init(&a, 2);
        let s = p.weight_stats();
        assert_eq!(s.len(), 2);
        for st in &s {
            assert!(st.absmax > 0.0);
            assert!(st.meansq > 0.0 && st.meansq < st.absmax * st.absmax);
        }
    }
}
