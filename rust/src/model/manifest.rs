//! Parse `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the only contract between the build-time Python world
//! and the Rust runtime: architecture shapes, parameter order, and the
//! exact input/output ordering of every compiled executable.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{FxpError, Result};
use crate::util::json::Json;

/// Element type of an executable input/output.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            _ => Err(FxpError::Manifest(format!("unknown dtype '{s}'"))),
        }
    }
}

/// One input or output of an executable.
#[derive(Clone, Debug)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled artifact (train_step / eval_batch / stats_batch / grads).
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

impl ArtifactSpec {
    pub fn input_index(&self, name: &str) -> Result<usize> {
        self.inputs
            .iter()
            .position(|i| i.name == name)
            .ok_or_else(|| FxpError::Manifest(format!("no input '{name}'")))
    }

    pub fn output_index(&self, name: &str) -> Result<usize> {
        self.outputs
            .iter()
            .position(|o| o.name == name)
            .ok_or_else(|| FxpError::Manifest(format!("no output '{name}'")))
    }
}

/// One architecture: layers, parameters, compiled artifacts.
#[derive(Clone, Debug)]
pub struct ArchSpec {
    pub name: String,
    /// input image (h, w, c)
    pub input: [usize; 3],
    pub num_classes: usize,
    /// number of weighted layers L
    pub num_layers: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    /// layer sequence: ("conv", out) | ("pool", 0) | ("fc", out)
    pub layers: Vec<(String, usize)>,
    /// flat parameter list [(name, shape)] in executable input order
    pub params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ArchSpec {
    pub fn artifact(&self, kind: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(kind).ok_or_else(|| {
            FxpError::Manifest(format!(
                "arch '{}' has no artifact '{kind}'",
                self.name
            ))
        })
    }

    /// Flat index of the last weighted layer's weight tensor.
    pub fn num_params(&self) -> usize {
        self.params.len()
    }
}

/// The whole manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub archs: BTreeMap<String, ArchSpec>,
}

fn parse_io(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        name: j.get("name")?.as_str()?.to_string(),
        shape: j.get("shape")?.usize_vec()?,
        dtype: Dtype::parse(j.get("dtype")?.as_str()?)?,
    })
}

fn parse_arch(name: &str, j: &Json) -> Result<ArchSpec> {
    let input = j.get("input")?.usize_vec()?;
    if input.len() != 3 {
        return Err(FxpError::Manifest("input must be [h,w,c]".into()));
    }
    let layers = j
        .get("layers")?
        .as_arr()?
        .iter()
        .map(|l| {
            let kind = l.get("kind")?.as_str()?.to_string();
            let out = match l.opt("out") {
                Some(o) => o.as_usize()?,
                None => 0,
            };
            Ok((kind, out))
        })
        .collect::<Result<Vec<_>>>()?;
    let params = j
        .get("params")?
        .as_arr()?
        .iter()
        .map(|p| {
            Ok((
                p.get("name")?.as_str()?.to_string(),
                p.get("shape")?.usize_vec()?,
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    let mut artifacts = BTreeMap::new();
    for (kind, a) in j.get("artifacts")?.as_obj()? {
        artifacts.insert(
            kind.clone(),
            ArtifactSpec {
                file: a.get("file")?.as_str()?.to_string(),
                inputs: a
                    .get("inputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
                outputs: a
                    .get("outputs")?
                    .as_arr()?
                    .iter()
                    .map(parse_io)
                    .collect::<Result<Vec<_>>>()?,
            },
        );
    }
    let spec = ArchSpec {
        name: name.to_string(),
        input: [input[0], input[1], input[2]],
        num_classes: j.get("num_classes")?.as_usize()?,
        num_layers: j.get("num_layers")?.as_usize()?,
        train_batch: j.get("train_batch")?.as_usize()?,
        eval_batch: j.get("eval_batch")?.as_usize()?,
        layers,
        params,
        artifacts,
    };
    // consistency: 2 params per weighted layer
    if spec.params.len() != 2 * spec.num_layers {
        return Err(FxpError::Manifest(format!(
            "arch '{name}': {} params but {} layers",
            spec.params.len(),
            spec.num_layers
        )));
    }
    Ok(spec)
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            FxpError::Manifest(format!(
                "cannot read {} (run `make artifacts`?): {e}",
                path.display()
            ))
        })?;
        Manifest::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let version = j.get("version")?.as_usize()?;
        if version != 1 {
            return Err(FxpError::Manifest(format!(
                "unsupported manifest version {version}"
            )));
        }
        let mut archs = BTreeMap::new();
        for (name, a) in j.get("archs")?.as_obj()? {
            archs.insert(name.clone(), parse_arch(name, a)?);
        }
        Ok(Manifest { dir, archs })
    }

    pub fn arch(&self, name: &str) -> Result<&ArchSpec> {
        self.archs.get(name).ok_or_else(|| {
            FxpError::Manifest(format!(
                "arch '{name}' not in manifest (have: {})",
                self.archs.keys().cloned().collect::<Vec<_>>().join(", ")
            ))
        })
    }

    pub fn artifact_path(&self, arch: &str, kind: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.arch(arch)?.artifact(kind)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SNIPPET: &str = r#"{
      "version": 1,
      "archs": {
        "t": {
          "input": [16,16,3], "num_classes": 10, "num_layers": 2,
          "train_batch": 16, "eval_batch": 32,
          "layers": [{"kind":"conv","out":8},{"kind":"pool"},{"kind":"fc","out":10}],
          "params": [
            {"name":"l0.w","shape":[3,3,3,8]}, {"name":"l0.b","shape":[8]},
            {"name":"l1.w","shape":[512,10]},  {"name":"l1.b","shape":[10]}
          ],
          "artifacts": {
            "eval_batch": {
              "file": "t_eval_batch.hlo.txt",
              "inputs": [
                {"name":"l0.w","shape":[3,3,3,8],"dtype":"f32"},
                {"name":"x","shape":[32,16,16,3],"dtype":"f32"},
                {"name":"y","shape":[32],"dtype":"i32"}
              ],
              "outputs": [
                {"name":"logits","shape":[32,10],"dtype":"f32"},
                {"name":"loss_sum","shape":[],"dtype":"f32"}
              ]
            }
          }
        }
      }
    }"#;

    #[test]
    fn parse_round_trip() {
        let m = Manifest::parse(SNIPPET, PathBuf::from("/tmp/a")).unwrap();
        let a = m.arch("t").unwrap();
        assert_eq!(a.input, [16, 16, 3]);
        assert_eq!(a.num_layers, 2);
        assert_eq!(a.params[2].1, vec![512, 10]);
        let e = a.artifact("eval_batch").unwrap();
        assert_eq!(e.inputs[2].dtype, Dtype::I32);
        assert_eq!(e.outputs[1].shape, Vec::<usize>::new());
        assert_eq!(e.input_index("x").unwrap(), 1);
        assert!(e.input_index("nope").is_err());
        assert_eq!(
            m.artifact_path("t", "eval_batch").unwrap(),
            PathBuf::from("/tmp/a/t_eval_batch.hlo.txt")
        );
    }

    #[test]
    fn errors() {
        let m = Manifest::parse(SNIPPET, PathBuf::from("/tmp")).unwrap();
        assert!(m.arch("nope").is_err());
        assert!(m.arch("t").unwrap().artifact("train_step").is_err());
        assert!(Manifest::parse("{\"version\": 2, \"archs\": {}}", PathBuf::new())
            .is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        // integration check against the actual AOT output when present
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            let t = m.arch("tiny").unwrap();
            assert_eq!(t.num_layers, 3);
            for kind in ["train_step", "eval_batch", "stats_batch", "grads"] {
                let a = t.artifact(kind).unwrap();
                assert!(m.dir.join(&a.file).exists());
                assert!(!a.inputs.is_empty() && !a.outputs.is_empty());
            }
        }
    }
}
