//! Checkpoint I/O: a small self-describing binary format (serde is not
//! available offline; the format is versioned and endian-explicit).
//!
//! Layout (little endian):
//!   magic   "FXPCKPT1"
//!   arch    u16 len + utf8 bytes
//!   step    u64
//!   count   u32                      number of tensors
//!   per tensor:
//!     name  u16 len + utf8 bytes
//!     ndim  u8, dims u64 * ndim
//!     data  f32 * prod(dims)

use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{FxpError, Result};
use crate::model::params::ParamSet;
use crate::tensor::Tensor;
#[cfg(test)]
use crate::tensor::TensorF;

const MAGIC: &[u8; 8] = b"FXPCKPT1";

/// A saved training state.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub arch: String,
    pub step: u64,
    pub params: ParamSet,
}

fn write_str(w: &mut impl Write, s: &str) -> Result<()> {
    let b = s.as_bytes();
    if b.len() > u16::MAX as usize {
        return Err(FxpError::Checkpoint("string too long".into()));
    }
    w.write_all(&(b.len() as u16).to_le_bytes())?;
    w.write_all(b)?;
    Ok(())
}

fn read_str(r: &mut impl Read) -> Result<String> {
    let mut lb = [0u8; 2];
    r.read_exact(&mut lb)?;
    let len = u16::from_le_bytes(lb) as usize;
    let mut b = vec![0u8; len];
    r.read_exact(&mut b)?;
    String::from_utf8(b).map_err(|_| FxpError::Checkpoint("bad utf8".into()))
}

impl Checkpoint {
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut w = BufWriter::new(File::create(path.as_ref())?);
        w.write_all(MAGIC)?;
        write_str(&mut w, &self.arch)?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for (name, t) in self.params.names.iter().zip(&self.params.tensors) {
            write_str(&mut w, name)?;
            w.write_all(&[t.shape().len() as u8])?;
            for &d in t.shape() {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // bulk write the f32 payload
            let bytes: Vec<u8> =
                t.data().iter().flat_map(|x| x.to_le_bytes()).collect();
            w.write_all(&bytes)?;
        }
        w.flush()?;
        // fsync: checkpoint writes feed atomic-rename caches (p1 seed
        // nets) whose rename must never land before the data blocks do
        w.get_ref().sync_all()?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut r = BufReader::new(File::open(path.as_ref())?);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != MAGIC {
            return Err(FxpError::Checkpoint(format!(
                "{}: bad magic",
                path.as_ref().display()
            )));
        }
        let arch = read_str(&mut r)?;
        let mut b8 = [0u8; 8];
        r.read_exact(&mut b8)?;
        let step = u64::from_le_bytes(b8);
        let mut b4 = [0u8; 4];
        r.read_exact(&mut b4)?;
        let count = u32::from_le_bytes(b4) as usize;
        let mut names = Vec::with_capacity(count);
        let mut tensors = Vec::with_capacity(count);
        for _ in 0..count {
            let name = read_str(&mut r)?;
            let mut nd = [0u8; 1];
            r.read_exact(&mut nd)?;
            let mut shape = Vec::with_capacity(nd[0] as usize);
            for _ in 0..nd[0] {
                r.read_exact(&mut b8)?;
                shape.push(u64::from_le_bytes(b8) as usize);
            }
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            names.push(name);
            tensors.push(Tensor::from_vec(&shape, data)?);
        }
        Ok(Checkpoint { arch, step, params: ParamSet { names, tensors } })
    }

    /// Validate against an expected arch/param list.
    pub fn check_matches(
        &self,
        arch: &str,
        expected: &[(String, Vec<usize>)],
    ) -> Result<()> {
        if self.arch != arch {
            return Err(FxpError::Checkpoint(format!(
                "checkpoint is for arch '{}', wanted '{arch}'",
                self.arch
            )));
        }
        if self.params.len() != expected.len() {
            return Err(FxpError::Checkpoint(format!(
                "{} tensors, expected {}",
                self.params.len(),
                expected.len()
            )));
        }
        for ((name, shape), (have_n, have_t)) in expected
            .iter()
            .zip(self.params.names.iter().zip(&self.params.tensors))
        {
            if name != have_n || shape.as_slice() != have_t.shape() {
                return Err(FxpError::Checkpoint(format!(
                    "tensor mismatch: manifest {name}{shape:?} vs checkpoint \
                     {have_n}{:?}",
                    have_t.shape()
                )));
            }
        }
        Ok(())
    }
}

/// Save just a ParamSet (helper used by the trainer).
pub fn save_params(
    path: impl AsRef<Path>,
    arch: &str,
    step: u64,
    params: &ParamSet,
) -> Result<()> {
    Checkpoint { arch: arch.to_string(), step, params: params.clone() }.save(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_params() -> ParamSet {
        ParamSet {
            names: vec!["l0.w".into(), "l0.b".into()],
            tensors: vec![
                TensorF::from_vec(&[2, 3], vec![1.0, -2.5, 3.25, 0.0, 1e-7, -1e7])
                    .unwrap(),
                TensorF::from_vec(&[3], vec![0.5, 0.25, -0.125]).unwrap(),
            ],
        }
    }

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("fxp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("a.ckpt");
        let ck = Checkpoint { arch: "tiny".into(), step: 1234, params: sample_params() };
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.arch, "tiny");
        assert_eq!(back.step, 1234);
        assert_eq!(back.params.names, ck.params.names);
        for (a, b) in back.params.tensors.iter().zip(&ck.params.tensors) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn check_matches() {
        let ck = Checkpoint { arch: "tiny".into(), step: 0, params: sample_params() };
        let good = vec![
            ("l0.w".to_string(), vec![2usize, 3]),
            ("l0.b".to_string(), vec![3usize]),
        ];
        ck.check_matches("tiny", &good).unwrap();
        assert!(ck.check_matches("other", &good).is_err());
        let bad = vec![
            ("l0.w".to_string(), vec![3usize, 2]),
            ("l0.b".to_string(), vec![3usize]),
        ];
        assert!(ck.check_matches("tiny", &bad).is_err());
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir().join("fxp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("garbage.ckpt");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
