//! Model metadata and parameter state on the Rust side.
//!
//! The network's *math* lives in the AOT-compiled executables; this
//! module owns everything around it: the manifest describing the
//! compiled artifacts (shapes, input/output orders), the parameter /
//! momentum tensors, and checkpoint I/O.

pub mod checkpoint;
pub mod manifest;
pub mod params;
pub mod zoo;

pub use manifest::{ArchSpec, ArtifactSpec, IoSpec, Manifest};
pub use params::ParamSet;
