//! # fxpnet
//!
//! Reproduction of *"Overcoming Challenges in Fixed Point Training of
//! Deep Convolutional Networks"* (Lin & Talathi, ICML 2016 Workshop on
//! On-Device Intelligence) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas fixed-point quantizer and
//!   fused quantized-matmul kernels (the paper's Figure 1 pipeline).
//! * **L2** (`python/compile/model.py`): quantization-aware CNN fwd/bwd
//!   with straight-through-estimator gradients -- the paper's "presumed"
//!   smooth gradient, i.e. the gradient mismatch is physically present.
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L3** (this crate): the coordinator -- calibration, the paper's
//!   three fine-tuning proposals, the Table 1 phase scheduler, the
//!   experiment grid, divergence detection, a pure-integer fixed-point
//!   inference engine, and every substrate those need.
//!
//! Python never runs on the request path; the binary is self-contained
//! once `artifacts/` is built.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fixedpoint;
pub mod inference;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod util;

pub use error::{FxpError, Result};

/// Crate version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
