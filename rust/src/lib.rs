//! # fxpnet
//!
//! Reproduction of *"Overcoming Challenges in Fixed Point Training of
//! Deep Convolutional Networks"* (Lin & Talathi, ICML 2016 Workshop on
//! On-Device Intelligence) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`): Pallas fixed-point quantizer and
//!   fused quantized-matmul kernels (the paper's Figure 1 pipeline).
//! * **L2** (`python/compile/model.py`): quantization-aware CNN fwd/bwd
//!   with straight-through-estimator gradients -- the paper's "presumed"
//!   smooth gradient, i.e. the gradient mismatch is physically present.
//!   AOT-lowered once to HLO text (`make artifacts`).
//! * **L3** (this crate): the coordinator -- calibration, the paper's
//!   three fine-tuning proposals, the Table 1 phase scheduler, the
//!   experiment grid, divergence detection, a pure-integer fixed-point
//!   inference engine, and every substrate those need.
//!
//! Python never runs on the request path; the binary is self-contained
//! once `artifacts/` is built.
//!
//! ## Parallel grid sweeps
//!
//! Every paper table is a (weight width x activation width) grid of
//! independent training/finetune jobs.  `coordinator::grid` executes
//! them through a `std::thread` worker pool (`coordinator::pool`) with:
//!
//! * **deterministic per-cell seeding** -- each cell's RNG seed derives
//!   from `(base seed, regime, w, a)` via `util::rng::derive_seed`, so
//!   tables are bit-identical for any `--workers` count, shard layout,
//!   or resume pattern (pinned by tests/grid_parallel.rs);
//! * **divergence/panic isolation** -- a cell that diverges, errors, or
//!   panics becomes the paper's "n/a" instead of killing the sweep;
//! * **sharding + resume** -- `--shard I/N` partitions cells round-robin
//!   across processes, and a JSON cell cache (`report::CellCache`, see
//!   the format notes in `coordinator::report`) lets interrupted sweeps
//!   resume and shards union into the full table.
//!
//! ## Offline build layout
//!
//! The workspace builds with zero external crates: `rust/xla-stub`
//! (package `xla`) stands in for the PJRT bindings (literals functional,
//! execution unavailable -- engine tests skip without `artifacts/`), and
//! `rust/log-shim` (package `log`) provides the log facade.  Swap the
//! real `xla` crate back in via one line of rust/Cargo.toml.
//!
//! Training does **not** require the relink: the native backend
//! (`crate::train`, `--backend native`, the default offline) is a
//! pure-Rust backprop + stochastic-rounding fixed-point SGD engine that
//! runs the paper's sweeps for real with zero external dependencies;
//! the XLA path remains available behind `coordinator::backend` for
//! environments with the real PJRT bindings.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured results.

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fixedpoint;
pub mod inference;
pub mod model;
pub mod netio;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod testutil;
pub mod train;
pub mod util;

pub use error::{FxpError, Result};

/// Crate version.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
