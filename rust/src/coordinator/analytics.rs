//! Grid-wide stability analytics behind `fxpnet report`.
//!
//! Ingests any mix of merged/per-shard cell caches (v4, including
//! aborted cells) and per-sweep stability reports (v2, which carry the
//! per-cell [`TelemetrySummary`] digests), unions them into per-sweep
//! datasets, and produces ONE deterministic analytics artifact:
//! per-(regime, weight-width) aggregates of final/peak saturation rate,
//! update-to-quantization-step ratio trajectories (fixed quantiles over
//! the pinned [`SUMMARY_WINDOW_STEPS`] windows), abort reasons/steps,
//! and the convergence-outcome join -- as a human table plus canonical
//! JSON that is byte-identical regardless of how the inputs were
//! produced (`--threads` count, `--shard I/N` split, grid vs cluster).
//!
//! Byte-determinism rests on three properties: cell results are pure
//! functions of `(base seed, regime, w, a)`; every map in the pipeline
//! is a `BTreeMap`; and floats serialize with shortest-round-trip
//! formatting (non-finite as `"nan"`/`"inf"`/`"-inf"` strings).  The
//! union is strict: the same cell appearing in two inputs must be
//! bit-equal ([`cells_bit_equal`]) and its telemetry byte-equal, so
//! mixed-backend or stale inputs fail loudly instead of averaging.
//!
//! `--suggest-thresholds` additionally fits per-regime abort thresholds
//! from the ingested data (closed-form, no RNG -- see
//! [`Analytics::suggest_thresholds`]): the learned [`AbortOverlay`] is
//! guaranteed never to abort a cell that converged in the sweeps it was
//! learned from, because every threshold is placed strictly outside the
//! envelope of the converged cells' observed extremes.

use std::collections::BTreeMap;
use std::path::Path;

use crate::bench::Table;
use crate::coordinator::regimes::{CellEval, Regime};
use crate::coordinator::report::{
    cell_eval_from_json, parse_cache_text, CACHE_VERSION, REPORT_VERSION,
};
use crate::coordinator::shard::cells_bit_equal;
use crate::coordinator::trainer::{AbortOverlay, AbortPolicy};
use crate::error::{FxpError, Result};
use crate::train::telemetry::{
    num_json, quantiles, TelemetrySummary, SUMMARY_WINDOW_STEPS,
};
use crate::util::json::Json;

/// One sweep's unioned data: identity, per-cell outcomes, and the
/// telemetry digests of every cell that trained.
#[derive(Clone, Debug)]
pub struct SweepData {
    pub arch: String,
    pub regime: Regime,
    pub base_seed: u64,
    pub cells: BTreeMap<String, CellEval>,
    pub telemetry: BTreeMap<String, TelemetrySummary>,
}

/// Accumulates input files into per-sweep datasets (keyed by
/// `(arch, regime seed-tag, base seed)`) and renders the analytics.
#[derive(Debug, Default)]
pub struct Analytics {
    sweeps: BTreeMap<(String, u64, u64), SweepData>,
}

/// The weight-width label of a cache cell key (`"w=4,a=8"` -> `"4"`).
fn width_of(key: &str) -> &str {
    key.strip_prefix("w=")
        .and_then(|rest| rest.split(",a=").next())
        .unwrap_or(key)
}

impl Analytics {
    pub fn new() -> Analytics {
        Analytics::default()
    }

    /// Number of distinct sweeps ingested so far.
    pub fn sweep_count(&self) -> usize {
        self.sweeps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sweeps.is_empty()
    }

    /// Ingested sweeps in deterministic `(arch, seed-tag, seed)` order.
    pub fn sweeps(&self) -> impl Iterator<Item = &SweepData> {
        self.sweeps.values()
    }

    /// Read and [`ingest_text`](Self::ingest_text) one input file.
    pub fn ingest_file(&mut self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            FxpError::config(format!("{}: {e}", path.display()))
        })?;
        self.ingest_text(&path.display().to_string(), &text)
    }

    /// Ingest one input, auto-detected by its version stamps: a
    /// `report_version` key marks a stability report (the version must
    /// match [`REPORT_VERSION`] and the kind must be `"stability"`), a
    /// bare `version` key marks a cell cache (must match
    /// [`CACHE_VERSION`]).  Anything else -- including version-less
    /// pre-v2 stability reports -- is refused with an error naming
    /// `label`.
    pub fn ingest_text(&mut self, label: &str, text: &str) -> Result<()> {
        let j = Json::parse(text)
            .map_err(|e| FxpError::Json(format!("{label}: {e}")))?;
        if let Some(v) = j.opt("report_version") {
            let v = v.as_usize()?;
            if v != REPORT_VERSION {
                return Err(FxpError::config(format!(
                    "{label}: report_version {v} is not supported \
                     (this build reads v{REPORT_VERSION}); regenerate the \
                     report with this fxpnet"
                )));
            }
            let kind = j.get("kind")?.as_str()?;
            if kind != "stability" {
                return Err(FxpError::config(format!(
                    "{label}: kind '{kind}' is not ingestible by `fxpnet \
                     report` (expected a 'stability' report or a cell cache)"
                )));
            }
            return self.ingest_stability(label, &j);
        }
        if j.opt("version").is_some() {
            let v = j.get("version")?.as_usize()?;
            if v != CACHE_VERSION {
                return Err(FxpError::config(format!(
                    "{label}: cell cache version {v} is not supported \
                     (this build reads v{CACHE_VERSION})"
                )));
            }
            let (header, cells) = parse_cache_text(text)
                .map_err(|e| FxpError::Json(format!("{label}: {e}")))?;
            let regime =
                Regime::from_seed_tag(header.regime_tag).ok_or_else(|| {
                    FxpError::Json(format!(
                        "{label}: unknown regime_tag {}",
                        header.regime_tag
                    ))
                })?;
            return self.merge(
                label,
                &header.arch,
                regime,
                header.base_seed,
                cells,
                BTreeMap::new(),
            );
        }
        Err(FxpError::config(format!(
            "{label}: unrecognized input -- neither a v{CACHE_VERSION} cell \
             cache nor a v{REPORT_VERSION} stability report (pre-versioned \
             stability reports must be regenerated)"
        )))
    }

    fn ingest_stability(&mut self, label: &str, j: &Json) -> Result<()> {
        let arch = j.get("arch")?.as_str()?.to_string();
        let regime_tag = j.get("regime_tag")?.as_usize()? as u64;
        let regime = Regime::from_seed_tag(regime_tag).ok_or_else(|| {
            FxpError::Json(format!("{label}: unknown regime_tag {regime_tag}"))
        })?;
        let tag = j.get("regime")?.as_str()?;
        if tag != regime.tag() {
            return Err(FxpError::Json(format!(
                "{label}: regime '{tag}' does not match regime_tag \
                 {regime_tag} ('{}')",
                regime.tag()
            )));
        }
        let seed_str = j.get("base_seed")?.as_str()?;
        let base_seed = seed_str.parse::<u64>().map_err(|_| {
            FxpError::Json(format!("{label}: bad base_seed '{seed_str}'"))
        })?;
        let mut cells = BTreeMap::new();
        let mut telemetry = BTreeMap::new();
        for (key, cell) in j.get("cells")?.as_obj()? {
            cells.insert(key.clone(), cell_eval_from_json(key, cell)?);
            if let Some(t) = cell.opt("telemetry") {
                telemetry.insert(
                    key.clone(),
                    TelemetrySummary::from_json(t).map_err(|e| {
                        FxpError::Json(format!(
                            "{label}: cell '{key}' telemetry: {e}"
                        ))
                    })?,
                );
            }
        }
        self.merge(label, &arch, regime, base_seed, cells, telemetry)
    }

    /// Union parsed cells/telemetry into the sweep's dataset.  Overlap
    /// is fine (a cache plus the stability report derived from it, or a
    /// resumed shard's cells appearing twice) -- but only bit-equal
    /// overlap: a conflicting duplicate means the inputs are not views
    /// of one sweep, and averaging them would fabricate data.
    pub fn merge(
        &mut self,
        label: &str,
        arch: &str,
        regime: Regime,
        base_seed: u64,
        cells: BTreeMap<String, CellEval>,
        telemetry: BTreeMap<String, TelemetrySummary>,
    ) -> Result<()> {
        let sweep = self
            .sweeps
            .entry((arch.to_string(), regime.seed_tag(), base_seed))
            .or_insert_with(|| SweepData {
                arch: arch.to_string(),
                regime,
                base_seed,
                cells: BTreeMap::new(),
                telemetry: BTreeMap::new(),
            });
        for (key, eval) in cells {
            if let Some(prev) = sweep.cells.get(&key) {
                if !cells_bit_equal(prev, &eval) {
                    return Err(FxpError::config(format!(
                        "{label}: cell '{key}' conflicts with an earlier \
                         input for sweep (arch={arch}, regime={}, \
                         seed={base_seed}) -- not views of one sweep",
                        regime.tag()
                    )));
                }
            } else {
                sweep.cells.insert(key, eval);
            }
        }
        for (key, summary) in telemetry {
            if let Some(prev) = sweep.telemetry.get(&key) {
                if prev.to_json().to_string() != summary.to_json().to_string() {
                    return Err(FxpError::config(format!(
                        "{label}: telemetry for cell '{key}' conflicts with \
                         an earlier input for sweep (arch={arch}, regime={}, \
                         seed={base_seed})",
                        regime.tag()
                    )));
                }
            } else {
                sweep.telemetry.insert(key, summary);
            }
        }
        Ok(())
    }

    /// Canonical analytics JSON -- a pure function of the ingested data,
    /// byte-identical across input provenance.
    pub fn to_json(&self) -> Json {
        let sweeps = self
            .sweeps
            .values()
            .map(|s| {
                let mut widths: BTreeMap<String, WidthAgg> = BTreeMap::new();
                let (mut ok, mut na, mut aborted) = (0usize, 0usize, 0usize);
                for (key, eval) in &s.cells {
                    let agg = widths.entry(width_of(key).to_string()).or_default();
                    match eval {
                        CellEval::Ok(_) => {
                            ok += 1;
                            agg.ok += 1;
                        }
                        CellEval::Na => {
                            na += 1;
                            agg.na += 1;
                        }
                        CellEval::Aborted { reason, step } => {
                            aborted += 1;
                            agg.aborted += 1;
                            let e = agg
                                .aborts
                                .entry(reason.as_str().to_string())
                                .or_insert((0, *step, *step));
                            e.0 += 1;
                            e.1 = e.1.min(*step);
                            e.2 = e.2.max(*step);
                        }
                    }
                    if let Some(t) = s.telemetry.get(key) {
                        agg.observe(t);
                    }
                }
                Json::obj(vec![
                    ("arch", Json::Str(s.arch.clone())),
                    ("regime", Json::Str(s.regime.tag().into())),
                    ("regime_tag", Json::from(s.regime.seed_tag() as usize)),
                    ("table", Json::from(s.regime.table_number())),
                    ("base_seed", Json::Str(s.base_seed.to_string())),
                    (
                        "summary",
                        Json::obj(vec![
                            ("ok", Json::from(ok)),
                            ("na", Json::from(na)),
                            ("aborted", Json::from(aborted)),
                            ("telemetry", Json::from(s.telemetry.len())),
                        ]),
                    ),
                    (
                        "widths",
                        Json::Obj(
                            widths
                                .iter()
                                .map(|(w, agg)| (w.clone(), agg.to_json()))
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("report_version", Json::from(REPORT_VERSION)),
            ("kind", Json::Str("analytics".into())),
            ("sweeps", Json::Arr(sweeps)),
        ])
    }

    /// Human-readable per-(sweep, width) table of the same aggregates.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "stability analytics (per regime x weight width)",
            &[
                "regime", "arch", "seed", "w", "ok", "na", "abrt", "tele",
                "sat_peak", "ratio_min", "aborts",
            ],
        );
        for s in self.sweeps.values() {
            let mut widths: BTreeMap<String, WidthAgg> = BTreeMap::new();
            for (key, eval) in &s.cells {
                let agg = widths.entry(width_of(key).to_string()).or_default();
                match eval {
                    CellEval::Ok(_) => agg.ok += 1,
                    CellEval::Na => agg.na += 1,
                    CellEval::Aborted { reason, step } => {
                        agg.aborted += 1;
                        let e = agg
                            .aborts
                            .entry(reason.as_str().to_string())
                            .or_insert((0, *step, *step));
                        e.0 += 1;
                        e.1 = e.1.min(*step);
                        e.2 = e.2.max(*step);
                    }
                }
                if let Some(tele) = s.telemetry.get(key) {
                    agg.observe(tele);
                }
            }
            for (w, agg) in &widths {
                let sat_peak = agg
                    .sat_peak
                    .iter()
                    .fold(f64::NEG_INFINITY, |m, &x| m.max(x));
                let aborts = agg
                    .aborts
                    .iter()
                    .map(|(r, (n, lo, hi))| {
                        if lo == hi {
                            format!("{r}x{n}@{lo}")
                        } else {
                            format!("{r}x{n}@{lo}-{hi}")
                        }
                    })
                    .collect::<Vec<_>>()
                    .join(" ");
                t.row(vec![
                    s.regime.tag().to_string(),
                    s.arch.clone(),
                    s.base_seed.to_string(),
                    w.clone(),
                    agg.ok.to_string(),
                    agg.na.to_string(),
                    agg.aborted.to_string(),
                    agg.tele.to_string(),
                    if sat_peak.is_finite() {
                        format!("{sat_peak:.4}")
                    } else {
                        "-".to_string()
                    },
                    match agg.ratio_min {
                        Some(r) => format!("{r:.3e}"),
                        None => "-".to_string(),
                    },
                    if aborts.is_empty() { "-".to_string() } else { aborts },
                ]);
            }
        }
        t.render()
    }

    /// Fit per-regime abort thresholds from the ingested sweeps --
    /// deterministic and closed-form (no RNG, no iteration-order
    /// dependence).  Per regime tag:
    ///
    /// * cells that converged (status ok) with telemetry form the
    ///   *safe envelope*; cells that diverged or aborted form the
    ///   *doomed set*;
    /// * `sat_rate`: midpoint between the highest converged `sat_peak`
    ///   and the smallest doomed `sat_peak` above it (1.0 -- never fires
    ///   -- when no doomed cell saturates harder than a converged one);
    /// * `collapse_ratio`: midpoint between the smallest converged
    ///   `ratio_min` and the largest doomed `ratio_min` below it (0.0 --
    ///   never fires -- when the classes don't separate);
    /// * `blowup_factor`: at least the default, raised until
    ///   `loss_start * factor >= loss_peak` (computed in f32, nudged up
    ///   bit-by-bit) for every converged cell whose peak exceeded
    ///   `loss_start + 1.0`;
    /// * `window` / `min_steps` keep their defaults;
    /// * a regime with no converged telemetry keeps
    ///   [`AbortPolicy::default`] (nothing safe to fit against).
    ///
    /// Because the live predicates are strict (`>` / `<`) and every
    /// per-step value is bounded by the run's recorded peak/min, a
    /// policy fit this way can never abort a cell that converged in the
    /// data it was fit from.
    pub fn suggest_thresholds(&self) -> AbortOverlay {
        let mut by_tag: BTreeMap<&str, Vec<(&CellEval, &TelemetrySummary)>> =
            BTreeMap::new();
        for s in self.sweeps.values() {
            for (key, eval) in &s.cells {
                if let Some(t) = s.telemetry.get(key) {
                    by_tag.entry(s.regime.tag()).or_default().push((eval, t));
                }
            }
        }
        let mut overlay = AbortOverlay::default();
        for (tag, cells) in by_tag {
            overlay.regimes.insert(tag.to_string(), fit_policy(&cells));
        }
        overlay
    }
}

/// Per-(sweep, width) accumulator behind [`Analytics::to_json`].
#[derive(Debug, Default)]
struct WidthAgg {
    ok: usize,
    na: usize,
    aborted: usize,
    tele: usize,
    sat_final: Vec<f64>,
    sat_peak: Vec<f64>,
    ratio_min: Option<f64>,
    /// start_step -> (max end_step, contributing cells, pooled ratio_q)
    windows: BTreeMap<usize, (usize, usize, Vec<f64>)>,
    /// reason -> (count, first step, last step)
    aborts: BTreeMap<String, (usize, usize, usize)>,
}

impl WidthAgg {
    fn observe(&mut self, t: &TelemetrySummary) {
        self.tele += 1;
        self.sat_final.push(t.sat_final);
        self.sat_peak.push(t.sat_peak);
        if let Some(r) = t.ratio_min {
            let r = r as f64;
            self.ratio_min =
                Some(self.ratio_min.map_or(r, |m| if r < m { r } else { m }));
        }
        for w in &t.windows {
            let e = self
                .windows
                .entry(w.start_step)
                .or_insert((w.end_step, 0, Vec::new()));
            e.0 = e.0.max(w.end_step);
            e.1 += 1;
            e.2.extend_from_slice(&w.ratio_q);
        }
    }

    fn to_json(&self) -> Json {
        let q_of = |vals: &[f64]| {
            if vals.is_empty() {
                Json::Arr(Vec::new())
            } else {
                let mut sorted = vals.to_vec();
                sorted.sort_by(f64::total_cmp);
                Json::Arr(quantiles(&sorted).into_iter().map(num_json).collect())
            }
        };
        // trajectory: per pinned window (aligned by start step, width
        // SUMMARY_WINDOW_STEPS), fixed quantiles over the pooled per-cell
        // window quantiles -- a cross-cell ratio-collapse profile
        let windows = self
            .windows
            .iter()
            .map(|(&start, (end, cells, pooled))| {
                Json::obj(vec![
                    ("start_step", Json::from(start)),
                    ("end_step", Json::from(*end)),
                    ("cells", Json::from(*cells)),
                    ("ratio_q", q_of(pooled)),
                ])
            })
            .collect();
        let aborts = self
            .aborts
            .iter()
            .map(|(r, (n, lo, hi))| {
                (
                    r.clone(),
                    Json::obj(vec![
                        ("count", Json::from(*n)),
                        ("first_step", Json::from(*lo)),
                        ("last_step", Json::from(*hi)),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("ok", Json::from(self.ok)),
            ("na", Json::from(self.na)),
            ("aborted", Json::from(self.aborted)),
            ("telemetry", Json::from(self.tele)),
            ("window_steps", Json::from(SUMMARY_WINDOW_STEPS)),
            ("sat_final_q", q_of(&self.sat_final)),
            ("sat_peak_q", q_of(&self.sat_peak)),
            (
                "ratio_min",
                match self.ratio_min {
                    Some(r) => num_json(r),
                    None => Json::Null,
                },
            ),
            ("windows", Json::Arr(windows)),
            ("aborts", Json::Obj(aborts)),
        ])
    }
}

/// Smallest f32 strictly above a positive finite `x`.
fn next_up(x: f32) -> f32 {
    f32::from_bits(x.to_bits() + 1)
}

/// Closed-form threshold fit for one regime's telemetry-bearing cells
/// (see [`Analytics::suggest_thresholds`] for the contract).
fn fit_policy(cells: &[(&CellEval, &TelemetrySummary)]) -> AbortPolicy {
    let d = AbortPolicy::default();
    let conv: Vec<&TelemetrySummary> = cells
        .iter()
        .filter(|(e, _)| matches!(e, CellEval::Ok(_)))
        .map(|(_, t)| *t)
        .collect();
    let doomed: Vec<&TelemetrySummary> = cells
        .iter()
        .filter(|(e, _)| !matches!(e, CellEval::Ok(_)))
        .map(|(_, t)| *t)
        .collect();
    if conv.is_empty() {
        return d;
    }

    let conv_sat_max =
        conv.iter().map(|t| t.sat_peak).fold(0.0f64, f64::max);
    let doomed_sat_above = doomed
        .iter()
        .map(|t| t.sat_peak)
        .filter(|&s| s > conv_sat_max)
        .fold(None, |m: Option<f64>, x| Some(m.map_or(x, |m| m.min(x))));
    let sat_rate = match doomed_sat_above {
        Some(s) => (conv_sat_max + s) / 2.0,
        None => 1.0,
    };

    let conv_ratio_min = conv
        .iter()
        .filter_map(|t| t.ratio_min)
        .fold(None, |m: Option<f32>, x| Some(m.map_or(x, |m| m.min(x))));
    let collapse_ratio = match conv_ratio_min {
        Some(cr) => {
            let doomed_below = doomed
                .iter()
                .filter_map(|t| t.ratio_min)
                .filter(|&r| r < cr)
                .fold(None, |m: Option<f32>, x| {
                    Some(m.map_or(x, |m| m.max(x)))
                });
            match doomed_below {
                Some(dr) => (cr + dr) / 2.0,
                None => 0.0,
            }
        }
        None => 0.0,
    };

    let mut blowup_factor = d.blowup_factor;
    for t in &conv {
        // the live predicate only fires when loss exceeds BOTH
        // start*factor and start+1.0, so only peaks past start+1.0
        // constrain the factor
        if t.loss_start.is_finite()
            && t.loss_start > 0.0
            && t.loss_peak.is_finite()
            && t.loss_peak > t.loss_start + 1.0
        {
            let mut need = t.loss_peak / t.loss_start;
            // f32 division can round down; nudge until the product
            // provably covers the peak
            while t.loss_start * need < t.loss_peak {
                need = next_up(need);
            }
            blowup_factor = blowup_factor.max(need);
        }
    }

    AbortPolicy {
        window: d.window,
        min_steps: d.min_steps,
        blowup_factor,
        sat_rate,
        collapse_ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::evaluator::EvalResult;
    use crate::coordinator::trainer::AbortReason;

    fn summary(
        sat_peak: f64,
        ratio_min: Option<f32>,
        loss_start: f32,
        loss_peak: f32,
    ) -> TelemetrySummary {
        TelemetrySummary {
            steps: 10,
            loss_start,
            loss_peak,
            loss_final: loss_start,
            sat_final: sat_peak / 2.0,
            sat_peak,
            ratio_min,
            ratio_final: ratio_min,
            windows: Vec::new(),
        }
    }

    fn ok_eval() -> CellEval {
        CellEval::Ok(EvalResult {
            n: 16,
            top1_err: 0.2,
            top5_err: 0.1,
            mean_loss: 1.0,
        })
    }

    fn sweep_with(
        cells: Vec<(&str, CellEval)>,
        telemetry: Vec<(&str, TelemetrySummary)>,
    ) -> Analytics {
        let mut a = Analytics::new();
        a.merge(
            "test",
            "tiny",
            Regime::Vanilla,
            42,
            cells.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
            telemetry
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
        .unwrap();
        a
    }

    #[test]
    fn width_of_parses_cell_keys() {
        assert_eq!(width_of("w=4,a=8"), "4");
        assert_eq!(width_of("w=Float,a=4"), "Float");
        assert_eq!(width_of("w=16,a=Float"), "16");
    }

    #[test]
    fn empty_analytics_renders_and_serializes() {
        let a = Analytics::new();
        assert!(a.is_empty());
        let j = a.to_json();
        assert_eq!(j.get("kind").unwrap().as_str().unwrap(), "analytics");
        assert_eq!(j.get("sweeps").unwrap().as_arr().unwrap().len(), 0);
        assert!(a.render().contains("stability analytics"));
        // a no-data overlay has no regime entries and resolves to default
        let o = a.suggest_thresholds();
        assert!(o.regimes.is_empty());
        assert_eq!(o.resolve("vanilla"), AbortPolicy::default());
    }

    #[test]
    fn conflicting_duplicate_cell_is_refused() {
        let mut a = sweep_with(vec![("w=4,a=4", ok_eval())], vec![]);
        // bit-equal duplicate unions fine
        a.merge(
            "dup",
            "tiny",
            Regime::Vanilla,
            42,
            [("w=4,a=4".to_string(), ok_eval())].into_iter().collect(),
            BTreeMap::new(),
        )
        .unwrap();
        // conflicting duplicate is an error
        let err = a
            .merge(
                "bad",
                "tiny",
                Regime::Vanilla,
                42,
                [("w=4,a=4".to_string(), CellEval::Na)].into_iter().collect(),
                BTreeMap::new(),
            )
            .unwrap_err();
        assert!(err.to_string().contains("w=4,a=4"), "{err}");
    }

    #[test]
    fn rejects_wrong_report_version_and_unversioned_input() {
        let mut a = Analytics::new();
        let err = a
            .ingest_text("x", r#"{"report_version": 1, "kind": "stability"}"#)
            .unwrap_err();
        assert!(err.to_string().contains("report_version 1"), "{err}");
        let err = a
            .ingest_text("x", r#"{"table": 3, "cells": []}"#)
            .unwrap_err();
        assert!(err.to_string().contains("unrecognized input"), "{err}");
        let err = a
            .ingest_text("x", r#"{"version": 3, "cells": {}}"#)
            .unwrap_err();
        assert!(err.to_string().contains("version 3"), "{err}");
        assert!(a.is_empty());
    }

    #[test]
    fn rejects_non_stability_report_kinds() {
        let mut a = Analytics::new();
        let err = a
            .ingest_text(
                "x",
                &format!(
                    r#"{{"report_version": {REPORT_VERSION}, "kind": "analytics", "sweeps": []}}"#
                ),
            )
            .unwrap_err();
        assert!(err.to_string().contains("kind 'analytics'"), "{err}");
    }

    #[test]
    fn learned_policy_separates_converged_from_doomed() {
        let a = sweep_with(
            vec![
                ("w=8,a=8", ok_eval()),
                ("w=4,a=4", CellEval::Na),
                (
                    "w=4,a=8",
                    CellEval::Aborted {
                        reason: AbortReason::UpdateCollapse,
                        step: 50,
                    },
                ),
            ],
            vec![
                ("w=8,a=8", summary(0.10, Some(1e-2), 2.0, 2.5)),
                ("w=4,a=4", summary(0.80, Some(2e-5), 2.0, 9.0)),
                ("w=4,a=8", summary(0.05, Some(1e-6), 2.0, 2.1)),
            ],
        );
        let o = a.suggest_thresholds();
        let p = o.resolve("vanilla");
        // sat: midpoint of 0.10 (conv max) and 0.80 (smallest doomed above)
        assert!((p.sat_rate - 0.45).abs() < 1e-12, "{}", p.sat_rate);
        // collapse: midpoint of 1e-2 (conv min) and 2e-5 (largest doomed below)
        assert!(p.collapse_ratio < 1e-2 && p.collapse_ratio > 2e-5);
        // blowup: conv peak 2.5 < start+1.0 -> default stands
        assert_eq!(p.blowup_factor, AbortPolicy::default().blowup_factor);
        // safety: no converged cell's extremes would trip the policy
        assert!(0.10 < p.sat_rate && 1e-2 > p.collapse_ratio);
        // untouched regimes resolve to the overlay default (builtin)
        assert_eq!(o.resolve("prop3"), AbortPolicy::default());
        // determinism: byte-identical on re-fit
        assert_eq!(
            a.suggest_thresholds().to_json().to_string(),
            o.to_json().to_string()
        );
    }

    #[test]
    fn learned_blowup_covers_converged_peak() {
        // converged cell that spiked to 5x its start: factor must grow
        let a = sweep_with(
            vec![("w=8,a=8", ok_eval())],
            vec![("w=8,a=8", summary(0.1, Some(1e-2), 2.0, 10.0))],
        );
        let p = a.suggest_thresholds().resolve("vanilla");
        assert!(p.blowup_factor >= 5.0);
        assert!(2.0f32 * p.blowup_factor >= 10.0);
        // no doomed cells at all: sat/collapse never fire
        assert_eq!(p.sat_rate, 1.0);
        assert_eq!(p.collapse_ratio, 0.0);
    }

    #[test]
    fn no_converged_regime_keeps_default_policy() {
        let a = sweep_with(
            vec![("w=4,a=4", CellEval::Na)],
            vec![("w=4,a=4", summary(0.9, Some(1e-7), 2.0, 50.0))],
        );
        assert_eq!(
            a.suggest_thresholds().resolve("vanilla"),
            AbortPolicy::default()
        );
    }

    #[test]
    fn analytics_json_is_merge_order_invariant() {
        let build = |order: &[usize]| {
            let mut a = Analytics::new();
            let parts: Vec<(String, CellEval)> = vec![
                ("w=4,a=4".into(), CellEval::Na),
                ("w=8,a=8".into(), ok_eval()),
                (
                    "w=16,a=4".into(),
                    CellEval::Aborted {
                        reason: AbortReason::NanLoss,
                        step: 7,
                    },
                ),
            ];
            for &i in order {
                let (k, v) = parts[i].clone();
                a.merge(
                    "t",
                    "tiny",
                    Regime::Vanilla,
                    42,
                    [(k.clone(), v)].into_iter().collect(),
                    [(k, summary(0.2, Some(1e-3), 2.0, 2.2))]
                        .into_iter()
                        .collect(),
                )
                .unwrap();
            }
            a.to_json().to_string()
        };
        let fwd = build(&[0, 1, 2]);
        assert_eq!(fwd, build(&[2, 0, 1]));
        assert_eq!(fwd, build(&[1, 2, 0]));
    }
}
