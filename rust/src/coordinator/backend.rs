//! The training/evaluation backend abstraction.
//!
//! Everything a regime needs from an execution engine is four
//! capabilities: describe an architecture, open a fine-tuning session
//! ([`crate::coordinator::trainer::TrainSession`]), evaluate a
//! parameter set under a quantization cell, and calibrate activation
//! statistics.  Two implementations exist:
//!
//! * [`XlaBackend`] -- the original PJRT path over AOT-compiled HLO
//!   (`artifacts/`); float-simulated quantization inside the compiled
//!   graph.  Requires the real `xla` crate to be relinked.
//! * `train::NativeBackend` -- the pure-Rust backprop + fixed-point SGD
//!   engine; runs offline with zero external dependencies and is the
//!   default whenever `artifacts/` is absent.
//!
//! [`BackendSpec`] is the cheap, `Send + Sync` description of a backend
//! that the parallel sweep engine clones into every worker thread (PJRT
//! engines are single-threaded by design, so each worker builds its own
//! instance from the spec).

use std::path::{Path, PathBuf};

use crate::coordinator::calibrate;
use crate::coordinator::evaluator::{self, EvalResult};
use crate::coordinator::trainer::{TrainSession, Trainer};
use crate::data::loader::LoaderCfg;
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::NetQuant;
use crate::runtime::Engine;

/// Everything needed to open one fine-tuning session.
pub struct SessionCfg<'a> {
    pub arch: &'a str,
    pub params: &'a ParamSet,
    pub nq: &'a NetQuant,
    pub upd: &'a [f32],
    pub lr: f32,
    pub momentum: f32,
    pub data: Dataset,
    pub loader: LoaderCfg,
    pub max_loss: f32,
    /// Seed of the backend's own stochastic streams (the native engine's
    /// stochastic weight-update rounding).  Derived from the cell seed,
    /// so sessions replay bit-for-bit; the XLA backend has no host-side
    /// stochastic state and ignores it.
    pub seed: u64,
    /// GEMM row-block workers inside one training step (the unified
    /// `--threads` flag; 0 and 1 both mean serial).  Purely a
    /// performance knob: the native engine's accumulation order is fixed
    /// and its rounding streams are pre-split per (step, layer), so loss
    /// histories are bit-identical for every value.  The XLA backend
    /// ignores it (PJRT owns its own threading).
    pub threads: usize,
}

/// One training/evaluation engine (see the module docs).
pub trait Backend {
    /// Short stable name ("native" / "xla") for logs and reports.
    fn name(&self) -> &'static str;

    /// Whether a command may substitute a fresh deterministic He init
    /// for a missing `--ckpt` (the native engine can train from scratch
    /// end-to-end; the XLA path expects a pretrained checkpoint).
    fn supports_fresh_init(&self) -> bool {
        false
    }

    /// The architecture description behind `name`.
    fn arch(&self, name: &str) -> Result<ArchSpec>;

    /// Open a fine-tuning session.
    fn new_session(&self, cfg: SessionCfg<'_>) -> Result<Box<dyn TrainSession>>;

    /// Held-out evaluation of `params` under the cell's quantization.
    fn evaluate(
        &self,
        arch: &str,
        params: &ParamSet,
        nq: &NetQuant,
        data: &Dataset,
    ) -> Result<EvalResult>;

    /// Per-layer activation statistics of the *float* network over up to
    /// `batches` calibration batches (absmax maxed, moments averaged).
    fn activation_stats(
        &self,
        arch: &str,
        params: &ParamSet,
        data: &Dataset,
        batches: usize,
    ) -> Result<Vec<LayerStats>>;
}

/// The XLA/PJRT backend: a thin adapter over [`Engine`].
pub struct XlaBackend {
    engine: Engine,
}

impl XlaBackend {
    pub fn new(engine: Engine) -> XlaBackend {
        XlaBackend { engine }
    }

    /// Open over an artifact directory (must contain `manifest.json`).
    pub fn open(artifacts_dir: impl AsRef<Path>) -> Result<XlaBackend> {
        Ok(XlaBackend { engine: Engine::cpu(artifacts_dir)? })
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }
}

// The XLA `Trainer` keeps the `TrainSession` telemetry defaults
// (`set_telemetry` is a no-op, `last_step_stats` returns None): PJRT
// owns the compiled graph, so per-layer gradient/update norms and
// saturation counters are not observable from the host.  Under an
// abort policy the loop still gets loss-only `StepStats`, so the
// NaN-loss and sustained-blowup predicates work on this backend; the
// saturation and update-collapse predicates simply never fire.
impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn arch(&self, name: &str) -> Result<ArchSpec> {
        Ok(self.engine.manifest.arch(name)?.clone())
    }

    fn new_session(&self, cfg: SessionCfg<'_>) -> Result<Box<dyn TrainSession>> {
        Ok(Box::new(Trainer::new(
            &self.engine,
            cfg.arch,
            cfg.params,
            cfg.nq,
            cfg.upd,
            cfg.lr,
            cfg.momentum,
            cfg.data,
            cfg.loader,
            cfg.max_loss,
        )?))
    }

    fn evaluate(
        &self,
        arch: &str,
        params: &ParamSet,
        nq: &NetQuant,
        data: &Dataset,
    ) -> Result<EvalResult> {
        evaluator::evaluate(&self.engine, arch, params, nq, data)
    }

    fn activation_stats(
        &self,
        arch: &str,
        params: &ParamSet,
        data: &Dataset,
        batches: usize,
    ) -> Result<Vec<LayerStats>> {
        Ok(calibrate::activation_stats(&self.engine, arch, params, data, batches)?
            .a_stats)
    }
}

/// Cheap description of a backend, cloned into every sweep worker.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BackendSpec {
    /// Pure-Rust training engine (`rust/src/train/`); no artifacts.
    Native,
    /// PJRT over the AOT artifacts in the given directory.
    Xla(PathBuf),
}

impl BackendSpec {
    /// Parse a `--backend` value.
    pub fn parse(s: &str, artifacts_dir: &str) -> Result<BackendSpec> {
        match s {
            "native" => Ok(BackendSpec::Native),
            "xla" => Ok(BackendSpec::Xla(PathBuf::from(artifacts_dir))),
            other => Err(FxpError::config(format!(
                "bad --backend '{other}': expected 'native' or 'xla'"
            ))),
        }
    }

    /// The default policy: XLA when the artifact directory exists (its
    /// compiled graphs are the reference semantics), native otherwise --
    /// so the offline build trains for real out of the box.
    pub fn auto(artifacts_dir: &str) -> BackendSpec {
        if Path::new(artifacts_dir).join("manifest.json").exists() {
            BackendSpec::Xla(PathBuf::from(artifacts_dir))
        } else {
            BackendSpec::Native
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendSpec::Native => "native",
            BackendSpec::Xla(_) => "xla",
        }
    }

    /// Instantiate the backend (one per sweep worker; PJRT engines are
    /// single-threaded by design).  Serial GEMMs -- see
    /// [`BackendSpec::build_with_threads`] for the threaded variant.
    pub fn build(&self) -> Result<Box<dyn Backend>> {
        self.build_with_threads(1)
    }

    /// [`BackendSpec::build`] with the native engine's GEMM row-block
    /// worker count set (the unified `--threads` flag; results are
    /// bit-identical for every value).  The XLA backend ignores it.
    pub fn build_with_threads(&self, threads: usize) -> Result<Box<dyn Backend>> {
        match self {
            BackendSpec::Native => Ok(Box::new(
                crate::train::NativeBackend::new().with_threads(threads),
            )),
            BackendSpec::Xla(dir) => Ok(Box::new(XlaBackend::open(dir)?)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parse_and_labels() {
        assert_eq!(
            BackendSpec::parse("native", "artifacts").unwrap(),
            BackendSpec::Native
        );
        assert_eq!(
            BackendSpec::parse("xla", "a").unwrap(),
            BackendSpec::Xla(PathBuf::from("a"))
        );
        assert!(BackendSpec::parse("cuda", "a").is_err());
        assert_eq!(BackendSpec::Native.label(), "native");
        assert_eq!(BackendSpec::Xla(PathBuf::new()).label(), "xla");
    }

    #[test]
    fn auto_prefers_native_without_artifacts() {
        let dir = std::env::temp_dir().join("fxp_backend_auto_none");
        let _ = std::fs::remove_dir_all(&dir);
        assert_eq!(
            BackendSpec::auto(dir.to_str().unwrap()),
            BackendSpec::Native
        );
        // and xla once a manifest appears
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{}").unwrap();
        assert_eq!(
            BackendSpec::auto(dir.to_str().unwrap()),
            BackendSpec::Xla(dir.clone())
        );
    }

    #[test]
    fn native_spec_builds_offline() {
        let b = BackendSpec::Native.build().unwrap();
        assert_eq!(b.name(), "native");
        assert!(b.arch("tiny").is_ok());
        assert!(b.arch("nope").is_err());
    }
}
