//! The SGD step loop, and the [`TrainSession`] abstraction both training
//! backends implement.
//!
//! Two engines can drive a fine-tuning run:
//!
//! * [`Trainer`] -- the XLA path: state (parameters + momenta) lives as
//!   XLA literals and is fed straight from one step's outputs into the
//!   next step's inputs -- only the batch and the scalar loss cross the
//!   host boundary per step (measured in EXPERIMENTS.md section Perf).
//!   Quantization configuration, update masks, lr and momentum are
//!   literals too, rebuilt only when a regime / phase changes them.
//!   Needs `artifacts/` and a real PJRT runtime (relink the `xla` crate).
//! * `train::NativeTrainer` -- the pure-Rust backprop engine: runs the
//!   same step contract offline, with stochastic-rounding fixed-point
//!   weight updates (Gupta et al. 2015).
//!
//! The regimes talk to either through the [`TrainSession`] trait; the
//! shared [`run_session`] loop owns divergence detection (the paper's
//! "fails to converge" -> `n/a`), so both backends judge runs by exactly
//! the same rule.

use std::rc::Rc;

use crate::data::loader::{Loader, LoaderCfg};
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;
use crate::runtime::literal::{to_literal, HostValue};
use crate::runtime::{Engine, Executable};
use crate::tensor::Tensor;

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// (step, loss) samples
    pub history: Vec<(usize, f32)>,
    /// true if the run hit the divergence detector
    pub diverged: bool,
    /// steps actually executed
    pub steps: usize,
}

impl TrainOutcome {
    pub fn final_loss(&self) -> Option<f32> {
        self.history.last().map(|&(_, l)| l)
    }

    /// Mean loss over the last `n` recorded samples.
    pub fn tail_mean(&self, n: usize) -> f32 {
        if self.history.is_empty() {
            return f32::NAN;
        }
        let tail = &self.history[self.history.len().saturating_sub(n)..];
        tail.iter().map(|&(_, l)| l).sum::<f32>() / tail.len() as f32
    }
}

/// Per-layer update mask builders (the `upd` input of `train_step`).
pub fn upd_all(num_layers: usize) -> Vec<f32> {
    vec![1.0; num_layers]
}

/// Proposal 2: only the top `k` layers update.
pub fn upd_top(num_layers: usize, k: usize) -> Vec<f32> {
    let mut v = vec![0.0; num_layers];
    for l in num_layers.saturating_sub(k)..num_layers {
        v[l] = 1.0;
    }
    v
}

/// Proposal 3 phases: exactly one layer updates.
pub fn upd_single(num_layers: usize, layer: usize) -> Vec<f32> {
    let mut v = vec![0.0; num_layers];
    v[layer] = 1.0;
    v
}

/// One in-progress fine-tuning run, behind either backend.
///
/// A session owns its parameter/momentum state and its data loader; the
/// regimes drive it through `step`/`set_config`/`reset_momenta` and read
/// the result back with `params`.  Divergence policy is not the
/// session's business -- [`run_session`] applies it identically to every
/// implementation.
pub trait TrainSession {
    /// One SGD step; returns the batch loss.
    fn step(&mut self) -> Result<f32>;

    /// Swap the quantization / update / lr configuration (phase change);
    /// parameter and momentum state is preserved.
    fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()>;

    /// Reset momenta to zero (used between Proposal 3 phases so stale
    /// velocity from the previous phase's layer does not leak).
    fn reset_momenta(&mut self) -> Result<()>;

    /// Read the current parameters back to the host.
    fn params(&self) -> Result<ParamSet>;

    /// Steps executed over the session's lifetime.
    fn global_step(&self) -> usize;

    /// Divergence threshold (loss above this, or NaN/Inf, is "n/a").
    fn max_loss(&self) -> f32;
}

/// Run `steps` steps of a session with divergence detection; records the
/// loss every `record_every` steps (and always the last).
///
/// "Diverged" (the paper's *fails to converge*, rendered `n/a` in the
/// tables) means any of:
/// * the loss goes NaN/Inf or exceeds the session's `max_loss` at any
///   step;
/// * for runs of >= 30 steps: the trailing-mean loss ends up clearly
///   *above* where the run started -- fine-tuning made the network
///   worse, which is exactly what happens when the mismatched gradients
///   point the wrong way (see results/gradient_mismatch_*).
pub fn run_session(
    s: &mut dyn TrainSession,
    steps: usize,
    record_every: usize,
) -> Result<TrainOutcome> {
    let max_loss = s.max_loss();
    let mut history = Vec::new();
    let mut first_losses: Vec<f32> = Vec::new();
    let mut tail: std::collections::VecDeque<f32> =
        std::collections::VecDeque::with_capacity(8);
    for i in 0..steps {
        let loss = s.step()?;
        if first_losses.len() < 5 {
            first_losses.push(loss);
        }
        if tail.len() == 8 {
            tail.pop_front();
        }
        tail.push_back(loss);
        if i % record_every.max(1) == 0 || i + 1 == steps {
            history.push((s.global_step(), loss));
        }
        if !loss.is_finite() || loss > max_loss {
            log::warn!(
                "diverged at step {} (loss {loss}): marking n/a",
                s.global_step()
            );
            return Ok(TrainOutcome { history, diverged: true, steps: i + 1 });
        }
    }
    if steps >= 30 {
        let start =
            first_losses.iter().sum::<f32>() / first_losses.len().max(1) as f32;
        let end = tail.iter().sum::<f32>() / tail.len().max(1) as f32;
        if end > (start * 1.3).max(start + 0.7) {
            log::warn!(
                "failed to converge: loss {start:.3} -> {end:.3} over {steps} \
                 steps; marking n/a"
            );
            return Ok(TrainOutcome { history, diverged: true, steps });
        }
    }
    Ok(TrainOutcome { history, diverged: false, steps })
}

pub struct Trainer {
    exe: Rc<Executable>,
    arch: ArchSpec,
    loader: Loader,
    /// params (2L) followed by momenta (2L), as literals
    state: Vec<xla::Literal>,
    /// w cfg (4) + a cfg (4) + upd + lr + mu, as literals
    cfg: Vec<xla::Literal>,
    pub max_loss: f32,
    step: usize,
}

fn vec_lit(v: &[f32]) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[v.len()], v.to_vec())?))
}

fn scalar_lit(v: f32) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[1], vec![v])?))
}

impl Trainer {
    /// Build a trainer for `arch` starting from `params` (momenta zero).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: &Engine,
        arch_name: &str,
        params: &ParamSet,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
        data: Dataset,
        loader_cfg: LoaderCfg,
        max_loss: f32,
    ) -> Result<Trainer> {
        let arch = engine.manifest.arch(arch_name)?.clone();
        if loader_cfg.batch != arch.train_batch {
            return Err(FxpError::config(format!(
                "loader batch {} != arch train batch {}",
                loader_cfg.batch, arch.train_batch
            )));
        }
        let exe = engine.executable(arch_name, "train_step")?;
        let mut state = Vec::with_capacity(2 * params.len());
        for t in &params.tensors {
            state.push(to_literal(&HostValue::F32(t.clone()))?);
        }
        for t in &params.tensors {
            state.push(to_literal(&HostValue::F32(Tensor::zeros(t.shape())))?);
        }
        let cfg = Self::build_cfg(nq, upd, lr, momentum)?;
        let loader = Loader::spawn(data, loader_cfg);
        Ok(Trainer { exe, arch, loader, state, cfg, max_loss, step: 0 })
    }

    fn build_cfg(
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<Vec<xla::Literal>> {
        let v = nq.vectors();
        Ok(vec![
            vec_lit(&v.w_step)?,
            vec_lit(&v.w_lo)?,
            vec_lit(&v.w_hi)?,
            vec_lit(&v.w_en)?,
            vec_lit(&v.a_step)?,
            vec_lit(&v.a_lo)?,
            vec_lit(&v.a_hi)?,
            vec_lit(&v.a_en)?,
            vec_lit(upd)?,
            scalar_lit(lr)?,
            scalar_lit(momentum)?,
        ])
    }

    /// Swap the quantization / update / lr configuration (phase change);
    /// parameter and momentum state is preserved.
    pub fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()> {
        self.cfg = Self::build_cfg(nq, upd, lr, momentum)?;
        Ok(())
    }

    /// Reset momenta to zero (used between Proposal 3 phases so stale
    /// velocity from the previous phase's layer does not leak).
    pub fn reset_momenta(&mut self) -> Result<()> {
        let n = self.state.len() / 2;
        for i in 0..n {
            let spec = &self.exe.spec.inputs[n + i];
            self.state[n + i] =
                to_literal(&HostValue::F32(Tensor::zeros(&spec.shape)))?;
        }
        Ok(())
    }

    pub fn global_step(&self) -> usize {
        self.step
    }

    /// One SGD step; returns the batch loss.
    pub fn step(&mut self) -> Result<f32> {
        let batch = self.loader.next_batch();
        let x = to_literal(&HostValue::F32(batch.images))?;
        let y = to_literal(&HostValue::I32(batch.labels))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(
            self.state.len() + 2 + self.cfg.len(),
        );
        inputs.extend(self.state.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(self.cfg.iter());
        let mut outs = self.exe.run_literals(&inputs)?;
        let loss_lit = outs.pop().expect("train_step outputs");
        let loss: f32 = loss_lit.get_first_element()?;
        self.state = outs;
        self.step += 1;
        Ok(loss)
    }

    /// Run `steps` steps with divergence detection (see [`run_session`],
    /// which owns the shared policy).
    pub fn run(&mut self, steps: usize, record_every: usize) -> Result<TrainOutcome> {
        run_session(self, steps, record_every)
    }

    /// Read the current parameters back to the host.
    pub fn params(&self) -> Result<ParamSet> {
        let n = self.state.len() / 2;
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for i in 0..n {
            let spec = &self.exe.spec.inputs[i];
            names.push(spec.name.clone());
            let data = self.state[i].to_vec::<f32>()?;
            tensors.push(Tensor::from_vec(&spec.shape, data)?);
        }
        Ok(ParamSet { names, tensors })
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }
}

impl TrainSession for Trainer {
    fn step(&mut self) -> Result<f32> {
        Trainer::step(self)
    }

    fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()> {
        Trainer::set_config(self, nq, upd, lr, momentum)
    }

    fn reset_momenta(&mut self) -> Result<()> {
        Trainer::reset_momenta(self)
    }

    fn params(&self) -> Result<ParamSet> {
        Trainer::params(self)
    }

    fn global_step(&self) -> usize {
        self.step
    }

    fn max_loss(&self) -> f32 {
        self.max_loss
    }
}
