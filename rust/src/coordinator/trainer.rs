//! The SGD step loop, and the [`TrainSession`] abstraction both training
//! backends implement.
//!
//! Two engines can drive a fine-tuning run:
//!
//! * [`Trainer`] -- the XLA path: state (parameters + momenta) lives as
//!   XLA literals and is fed straight from one step's outputs into the
//!   next step's inputs -- only the batch and the scalar loss cross the
//!   host boundary per step (measured in EXPERIMENTS.md section Perf).
//!   Quantization configuration, update masks, lr and momentum are
//!   literals too, rebuilt only when a regime / phase changes them.
//!   Needs `artifacts/` and a real PJRT runtime (relink the `xla` crate).
//! * `train::NativeTrainer` -- the pure-Rust backprop engine: runs the
//!   same step contract offline, with stochastic-rounding fixed-point
//!   weight updates (Gupta et al. 2015).
//!
//! The regimes talk to either through the [`TrainSession`] trait; the
//! shared [`run_session`] loop owns divergence detection (the paper's
//! "fails to converge" -> `n/a`), so both backends judge runs by exactly
//! the same rule.

use std::rc::Rc;

use crate::data::loader::{Loader, LoaderCfg};
use crate::data::synth::Dataset;
use crate::error::{FxpError, Result};
use crate::model::manifest::ArchSpec;
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;
use crate::runtime::literal::{to_literal, HostValue};
use crate::runtime::{Engine, Executable};
use crate::tensor::Tensor;
use crate::train::telemetry::{StepStats, TelemetryLog};
use crate::util::json::Json;

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct TrainOutcome {
    /// (step, loss) samples
    pub history: Vec<(usize, f32)>,
    /// true if the run hit the divergence detector
    pub diverged: bool,
    /// steps actually executed
    pub steps: usize,
    /// set when an [`AbortPolicy`] ended the run early: the predicate
    /// that fired and the global step at which it did
    pub aborted: Option<(AbortReason, usize)>,
}

impl TrainOutcome {
    pub fn final_loss(&self) -> Option<f32> {
        self.history.last().map(|&(_, l)| l)
    }

    /// Mean loss over the last `n` recorded samples (all of them when
    /// fewer than `n` were recorded; each sample counts exactly once).
    /// NaN when nothing was recorded or `n == 0`.
    pub fn tail_mean(&self, n: usize) -> f32 {
        let take = n.min(self.history.len());
        if take == 0 {
            return f32::NAN;
        }
        let tail = &self.history[self.history.len() - take..];
        tail.iter().map(|&(_, l)| l).sum::<f32>() / take as f32
    }
}

/// Why an [`AbortPolicy`] ended a run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbortReason {
    /// loss went NaN/Inf or exceeded the session's `max_loss`
    NanLoss,
    /// loss stayed above `blowup_factor` x the starting loss for a full
    /// window
    LossBlowup,
    /// the fraction of clipped quantized elements stayed above
    /// `sat_rate` for a full window
    Saturation,
    /// the smallest update-to-quantization-step ratio stayed below
    /// `collapse_ratio` for a full window (Li et al.: updates vanish
    /// beneath the weight grid)
    UpdateCollapse,
}

impl AbortReason {
    /// Stable string form (cell cache / stability report schema).
    pub fn as_str(&self) -> &'static str {
        match self {
            AbortReason::NanLoss => "nan-loss",
            AbortReason::LossBlowup => "loss-blowup",
            AbortReason::Saturation => "saturation",
            AbortReason::UpdateCollapse => "update-collapse",
        }
    }

    pub fn parse(s: &str) -> Option<AbortReason> {
        match s {
            "nan-loss" => Some(AbortReason::NanLoss),
            "loss-blowup" => Some(AbortReason::LossBlowup),
            "saturation" => Some(AbortReason::Saturation),
            "update-collapse" => Some(AbortReason::UpdateCollapse),
            _ => None,
        }
    }
}

/// Windowed early-abort predicates over the telemetry stream: end a
/// provably-doomed cell before its step budget runs out.  All inputs
/// (loss, saturation rates, update ratios) are bit-identical for any
/// `--threads` count, so the abort decision -- both the reason and the
/// step -- is too.  The full-run path stays the reference: policy `None`
/// (`--no-early-abort`) is byte-identical to the pre-policy loop, and a
/// policy can only end a run the detector would call diverged anyway or
/// whose sustained statistics match a doomed profile.
#[derive(Clone, Debug, PartialEq)]
pub struct AbortPolicy {
    /// consecutive flagged steps a sustained predicate needs to fire
    pub window: usize,
    /// sustained predicates are inert for the first `min_steps` steps
    /// (the NaN/max-loss check is always live)
    pub min_steps: usize,
    /// `LossBlowup`: loss > max(blowup_factor * start, start + 1.0)
    pub blowup_factor: f32,
    /// `Saturation`: fraction of clipped quantized elements per step
    pub sat_rate: f64,
    /// `UpdateCollapse`: min per-layer mean |update| / weight step
    pub collapse_ratio: f32,
}

impl Default for AbortPolicy {
    fn default() -> AbortPolicy {
        AbortPolicy {
            window: 8,
            min_steps: 20,
            blowup_factor: 3.0,
            sat_rate: 0.5,
            collapse_ratio: 1e-3,
        }
    }
}

/// Schema version stamped into `--abort-policy` overlay files; bumped
/// whenever the policy fields or their semantics change.
pub const POLICY_VERSION: usize = 1;

impl AbortPolicy {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("window", Json::from(self.window)),
            ("min_steps", Json::from(self.min_steps)),
            ("blowup_factor", Json::Num(self.blowup_factor as f64)),
            ("sat_rate", Json::Num(self.sat_rate)),
            ("collapse_ratio", Json::Num(self.collapse_ratio as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AbortPolicy> {
        Ok(AbortPolicy {
            window: j.get("window")?.as_usize()?,
            min_steps: j.get("min_steps")?.as_usize()?,
            blowup_factor: j.get("blowup_factor")?.as_f64()? as f32,
            sat_rate: j.get("sat_rate")?.as_f64()?,
            collapse_ratio: j.get("collapse_ratio")?.as_f64()? as f32,
        })
    }

    /// The policy's parameters as a stable word sequence for seed/cache
    /// fingerprints (floats by bit pattern): two sweeps agree on this
    /// iff their resolved policies are bit-identical.
    pub fn fingerprint_words(&self) -> [u64; 5] {
        [
            self.window as u64,
            self.min_steps as u64,
            self.blowup_factor.to_bits() as u64,
            self.sat_rate.to_bits(),
            self.collapse_ratio.to_bits() as u64,
        ]
    }
}

/// Per-regime [`AbortPolicy`] overrides, loaded from a `--abort-policy`
/// overlay file (the output of `fxpnet report --suggest-thresholds`).
///
/// Resolution order for a regime tag: an exact `regimes` entry, else the
/// overlay's `default` policy, else [`AbortPolicy::default`].  The file
/// shape is
///
/// ```json
/// {"policy_version": 1, "kind": "abort-policy",
///  "default": {"window": 8, ...},
///  "regimes": {"vanilla": {"window": 8, ...}}}
/// ```
///
/// with `default` optional and `regimes` possibly empty.  Files with a
/// different `policy_version` are refused outright -- a stale overlay
/// silently reinterpreted under new predicate semantics could abort
/// cells that would converge.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AbortOverlay {
    pub default: Option<AbortPolicy>,
    pub regimes: std::collections::BTreeMap<String, AbortPolicy>,
}

impl AbortOverlay {
    /// The effective policy for one regime tag (see type docs).
    pub fn resolve(&self, tag: &str) -> AbortPolicy {
        self.regimes
            .get(tag)
            .or(self.default.as_ref())
            .cloned()
            .unwrap_or_default()
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("policy_version", Json::from(POLICY_VERSION)),
            ("kind", Json::from("abort-policy")),
            (
                "regimes",
                Json::Obj(
                    self.regimes
                        .iter()
                        .map(|(k, p)| (k.clone(), p.to_json()))
                        .collect(),
                ),
            ),
        ];
        if let Some(d) = &self.default {
            pairs.push(("default", d.to_json()));
        }
        Json::obj(pairs)
    }

    pub fn parse(text: &str) -> Result<AbortOverlay> {
        let j = Json::parse(text)?;
        let version = j.get("policy_version")?.as_usize()?;
        if version != POLICY_VERSION {
            return Err(FxpError::config(format!(
                "abort-policy overlay has policy_version {version}, this \
                 build expects {POLICY_VERSION}; regenerate it with \
                 `fxpnet report --suggest-thresholds`"
            )));
        }
        let mut regimes = std::collections::BTreeMap::new();
        for (tag, p) in j.get("regimes")?.as_obj()? {
            regimes.insert(tag.clone(), AbortPolicy::from_json(p)?);
        }
        let default = match j.opt("default") {
            Some(d) => Some(AbortPolicy::from_json(d)?),
            None => None,
        };
        Ok(AbortOverlay { default, regimes })
    }

    pub fn load(path: &str) -> Result<AbortOverlay> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            FxpError::config(format!("abort-policy overlay {path}: {e}"))
        })?;
        AbortOverlay::parse(&text).map_err(|e| {
            FxpError::config(format!("abort-policy overlay {path}: {e}"))
        })
    }
}

/// Consecutive-window state for one run under a policy.
struct AbortWatch<'a> {
    policy: &'a AbortPolicy,
    blowup_run: usize,
    sat_run: usize,
    collapse_run: usize,
}

impl<'a> AbortWatch<'a> {
    fn new(policy: &'a AbortPolicy) -> AbortWatch<'a> {
        AbortWatch { policy, blowup_run: 0, sat_run: 0, collapse_run: 0 }
    }

    /// Feed one step's stats; `Some(reason)` when a predicate fires.
    /// `step_in_run` counts from 1 within this `run_session_with` call.
    fn observe(
        &mut self,
        step_in_run: usize,
        st: &StepStats,
        first_losses: &[f32],
    ) -> Option<AbortReason> {
        let p = self.policy;
        if step_in_run <= p.min_steps || first_losses.is_empty() {
            return None;
        }
        let start =
            first_losses.iter().sum::<f32>() / first_losses.len() as f32;
        if st.loss > (start * p.blowup_factor).max(start + 1.0) {
            self.blowup_run += 1;
        } else {
            self.blowup_run = 0;
        }
        if self.blowup_run >= p.window {
            return Some(AbortReason::LossBlowup);
        }
        // saturation / collapse need real quantization telemetry; a
        // stats-less backend (loss-only records) degrades to the loss
        // predicates above
        let has_elems = st.layers.iter().any(|l| l.n_w + l.n_a > 0);
        if has_elems && st.sat_rate() > p.sat_rate {
            self.sat_run += 1;
        } else {
            self.sat_run = 0;
        }
        if self.sat_run >= p.window {
            return Some(AbortReason::Saturation);
        }
        match st.min_upd_to_step() {
            Some(r) if r < p.collapse_ratio => self.collapse_run += 1,
            _ => self.collapse_run = 0,
        }
        if self.collapse_run >= p.window {
            return Some(AbortReason::UpdateCollapse);
        }
        None
    }
}

/// Per-layer update mask builders (the `upd` input of `train_step`).
pub fn upd_all(num_layers: usize) -> Vec<f32> {
    vec![1.0; num_layers]
}

/// Proposal 2: only the top `k` layers update.
pub fn upd_top(num_layers: usize, k: usize) -> Vec<f32> {
    let mut v = vec![0.0; num_layers];
    for l in num_layers.saturating_sub(k)..num_layers {
        v[l] = 1.0;
    }
    v
}

/// Proposal 3 phases: exactly one layer updates.
pub fn upd_single(num_layers: usize, layer: usize) -> Vec<f32> {
    let mut v = vec![0.0; num_layers];
    v[layer] = 1.0;
    v
}

/// One in-progress fine-tuning run, behind either backend.
///
/// A session owns its parameter/momentum state and its data loader; the
/// regimes drive it through `step`/`set_config`/`reset_momenta` and read
/// the result back with `params`.  Divergence policy is not the
/// session's business -- [`run_session`] applies it identically to every
/// implementation.
pub trait TrainSession {
    /// One SGD step; returns the batch loss.
    fn step(&mut self) -> Result<f32>;

    /// Swap the quantization / update / lr configuration (phase change);
    /// parameter and momentum state is preserved.
    fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()>;

    /// Reset momenta to zero (used between Proposal 3 phases so stale
    /// velocity from the previous phase's layer does not leak).
    fn reset_momenta(&mut self) -> Result<()>;

    /// Read the current parameters back to the host.
    fn params(&self) -> Result<ParamSet>;

    /// Steps executed over the session's lifetime.
    fn global_step(&self) -> usize;

    /// Divergence threshold (loss above this, or NaN/Inf, is "n/a").
    fn max_loss(&self) -> f32;

    /// Turn per-step telemetry collection on/off.  Collection must never
    /// change the session's numerics or RNG streams; backends without
    /// telemetry ignore this (default).
    fn set_telemetry(&mut self, _on: bool) {}

    /// Stats of the most recent step, when the backend collects them
    /// (default: none -- `run_session_with` degrades to loss-only
    /// records).
    fn last_step_stats(&self) -> Option<&StepStats> {
        None
    }
}

/// Run `steps` steps of a session with divergence detection; records the
/// loss every `record_every` steps (and always the last).
///
/// "Diverged" (the paper's *fails to converge*, rendered `n/a` in the
/// tables) means any of:
/// * the loss goes NaN/Inf or exceeds the session's `max_loss` at any
///   step;
/// * for runs of >= 30 steps: the trailing-mean loss ends up clearly
///   *above* where the run started -- fine-tuning made the network
///   worse, which is exactly what happens when the mismatched gradients
///   point the wrong way (see results/gradient_mismatch_*).
pub fn run_session(
    s: &mut dyn TrainSession,
    steps: usize,
    record_every: usize,
) -> Result<TrainOutcome> {
    run_session_with(s, steps, record_every, None, None)
}

/// [`run_session`] with optional early abort and telemetry capture.
///
/// * `policy` -- when set, the windowed [`AbortPolicy`] predicates end a
///   doomed run early with `aborted = Some((reason, global_step))`; the
///   NaN/max-loss divergence of the base loop is then reported as
///   [`AbortReason::NanLoss`] (same step, same trajectory: telemetry
///   collection changes no numerics, so the run is bit-identical to the
///   no-policy run up to the abort step).
/// * `sink` -- when set, receives one [`StepStats`] per executed step.
///   Backends without telemetry produce loss-only records.
///
/// With both `None` this *is* `run_session`, byte for byte.
pub fn run_session_with(
    s: &mut dyn TrainSession,
    steps: usize,
    record_every: usize,
    policy: Option<&AbortPolicy>,
    mut sink: Option<&mut TelemetryLog>,
) -> Result<TrainOutcome> {
    let max_loss = s.max_loss();
    let collect = policy.is_some() || sink.is_some();
    s.set_telemetry(collect);
    let mut watch = policy.map(AbortWatch::new);
    let mut history = Vec::new();
    let mut first_losses: Vec<f32> = Vec::new();
    let mut tail: std::collections::VecDeque<f32> =
        std::collections::VecDeque::with_capacity(8);
    for i in 0..steps {
        let loss = s.step()?;
        if first_losses.len() < 5 {
            first_losses.push(loss);
        }
        if tail.len() == 8 {
            tail.pop_front();
        }
        tail.push_back(loss);
        if i % record_every.max(1) == 0 || i + 1 == steps {
            history.push((s.global_step(), loss));
        }
        let stats = if collect {
            Some(s.last_step_stats().cloned().unwrap_or_else(|| StepStats {
                step: s.global_step(),
                loss,
                layers: Vec::new(),
            }))
        } else {
            None
        };
        if let (Some(log), Some(st)) = (sink.as_deref_mut(), stats.as_ref()) {
            log.push(st.clone());
        }
        if !loss.is_finite() || loss > max_loss {
            log::warn!(
                "diverged at step {} (loss {loss}): marking n/a",
                s.global_step()
            );
            let aborted =
                policy.map(|_| (AbortReason::NanLoss, s.global_step()));
            return Ok(TrainOutcome {
                history,
                diverged: true,
                steps: i + 1,
                aborted,
            });
        }
        if let (Some(w), Some(st)) = (watch.as_mut(), stats.as_ref()) {
            if let Some(reason) = w.observe(i + 1, st, &first_losses) {
                log::warn!(
                    "abort policy fired at step {} ({}): ending run early",
                    s.global_step(),
                    reason.as_str()
                );
                return Ok(TrainOutcome {
                    history,
                    diverged: true,
                    steps: i + 1,
                    aborted: Some((reason, s.global_step())),
                });
            }
        }
    }
    if steps >= 30 {
        let start =
            first_losses.iter().sum::<f32>() / first_losses.len().max(1) as f32;
        let end = tail.iter().sum::<f32>() / tail.len().max(1) as f32;
        if end > (start * 1.3).max(start + 0.7) {
            log::warn!(
                "failed to converge: loss {start:.3} -> {end:.3} over {steps} \
                 steps; marking n/a"
            );
            return Ok(TrainOutcome {
                history,
                diverged: true,
                steps,
                aborted: None,
            });
        }
    }
    Ok(TrainOutcome { history, diverged: false, steps, aborted: None })
}

pub struct Trainer {
    exe: Rc<Executable>,
    arch: ArchSpec,
    loader: Loader,
    /// params (2L) followed by momenta (2L), as literals
    state: Vec<xla::Literal>,
    /// w cfg (4) + a cfg (4) + upd + lr + mu, as literals
    cfg: Vec<xla::Literal>,
    pub max_loss: f32,
    step: usize,
}

fn vec_lit(v: &[f32]) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[v.len()], v.to_vec())?))
}

fn scalar_lit(v: f32) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[1], vec![v])?))
}

impl Trainer {
    /// Build a trainer for `arch` starting from `params` (momenta zero).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        engine: &Engine,
        arch_name: &str,
        params: &ParamSet,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
        data: Dataset,
        loader_cfg: LoaderCfg,
        max_loss: f32,
    ) -> Result<Trainer> {
        let arch = engine.manifest.arch(arch_name)?.clone();
        if loader_cfg.batch != arch.train_batch {
            return Err(FxpError::config(format!(
                "loader batch {} != arch train batch {}",
                loader_cfg.batch, arch.train_batch
            )));
        }
        let exe = engine.executable(arch_name, "train_step")?;
        let mut state = Vec::with_capacity(2 * params.len());
        for t in &params.tensors {
            state.push(to_literal(&HostValue::F32(t.clone()))?);
        }
        for t in &params.tensors {
            state.push(to_literal(&HostValue::F32(Tensor::zeros(t.shape())))?);
        }
        let cfg = Self::build_cfg(nq, upd, lr, momentum)?;
        let loader = Loader::spawn(data, loader_cfg);
        Ok(Trainer { exe, arch, loader, state, cfg, max_loss, step: 0 })
    }

    fn build_cfg(
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<Vec<xla::Literal>> {
        let v = nq.vectors();
        Ok(vec![
            vec_lit(&v.w_step)?,
            vec_lit(&v.w_lo)?,
            vec_lit(&v.w_hi)?,
            vec_lit(&v.w_en)?,
            vec_lit(&v.a_step)?,
            vec_lit(&v.a_lo)?,
            vec_lit(&v.a_hi)?,
            vec_lit(&v.a_en)?,
            vec_lit(upd)?,
            scalar_lit(lr)?,
            scalar_lit(momentum)?,
        ])
    }

    /// Swap the quantization / update / lr configuration (phase change);
    /// parameter and momentum state is preserved.
    pub fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()> {
        self.cfg = Self::build_cfg(nq, upd, lr, momentum)?;
        Ok(())
    }

    /// Reset momenta to zero (used between Proposal 3 phases so stale
    /// velocity from the previous phase's layer does not leak).
    pub fn reset_momenta(&mut self) -> Result<()> {
        let n = self.state.len() / 2;
        for i in 0..n {
            let spec = &self.exe.spec.inputs[n + i];
            self.state[n + i] =
                to_literal(&HostValue::F32(Tensor::zeros(&spec.shape)))?;
        }
        Ok(())
    }

    pub fn global_step(&self) -> usize {
        self.step
    }

    /// One SGD step; returns the batch loss.
    pub fn step(&mut self) -> Result<f32> {
        let batch = self.loader.next_batch();
        let x = to_literal(&HostValue::F32(batch.images))?;
        let y = to_literal(&HostValue::I32(batch.labels))?;
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(
            self.state.len() + 2 + self.cfg.len(),
        );
        inputs.extend(self.state.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(self.cfg.iter());
        let mut outs = self.exe.run_literals(&inputs)?;
        let loss_lit = outs.pop().expect("train_step outputs");
        let loss: f32 = loss_lit.get_first_element()?;
        self.state = outs;
        self.step += 1;
        Ok(loss)
    }

    /// Run `steps` steps with divergence detection (see [`run_session`],
    /// which owns the shared policy).
    pub fn run(&mut self, steps: usize, record_every: usize) -> Result<TrainOutcome> {
        run_session(self, steps, record_every)
    }

    /// Read the current parameters back to the host.
    pub fn params(&self) -> Result<ParamSet> {
        let n = self.state.len() / 2;
        let mut names = Vec::with_capacity(n);
        let mut tensors = Vec::with_capacity(n);
        for i in 0..n {
            let spec = &self.exe.spec.inputs[i];
            names.push(spec.name.clone());
            let data = self.state[i].to_vec::<f32>()?;
            tensors.push(Tensor::from_vec(&spec.shape, data)?);
        }
        Ok(ParamSet { names, tensors })
    }

    pub fn arch(&self) -> &ArchSpec {
        &self.arch
    }
}

impl TrainSession for Trainer {
    fn step(&mut self) -> Result<f32> {
        Trainer::step(self)
    }

    fn set_config(
        &mut self,
        nq: &NetQuant,
        upd: &[f32],
        lr: f32,
        momentum: f32,
    ) -> Result<()> {
        Trainer::set_config(self, nq, upd, lr, momentum)
    }

    fn reset_momenta(&mut self) -> Result<()> {
        Trainer::reset_momenta(self)
    }

    fn params(&self) -> Result<ParamSet> {
        Trainer::params(self)
    }

    fn global_step(&self) -> usize {
        self.step
    }

    fn max_loss(&self) -> f32 {
        self.max_loss
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::train::telemetry::LayerStepStats;

    /// Loss-scripted stand-in session (no engine, no net).
    struct Scripted {
        losses: Vec<f32>,
        /// per-step layer stats; recycled cyclically when shorter than
        /// the loss script
        layers: Vec<Vec<LayerStepStats>>,
        step: usize,
        last: Option<StepStats>,
        telemetry: bool,
    }

    impl Scripted {
        fn new(losses: Vec<f32>) -> Scripted {
            Scripted { losses, layers: Vec::new(), step: 0, last: None, telemetry: false }
        }
    }

    impl TrainSession for Scripted {
        fn step(&mut self) -> Result<f32> {
            let loss = self.losses[self.step % self.losses.len()];
            self.step += 1;
            if self.telemetry {
                let layers = if self.layers.is_empty() {
                    Vec::new()
                } else {
                    self.layers[(self.step - 1) % self.layers.len()].clone()
                };
                self.last = Some(StepStats { step: self.step, loss, layers });
            }
            Ok(loss)
        }
        fn set_config(&mut self, _: &NetQuant, _: &[f32], _: f32, _: f32) -> Result<()> {
            Ok(())
        }
        fn reset_momenta(&mut self) -> Result<()> {
            Ok(())
        }
        fn params(&self) -> Result<ParamSet> {
            Ok(ParamSet { names: Vec::new(), tensors: Vec::new() })
        }
        fn global_step(&self) -> usize {
            self.step
        }
        fn max_loss(&self) -> f32 {
            30.0
        }
        fn set_telemetry(&mut self, on: bool) {
            self.telemetry = on;
        }
        fn last_step_stats(&self) -> Option<&StepStats> {
            self.last.as_ref()
        }
    }

    fn outcome(history: &[f32]) -> TrainOutcome {
        TrainOutcome {
            history: history.iter().enumerate().map(|(i, &l)| (i + 1, l)).collect(),
            diverged: false,
            steps: history.len(),
            aborted: None,
        }
    }

    /// Window semantics at the boundary: with fewer than `n` samples the
    /// tail is the whole history, each sample counted exactly once -- a
    /// short history must never weight any sample twice.
    #[test]
    fn tail_mean_window_boundary() {
        let o = outcome(&[1.0, 2.0, 3.0]);
        // n > len: plain mean of all three, each counted once
        assert_eq!(o.tail_mean(5), 2.0);
        assert_eq!(o.tail_mean(3), 2.0);
        // n < len: exactly the last n
        assert_eq!(o.tail_mean(2), 2.5);
        assert_eq!(o.tail_mean(1), 3.0);
        // degenerate windows are NaN, not a panic or a fake 0
        assert!(o.tail_mean(0).is_nan());
        assert!(outcome(&[]).tail_mean(4).is_nan());
    }

    #[test]
    fn abort_reason_strings_round_trip() {
        for r in [
            AbortReason::NanLoss,
            AbortReason::LossBlowup,
            AbortReason::Saturation,
            AbortReason::UpdateCollapse,
        ] {
            assert_eq!(AbortReason::parse(r.as_str()), Some(r));
        }
        assert_eq!(AbortReason::parse("bogus"), None);
    }

    #[test]
    fn policy_none_matches_legacy_loop() {
        let losses: Vec<f32> = (0..40).map(|i| 2.0 - 0.01 * i as f32).collect();
        let a = run_session(&mut Scripted::new(losses.clone()), 40, 10).unwrap();
        let b = run_session_with(&mut Scripted::new(losses), 40, 10, None, None)
            .unwrap();
        assert_eq!(a.history, b.history);
        assert_eq!(a.diverged, b.diverged);
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.aborted, None);
        assert_eq!(b.aborted, None);
    }

    #[test]
    fn nan_loss_becomes_abort_under_policy() {
        let mut losses = vec![2.0f32; 12];
        losses[7] = f32::NAN;
        let policy = AbortPolicy::default();
        let out = run_session_with(
            &mut Scripted::new(losses.clone()),
            12,
            1,
            Some(&policy),
            None,
        )
        .unwrap();
        assert!(out.diverged);
        assert_eq!(out.steps, 8);
        assert_eq!(out.aborted, Some((AbortReason::NanLoss, 8)));
        // without a policy: same step, same divergence, no abort record
        let legacy = run_session(&mut Scripted::new(losses), 12, 1).unwrap();
        assert!(legacy.diverged);
        assert_eq!(legacy.steps, 8);
        assert_eq!(legacy.aborted, None);
        assert_eq!(legacy.history, out.history);
    }

    #[test]
    fn sustained_blowup_aborts_after_window_not_before() {
        // healthy start, then the loss parks at 4x the baseline (but
        // under max_loss, so only the sustained predicate can see it)
        let mut losses = vec![2.0f32; 5];
        losses.extend(vec![8.0f32; 60]);
        let policy = AbortPolicy::default();
        let out = run_session_with(
            &mut Scripted::new(losses),
            60,
            1,
            Some(&policy),
            None,
        )
        .unwrap();
        assert!(out.diverged);
        assert_eq!(out.aborted.map(|(r, _)| r), Some(AbortReason::LossBlowup));
        // inert through min_steps, then needs `window` consecutive hits
        let step = out.aborted.unwrap().1;
        assert_eq!(step, policy.min_steps + policy.window);
        assert_eq!(out.steps, step);
    }

    #[test]
    fn saturation_and_collapse_predicates_fire_on_stats() {
        let sat_layer = LayerStepStats {
            active: true,
            quantized: true,
            grad_l2: 1.0,
            update_l2: 0.1,
            upd_to_step: 0.5,
            sat_w: 90,
            sat_a: 0,
            n_w: 100,
            n_a: 0,
        };
        let mut s = Scripted::new(vec![2.0]);
        s.layers = vec![vec![sat_layer.clone()]];
        let policy = AbortPolicy::default();
        let out = run_session_with(&mut s, 60, 1, Some(&policy), None).unwrap();
        assert_eq!(
            out.aborted.map(|(r, _)| r),
            Some(AbortReason::Saturation)
        );
        assert_eq!(out.aborted.unwrap().1, policy.min_steps + policy.window);

        let collapsed = LayerStepStats {
            upd_to_step: 1e-5,
            sat_w: 0,
            ..sat_layer
        };
        let mut s = Scripted::new(vec![2.0]);
        s.layers = vec![vec![collapsed]];
        let out = run_session_with(&mut s, 60, 1, Some(&policy), None).unwrap();
        assert_eq!(
            out.aborted.map(|(r, _)| r),
            Some(AbortReason::UpdateCollapse)
        );

        // a healthy profile never trips anything
        let healthy = LayerStepStats {
            active: true,
            quantized: true,
            grad_l2: 1.0,
            update_l2: 0.1,
            upd_to_step: 0.3,
            sat_w: 1,
            sat_a: 2,
            n_w: 100,
            n_a: 1000,
        };
        let mut s = Scripted::new(vec![2.0, 1.9, 1.8]);
        s.layers = vec![vec![healthy]];
        let out = run_session_with(&mut s, 60, 1, Some(&policy), None).unwrap();
        assert_eq!(out.aborted, None);
        assert!(!out.diverged);
    }

    #[test]
    fn abort_overlay_resolution_and_round_trip() {
        let tuned = AbortPolicy {
            window: 12,
            min_steps: 30,
            blowup_factor: 4.5,
            sat_rate: 0.7,
            collapse_ratio: 2.5e-4,
        };
        let mut overlay = AbortOverlay::default();
        overlay.regimes.insert("vanilla".into(), tuned.clone());
        // exact regime entry wins; unknown tags fall through to the
        // built-in default when the overlay has none of its own
        assert_eq!(overlay.resolve("vanilla").window, 12);
        assert_eq!(overlay.resolve("prop3").window, AbortPolicy::default().window);
        overlay.default = Some(AbortPolicy { window: 99, ..tuned.clone() });
        assert_eq!(overlay.resolve("prop3").window, 99);
        assert_eq!(overlay.resolve("vanilla").window, 12);

        let text = overlay.to_json().to_string();
        let back = AbortOverlay::parse(&text).unwrap();
        assert_eq!(back, overlay);
        assert_eq!(
            back.resolve("vanilla").fingerprint_words(),
            tuned.fingerprint_words()
        );
    }

    #[test]
    fn abort_overlay_refuses_wrong_version() {
        let mut j = AbortOverlay::default().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("policy_version".into(), Json::from(POLICY_VERSION + 1));
        }
        let err = AbortOverlay::parse(&j.to_string()).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("policy_version"), "{msg}");
        // and files missing the stamp entirely are refused too
        assert!(AbortOverlay::parse("{}").is_err());
    }

    #[test]
    fn telemetry_sink_records_every_step() {
        let mut s = Scripted::new(vec![2.0, 1.5, 1.0, 0.5]);
        let mut log = TelemetryLog::default();
        let out =
            run_session_with(&mut s, 4, 2, None, Some(&mut log)).unwrap();
        assert_eq!(out.steps, 4);
        assert_eq!(log.len(), 4);
        assert_eq!(log.steps[2].step, 3);
        assert_eq!(log.steps[2].loss, 1.0);
        // stats-less backends produce loss-only records
        assert!(log.steps.iter().all(|st| st.layers.is_empty()));
    }
}
