//! The five experiment regimes behind the paper's Tables 2-6.
//!
//! Every regime answers: given the pretrained float network, what are
//! the parameters and the quantization configuration we finally evaluate
//! for grid cell (weight width, activation width)?
//!
//! * `NoFinetune`  (Table 2): quantize, evaluate.
//! * `Vanilla`     (Table 3): fine-tune all layers under the cell's full
//!   quantization; divergence -> n/a.
//! * `Prop1`       (Table 4): take the float-activation fine-tuned net
//!   for this weight width ("the last row of Table 3") and just switch
//!   on activation quantization at eval.
//! * `Prop2`       (Table 5): from the Prop1 net, fine-tune only the top
//!   layer(s) under full quantization.
//! * `Prop3`       (Table 6): from the Prop1 net, run the Table 1
//!   bottom-to-top phase schedule, then evaluate fully quantized.

use crate::coordinator::backend::{Backend, SessionCfg};
use crate::coordinator::config::RunCfg;
use crate::coordinator::evaluator::EvalResult;
use crate::coordinator::phases;
use crate::coordinator::trainer::{
    run_session, run_session_with, upd_all, upd_single, upd_top, AbortPolicy,
    AbortReason, TrainSession,
};
use crate::data::loader::LoaderCfg;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::model::params::ParamSet;
use crate::quant::calib::LayerStats;
use crate::quant::policy::{NetQuant, WidthSpec};
use crate::train::telemetry::{TelemetryLog, TelemetrySummary};
use crate::util::rng::derive_seed;

/// Regime selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Regime {
    NoFinetune,
    Vanilla,
    Prop1,
    Prop2 { top_layers: usize },
    Prop3,
}

impl Regime {
    pub fn parse(s: &str) -> Option<Regime> {
        match s {
            "none" | "noft" => Some(Regime::NoFinetune),
            "vanilla" => Some(Regime::Vanilla),
            "prop1" => Some(Regime::Prop1),
            "prop2" => Some(Regime::Prop2 { top_layers: 1 }),
            "prop3" => Some(Regime::Prop3),
            _ => None,
        }
    }

    /// Canonical short tag: the primary `parse` spelling.  Keys the
    /// per-regime entries of an
    /// [`AbortOverlay`](crate::coordinator::trainer::AbortOverlay) and
    /// the regime field of stability reports, so it must stay stable.
    pub fn tag(&self) -> &'static str {
        match self {
            Regime::NoFinetune => "none",
            Regime::Vanilla => "vanilla",
            Regime::Prop1 => "prop1",
            Regime::Prop2 { .. } => "prop2",
            Regime::Prop3 => "prop3",
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Regime::NoFinetune => "no fine-tuning (Table 2)",
            Regime::Vanilla => "vanilla fine-tuning (Table 3)",
            Regime::Prop1 => "Proposal 1 (Table 4)",
            Regime::Prop2 { .. } => "Proposal 2 (Table 5)",
            Regime::Prop3 => "Proposal 3 (Table 6)",
        }
    }

    /// Which paper table this regime regenerates.
    pub fn table_number(&self) -> usize {
        match self {
            Regime::NoFinetune => 2,
            Regime::Vanilla => 3,
            Regime::Prop1 => 4,
            Regime::Prop2 { .. } => 5,
            Regime::Prop3 => 6,
        }
    }

    /// Stable tag for seed derivation.  Also folds in `top_layers` so
    /// Proposal 2 variants get distinct streams.
    pub fn seed_tag(&self) -> u64 {
        match self {
            Regime::Prop2 { top_layers } => 5 | ((*top_layers as u64) << 8),
            other => other.table_number() as u64,
        }
    }

    /// Inverse of [`Regime::seed_tag`] -- reconstructs the regime from a
    /// cell-cache or sweep-manifest header, so `grid merge` can render a
    /// merged table without being told the regime again.
    pub fn from_seed_tag(tag: u64) -> Option<Regime> {
        match tag {
            2 => Some(Regime::NoFinetune),
            3 => Some(Regime::Vanilla),
            4 => Some(Regime::Prop1),
            6 => Some(Regime::Prop3),
            t if t & 0xff == 5 && t >> 8 > 0 => {
                Some(Regime::Prop2 { top_layers: (t >> 8) as usize })
            }
            _ => None,
        }
    }

    /// True for the regimes seeded by the float-activation fine-tuned net
    /// ("the last row of Table 3").
    pub fn needs_p1_net(&self) -> bool {
        matches!(self, Regime::Prop1 | Regime::Prop2 { .. } | Regime::Prop3)
    }
}

/// Everything the regimes need to run one cell.
pub struct CellCtx<'a> {
    /// The training/evaluation engine (native or XLA) -- the regimes are
    /// backend-agnostic and execute identically on either.
    pub backend: &'a dyn Backend,
    pub arch: &'a str,
    pub train_data: &'a Dataset,
    pub eval_data: &'a Dataset,
    /// activation stats of the pretrained float net
    pub a_stats: &'a [LayerStats],
    pub cfg: &'a RunCfg,
    /// Cell-scoped seed (see `grid::cell_seed` / `grid::p1_seed`): a pure
    /// function of `(base seed, regime, weight width, activation width)`,
    /// never of worker identity or scheduling order, so parallel sweeps
    /// replay the serial runner bit-for-bit.
    pub cell_seed: u64,
}

impl<'a> CellCtx<'a> {
    fn loader_cfg(&self, tag: u64) -> Result<LoaderCfg> {
        let spec = self.backend.arch(self.arch)?;
        Ok(LoaderCfg {
            batch: spec.train_batch,
            augment: self.cfg.augment,
            max_shift: 2,
            seed: derive_seed(self.cell_seed, "loader", &[tag]),
        })
    }

    /// Resolve the cell's full quantization against `params`' weights.
    pub fn resolve(
        &self,
        params: &ParamSet,
        w: WidthSpec,
        a: WidthSpec,
    ) -> Result<NetQuant> {
        let w_stats = params.weight_stats();
        NetQuant::for_cell(w, a, &w_stats, self.a_stats, self.cfg.method)
    }

    fn trainer(
        &self,
        params: &ParamSet,
        nq: &NetQuant,
        upd: &[f32],
        tag: u64,
    ) -> Result<Box<dyn TrainSession>> {
        self.backend.new_session(SessionCfg {
            arch: self.arch,
            params,
            nq,
            upd,
            lr: self.cfg.lr,
            momentum: self.cfg.momentum,
            data: self.train_data.clone(),
            loader: self.loader_cfg(tag)?,
            max_loss: self.cfg.max_loss,
            // the native engine's stochastic weight-update rounding
            // stream: keyed by the cell and the regime's stream tag,
            // like every other per-cell stochastic stream
            seed: derive_seed(self.cell_seed, "sgd-round", &[tag]),
            threads: self.cfg.threads,
        })
    }

    fn evaluate(&self, params: &ParamSet, nq: &NetQuant) -> Result<EvalResult> {
        self.backend.evaluate(self.arch, params, nq, self.eval_data)
    }

    /// The cell's early-abort policy: the regime's resolved thresholds
    /// (built-in defaults, or an `--abort-policy` overlay entry) when
    /// `cfg.early_abort` is on, `None` (reference full-run path) under
    /// `--no-early-abort`.
    pub fn abort_policy(&self, regime: Regime) -> Option<AbortPolicy> {
        self.cfg.abort_policy(regime.tag())
    }
}

/// Outcome of one grid cell.
///
/// `Na` covers the legacy divergence outcome (NaN / runaway loss with no
/// abort policy, or a missing Prop1 seed net); `Aborted` records the
/// abort policy ending a doomed cell early, with the predicate that
/// fired and the global step it fired at.  Both render as a miss in the
/// paper tables (`Aborted` shows "div@{step}" in the text table, and
/// both serialize as `null` metrics in the table JSON, so a sweep with
/// early abort produces byte-identical table JSON to the reference
/// full-run sweep for every cell that completes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CellEval {
    Ok(EvalResult),
    Na,
    Aborted { reason: AbortReason, step: usize },
}

/// Historic alias (PR 4 used `Option<EvalResult>`; `CellEval::Na` now
/// plays `None`'s role).
pub type CellResult = CellEval;

impl CellEval {
    /// The evaluation metrics, when the cell completed.
    pub fn ok(self) -> Option<EvalResult> {
        match self {
            CellEval::Ok(e) => Some(e),
            _ => None,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, CellEval::Ok(_))
    }
}

/// Run one grid cell under `regime`.
///
/// The single dispatch shared by the serial `GridRunner` and the
/// parallel sweep engine, so both execute byte-identical logic.  `p1` is
/// the float-activation fine-tuned net for the cell's weight width
/// (required by Proposals 1-3; `None` there means that seed training
/// itself diverged, which makes the whole cell `n/a`).
pub fn dispatch_cell(
    ctx: &CellCtx,
    regime: Regime,
    base: &ParamSet,
    p1: Option<&ParamSet>,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<CellResult> {
    Ok(dispatch_cell_full(ctx, regime, base, p1, w, a)?.0)
}

/// [`dispatch_cell`] plus the cell's stability-telemetry digest.
///
/// Training regimes (vanilla, Proposals 2/3) always collect per-step
/// telemetry -- collection never changes the numerics (the PR 6
/// determinism contract, pinned in `rust/tests/train_native.rs`) -- and
/// return its [`TelemetrySummary`].  Evaluation-only cells (no-finetune,
/// Proposal 1, float-activation Proposal 3) train nothing and return
/// `None`.
pub fn dispatch_cell_full(
    ctx: &CellCtx,
    regime: Regime,
    base: &ParamSet,
    p1: Option<&ParamSet>,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<(CellResult, Option<TelemetrySummary>)> {
    match regime {
        Regime::NoFinetune => Ok((run_no_finetune(ctx, base, w, a)?, None)),
        Regime::Vanilla => run_vanilla(ctx, base, w, a),
        Regime::Prop1 | Regime::Prop2 { .. } | Regime::Prop3 => match p1 {
            None => Ok((CellEval::Na, None)), // seed training itself diverged
            Some(p1) => match regime {
                Regime::Prop1 => Ok((run_prop1(ctx, p1, w, a)?, None)),
                Regime::Prop2 { top_layers } => {
                    run_prop2(ctx, p1, w, a, top_layers)
                }
                Regime::Prop3 => {
                    // float activations: nothing to schedule; the p1 net
                    // already IS the answer (matches the paper: the Float
                    // row repeats across Tables 4-6)
                    if a == WidthSpec::Float {
                        Ok((run_prop1(ctx, p1, w, a)?, None))
                    } else {
                        run_prop3(ctx, p1, w, a)
                    }
                }
                _ => unreachable!(),
            },
        },
    }
}

/// Table 2: quantize the pretrained net, no fine-tuning.
pub fn run_no_finetune(
    ctx: &CellCtx,
    base: &ParamSet,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<CellResult> {
    let nq = ctx.resolve(base, w, a)?;
    Ok(CellEval::Ok(ctx.evaluate(base, &nq)?))
}

/// Table 3: plain fine-tuning of all layers under the cell's config.
/// Returns the eval outcome plus the run's telemetry digest.
pub fn run_vanilla(
    ctx: &CellCtx,
    base: &ParamSet,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<(CellResult, Option<TelemetrySummary>)> {
    let nq = ctx.resolve(base, w, a)?;
    let l = nq.num_layers();
    let mut tr = ctx.trainer(base, &nq, &upd_all(l), 3)?;
    let policy = ctx.abort_policy(Regime::Vanilla);
    let mut tlog = TelemetryLog::default();
    let out = run_session_with(
        &mut *tr,
        ctx.cfg.finetune_steps,
        10,
        policy.as_ref(),
        Some(&mut tlog),
    )?;
    let summary = TelemetrySummary::summarize(&tlog);
    if let Some((reason, step)) = out.aborted {
        return Ok((CellEval::Aborted { reason, step }, summary));
    }
    if out.diverged {
        return Ok((CellEval::Na, summary));
    }
    let tuned = tr.params()?;
    // re-resolve weight formats against the *tuned* weights for eval
    let nq_eval = ctx.resolve(&tuned, w, a)?;
    Ok((CellEval::Ok(ctx.evaluate(&tuned, &nq_eval)?), summary))
}

/// The "last row of Table 3": fine-tune with quantized weights but float
/// activations.  These nets seed Proposals 1-3; the grid runner caches
/// one per weight width.
pub fn train_float_act_net(
    ctx: &CellCtx,
    base: &ParamSet,
    w: WidthSpec,
) -> Result<Option<ParamSet>> {
    if w == WidthSpec::Float {
        return Ok(Some(base.clone()));
    }
    let nq = ctx.resolve(base, w, WidthSpec::Float)?;
    let l = nq.num_layers();
    let mut tr = ctx.trainer(base, &nq, &upd_all(l), 5)?;
    let out = run_session(&mut *tr, ctx.cfg.finetune_steps, 10)?;
    if out.diverged {
        return Ok(None);
    }
    Ok(Some(tr.params()?))
}

/// Table 4 (Proposal 1): evaluate the float-activation net with the
/// cell's activation quantization switched on post-hoc.
pub fn run_prop1(
    ctx: &CellCtx,
    p1net: &ParamSet,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<CellResult> {
    let nq = ctx.resolve(p1net, w, a)?;
    Ok(CellEval::Ok(ctx.evaluate(p1net, &nq)?))
}

/// Table 5 (Proposal 2): from the Prop1 net, fine-tune only the top
/// `top_layers` layers under the full cell config.
pub fn run_prop2(
    ctx: &CellCtx,
    p1net: &ParamSet,
    w: WidthSpec,
    a: WidthSpec,
    top_layers: usize,
) -> Result<(CellResult, Option<TelemetrySummary>)> {
    let nq = ctx.resolve(p1net, w, a)?;
    let l = nq.num_layers();
    let mut tr = ctx.trainer(p1net, &nq, &upd_top(l, top_layers), 7)?;
    let policy = ctx.abort_policy(Regime::Prop2 { top_layers });
    let mut tlog = TelemetryLog::default();
    let out = run_session_with(
        &mut *tr,
        ctx.cfg.finetune_steps,
        10,
        policy.as_ref(),
        Some(&mut tlog),
    )?;
    let summary = TelemetrySummary::summarize(&tlog);
    if let Some((reason, step)) = out.aborted {
        return Ok((CellEval::Aborted { reason, step }, summary));
    }
    if out.diverged {
        return Ok((CellEval::Na, summary));
    }
    let tuned = tr.params()?;
    let nq_eval = ctx.resolve(&tuned, w, a)?;
    Ok((CellEval::Ok(ctx.evaluate(&tuned, &nq_eval)?), summary))
}

/// Table 6 (Proposal 3): the Table 1 schedule from the Prop1 net.
pub fn run_prop3(
    ctx: &CellCtx,
    p1net: &ParamSet,
    w: WidthSpec,
    a: WidthSpec,
) -> Result<(CellResult, Option<TelemetrySummary>)> {
    let full = ctx.resolve(p1net, w, a)?;
    let l = full.num_layers();
    let sched = phases::schedule(l);
    // start from phase 1's configuration
    let mut tr = {
        let p = sched[0];
        let nq = full.with_act_prefix(p.act_prefix);
        ctx.trainer(p1net, &nq, &upd_single(l, p.update_layer), 11)?
    };
    let policy = ctx.abort_policy(Regime::Prop3);
    // one log across all phases: global steps keep counting, so the
    // summary windows span the whole schedule
    let mut tlog = TelemetryLog::default();
    for (i, p) in sched.iter().enumerate() {
        if i > 0 {
            let nq = full.with_act_prefix(p.act_prefix);
            tr.set_config(
                &nq,
                &upd_single(l, p.update_layer),
                ctx.cfg.lr,
                ctx.cfg.momentum,
            )?;
            tr.reset_momenta()?;
        }
        let out = run_session_with(
            &mut *tr,
            ctx.cfg.phase_steps,
            10,
            policy.as_ref(),
            Some(&mut tlog),
        )?;
        if let Some((reason, step)) = out.aborted {
            log::warn!("prop3 phase {} aborted ({})", p.number, reason.as_str());
            return Ok((
                CellEval::Aborted { reason, step },
                TelemetrySummary::summarize(&tlog),
            ));
        }
        if out.diverged {
            log::warn!("prop3 phase {} diverged", p.number);
            return Ok((CellEval::Na, TelemetrySummary::summarize(&tlog)));
        }
    }
    let summary = TelemetrySummary::summarize(&tlog);
    let tuned = tr.params()?;
    let nq_eval = ctx.resolve(&tuned, w, a)?;
    Ok((CellEval::Ok(ctx.evaluate(&tuned, &nq_eval)?), summary))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_tags_distinct() {
        let tags: Vec<u64> = [
            Regime::NoFinetune,
            Regime::Vanilla,
            Regime::Prop1,
            Regime::Prop2 { top_layers: 1 },
            Regime::Prop2 { top_layers: 2 },
            Regime::Prop3,
        ]
        .iter()
        .map(|r| r.seed_tag())
        .collect();
        let mut uniq = tags.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), tags.len(), "{tags:?}");
        assert!(Regime::Prop2 { top_layers: 1 }.needs_p1_net());
        assert!(!Regime::Vanilla.needs_p1_net());
    }

    #[test]
    fn seed_tag_round_trips() {
        for r in [
            Regime::NoFinetune,
            Regime::Vanilla,
            Regime::Prop1,
            Regime::Prop2 { top_layers: 1 },
            Regime::Prop2 { top_layers: 3 },
            Regime::Prop3,
        ] {
            assert_eq!(Regime::from_seed_tag(r.seed_tag()), Some(r));
        }
        assert_eq!(Regime::from_seed_tag(0), None);
        assert_eq!(Regime::from_seed_tag(5), None); // Prop2 with 0 layers
        assert_eq!(Regime::from_seed_tag(999), None);
    }

    #[test]
    fn regime_tags_parse_back() {
        for r in [
            Regime::NoFinetune,
            Regime::Vanilla,
            Regime::Prop1,
            Regime::Prop2 { top_layers: 1 },
            Regime::Prop3,
        ] {
            // tag is the canonical parse spelling (Prop2 re-parses with
            // the default top_layers -- the tag keys overlay entries,
            // not the variant's parameters)
            assert_eq!(Regime::parse(r.tag()), Some(r));
        }
        assert_eq!(Regime::Prop2 { top_layers: 3 }.tag(), "prop2");
    }

    #[test]
    fn regime_parse_and_labels() {
        assert_eq!(Regime::parse("vanilla"), Some(Regime::Vanilla));
        assert_eq!(Regime::parse("prop2"), Some(Regime::Prop2 { top_layers: 1 }));
        assert_eq!(Regime::parse("bogus"), None);
        for (r, t) in [
            (Regime::NoFinetune, 2),
            (Regime::Vanilla, 3),
            (Regime::Prop1, 4),
            (Regime::Prop2 { top_layers: 1 }, 5),
            (Regime::Prop3, 6),
        ] {
            assert_eq!(r.table_number(), t);
            assert!(r.label().contains(&format!("Table {t}")));
        }
    }
}
