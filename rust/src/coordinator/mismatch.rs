//! Gradient-mismatch measurement (the paper's section 2.2 claim): compare
//! weight gradients computed through the float graph vs. through the
//! quantized(-STE) graph, layer by layer, using the `grads` executable.

use crate::data::loader::sequential_batches;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::model::params::ParamSet;
use crate::quant::calib::{CalibMethod, LayerStats};
use crate::quant::policy::{NetQuant, WidthSpec};
use crate::runtime::literal::{to_literal, HostValue};
use crate::runtime::Engine;
use crate::tensor::Tensor;

fn vec_lit(v: &[f32]) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[v.len()], v.to_vec())?))
}

fn cfg_lits(nq: &NetQuant) -> Result<Vec<xla::Literal>> {
    let v = nq.vectors();
    Ok(vec![
        vec_lit(&v.w_step)?,
        vec_lit(&v.w_lo)?,
        vec_lit(&v.w_hi)?,
        vec_lit(&v.w_en)?,
        vec_lit(&v.a_step)?,
        vec_lit(&v.a_lo)?,
        vec_lit(&v.a_hi)?,
        vec_lit(&v.a_en)?,
    ])
}

/// Per-layer cosine similarity between float-path and quantized-path
/// *weight* gradients at `bits`-wide weights and activations (logits kept
/// at 16-bit, the paper's protocol), averaged over one training batch.
#[allow(clippy::too_many_arguments)]
pub fn gradient_mismatch(
    engine: &Engine,
    arch: &str,
    params: &ParamSet,
    a_stats: &[LayerStats],
    data: &Dataset,
    bits: u8,
    method: CalibMethod,
) -> Result<Vec<f64>> {
    let spec = engine.manifest.arch(arch)?;
    let exe = engine.executable(arch, "grads")?;
    let l = spec.num_layers;

    let float_cfg = cfg_lits(&NetQuant::all_float(l))?;
    let q = NetQuant::for_cell(
        WidthSpec::Bits(bits),
        WidthSpec::Bits(bits),
        &params.weight_stats(),
        a_stats,
        method,
    )?;
    let quant_cfg = cfg_lits(&q)?;

    let param_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(|t| to_literal(&HostValue::F32(t.clone())))
        .collect::<Result<_>>()?;

    // one batch at the training batch size
    let (images, labels, _) = sequential_batches(data, spec.train_batch)?
        .into_iter()
        .next()
        .expect("dataset empty");
    let x = to_literal(&HostValue::F32(images))?;
    let y = to_literal(&HostValue::I32(labels))?;

    let run = |cfg: &[xla::Literal]| -> Result<Vec<xla::Literal>> {
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(param_lits.iter());
        inputs.push(&x);
        inputs.push(&y);
        inputs.extend(cfg.iter());
        exe.run_literals(&inputs)
    };
    let outs_f = run(&float_cfg)?;
    let outs_q = run(&quant_cfg)?;

    // outputs: loss, then g.<param> in param order; weight grads at 1 + 2l
    let mut cosines = Vec::with_capacity(l);
    for li in 0..l {
        let idx = 1 + 2 * li;
        let gf = exe.output_host(&outs_f, &exe.spec.outputs[idx].name)?.into_f32()?;
        let gq = exe.output_host(&outs_q, &exe.spec.outputs[idx].name)?.into_f32()?;
        cosines.push(gf.cosine(&gq)?);
    }
    Ok(cosines)
}
