//! Held-out evaluation: top-k error and mean loss under a quantization
//! configuration -- via the `eval_batch` executable (the float-simulated
//! XLA path) or via the pure-integer batched GEMM engine
//! ([`evaluate_int`]).

use crate::data::loader::sequential_batches;
use crate::data::synth::Dataset;
use crate::error::Result;
use crate::inference::{FixedPointNet, Scratch};
use crate::model::params::ParamSet;
use crate::quant::policy::NetQuant;
use crate::runtime::literal::{to_literal, HostValue};
use crate::runtime::Engine;
use crate::tensor::{Tensor, TensorF};

/// Evaluation result.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvalResult {
    pub n: usize,
    pub top1_err: f64,
    pub top5_err: f64,
    pub mean_loss: f64,
}

impl std::fmt::Display for EvalResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} top1 {:.2}% top5 {:.2}% loss {:.4}",
            self.n,
            self.top1_err * 100.0,
            self.top5_err * 100.0,
            self.mean_loss
        )
    }
}

fn vec_lit(v: &[f32]) -> Result<xla::Literal> {
    to_literal(&HostValue::F32(Tensor::from_vec(&[v.len()], v.to_vec())?))
}

/// Accumulate (top-1 misses, top-5 misses, summed softmax NLL) over the
/// first `valid` rows of a (n, classes) logit matrix -- the one metric
/// loop shared by the XLA eval path and the integer-engine path.
fn accumulate_metrics(
    logits: &TensorF,
    labels: &[i32],
    valid: usize,
) -> Result<(usize, usize, f64)> {
    let nc = logits.shape()[1];
    let topk = logits.topk_rows(5)?;
    let mut top1_wrong = 0usize;
    let mut top5_wrong = 0usize;
    let mut loss_sum = 0f64;
    for i in 0..valid {
        let y = labels[i] as usize;
        if topk[i][0] != y {
            top1_wrong += 1;
        }
        if !topk[i].contains(&y) {
            top5_wrong += 1;
        }
        // host-side softmax NLL
        let row = &logits.data()[i * nc..(i + 1) * nc];
        let m = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let z: f64 = row.iter().map(|&v| ((v - m) as f64).exp()).sum();
        loss_sum += -((row[y] - m) as f64 - z.ln());
    }
    Ok((top1_wrong, top5_wrong, loss_sum))
}

/// Top-1/top-5 error and mean softmax NLL from a (n, classes) logit
/// matrix against integer labels.
pub fn metrics_from_logits(logits: &TensorF, labels: &[i32]) -> Result<EvalResult> {
    let n = logits.shape()[0];
    debug_assert_eq!(labels.len(), n);
    let (top1_wrong, top5_wrong, loss_sum) = accumulate_metrics(logits, labels, n)?;
    Ok(EvalResult {
        n,
        top1_err: top1_wrong as f64 / n.max(1) as f64,
        top5_err: top5_wrong as f64 / n.max(1) as f64,
        mean_loss: loss_sum / n.max(1) as f64,
    })
}

/// Evaluate a built [`FixedPointNet`] on `data` with the pure-integer
/// batched GEMM engine -- no XLA involvement, runs in the offline build.
/// `threads` shards GEMM row-blocks; the result is bit-identical for
/// every thread count.
pub fn evaluate_int(
    net: &FixedPointNet,
    data: &Dataset,
    threads: usize,
) -> Result<EvalResult> {
    evaluate_int_batched(net, data, data.len().max(1), threads)
}

/// [`evaluate_int`] in `chunk`-image slices through one warm [`Scratch`]
/// arena, so the activation planes stay `chunk`-sized instead of growing
/// with the whole dataset (the native backend evaluates full grids this
/// way, chunked by the arch's `eval_batch`).  The integer engine is
/// per-image exact, so the chunking -- like the thread count -- cannot
/// change the result.
pub fn evaluate_int_batched(
    net: &FixedPointNet,
    data: &Dataset,
    chunk: usize,
    threads: usize,
) -> Result<EvalResult> {
    let total = data.len();
    let nc = net.num_classes();
    let (h, w, c) = net.input_shape();
    let img_len = h * w * c;
    let chunk = chunk.max(1).min(total.max(1));
    let mut scratch = Scratch::for_net(net, chunk, threads);
    let mut logits = vec![0f32; total * nc];
    let mut i = 0usize;
    while i < total {
        let n = chunk.min(total - i);
        // contiguous row range of the row-major dataset tensor: feed it
        // straight through, no per-chunk gather/copy
        net.forward_slice_into(
            &data.images.data()[i * img_len..(i + n) * img_len],
            n,
            &mut scratch,
            threads,
            &mut logits[i * nc..(i + n) * nc],
        )?;
        i += n;
    }
    let logits = Tensor::from_vec(&[total, nc], logits)?;
    metrics_from_logits(&logits, data.labels.data())
}

/// Evaluate `params` on `data` under `nq`.
pub fn evaluate(
    engine: &Engine,
    arch: &str,
    params: &ParamSet,
    nq: &NetQuant,
    data: &Dataset,
) -> Result<EvalResult> {
    let spec = engine.manifest.arch(arch)?;
    let exe = engine.executable(arch, "eval_batch")?;
    let v = nq.vectors();
    let cfg = [
        vec_lit(&v.w_step)?,
        vec_lit(&v.w_lo)?,
        vec_lit(&v.w_hi)?,
        vec_lit(&v.w_en)?,
        vec_lit(&v.a_step)?,
        vec_lit(&v.a_lo)?,
        vec_lit(&v.a_hi)?,
        vec_lit(&v.a_en)?,
    ];
    let param_lits: Vec<xla::Literal> = params
        .tensors
        .iter()
        .map(|t| to_literal(&HostValue::F32(t.clone())))
        .collect::<Result<_>>()?;

    let mut n_total = 0usize;
    let mut top1_wrong = 0usize;
    let mut top5_wrong = 0usize;
    let mut loss_sum = 0f64;
    for (images, labels, valid) in sequential_batches(data, spec.eval_batch)? {
        let x = to_literal(&HostValue::F32(images))?;
        let y_lit = to_literal(&HostValue::I32(labels.clone()))?;
        let mut inputs: Vec<&xla::Literal> = Vec::new();
        inputs.extend(param_lits.iter());
        inputs.push(&x);
        inputs.push(&y_lit);
        inputs.extend(cfg.iter());
        let outs = exe.run_literals(&inputs)?;
        let logits = exe.output_host(&outs, "logits")?.into_f32()?;
        // loss_sum from the executable includes padded rows; avoid that
        // by scoring only the `valid` rows host-side
        let (t1, t5, ls) = accumulate_metrics(&logits, labels.data(), valid)?;
        top1_wrong += t1;
        top5_wrong += t5;
        loss_sum += ls;
        n_total += valid;
    }
    Ok(EvalResult {
        n: n_total,
        top1_err: top1_wrong as f64 / n_total.max(1) as f64,
        top5_err: top5_wrong as f64 / n_total.max(1) as f64,
        mean_loss: loss_sum / n_total.max(1) as f64,
    })
}
